# Entry points for the Rust serving stack. `make perf` is the one-command
# perf-regression check: release build + the hot-path and serving benches,
# run headlessly (their PJRT-dependent sections self-skip when AOT
# artifacts are absent, so this works on any machine).

CARGO ?= cargo
MANIFEST := rust/Cargo.toml

.PHONY: build test test-full stress docs check perf trace-demo slo-demo

build:
	$(CARGO) build --release --manifest-path $(MANIFEST)

test:
	$(CARGO) test -q --manifest-path $(MANIFEST)

# Release-mode run of the numerically heavy suites: the cross-solver
# conformance sweep (every method × prediction × spacing, planned vs
# reference bit-identity), the empirical convergence-order suite
# (log-error regression against each method's order claim), the batching
# equivalence suite (batched lockstep runs — mixed-conditioning cohorts
# included — bit-identical to solo runs across the zoo), the chaos
# fault-injection suite (panic isolation, deadlines, batch + per-member
# quarantine, pool supervision under 10%-ish injected faults, shard fault
# isolation), the sharded-coordinator invariant suite (deterministic
# plan-key routing, conditioning-independent routes, shard-count-
# independent outputs, exact metrics aggregation, the collapsed-vs-split
# batch-key ablation), and the span-tree tracing suite (one complete
# admit-to-respond tree per request under chaos, steal attribution,
# quarantine spans, wire round-trip of trace ids), and the telemetry-plane
# suite (windowed rates vs deterministic replay, Prometheus round-trip,
# exactly-once-or-counted push delivery under chaos, SLO burn-rate
# breaches, corrector-delta health trends). All suites are sized to
# also pass inside plain `make test` (debug) so the tier-1 gate exercises
# them; this target re-runs just these optimized, which is the fast path
# when iterating on solver numerics or the serving layer.
test-full:
	$(CARGO) test --release -q --manifest-path $(MANIFEST) \
		--test solver_conformance --test solver_convergence \
		--test batch_equiv --test fault_injection --test shard_serving \
		--test trace_spans --test telemetry

# Submitter-storm stress run: the shard/chaos concurrency suites in
# release mode with elevated thread and request counts (UNIPC_STRESS=1).
# Slower than test-full; run when touching the coordinator's locking,
# routing, or stealing logic.
stress:
	UNIPC_STRESS=1 $(CARGO) test --release -q --manifest-path $(MANIFEST) \
		--test shard_serving --test fault_injection

# API docs for the crate (README.md links into these module docs).
docs:
	$(CARGO) doc --no-deps --manifest-path $(MANIFEST)

# The CI gate: build, clippy with warnings promoted to errors, full test
# suite (incl. doctests and the equivalence / allocation proofs), the
# release-mode conformance + convergence + chaos + shard suites, and
# rustdoc with warnings promoted to errors so doc rot fails fast. For a
# heavier concurrency shakedown of the sharded coordinator, run
# `make stress` (UNIPC_STRESS=1 submitter storms) on top.
check:
	$(CARGO) build --release --manifest-path $(MANIFEST)
	$(CARGO) clippy --all-targets --manifest-path $(MANIFEST) -- -D warnings
	$(CARGO) test -q --manifest-path $(MANIFEST)
	$(MAKE) test-full
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps --manifest-path $(MANIFEST)

# Hot-path microbenches (emits rust/BENCH_hot_path.json: name -> ns/iter)
# followed by the end-to-end serving load sweep (which also exports
# rust/TRACE_serving.json, a Chrome trace of the traced load point).
perf: build
	$(CARGO) bench --bench perf_hot_path --manifest-path $(MANIFEST)
	$(CARGO) bench --bench serving_load --manifest-path $(MANIFEST)

# One-command observability demo: serves the analytic backend, fires a
# short mixed workload at trace=steps, prints the latency/stage breakdown,
# and writes rust/TRACE_demo.json — load it in chrome://tracing or
# https://ui.perfetto.dev to see per-request span trees.
trace-demo: build
	cd rust && $(CARGO) run --release --quiet -- trace-demo --out TRACE_demo.json

# End-to-end SLO probe: configures a worker_panic burn-rate objective,
# injects eval-panic chaos that burns through its budget, and verifies —
# via a live push-channel subscription — that exactly the expected
# slo_breach events fire. Exits nonzero when the telemetry plane fails to
# observe the breach, so CI can gate on it.
slo-demo: build
	cd rust && $(CARGO) run --release --quiet -- slo-demo
