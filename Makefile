# Entry points for the Rust serving stack. `make perf` is the one-command
# perf-regression check: release build + the hot-path and serving benches,
# run headlessly (their PJRT-dependent sections self-skip when AOT
# artifacts are absent, so this works on any machine).

CARGO ?= cargo
MANIFEST := rust/Cargo.toml

.PHONY: build test docs check perf

build:
	$(CARGO) build --release --manifest-path $(MANIFEST)

test:
	$(CARGO) test -q --manifest-path $(MANIFEST)

# API docs for the crate (README.md links into these module docs).
docs:
	$(CARGO) doc --no-deps --manifest-path $(MANIFEST)

# The CI gate: build, full test suite (incl. doctests and the equivalence /
# allocation proofs), and rustdoc with warnings promoted to errors so doc
# rot fails fast.
check:
	$(CARGO) build --release --manifest-path $(MANIFEST)
	$(CARGO) test -q --manifest-path $(MANIFEST)
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps --manifest-path $(MANIFEST)

# Hot-path microbenches (emits rust/BENCH_hot_path.json: name -> ns/iter)
# followed by the end-to-end serving load sweep.
perf: build
	$(CARGO) bench --bench perf_hot_path --manifest-path $(MANIFEST)
	$(CARGO) bench --bench serving_load --manifest-path $(MANIFEST)
