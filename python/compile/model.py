"""Layer-2: the learned noise-prediction network eps_theta in pure JAX.

A time-conditioned residual MLP with one attention block (the attention is
the L1 Pallas kernel, so it lowers into the same HLO the rust runtime
executes). Small by design (~0.4M params): the serving/runtime path it
exercises is identical to a big UNet's, and training to convergence on the
synthetic benchmark takes minutes on CPU (see train.py).

Parametrization: predicts epsilon (noise). Supports class conditioning with
a null class for classifier-free guidance (paper SS4.1's latent-space
setting).
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.attention import attention


class ModelConfig:
    """Hyper-parameters; serialized into the AOT manifest."""

    def __init__(
        self,
        dim: int = 16,
        width: int = 128,
        depth: int = 3,
        tokens: int = 8,
        n_classes: int = 10,
        temb_dim: int = 64,
    ):
        assert width % tokens == 0, "width must split into attention tokens"
        self.dim = dim
        self.width = width
        self.depth = depth
        self.tokens = tokens
        self.n_classes = n_classes  # class `n_classes` is the null token
        self.temb_dim = temb_dim

    def to_dict(self) -> Dict[str, int]:
        return {
            "dim": self.dim,
            "width": self.width,
            "depth": self.depth,
            "tokens": self.tokens,
            "n_classes": self.n_classes,
            "temb_dim": self.temb_dim,
        }

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "ModelConfig":
        return ModelConfig(**{k: int(v) for k, v in d.items()})


def _dense_init(key, fan_in: int, fan_out: int):
    scale = 1.0 / math.sqrt(fan_in)
    return jax.random.uniform(key, (fan_in, fan_out), jnp.float32, -scale, scale)


def init_params(cfg: ModelConfig, key) -> Dict[str, jnp.ndarray]:
    """Flat, name-keyed parameter dict (deterministic iteration order is the
    sorted key order — the same order the AOT manifest records)."""
    keys = jax.random.split(key, 64)
    ki = iter(keys)
    p: Dict[str, jnp.ndarray] = {}
    w = cfg.width
    p["in.w"] = _dense_init(next(ki), cfg.dim, w)
    p["in.b"] = jnp.zeros((w,), jnp.float32)
    p["temb.w1"] = _dense_init(next(ki), cfg.temb_dim, w)
    p["temb.b1"] = jnp.zeros((w,), jnp.float32)
    p["temb.w2"] = _dense_init(next(ki), w, w)
    p["temb.b2"] = jnp.zeros((w,), jnp.float32)
    p["label.emb"] = 0.02 * jax.random.normal(next(ki), (cfg.n_classes + 1, w), jnp.float32)
    for i in range(cfg.depth):
        p[f"blk{i}.norm.g"] = jnp.ones((w,), jnp.float32)
        p[f"blk{i}.norm.b"] = jnp.zeros((w,), jnp.float32)
        p[f"blk{i}.film.w"] = _dense_init(next(ki), w, 2 * w)
        p[f"blk{i}.film.b"] = jnp.zeros((2 * w,), jnp.float32)
        p[f"blk{i}.mlp.w1"] = _dense_init(next(ki), w, 4 * w)
        p[f"blk{i}.mlp.b1"] = jnp.zeros((4 * w,), jnp.float32)
        p[f"blk{i}.mlp.w2"] = _dense_init(next(ki), 4 * w, w)
        p[f"blk{i}.mlp.b2"] = jnp.zeros((w,), jnp.float32)
    # Attention block (QKV + output projection).
    p["attn.norm.g"] = jnp.ones((w,), jnp.float32)
    p["attn.norm.b"] = jnp.zeros((w,), jnp.float32)
    p["attn.wq"] = _dense_init(next(ki), w, w)
    p["attn.wk"] = _dense_init(next(ki), w, w)
    p["attn.wv"] = _dense_init(next(ki), w, w)
    p["attn.wo"] = _dense_init(next(ki), w, w)
    p["out.norm.g"] = jnp.ones((w,), jnp.float32)
    p["out.norm.b"] = jnp.zeros((w,), jnp.float32)
    p["out.w"] = jnp.zeros((w, cfg.dim), jnp.float32)  # zero-init output
    p["out.b"] = jnp.zeros((cfg.dim,), jnp.float32)
    return p


def param_names(cfg: ModelConfig) -> List[str]:
    """The positional parameter order used by the AOT artifacts."""
    return sorted(init_params(cfg, jax.random.PRNGKey(0)).keys())


def param_list(params: Dict[str, jnp.ndarray]) -> List[jnp.ndarray]:
    return [params[k] for k in sorted(params.keys())]


def params_from_list(cfg: ModelConfig, flat: List[jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    names = param_names(cfg)
    assert len(names) == len(flat)
    return dict(zip(names, flat))


def _layernorm(x, g, b, eps=1e-5):
    m = jnp.mean(x, axis=-1, keepdims=True)
    v = jnp.var(x, axis=-1, keepdims=True)
    return (x - m) / jnp.sqrt(v + eps) * g + b


def _time_embedding(t, temb_dim: int):
    """Sinusoidal features of t in [0, 1] (standard DDPM embedding)."""
    half = temb_dim // 2
    freqs = jnp.exp(jnp.linspace(0.0, math.log(1000.0), half))
    ang = t[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def eps_model(params: Dict[str, jnp.ndarray], cfg: ModelConfig, x, t, y,
              use_pallas: bool = True):
    """eps_theta(x, t, y): x [B, dim], t [B], y [B] int32 (n_classes = null).

    Returns predicted noise [B, dim].

    `use_pallas=False` swaps the attention block to the jnp reference —
    needed for training (pallas_call has no reverse-mode autodiff rule);
    the two are assert_allclose-equal in python/tests/test_kernels.py, and
    the AOT inference artifacts always use the kernel.
    """
    w = cfg.width
    b = x.shape[0]

    temb = _time_embedding(t, cfg.temb_dim)
    c = jnp.tanh(temb @ params["temb.w1"] + params["temb.b1"])
    c = c @ params["temb.w2"] + params["temb.b2"]
    c = c + params["label.emb"][y]

    h = x @ params["in.w"] + params["in.b"]
    for i in range(cfg.depth):
        film = c @ params[f"blk{i}.film.w"] + params[f"blk{i}.film.b"]
        scale, shift = film[:, :w], film[:, w:]
        hn = _layernorm(h, params[f"blk{i}.norm.g"], params[f"blk{i}.norm.b"])
        hn = hn * (1.0 + scale) + shift
        hh = jax.nn.silu(hn @ params[f"blk{i}.mlp.w1"] + params[f"blk{i}.mlp.b1"])
        h = h + hh @ params[f"blk{i}.mlp.w2"] + params[f"blk{i}.mlp.b2"]

    # Attention over `tokens` chunks of the hidden state (L1 Pallas kernel).
    hn = _layernorm(h, params["attn.norm.g"], params["attn.norm.b"])
    q = (hn @ params["attn.wq"]).reshape(b, cfg.tokens, w // cfg.tokens)
    k = (hn @ params["attn.wk"]).reshape(b, cfg.tokens, w // cfg.tokens)
    v = (hn @ params["attn.wv"]).reshape(b, cfg.tokens, w // cfg.tokens)
    if use_pallas:
        a = attention(q, k, v).reshape(b, w)
    else:
        from .kernels.ref import attention_ref

        a = attention_ref(q, k, v).reshape(b, w)
    h = h + a @ params["attn.wo"]

    hn = _layernorm(h, params["out.norm.g"], params["out.norm.b"])
    return hn @ params["out.w"] + params["out.b"]


def eps_model_cfg(params, cfg: ModelConfig, x, t, y, guidance_scale):
    """Classifier-free guidance: (1+s)*eps(x,t,y) - s*eps(x,t,null).

    Both branches run in one batched evaluation (2B rows), matching how
    production CFG is served.
    """
    b = x.shape[0]
    null = jnp.full((b,), cfg.n_classes, jnp.int32)
    x2 = jnp.concatenate([x, x], axis=0)
    t2 = jnp.concatenate([t, t], axis=0)
    y2 = jnp.concatenate([y.astype(jnp.int32), null], axis=0)
    eps = eps_model(params, cfg, x2, t2, y2)
    cond, uncond = eps[:b], eps[b:]
    return (1.0 + guidance_scale) * cond - guidance_scale * uncond


def count_params(params: Dict[str, jnp.ndarray]) -> int:
    return sum(int(v.size) for v in params.values())


def shapes(params: Dict[str, jnp.ndarray]) -> List[Tuple[str, List[int]]]:
    return [(k, list(params[k].shape)) for k in sorted(params.keys())]
