"""Synthetic benchmark data for training the learned denoiser.

The training distribution is a class-conditional Gaussian mixture (2
components per class on a jittered sphere) — the same family the rust
`analytic` substrate uses, so the learned model can be validated against an
exact score. The mixture spec is written to `artifacts/mixture.json` and
loaded by the rust side for ground-truth metrics (DESIGN.md SS2).
"""

from __future__ import annotations

import json
from typing import Dict, List

import numpy as np


def make_mixture(
    dim: int = 16,
    n_classes: int = 10,
    comps_per_class: int = 2,
    radius: float = 3.0,
    std: float = 0.55,
    seed: int = 2024,
) -> Dict:
    """Deterministic mixture spec: means on a sphere, jittered stds/weights."""
    rng = np.random.default_rng(seed)
    k = n_classes * comps_per_class
    means = rng.normal(size=(k, dim))
    means *= radius / np.linalg.norm(means, axis=1, keepdims=True)
    stds = std * (0.8 + 0.4 * rng.random(k))
    weights = 0.5 + rng.random(k)
    weights /= weights.sum()
    return {
        "dim": dim,
        "n_classes": n_classes,
        "comps_per_class": comps_per_class,
        "means": means.tolist(),
        "stds": stds.tolist(),
        "weights": weights.tolist(),
    }


def save_mixture(spec: Dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(spec, f)


def load_mixture(path: str) -> Dict:
    with open(path) as f:
        return json.load(f)


def class_of_component(spec: Dict, k: int) -> int:
    return k // spec["comps_per_class"]


def sample_batch(spec: Dict, rng: np.random.Generator, n: int):
    """Draw (x0 [n, dim] f32, labels [n] i32) from the mixture."""
    means = np.asarray(spec["means"])
    stds = np.asarray(spec["stds"])
    weights = np.asarray(spec["weights"])
    ks = rng.choice(len(weights), size=n, p=weights)
    x = means[ks] + stds[ks, None] * rng.normal(size=(n, spec["dim"]))
    labels = ks // spec["comps_per_class"]
    return x.astype(np.float32), labels.astype(np.int32)


def exact_eps(spec: Dict, x: np.ndarray, t: float, alpha: float, sigma: float,
              subset: List[int] | None = None) -> np.ndarray:
    """Closed-form eps*(x, t) for the mixture (numpy mirror of
    rust `analytic::gmm`); used to validate the trained network."""
    means = np.asarray(spec["means"])
    stds = np.asarray(spec["stds"])
    weights = np.asarray(spec["weights"])
    if subset is not None:
        means, stds, weights = means[subset], stds[subset], weights[subset]
    d = x.shape[1]
    v = alpha**2 * stds**2 + sigma**2  # [K]
    diff = x[:, None, :] - alpha * means[None, :, :]  # [N, K, D]
    sq = np.sum(diff**2, axis=-1)  # [N, K]
    logp = np.log(weights)[None, :] - 0.5 * d * np.log(v)[None, :] - sq / (2 * v)[None, :]
    logp -= logp.max(axis=1, keepdims=True)
    g = np.exp(logp)
    g /= g.sum(axis=1, keepdims=True)
    out = np.einsum("nk,nkd->nd", g / v[None, :], diff)
    return (sigma * out).astype(x.dtype)
