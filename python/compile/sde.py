"""VP noise schedule — python mirror of `rust/src/sched/mod.rs`.

Held to golden-value parity with the Rust implementation by
`python/tests/test_sde_parity.py`; if you change constants here, change them
there (and in Rust) too.
"""

from __future__ import annotations

import jax.numpy as jnp


class VpLinear:
    """VP SDE with linear beta(t); ScoreSDE continuous-time convention."""

    def __init__(self, beta_0: float = 0.1, beta_1: float = 20.0):
        self.beta_0 = beta_0
        self.beta_1 = beta_1

    def log_alpha(self, t):
        return -(t**2) * (self.beta_1 - self.beta_0) / 4.0 - t * self.beta_0 / 2.0

    def alpha(self, t):
        return jnp.exp(self.log_alpha(t))

    def sigma(self, t):
        return jnp.sqrt(-jnp.expm1(2.0 * self.log_alpha(t)))

    def lam(self, t):
        """Half log-SNR lambda_t = log(alpha_t / sigma_t)."""
        la = self.log_alpha(t)
        return la - 0.5 * jnp.log(-jnp.expm1(2.0 * la))

    def t_of_lambda(self, lam):
        """Closed-form inverse (DPM-Solver appendix)."""
        l = jnp.logaddexp(-2.0 * lam, 0.0)
        tmp = 2.0 * (self.beta_1 - self.beta_0) * l
        delta = self.beta_0**2 + tmp
        return tmp / ((jnp.sqrt(delta) + self.beta_0) * (self.beta_1 - self.beta_0))

    def marginal_sample(self, key, x0, t):
        """Draw x_t ~ q(x_t | x_0) = N(alpha_t x0, sigma_t^2 I)."""
        import jax

        eps = jax.random.normal(key, x0.shape, x0.dtype)
        a = self.alpha(t)
        s = self.sigma(t)
        # t may be per-sample [B]; broadcast over trailing dims.
        while a.ndim < x0.ndim:
            a = a[..., None]
            s = s[..., None]
        return a * x0 + s * eps, eps
