"""Layer-1 Pallas kernel: fused single-head attention.

TPU adaptation of the GPU flash-attention pattern (DESIGN.md
§Hardware-Adaptation): instead of CUDA threadblocks staging K/V tiles
through shared memory, the BlockSpec grid streams one (q-tile, full-KV)
working set through VMEM per grid step, and the kernel keeps a running
(max, denominator, accumulator) triple so only O(T_q x D) state lives in
registers/VMEM. Lowered with interpret=True — the CPU PJRT plugin cannot
execute Mosaic custom-calls; on a real TPU the same BlockSpec lowers to MXU
matmuls over 128-aligned tiles.

VMEM budget per grid step (f32): q-tile T_q x D + K,V tiles 2 x T_k x D +
accumulator T_q x D. With the model's T=8, D=16 this is well under a
single core's ~16 MiB VMEM; the tiling knobs exist for the perf study in
EXPERIMENTS.md §Perf-L1.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int):
    """One (batch, q-tile) grid step: online-softmax attention."""
    q = q_ref[0]  # [Tq, D]
    t_k = k_ref.shape[1]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))

    n_kv = t_k // block_k

    def body(i, carry):
        m_prev, l_prev, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0], i * block_k, block_k, axis=0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0], i * block_k, block_k, axis=0)
        s = jnp.dot(q, k.T) * scale  # [Tq, Tk]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * corr + jnp.dot(p, v)
        return m_new, l_new, acc_new

    m0 = jnp.full((q.shape[0], 1), -jnp.inf, q.dtype)
    l0 = jnp.zeros((q.shape[0], 1), q.dtype)
    acc0 = jnp.zeros_like(q)
    _, l, acc = jax.lax.fori_loop(0, n_kv, body, (m0, l0, acc0))
    o_ref[0] = acc / l


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def attention(q, k, v, block_q: int = 0, block_k: int = 0):
    """Fused attention over [B, T, D] tensors via Pallas (interpret mode).

    block_q / block_k default to the full sequence (single tile) — the right
    choice for the model's T=8; the knobs are exercised by the kernel tests
    and the perf study.
    """
    b, t, d = q.shape
    assert k.shape == (b, t, d) and v.shape == (b, t, d)
    bq = block_q or t
    bk = block_k or t
    assert t % bq == 0 and t % bk == 0, "tile sizes must divide T"

    grid = (b, t // bq)
    return pl.pallas_call(
        functools.partial(_attn_kernel, block_k=bk),
        out_shape=jax.ShapeDtypeStruct((b, t, d), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda i, j: (i, j, 0)),  # q tile
            pl.BlockSpec((1, t, d), lambda i, j: (i, 0, 0)),  # full K
            pl.BlockSpec((1, t, d), lambda i, j: (i, 0, 0)),  # full V
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda i, j: (i, j, 0)),
        interpret=True,
    )(q, k, v)


def attention_vmem_bytes(t: int, d: int, block_q: int = 0, block_k: int = 0,
                         dtype_bytes: int = 4) -> int:
    """Static VMEM footprint estimate per grid step (perf study, §Perf-L1)."""
    bq = block_q or t
    bk = block_k or t
    q_tile = bq * d
    kv_tiles = 2 * t * d  # full K and V are resident per grid step
    acc = bq * d
    softmax_state = 2 * bq
    scores = bq * bk
    return dtype_bytes * (q_tile + kv_tiles + acc + softmax_state + scores)
