"""Layer-1 Pallas kernel: the fused UniPC state update.

The UniPC step (Eq. 3 / Algorithms 5-8) is a memory-bound linear
combination over the multistep buffer:

    out = a * x_prev + b * m0 + s * sum_p c_p * D1s[p]

Done naively (one axpy per buffer entry) it reads the state P+2 times; this
kernel fuses the whole combination into a single pass — one read per input
tile, one write — which is exactly the optimization the rust host path
mirrors in `tensor::weighted_sum`. The BlockSpec grid tiles the batch so a
[tile, D] slab of every operand is resident in VMEM at once (HBM<->VMEM
schedule; a CUDA port would use threadblock striding here).

interpret=True for CPU PJRT; see kernels/attention.py for the rationale.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _update_kernel(coef_ref, x_ref, m0_ref, d1s_ref, o_ref):
    """One batch-tile grid step.

    coef_ref: [P + 3] — c_0..c_{P-1}, then (a, b, s).
    x_ref, m0_ref: [tile, D]; d1s_ref: [P, tile, D].
    """
    p = d1s_ref.shape[0]
    coefs = coef_ref[...]
    a = coefs[p]
    b = coefs[p + 1]
    s = coefs[p + 2]
    acc = a * x_ref[...] + b * m0_ref[...]

    def body(i, acc):
        return acc + s * coefs[i] * d1s_ref[i]

    o_ref[...] = jax.lax.fori_loop(0, p, body, acc)


@functools.partial(jax.jit, static_argnames=("block_b",))
def unipc_update(x_prev, m0, d1s, coeffs, a_coef, b_coef, res_scale, block_b: int = 0):
    """Fused UniPC update over [B, D] state with a [P, B, D] buffer."""
    b, d = x_prev.shape
    p = d1s.shape[0]
    assert m0.shape == (b, d)
    assert d1s.shape == (p, b, d)
    assert coeffs.shape == (p,)
    tile = block_b or b
    assert b % tile == 0, "batch tile must divide B"

    packed = jnp.concatenate(
        [
            coeffs.astype(x_prev.dtype),
            jnp.asarray([a_coef, b_coef, res_scale], x_prev.dtype),
        ]
    )
    grid = (b // tile,)
    return pl.pallas_call(
        _update_kernel,
        out_shape=jax.ShapeDtypeStruct((b, d), x_prev.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((p + 3,), lambda i: (0,)),  # coefficients (broadcast)
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((p, tile, d), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((tile, d), lambda i: (i, 0)),
        interpret=True,
    )(packed, x_prev, m0, d1s)


def unipc_update_vmem_bytes(b_tile: int, d: int, p: int, dtype_bytes: int = 4) -> int:
    """Static VMEM footprint per grid step (perf study, §Perf-L1)."""
    return dtype_bytes * ((p + 3) + (2 + p) * b_tile * d + b_tile * d)
