"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
signal (pytest + hypothesis sweep shapes against these)."""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v):
    """Single-head scaled-dot-product attention.

    q, k, v: [B, T, D] -> [B, T, D].
    """
    d = q.shape[-1]
    s = jnp.einsum("btd,bsd->bts", q, k) / jnp.sqrt(jnp.asarray(d, q.dtype))
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    o = jnp.einsum("bts,bsd->btd", p, v)
    return o / jnp.sum(p, axis=-1, keepdims=True)


def unipc_update_ref(x_prev, m0, d1s, coeffs, a_coef, b_coef, res_scale):
    """UniPC linear-combination update (Eq. 3 / Alg. 5-8 inner step).

    x_prev, m0 : [B, D]      state at t_{i-1} and buffered model output
    d1s        : [P, B, D]   stacked D_m / r_m differences
    coeffs     : [P]         combination coefficients (already B(h)-scaled)
    a_coef, b_coef, res_scale : scalars
        out = a_coef * x_prev + b_coef * m0
              + res_scale * sum_p coeffs[p] * d1s[p]
    """
    res = jnp.einsum("p,pbd->bd", coeffs, d1s)
    return a_coef * x_prev + b_coef * m0 + res_scale * res
