"""Train the tiny denoiser on the synthetic mixture (build-time only).

Standard epsilon-prediction objective with label dropout for classifier-free
guidance. Hand-rolled Adam (no optax in the image's dependency closure).
Writes `artifacts/model.upw` (weights, rust-readable) and
`artifacts/mixture.json` (ground-truth spec).

Usage: python -m compile.train [--steps 4000] [--out ../artifacts]
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from .model import ModelConfig, count_params, eps_model, init_params
from .sde import VpLinear


def save_upw(params: dict, path: str) -> None:
    """Write the `.upw` weights container (see rust/src/weights/mod.rs)."""
    names = sorted(params.keys())
    with open(path, "wb") as f:
        f.write(b"UPW1")
        f.write(struct.pack("<I", len(names)))
        for n in names:
            arr = np.asarray(params[n], np.float32)
            nb = n.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(struct.pack("<B", 0))
        for n in names:
            f.write(np.ascontiguousarray(params[n], np.float32).tobytes())


def adam_update(params, grads, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    bc1 = 1 - b1**step
    bc2 = 1 - b2**step
    params = jax.tree.map(
        lambda p, mm, vv: p - lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps), params, m, v
    )
    return params, m, v


def train(
    steps: int = 4000,
    batch: int = 256,
    lr: float = 2e-3,
    seed: int = 0,
    label_dropout: float = 0.1,
    out_dir: str = "../artifacts",
    log_every: int = 500,
) -> dict:
    cfg = ModelConfig()
    spec = data_mod.make_mixture(dim=cfg.dim, n_classes=cfg.n_classes)
    sched = VpLinear()
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    print(f"model params: {count_params(params)}")

    @jax.jit
    def loss_fn(params, x0, labels, t, noise_key):
        xt, eps = sched.marginal_sample(noise_key, x0, t)
        pred = eps_model(params, cfg, xt, t, labels, use_pallas=False)
        return jnp.mean((pred - eps) ** 2)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    rng = np.random.default_rng(seed + 1)
    t0 = time.time()
    losses = []
    for step in range(1, steps + 1):
        x0, labels = data_mod.sample_batch(spec, rng, batch)
        # Label dropout -> null class for CFG training.
        drop = rng.random(batch) < label_dropout
        labels = labels.copy()
        labels[drop] = cfg.n_classes
        t = rng.uniform(1e-3, 1.0, size=batch).astype(np.float32)
        key, nk = jax.random.split(key)
        # Cosine LR decay with short warmup.
        cur_lr = lr * min(step / 100.0, 1.0) * 0.5 * (
            1.0 + np.cos(np.pi * step / steps)
        )
        loss, grads = grad_fn(params, jnp.asarray(x0), jnp.asarray(labels), jnp.asarray(t), nk)
        params, m, v = adam_update(params, grads, m, v, step, cur_lr)
        losses.append(float(loss))
        if step % log_every == 0 or step == 1:
            print(
                f"step {step:5d}  loss {np.mean(losses[-log_every:]):.4f}  "
                f"({time.time() - t0:.1f}s)"
            )

    os.makedirs(out_dir, exist_ok=True)
    save_upw(params, os.path.join(out_dir, "model.upw"))
    data_mod.save_mixture(spec, os.path.join(out_dir, "mixture.json"))
    with open(os.path.join(out_dir, "train_log.json"), "w") as f:
        json.dump(
            {
                "steps": steps,
                "final_loss": float(np.mean(losses[-200:])),
                "params": count_params(params),
                "config": cfg.to_dict(),
            },
            f,
        )
    print(f"saved weights + mixture to {out_dir}")
    return {"params": params, "cfg": cfg, "spec": spec, "losses": losses}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=4000)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--out", type=str, default="../artifacts")
    args = ap.parse_args()
    train(steps=args.steps, batch=args.batch, lr=args.lr, out_dir=args.out)


if __name__ == "__main__":
    main()
