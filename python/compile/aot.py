"""AOT lowering: JAX -> HLO **text** artifacts for the rust PJRT runtime.

HLO text (not serialized HloModuleProto) is the interchange format: jax >=
0.5 emits protos with 64-bit instruction ids that xla_extension 0.5.1 (the
version the published `xla` crate binds) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Lowered entry points (each at a fixed set of static batch sizes):
  eps_b{B}        eps_theta(params..., x[B,d], t[B], y[B])        -> eps[B,d]
  eps_cfg_b{B}    CFG: (params..., x, t, y, scale[])              -> eps[B,d]
  correct_b{B}    fused eval+UniC step (params..., x_pred, t, x_prev,
                  m0, d1s[P,B,d], coeffs[P+3])                    -> (x_c, m_t)
                  (uses the L1 pallas unipc_update kernel; one PJRT call
                   instead of model-call + host update)

Everything is recorded in artifacts/manifest.json: parameter order/shapes,
artifact -> input signature, schedule constants, model config.

Usage: python -m compile.aot [--out ../artifacts] [--batches 1,4,16,64]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.unipc_update import unipc_update
from .model import ModelConfig, eps_model, eps_model_cfg, init_params, param_names
from .sde import VpLinear


def _load_upw(path: str) -> dict:
    """Read the .upw container back into a param dict (golden generation)."""
    import struct

    import numpy as np

    raw = open(path, "rb").read()
    assert raw[:4] == b"UPW1"
    pos = 4
    (n,) = struct.unpack_from("<I", raw, pos)
    pos += 4
    headers = []
    for _ in range(n):
        (nl,) = struct.unpack_from("<I", raw, pos)
        pos += 4
        name = raw[pos : pos + nl].decode()
        pos += nl
        (nd,) = struct.unpack_from("<I", raw, pos)
        pos += 4
        dims = struct.unpack_from("<" + "I" * nd, raw, pos)
        pos += 4 * nd
        pos += 1  # dtype
        headers.append((name, dims))
    params = {}
    for name, dims in headers:
        cnt = int(np.prod(dims)) if dims else 1
        params[name] = jnp.asarray(
            np.frombuffer(raw, np.float32, cnt, pos).reshape(dims)
        )
        pos += 4 * cnt
    return params

# Corrector buffer depth baked into the fused-correct artifact (order <= 3 +
# the current-point difference; see rust coordinator).
FUSED_P = 3


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_eps(cfg: ModelConfig, batch: int):
    names = param_names(cfg)
    params0 = init_params(cfg, jax.random.PRNGKey(0))

    def fn(*args):
        flat = args[: len(names)]
        x, t, y = args[len(names) :]
        params = dict(zip(names, flat))
        return (eps_model(params, cfg, x, t, y),)

    specs = [jax.ShapeDtypeStruct(params0[n].shape, jnp.float32) for n in names]
    specs += [
        jax.ShapeDtypeStruct((batch, cfg.dim), jnp.float32),
        jax.ShapeDtypeStruct((batch,), jnp.float32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    ]
    return jax.jit(fn).lower(*specs)


def lower_eps_cfg(cfg: ModelConfig, batch: int):
    names = param_names(cfg)
    params0 = init_params(cfg, jax.random.PRNGKey(0))

    def fn(*args):
        flat = args[: len(names)]
        x, t, y, scale = args[len(names) :]
        params = dict(zip(names, flat))
        return (eps_model_cfg(params, cfg, x, t, y, scale),)

    specs = [jax.ShapeDtypeStruct(params0[n].shape, jnp.float32) for n in names]
    specs += [
        jax.ShapeDtypeStruct((batch, cfg.dim), jnp.float32),
        jax.ShapeDtypeStruct((batch,), jnp.float32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.float32),
    ]
    return jax.jit(fn).lower(*specs)


def lower_correct(cfg: ModelConfig, batch: int):
    """Fused UniC step: evaluate the model at the predicted point and apply
    the corrector combination in one XLA program (EXPERIMENTS.md SS Perf-L2).

    coeffs layout: [c_1..c_P, a_coef, b_coef, res_scale]; the residual term
    adds c_P * (m_t - m0) for the current point (r_P = 1), with unused buffer
    slots zero-padded by the caller.
    """
    names = param_names(cfg)
    params0 = init_params(cfg, jax.random.PRNGKey(0))

    def fn(*args):
        flat = args[: len(names)]
        x_pred, t, y, x_prev, m0, d1s, coeffs = args[len(names) :]
        params = dict(zip(names, flat))
        m_t = eps_model(params, cfg, x_pred, t, y)
        # D_P / r_P with r_P = 1 is (m_t - m0); stack it into the buffer.
        d1s_full = jnp.concatenate([d1s, (m_t - m0)[None]], axis=0)
        x_c = unipc_update(
            x_prev,
            m0,
            d1s_full,
            coeffs[: FUSED_P + 1],
            coeffs[FUSED_P + 1],
            coeffs[FUSED_P + 2],
            coeffs[FUSED_P + 3],
        )
        return (x_c, m_t)

    specs = [jax.ShapeDtypeStruct(params0[n].shape, jnp.float32) for n in names]
    specs += [
        jax.ShapeDtypeStruct((batch, cfg.dim), jnp.float32),  # x_pred
        jax.ShapeDtypeStruct((batch,), jnp.float32),  # t
        jax.ShapeDtypeStruct((batch,), jnp.int32),  # y
        jax.ShapeDtypeStruct((batch, cfg.dim), jnp.float32),  # x_prev
        jax.ShapeDtypeStruct((batch, cfg.dim), jnp.float32),  # m0
        jax.ShapeDtypeStruct((FUSED_P, batch, cfg.dim), jnp.float32),  # d1s
        jax.ShapeDtypeStruct((FUSED_P + 4,), jnp.float32),  # coeffs
    ]
    return jax.jit(fn).lower(*specs)


def build(out_dir: str, batches: list[int]) -> dict:
    cfg = ModelConfig()
    sched = VpLinear()
    os.makedirs(out_dir, exist_ok=True)
    params0 = init_params(cfg, jax.random.PRNGKey(0))
    names = param_names(cfg)

    artifacts = {}
    for b in batches:
        for kind, lower in (
            ("eps", lower_eps),
            ("eps_cfg", lower_eps_cfg),
            ("correct", lower_correct),
        ):
            name = f"{kind}_b{b}"
            text = to_hlo_text(lower(cfg, b))
            path = os.path.join(out_dir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            artifacts[name] = {"file": f"{name}.hlo.txt", "kind": kind, "batch": b}
            print(f"wrote {path} ({len(text)} chars)")

    # Golden input/output pair for the rust runtime's end-to-end check:
    # computed with the *trained* weights when present, else init weights.
    import numpy as np

    weights_path = os.path.join(out_dir, "model.upw")
    if os.path.exists(weights_path):
        golden_params = _load_upw(weights_path)
    else:
        golden_params = params0
    gb = min(batches)
    gx = jnp.asarray(
        np.linspace(-1.0, 1.0, gb * cfg.dim, dtype=np.float32).reshape(gb, cfg.dim)
    )
    gt = jnp.full((gb,), 0.5, jnp.float32)
    gy = jnp.zeros((gb,), jnp.int32)
    from .model import eps_model as _eps

    g_eps = _eps(golden_params, cfg, gx, gt, gy)
    g_cfg = eps_model_cfg(golden_params, cfg, gx, gt, gy, jnp.float32(2.0))
    golden = {
        "batch": gb,
        "x": [float(v) for v in np.asarray(gx).ravel()],
        "t": 0.5,
        "y": 0,
        "eps": [float(v) for v in np.asarray(g_eps).ravel()],
        "cfg_scale": 2.0,
        "eps_cfg": [float(v) for v in np.asarray(g_cfg).ravel()],
        "weights": "trained" if os.path.exists(weights_path) else "init",
    }
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(golden, f)

    manifest = {
        "model": cfg.to_dict(),
        "param_names": names,
        "param_shapes": {n: list(params0[n].shape) for n in names},
        "schedule": {"kind": "vp_linear", "beta_0": sched.beta_0, "beta_1": sched.beta_1},
        "fused_p": FUSED_P,
        "batches": batches,
        "artifacts": artifacts,
        "weights": "model.upw",
        "mixture": "mixture.json",
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {out_dir}/manifest.json")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=str, default="../artifacts")
    ap.add_argument("--batches", type=str, default="1,4,16,64")
    args = ap.parse_args()
    build(args.out, [int(b) for b in args.batches.split(",")])


if __name__ == "__main__":
    main()
