"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, dtypes-compatible ranges, and tile sizes;
assert_allclose against ref.py is THE build-time correctness signal for the
kernels that end up inside the AOT artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention, attention_vmem_bytes
from compile.kernels.ref import attention_ref, unipc_update_ref
from compile.kernels.unipc_update import unipc_update, unipc_update_vmem_bytes


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestAttention:
    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 4),
        t_pow=st.integers(1, 4),
        d=st.sampled_from([4, 8, 16, 32]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_reference_across_shapes(self, b, t_pow, d, seed):
        t = 2**t_pow
        q = rand(seed, (b, t, d))
        k = rand(seed + 1, (b, t, d))
        v = rand(seed + 2, (b, t, d))
        out = attention(q, k, v)
        ref = attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(
        bq_pow=st.integers(0, 3),
        bk_pow=st.integers(0, 3),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_tilings_agree(self, bq_pow, bk_pow, seed):
        t, d = 8, 16
        q = rand(seed, (2, t, d))
        k = rand(seed + 1, (2, t, d))
        v = rand(seed + 2, (2, t, d))
        out = attention(q, k, v, block_q=2**bq_pow, block_k=2**bk_pow)
        ref = attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)

    def test_large_logits_stable(self):
        # Online softmax must survive large score magnitudes.
        q = rand(0, (1, 8, 16), scale=30.0)
        k = rand(1, (1, 8, 16), scale=30.0)
        v = rand(2, (1, 8, 16))
        out = attention(q, k, v, block_k=2)
        assert bool(jnp.all(jnp.isfinite(out)))
        ref = attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)

    def test_uniform_values_average(self):
        # With identical K rows, attention averages V exactly.
        q = rand(0, (1, 4, 8))
        k = jnp.ones((1, 4, 8), jnp.float32)
        v = rand(1, (1, 4, 8))
        out = attention(q, k, v)
        expect = jnp.broadcast_to(jnp.mean(v, axis=1, keepdims=True), v.shape)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-6)

    def test_bad_tile_rejected(self):
        q = rand(0, (1, 8, 4))
        with pytest.raises(AssertionError):
            attention(q, q, q, block_q=3)

    def test_vmem_estimate_monotone_in_tiles(self):
        small = attention_vmem_bytes(128, 64, block_q=16)
        big = attention_vmem_bytes(128, 64, block_q=128)
        assert small < big


class TestUnipcUpdate:
    @settings(max_examples=25, deadline=None)
    @given(
        b_pow=st.integers(0, 4),
        d=st.sampled_from([2, 8, 16, 33]),
        p=st.integers(1, 4),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_reference_across_shapes(self, b_pow, d, p, seed):
        b = 2**b_pow
        x = rand(seed, (b, d))
        m0 = rand(seed + 1, (b, d))
        d1s = rand(seed + 2, (p, b, d))
        coeffs = rand(seed + 3, (p,))
        out = unipc_update(x, m0, d1s, coeffs, 1.2, -0.4, 0.9)
        ref = unipc_update_ref(x, m0, d1s, coeffs, 1.2, -0.4, 0.9)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(tile_pow=st.integers(0, 3), seed=st.integers(0, 2**31 - 1))
    def test_batch_tiling_agrees(self, tile_pow, seed):
        b, d, p = 8, 16, 3
        x = rand(seed, (b, d))
        m0 = rand(seed + 1, (b, d))
        d1s = rand(seed + 2, (p, b, d))
        coeffs = rand(seed + 3, (p,))
        out = unipc_update(x, m0, d1s, coeffs, 0.7, 0.1, -1.0, block_b=2**tile_pow)
        ref = unipc_update_ref(x, m0, d1s, coeffs, 0.7, 0.1, -1.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)

    def test_zero_coeffs_is_affine_only(self):
        x = rand(0, (2, 4))
        m0 = rand(1, (2, 4))
        d1s = rand(2, (2, 2, 4))
        out = unipc_update(x, m0, d1s, jnp.zeros((2,)), 2.0, 3.0, 5.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(2.0 * x + 3.0 * m0), atol=1e-6)

    def test_vmem_estimate(self):
        assert unipc_update_vmem_bytes(8, 16, 3) > 0
        assert unipc_update_vmem_bytes(8, 16, 3) < unipc_update_vmem_bytes(64, 16, 3)
