"""L2 model tests: shapes, determinism, pallas/ref parity, CFG identity."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import (
    ModelConfig,
    count_params,
    eps_model,
    eps_model_cfg,
    init_params,
    param_list,
    param_names,
    params_from_list,
)


def setup_module(_m):
    global CFG, PARAMS
    CFG = ModelConfig()
    PARAMS = init_params(CFG, jax.random.PRNGKey(7))
    # out.w is zero-initialized (standard for diffusion nets), which makes
    # the raw init output identically zero; perturb it so conditioning tests
    # can observe the interior of the network.
    PARAMS["out.w"] = 0.05 * jax.random.normal(
        jax.random.PRNGKey(8), PARAMS["out.w"].shape, jnp.float32
    )


def batch(b, seed=0):
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (b, CFG.dim), jnp.float32)
    t = jax.random.uniform(jax.random.fold_in(k, 1), (b,), jnp.float32, 0.01, 1.0)
    y = jax.random.randint(jax.random.fold_in(k, 2), (b,), 0, CFG.n_classes)
    return x, t, y


def test_output_shape_and_finite():
    for b in (1, 3, 16):
        x, t, y = batch(b)
        e = eps_model(PARAMS, CFG, x, t, y)
        assert e.shape == (b, CFG.dim)
        assert bool(jnp.all(jnp.isfinite(e)))


def test_deterministic():
    x, t, y = batch(4)
    a = eps_model(PARAMS, CFG, x, t, y)
    b = eps_model(PARAMS, CFG, x, t, y)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pallas_and_reference_paths_agree():
    x, t, y = batch(8)
    a = eps_model(PARAMS, CFG, x, t, y, use_pallas=True)
    b = eps_model(PARAMS, CFG, x, t, y, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5)


def test_time_conditioning_matters():
    x, t, y = batch(4)
    a = eps_model(PARAMS, CFG, x, t, y)
    b = eps_model(PARAMS, CFG, x, t * 0.3, y)
    assert float(jnp.max(jnp.abs(a - b))) > 1e-6


def test_label_conditioning_matters():
    x, t, y = batch(4)
    a = eps_model(PARAMS, CFG, x, t, y)
    b = eps_model(PARAMS, CFG, x, t, (y + 1) % CFG.n_classes)
    assert float(jnp.max(jnp.abs(a - b))) > 1e-8


def test_cfg_zero_scale_equals_conditional():
    x, t, y = batch(4)
    guided = eps_model_cfg(PARAMS, CFG, x, t, y, 0.0)
    cond = eps_model(PARAMS, CFG, x, t, y)
    np.testing.assert_allclose(np.asarray(guided), np.asarray(cond), atol=1e-5, rtol=1e-5)


def test_cfg_linear_in_scale():
    x, t, y = batch(4)
    e0 = eps_model_cfg(PARAMS, CFG, x, t, y, 0.0)
    e1 = eps_model_cfg(PARAMS, CFG, x, t, y, 1.0)
    e2 = eps_model_cfg(PARAMS, CFG, x, t, y, 2.0)
    # eps(s) is affine in s: e2 - e1 == e1 - e0.
    np.testing.assert_allclose(
        np.asarray(e2 - e1), np.asarray(e1 - e0), atol=1e-4, rtol=1e-4
    )


def test_param_roundtrip_and_order():
    names = param_names(CFG)
    assert names == sorted(names)
    flat = param_list(PARAMS)
    rec = params_from_list(CFG, flat)
    assert set(rec.keys()) == set(PARAMS.keys())
    x, t, y = batch(2)
    np.testing.assert_array_equal(
        np.asarray(eps_model(PARAMS, CFG, x, t, y)),
        np.asarray(eps_model(rec, CFG, x, t, y)),
    )


def test_param_count_documented():
    # README cites ~0.6M params; keep it honest.
    n = count_params(PARAMS)
    assert 3e5 < n < 1.5e6, n
