"""Schedule parity: python/compile/sde.py must match rust/src/sched/mod.rs
to ~1e-9 on shared golden values (see `golden_values_vp_linear` there)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.sde import VpLinear

S = VpLinear()


def test_golden_values_match_rust():
    # Same constants asserted in rust/src/sched/mod.rs tests.
    assert abs(float(S.lam(1e-3)) - 4.557714932729898) < 1e-6
    assert abs(float(S.lam(1.0)) - (-5.024978406659204)) < 1e-6
    assert abs(float(S.lam(0.5)) - (-1.2275677344107871)) < 1e-6


def test_alpha_sigma_pythagorean():
    for t in (0.01, 0.3, 0.7, 1.0):
        a = float(S.alpha(t))
        s = float(S.sigma(t))
        assert abs(a * a + s * s - 1.0) < 1e-6


@settings(max_examples=50, deadline=None)
@given(t=st.floats(1e-3, 1.0))
def test_lambda_roundtrip(t):
    lam = S.lam(jnp.float64(t)) if False else S.lam(t)
    t2 = float(S.t_of_lambda(lam))
    assert abs(t2 - t) < 1e-4, (t, t2)


def test_lambda_monotone_decreasing():
    ts = np.linspace(1e-3, 1.0, 200)
    lams = np.asarray([float(S.lam(t)) for t in ts])
    assert np.all(np.diff(lams) < 0)


def test_marginal_sample_moments():
    import jax

    key = jax.random.PRNGKey(0)
    x0 = jnp.ones((20000, 2), jnp.float32)
    t = jnp.full((20000,), 0.5, jnp.float32)
    xt, eps = S.marginal_sample(key, x0, t)
    a = float(S.alpha(0.5))
    s = float(S.sigma(0.5))
    assert abs(float(jnp.mean(xt)) - a) < 0.02
    assert abs(float(jnp.std(xt)) - s) < 0.02
    assert abs(float(jnp.mean(eps))) < 0.02
