"""Data substrate + AOT pipeline tests: mixture spec determinism, exact-score
parity with a numerical gradient, .upw writer vs rust layout, and a full
lower->HLO-text smoke (batch 1) asserting the artifact parses as HLO text."""

import json
import os
import struct
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as data_mod
from compile.model import ModelConfig
from compile.sde import VpLinear
from compile.train import save_upw


def test_mixture_deterministic_and_normalized():
    a = data_mod.make_mixture()
    b = data_mod.make_mixture()
    assert a == b
    assert abs(sum(a["weights"]) - 1.0) < 1e-9
    assert len(a["means"]) == a["n_classes"] * a["comps_per_class"]


def test_sample_batch_labels_consistent():
    spec = data_mod.make_mixture()
    rng = np.random.default_rng(0)
    x, labels = data_mod.sample_batch(spec, rng, 512)
    assert x.shape == (512, spec["dim"])
    assert labels.min() >= 0 and labels.max() < spec["n_classes"]


def test_exact_eps_matches_numerical_score():
    spec = data_mod.make_mixture(dim=3, n_classes=2, comps_per_class=1)
    sched = VpLinear()
    t = 0.4
    a = float(sched.alpha(t))
    s = float(sched.sigma(t))

    means = np.asarray(spec["means"])
    stds = np.asarray(spec["stds"])
    weights = np.asarray(spec["weights"])

    def logq(x):
        v = a**2 * stds**2 + s**2
        sq = np.sum((x[None, :] - a * means) ** 2, axis=-1)
        terms = np.log(weights) - 1.5 * np.log(2 * np.pi * v) - sq / (2 * v)
        m = terms.max()
        return m + np.log(np.exp(terms - m).sum())

    x = np.array([0.4, -0.8, 0.1])
    h = 1e-5
    grad = np.array(
        [
            (logq(x + h * np.eye(3)[j]) - logq(x - h * np.eye(3)[j])) / (2 * h)
            for j in range(3)
        ]
    )
    eps = data_mod.exact_eps(spec, x[None, :].astype(np.float64), t, a, s)[0]
    np.testing.assert_allclose(eps, -s * grad, atol=1e-5)


def test_upw_layout_matches_rust_reader_spec():
    """Byte-level check of the writer against the documented layout."""
    params = {"b": np.asarray([1.5, -2.0], np.float32), "a": np.ones((2, 2), np.float32)}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "w.upw")
        save_upw(params, path)
        raw = open(path, "rb").read()
    assert raw[:4] == b"UPW1"
    (n,) = struct.unpack_from("<I", raw, 4)
    assert n == 2
    # First tensor header is 'a' (sorted order).
    (name_len,) = struct.unpack_from("<I", raw, 8)
    assert raw[12 : 12 + name_len] == b"a"
    # Payload tail: 4 floats of 'a' then 2 of 'b'.
    floats = np.frombuffer(raw[-6 * 4 :], np.float32)
    np.testing.assert_array_equal(floats[:4], np.ones(4, np.float32))
    np.testing.assert_array_equal(floats[4:], np.asarray([1.5, -2.0], np.float32))


def test_aot_lowering_emits_parsable_hlo():
    from compile.aot import lower_eps, to_hlo_text

    cfg = ModelConfig()
    text = to_hlo_text(lower_eps(cfg, 1))
    assert "HloModule" in text
    assert "ENTRY" in text
    # One f32[1,16] input for x and the tuple-return convention.
    assert "f32[1,16]" in text


def test_manifest_schema(tmp_path):
    from compile.aot import build

    manifest = build(str(tmp_path), [1])
    assert set(manifest["artifacts"].keys()) == {"eps_b1", "eps_cfg_b1", "correct_b1"}
    assert manifest["schedule"]["kind"] == "vp_linear"
    assert len(manifest["param_names"]) == len(manifest["param_shapes"])
    on_disk = json.load(open(tmp_path / "manifest.json"))
    assert on_disk["batches"] == [1]
