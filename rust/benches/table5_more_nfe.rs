//! Table 5 reproduction: guided sampling at 10–25 NFE on the
//! class-conditional ImageNet-256 stand-in with guidance scale s = 8.0.
//! Methods: DDIM, DPM-Solver (singlestep-3), PNDM, DEIS, DPM-Solver++(2M),
//! UniPC-2 (ours).
//!
//! Expected shape (paper): UniPC < DPM-Solver++ < DDIM/DEIS everywhere;
//! DPM-Solver (singlestep) and PNDM are unstable/poor at NFE 10 and only
//! recover at 20–25.

use unipc::analytic::datasets::{dataset, DatasetSpec};
use unipc::analytic::GuidedGmmModel;
use unipc::evalharness::{RefErr, ResultTable};
use unipc::numerics::vandermonde::BFunction;
use unipc::sched::VpLinear;
use unipc::solver::{Method, Prediction, SampleOptions};

fn main() {
    let nfes = [10usize, 15, 20, 25];
    let spec = DatasetSpec::ImagenetLike;
    let gm = dataset(spec);
    let sched = VpLinear::default();
    let model = GuidedGmmModel {
        gm: &gm,
        sched: &sched,
        class_components: spec.class_components(3),
        scale: 8.0,
    };
    let re = RefErr::new(&model, &sched, 12, 42, 1.0, 1e-3, 4000);

    let rows: Vec<(&str, Box<dyn Fn(usize) -> SampleOptions>)> = vec![
        (
            "DDIM",
            Box::new(|s| SampleOptions::new(Method::Ddim { pred: Prediction::Noise }, s)),
        ),
        (
            "DPM-Solver (3S)",
            Box::new(|s| SampleOptions::new(Method::DpmSolverSingle { order: 3 }, s)),
        ),
        ("PNDM", Box::new(|s| SampleOptions::new(Method::Plms, s))),
        ("DEIS-2", Box::new(|s| SampleOptions::new(Method::Deis { order: 2 }, s))),
        (
            "DPM-Solver++(2M)",
            Box::new(|s| SampleOptions::new(Method::DpmSolverPp { order: 2 }, s)),
        ),
        (
            "UniPC-2 (ours)",
            Box::new(|s| SampleOptions::unipc(2, BFunction::Bh2, Prediction::Data, s)),
        ),
    ];

    let mut table = ResultTable::new(
        "Table 5 imagenet-like s=8.0 — l2 to reference, 10-25 NFE",
        &nfes,
    );
    for (label, mk) in &rows {
        table.push(label, nfes.iter().map(|&n| re.err(&model, &sched, &mk(n))).collect());
    }
    table.emit("table5_more_nfe.json");

    // Shape checks mirroring the paper's orderings.
    let mut wins = 0;
    for (i, &n) in nfes.iter().enumerate() {
        let unipc = table.rows.last().unwrap().1[i];
        let dpmpp = table.rows[4].1[i];
        if unipc <= dpmpp * 1.02 {
            wins += 1;
        } else {
            eprintln!("note: DPM-Solver++ ahead at NFE={n} ({dpmpp:.4} vs {unipc:.4})");
        }
    }
    assert!(wins >= 3, "UniPC must match/beat DPM-Solver++ on most of the grid");
    // Singlestep DPM-Solver should trail multistep at NFE=10 (paper: 114.6
    // vs 9.56 FID).
    assert!(
        table.rows[1].1[0] > table.rows[4].1[0],
        "singlestep should trail multistep at NFE=10"
    );
}
