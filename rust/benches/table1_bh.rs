//! Table 1 reproduction: the B(h) ablation. UniPC-3 with B₁(h)=h vs
//! B₂(h)=e^h−1, against DPM-Solver++(3M), on the three unconditional
//! benchmarks at NFE ∈ {5, 6, 8, 10}.
//!
//! Expected shape (paper): both UniPC variants beat DPM-Solver++; B₁ is
//! ahead at 5–6 NFE, B₂ catches up by 8–10.

use unipc::analytic::datasets::{dataset, DatasetSpec};
use unipc::analytic::GmmModel;
use unipc::evalharness::{RefErr, ResultTable};
use unipc::numerics::vandermonde::BFunction;
use unipc::sched::VpLinear;
use unipc::solver::{Method, Prediction, SampleOptions};

fn main() {
    let nfes = [5usize, 6, 8, 10];
    for spec in [DatasetSpec::Cifar10Like, DatasetSpec::BedroomLike, DatasetSpec::FfhqLike] {
        let gm = dataset(spec);
        let sched = VpLinear::default();
        let model = GmmModel { gm: &gm, sched: &sched };
        let re = RefErr::new(&model, &sched, 16, 42, 1.0, 1e-3, 3000);

        let mut table = ResultTable::new(
            &format!("Table 1 {} — B(h) ablation (l2 to reference)", spec.name()),
            &nfes,
        );
        let rows: Vec<(&str, Box<dyn Fn(usize) -> SampleOptions>)> = vec![
            (
                "DPM-Solver++(3M)",
                Box::new(|s| SampleOptions::new(Method::DpmSolverPp { order: 3 }, s)),
            ),
            (
                "UniPC (B1=h)",
                Box::new(|s| SampleOptions::unipc(3, BFunction::Bh1, Prediction::Noise, s)),
            ),
            (
                "UniPC (B2=e^h-1)",
                Box::new(|s| SampleOptions::unipc(3, BFunction::Bh2, Prediction::Noise, s)),
            ),
        ];
        for (label, mk) in &rows {
            table.push(label, nfes.iter().map(|&n| re.err(&model, &sched, &mk(n))).collect());
        }
        table.emit(&format!("table1_{}.json", spec.name()));

        // Both UniPC variants must beat the baseline everywhere.
        for &n in &nfes {
            let w = table.winner(n).unwrap();
            assert_ne!(w, "DPM-Solver++(3M)", "baseline must not win at NFE={n}");
        }
    }
}
