//! Table 3 reproduction: the UniC upper bound. DPM-Solver++(3M) vs +UniC vs
//! +UniC-oracle (which re-evaluates ε at the corrected point; ~2× NFE) on
//! the Bedroom/FFHQ stand-ins, sampling steps ∈ {5, 6, 8, 10}.
//!
//! Expected shape (paper): oracle < UniC < baseline, with the largest gaps
//! at 5–6 steps.

use unipc::analytic::datasets::{dataset, DatasetSpec};
use unipc::analytic::GmmModel;
use unipc::evalharness::{RefErr, ResultTable};
use unipc::numerics::vandermonde::BFunction;
use unipc::sched::VpLinear;
use unipc::solver::unipc::CoeffVariant;
use unipc::solver::{Method, SampleOptions};

fn main() {
    let steps_grid = [5usize, 6, 8, 10];
    for spec in [DatasetSpec::BedroomLike, DatasetSpec::FfhqLike] {
        let gm = dataset(spec);
        let sched = VpLinear::default();
        let model = GmmModel { gm: &gm, sched: &sched };
        let re = RefErr::new(&model, &sched, 16, 42, 1.0, 1e-3, 3000);

        let base = |s: usize| SampleOptions::new(Method::DpmSolverPp { order: 3 }, s);
        let mut table = ResultTable::new(
            &format!("Table 3 {} — UniC vs UniC-oracle (l2 to reference)", spec.name()),
            &steps_grid,
        );
        table.push(
            "DPM-Solver++(3M)",
            steps_grid.iter().map(|&s| re.err(&model, &sched, &base(s))).collect(),
        );
        table.push(
            "+UniC",
            steps_grid
                .iter()
                .map(|&s| {
                    re.err(
                        &model,
                        &sched,
                        &base(s).with_unic(CoeffVariant::Bh(BFunction::Bh2), false),
                    )
                })
                .collect(),
        );
        table.push(
            "+UniC-oracle (2x NFE)",
            steps_grid
                .iter()
                .map(|&s| {
                    re.err(
                        &model,
                        &sched,
                        &base(s).with_unic(CoeffVariant::Bh(BFunction::Bh2), true),
                    )
                })
                .collect(),
        );
        table.emit(&format!("table3_{}.json", spec.name()));

        // Shape: oracle ≤ unic ≤ base at the small-step end.
        let b = &table.rows[0].1;
        let u = &table.rows[1].1;
        let o = &table.rows[2].1;
        // UniC should help on the bulk of the grid; the 5-step cell is noisy
        // on this substitute. The oracle must dominate everywhere (paper).
        let improved = b.iter().zip(u).filter(|(bb, uu)| uu < bb).count();
        assert!(improved >= 2, "UniC should improve most step budgets: {b:?} -> {u:?}");
        for (oo, bb) in o.iter().zip(b) {
            assert!(oo < bb, "oracle must beat the baseline everywhere: {o:?} vs {b:?}");
        }
    }
}
