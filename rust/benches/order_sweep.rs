//! Empirical order-of-convergence validation of Theorem 3.1, Corollary 3.2
//! and Propositions D.5/D.6: with O(h^p)-accurate starting values (exact
//! warm-up), the measured global-error slope must be ≈ p for UniP-p and
//! ≈ p+1 for UniPC-p.

use unipc::analytic::datasets::{dataset, DatasetSpec};
use unipc::analytic::{reference_solution, GmmModel};
use unipc::evalharness::ResultTable;
use unipc::numerics::vandermonde::BFunction;
use unipc::rng::Rng;
use unipc::sched::VpLinear;
use unipc::solver::{sample, Method, Prediction, SampleOptions};

fn slope(steps: &[usize], errs: &[f64]) -> f64 {
    let n = steps.len() as f64;
    let xs: Vec<f64> = steps.iter().map(|&s| (s as f64).log2()).collect();
    let ys: Vec<f64> = errs.iter().map(|e| e.log2()).collect();
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let num: f64 = xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let den: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    -num / den
}

fn main() {
    let gm = dataset(DatasetSpec::Cifar10Like);
    let sched = VpLinear::default();
    let model = GmmModel { gm: &gm, sched: &sched };
    let mut rng = Rng::seed_from(5);
    let x_t = rng.normal_tensor(&[4, gm.dim]);
    let truth = reference_solution(&model, &sched, &x_t, 1.0, 1e-3, 8000);

    let grid = [160usize, 320, 640, 1280];
    let mut table = ResultTable::new("Order sweep (global error; slope = order)", &grid);
    let mut slopes: Vec<(String, f64, f64, bool)> = Vec::new(); // (name, slope, expected, assert)

    // Orders ≥ 5 exercise the arity-5/6 fused weighted_sum paths, but their
    // global errors sit at/below the f64 noise floor of the RK4 reference on
    // this grid, so their slopes are reported without assertion.
    for (name, order, corrector, expected, check) in [
        ("UniP-1 (DDIM)", 1usize, false, 1.0, true),
        ("UniP-2", 2, false, 2.0, true),
        ("UniP-3", 3, false, 3.0, true),
        ("UniPC-1", 1, true, 2.0, true),
        ("UniPC-2", 2, true, 3.0, true),
        ("UniPC-3", 3, true, 4.0, true),
        ("UniP-5", 5, false, 5.0, false),
        ("UniPC-5", 5, true, 6.0, false),
        ("UniPC-6", 6, true, 7.0, false),
    ] {
        let errs: Vec<f64> = grid
            .iter()
            .map(|&steps| {
                let mut opts = if corrector {
                    SampleOptions::unipc(order, BFunction::Bh2, Prediction::Noise, steps)
                } else {
                    SampleOptions::new(
                        Method::unip(order, BFunction::Bh2, Prediction::Noise),
                        steps,
                    )
                };
                opts.exact_warmup = true;
                sample(&model, &sched, &x_t, &opts).x.sub(&truth).norm()
            })
            .collect();
        let s = slope(&grid, &errs);
        slopes.push((name.to_string(), s, expected, check));
        table.push(&format!("{name} (slope {s:.2})"), errs);
    }
    table.emit("order_sweep.json");

    println!("{:<16} {:>8} {:>9}", "method", "slope", "expected");
    for (name, s, exp, check) in &slopes {
        let note = if *check { "" } else { "  (noise floor — not asserted)" };
        println!("{name:<16} {s:>8.2} {exp:>9.1}{note}");
        // Allow generous tolerance near the f64 noise floor for UniPC-3.
        assert!(
            !check || (s - exp).abs() < 0.9,
            "{name}: measured slope {s:.2}, expected ~{exp}"
        );
    }
}
