//! Serving benchmark: throughput/latency of the full stack under open-loop
//! Poisson load, plus a dynamic-batching ablation (batch window vs mean
//! rows per PJRT call). Uses the trained PJRT backend when artifacts exist,
//! the analytic backend otherwise (the coordinator path is identical).
//!
//! This is the serving-system counterpart of the paper's NFE claims: UniPC
//! at 8 NFE serves ~(20/8)× the throughput of a 20-NFE baseline at equal
//! quality budget, because the solver *is* the unit of serving cost.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use unipc::analytic::datasets::{dataset, DatasetSpec};
use unipc::config::ServerConfig;
use unipc::coordinator::{
    silence_injected_panics, ChaosConfig, ModelBackend, SampleRequest, Service,
};
use unipc::json::Value;
use unipc::runtime::{EngineOptions, PjrtHandle};
use unipc::server::{run_load, LoadConfig, Server};

fn backend(batch_wait_us: u64) -> (ModelBackend, &'static str) {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() && dir.join("model.upw").exists() {
        let h = PjrtHandle::spawn(
            &dir,
            None,
            EngineOptions {
                max_batch: 64,
                batch_wait: Duration::from_micros(batch_wait_us),
            },
        )
        .expect("pjrt");
        (ModelBackend::Pjrt(h), "pjrt")
    } else {
        let spec = DatasetSpec::Cifar10Like;
        let gm = Arc::new(dataset(spec));
        let classes = (0..spec.n_classes()).map(|c| spec.class_components(c)).collect();
        (
            ModelBackend::Analytic { gm, class_components: Arc::new(classes) },
            "analytic",
        )
    }
}

fn run_point(
    rps: f64,
    total: usize,
    batch_wait_us: u64,
    workers: usize,
    batch_linger_us: u64,
) -> String {
    let (be, kind) = backend(batch_wait_us);
    let pjrt = match &be {
        ModelBackend::Pjrt(h) => Some(h.clone()),
        _ => None,
    };
    let svc = Service::start(
        ServerConfig { workers, queue_cap: 512, batch_linger_us, ..Default::default() },
        be,
    );
    let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();

    let cfg = LoadConfig {
        rps,
        total,
        connections: 4,
        template: SampleRequest {
            n: 4,
            steps: 8,
            method: "unipc-3".into(),
            unic: true,
            seed: 0,
            return_samples: false,
            ..Default::default()
        },
        seed: 9,
        key_mix: 1,
        mix_guidance: None,
        plan_mix: 1,
    };
    let mut report = run_load(&server.addr.to_string(), &cfg).unwrap();
    let mut line = format!(
        "[{kind}] rps={rps:<6} wait={batch_wait_us:>5}us linger={batch_linger_us:>5}us workers={workers}: {}",
        report.summary()
    );
    let m = svc.metrics_json();
    let counter = |key: &str| m.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0);
    line.push_str(&format!(
        "  batched_runs={} ws_reuses={}",
        counter("batched_runs"),
        counter("workspace_reuses"),
    ));
    if let Some(h) = pjrt {
        let s = h.stats().unwrap();
        line.push_str(&format!(
            "  pjrt: calls={} mean_rows/call={:.2} padded={}",
            s.calls,
            s.mean_rows_per_call(),
            s.padded_rows
        ));
        h.shutdown();
    }
    server.stop();
    svc.shutdown();
    line
}

/// Chaos ablation: same workload, 10% of model evals injected with a
/// panic / NaN row / latency spike each. Measures what fault tolerance
/// costs and proves the serving invariants hold under load: every request
/// gets exactly one typed response and the worker pool never shrinks.
fn run_chaos_point(rps: f64, total: usize) -> String {
    silence_injected_panics();
    let (be, kind) = backend(200);
    let be = ModelBackend::chaos(
        be,
        ChaosConfig {
            seed: 7,
            panic_rate: 0.10,
            nan_rate: 0.10,
            latency_rate: 0.10,
            latency_us: 500,
            ..ChaosConfig::default()
        },
    );
    let svc = Service::start(
        ServerConfig { workers: 4, queue_cap: 512, ..Default::default() },
        be,
    );
    let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
    let cfg = LoadConfig {
        rps,
        total,
        connections: 4,
        template: SampleRequest {
            n: 4,
            steps: 8,
            method: "unipc-3".into(),
            unic: true,
            seed: 0,
            return_samples: false,
            ..Default::default()
        },
        seed: 9,
        key_mix: 1,
        mix_guidance: None,
        plan_mix: 1,
    };
    let mut report = run_load(&server.addr.to_string(), &cfg).unwrap();
    let m = svc.metrics_json();
    let counter = |key: &str| m.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0);
    let line = format!(
        "[{kind}+chaos] rps={rps:<6}: {}  restarts={} quarantined={} batch_retries={} deadline_exceeded={}",
        report.summary(),
        counter("worker_restarts"),
        counter("quarantined_members"),
        counter("batch_retries"),
        counter("deadline_exceeded"),
    );
    server.stop();
    svc.shutdown();
    line
}

/// Tracing point (PR 9): the rps=16 workload re-run at `trace=steps`, the
/// most expensive tracing level (a model_eval/solver_step span pair per
/// planned step on every batch). Prints the stage breakdown the loadgen
/// now derives from response timing stamps, reports how many span events
/// the shard rings retained, and exports the whole run as a Chrome
/// `trace_event` JSON (`TRACE_serving.json` — load it in
/// `chrome://tracing` or Perfetto).
fn run_traced_point(rps: f64, total: usize) -> String {
    let (be, kind) = backend(200);
    let svc = Service::start(
        ServerConfig {
            workers: 4,
            queue_cap: 512,
            trace: unipc::trace::TraceLevel::Steps,
            ..Default::default()
        },
        be,
    );
    let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
    let cfg = LoadConfig {
        rps,
        total,
        connections: 4,
        template: SampleRequest {
            n: 4,
            steps: 8,
            method: "unipc-3".into(),
            unic: true,
            seed: 0,
            return_samples: false,
            ..Default::default()
        },
        seed: 9,
        key_mix: 1,
        mix_guidance: None,
        plan_mix: 1,
    };
    let mut report = run_load(&server.addr.to_string(), &cfg).unwrap();
    let m = svc.metrics_json();
    let counter = |key: &str| m.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0);
    let chrome = svc.chrome_trace_json();
    let events =
        chrome.get("traceEvents").and_then(|v| v.as_arr()).map(|a| a.len()).unwrap_or(0);
    let _ = std::fs::write("TRACE_serving.json", chrome.to_string());
    let line = format!(
        "[{kind}+trace=steps] rps={rps:<6}: {}  spans_recorded={} spans_dropped={} ({events} chrome events -> TRACE_serving.json)",
        report.summary(),
        counter("trace_recorded"),
        counter("trace_dropped"),
    );
    server.stop();
    svc.shutdown();
    line
}

/// One shard-count ablation point: saturating open-loop load at a fixed
/// worker count, workload fanned across 8 *plan keys* (distinct step
/// counts via `plan_mix`) so a multi-shard coordinator can actually spread
/// admission — conditioning no longer fans the key, so `key_mix` would
/// all land on one shard. Small cheap requests (n=1, no sample payload)
/// keep the solver out of the way — the point measures queue-lock
/// contention, which is what sharding removes.
/// Returns the printable line plus (requests/s, steals) for the JSON dump.
fn run_shard_point(shards: usize, total: usize) -> (String, f64, f64) {
    let (be, kind) = backend(200);
    let svc = Service::start(
        ServerConfig { workers: 8, shards, queue_cap: 4096, ..Default::default() },
        be,
    );
    let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
    let cfg = LoadConfig {
        rps: 200_000.0, // far past capacity: measures service rate, not offered load
        total,
        connections: 32,
        template: SampleRequest {
            n: 1,
            steps: 5,
            method: "unipc-3".into(),
            unic: true,
            seed: 0,
            return_samples: false,
            ..Default::default()
        },
        seed: 9,
        key_mix: 1,
        mix_guidance: None,
        plan_mix: 8,
    };
    let mut report = run_load(&server.addr.to_string(), &cfg).unwrap();
    let rps_achieved = report.ok as f64 / report.wall.as_secs_f64();
    let m = svc.metrics_json();
    let counter = |key: &str| m.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0);
    let line = format!(
        "[{kind}] shards={shards} workers=8 keys=8: {}  req/s={rps_achieved:.0} steals={} batched_runs={}",
        report.summary(),
        counter("steals"),
        counter("batched_runs"),
    );
    server.stop();
    svc.shutdown();
    (line, rps_achieved, counter("steals"))
}

/// Conditioning-mix ablation: one worker, one plan key, traffic fanned
/// across 8 classes with guidance on every other request. With the
/// collapsed batch key (PR 8) the whole mix stacks into one lockstep
/// cohort per linger window; `split_cond_batches: true` restores the
/// legacy per-conditioning keys as the baseline. Reports the member-
/// weighted mean batch size from `batch_size_hist` plus the mixed-cohort
/// counters — the steady-state cohorts should be visibly larger collapsed.
fn run_cond_mix_point(split: bool, rps: f64, total: usize) -> String {
    let (be, kind) = backend(200);
    let svc = Service::start(
        ServerConfig {
            workers: 1,
            shards: 1,
            queue_cap: 4096,
            batch_linger_us: 2_000,
            split_cond_batches: split,
            ..Default::default()
        },
        be,
    );
    let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
    let cfg = LoadConfig {
        rps,
        total,
        connections: 4,
        template: SampleRequest {
            n: 1,
            steps: 5,
            method: "unipc-3".into(),
            unic: true,
            seed: 0,
            return_samples: false,
            ..Default::default()
        },
        seed: 9,
        key_mix: 8,
        mix_guidance: Some(2.0),
        plan_mix: 1,
    };
    let mut report = run_load(&server.addr.to_string(), &cfg).unwrap();
    let m = svc.metrics_json();
    let counter = |key: &str| m.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0);
    let hist: Vec<f64> = m
        .get("batch_size_hist")
        .and_then(|v| v.as_arr())
        .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
        .unwrap_or_default();
    let runs: f64 = hist.iter().sum();
    let members: f64 = hist.iter().enumerate().map(|(i, c)| (i + 1) as f64 * c).sum();
    let mean_batch = if runs > 0.0 { members / runs } else { 0.0 };
    let line = format!(
        "[{kind}] split_cond_batches={split}: {}  mean_batch={mean_batch:.2} batched_runs={} mixed_cond_batches={}",
        report.summary(),
        counter("batched_runs"),
        counter("mixed_cond_batches"),
    );
    server.stop();
    svc.shutdown();
    line
}

fn main() {
    println!("== serving load sweep (4 samples/request, UniPC-3 @ 8 NFE) ==");
    let mut lines = Vec::new();
    for rps in [4.0, 8.0, 16.0] {
        lines.push(run_point(rps, 48, 200, 4, 0));
    }
    println!("-- offered-load sweep --");
    for l in &lines {
        println!("{l}");
    }

    println!("-- batching-window ablation (rps=16) --");
    for wait in [0u64, 200, 2000] {
        println!("{}", run_point(16.0, 48, wait, 4, 0));
    }

    println!("-- worker-count ablation (rps=16) --");
    for workers in [1usize, 2, 8] {
        println!("{}", run_point(16.0, 48, 200, workers, 0));
    }

    // Request batching (PR 2): same-plan requests coalesce into lockstep
    // batched runs. linger=0 batches only what is already queued; larger
    // windows trade first-token latency for bigger stacked batches.
    println!("-- request-batching ablation (rps=16, 1 worker) --");
    for linger in [0u64, 500, 5000] {
        println!("{}", run_point(16.0, 48, 200, 1, linger));
    }

    // Fault tolerance (PR 6): 10% injected panics/NaNs/latency spikes.
    // Failed requests get typed responses; the pool self-heals.
    println!("-- chaos ablation (10% injected faults, rps=16) --");
    println!("{}", run_chaos_point(16.0, 48));

    // Request tracing (PR 9): the same workload at the most expensive
    // span level, exported as a Chrome trace artifact. The printed stage
    // breakdown (queue vs compute, model vs solver) comes from the
    // response timing stamps every run above also carries.
    println!("-- tracing point (trace=steps, rps=16) --");
    println!("{}", run_traced_point(16.0, 48));

    // Per-member conditioning (PR 8): same plan, 8 classes + alternating
    // guidance. The collapsed batch key stacks the whole mix into one
    // cohort; the split baseline shows what the legacy key cost.
    println!("-- conditioning-mix ablation (1 worker, 8 classes, alternating guidance) --");
    for split in [true, false] {
        println!("{}", run_cond_mix_point(split, 400.0, 64));
    }

    // Coordinator sharding (PR 7): fixed 8 workers, saturating load over 8
    // batch keys, shard count swept. One queue serializes admission + the
    // assembler scan; sharding splits that lock. Emits
    // BENCH_serving_shards.json (shard count → req/s, steals) next to
    // BENCH_hot_path.json for the tracked perf trajectory.
    println!("-- shard-count ablation (8 workers, saturating, 8 batch keys) --");
    let mut shard_pairs: Vec<(String, Value)> = Vec::new();
    let mut baseline_1_shard = 0.0;
    for shards in [1usize, 2, 4, 8] {
        let (line, rps, steals) = run_shard_point(shards, 1600);
        println!("{line}");
        if shards == 1 {
            baseline_1_shard = rps;
        }
        shard_pairs.push((format!("shards_{shards}_req_per_sec"), rps.into()));
        shard_pairs.push((format!("shards_{shards}_steals"), steals.into()));
    }
    if baseline_1_shard > 0.0 {
        let best = shard_pairs
            .iter()
            .filter(|(k, _)| k.ends_with("req_per_sec"))
            .filter_map(|(_, v)| v.as_f64())
            .fold(0.0f64, f64::max);
        shard_pairs.push(("speedup_best_vs_1_shard".into(), (best / baseline_1_shard).into()));
    }
    let pairs: Vec<(&str, Value)> =
        shard_pairs.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
    let _ = std::fs::write("BENCH_serving_shards.json", Value::obj(pairs).to_string());
    println!("wrote BENCH_serving_shards.json");
}
