//! Figure 4a/4b (+ Table 9's s=1.0 row) reproduction: guided sampling
//! quality vs NFE on the ImageNet-256 stand-in at guidance scales
//! s ∈ {8.0, 4.0, 1.0}. Series: DDIM, DPM-Solver++(2M), UniPC-2 (B₂) —
//! the figure's method set.
//!
//! Expected shape (paper): UniPC converges fastest at every scale, and the
//! margin grows with the guidance scale (larger s ⇒ stiffer dynamics).

use unipc::analytic::datasets::{dataset, DatasetSpec};
use unipc::analytic::GuidedGmmModel;
use unipc::evalharness::{RefErr, ResultTable};
use unipc::numerics::vandermonde::BFunction;
use unipc::sched::VpLinear;
use unipc::solver::{DynamicThresholding, Method, Prediction, SampleOptions};

fn main() {
    let nfes = [5usize, 6, 7, 8, 9, 10];
    let spec = DatasetSpec::ImagenetLike;
    let gm = dataset(spec);
    let sched = VpLinear::default();

    for scale in [1.0, 4.0, 8.0] {
        let model = GuidedGmmModel {
            gm: &gm,
            sched: &sched,
            class_components: spec.class_components(3),
            scale,
        };
        let re = RefErr::new(&model, &sched, 12, 42, 1.0, 1e-3, 4000);

        let rows: Vec<(&str, Box<dyn Fn(usize) -> SampleOptions>)> = vec![
            (
                "DDIM",
                Box::new(|s| SampleOptions::new(Method::Ddim { pred: Prediction::Noise }, s)),
            ),
            (
                "DPM-Solver++(2M)",
                Box::new(|s| {
                    let mut o = SampleOptions::new(Method::DpmSolverPp { order: 2 }, s);
                    // Dynamic-thresholding analog for unbounded data
                    // (clip-only; DESIGN.md §2): tame large-guidance x₀
                    // extrapolations, as the paper does for pixel space.
                    o.thresholding = Some(DynamicThresholding::clip(8.0));
                    o
                }),
            ),
            (
                "UniPC-2 (ours)",
                Box::new(|s| {
                    let mut o = SampleOptions::unipc(2, BFunction::Bh2, Prediction::Data, s);
                    o.thresholding = Some(DynamicThresholding::clip(8.0));
                    o
                }),
            ),
        ];

        let mut table = ResultTable::new(
            &format!("Fig.4 imagenet-like s={scale} — l2 to reference"),
            &nfes,
        );
        for (label, mk) in &rows {
            table.push(label, nfes.iter().map(|&n| re.err(&model, &sched, &mk(n))).collect());
        }
        table.emit(&format!("fig4_s{scale}.json"));

        // Shape: UniPC wins a clear majority of the NFE grid (individual
        // low-NFE cells are noisy at extreme guidance on this substitute).
        let wins_unipc = nfes
            .iter()
            .filter(|&&n| table.winner(n) == Some("UniPC-2 (ours)"))
            .count();
        // UniPC must beat DPM-Solver++(2M) (its direct high-order rival) on
        // most of the grid at every scale; at moderate scales it should win
        // the table outright (at s=8 the paper itself shows DDIM competitive
        // at NFE 5, Table 9).
        let beats_dpmpp = (0..nfes.len())
            .filter(|&i| table.rows[2].1[i] <= table.rows[1].1[i])
            .count();
        assert!(
            beats_dpmpp * 2 >= nfes.len(),
            "UniPC should beat DPM-Solver++ on most of the s={scale} grid ({beats_dpmpp}/{})",
            nfes.len()
        );
        if scale <= 1.0 {
            assert!(
                wins_unipc * 2 > nfes.len(),
                "UniPC should win a majority at s={scale} (won {wins_unipc}/{})",
                nfes.len()
            );
        }
    }
}
