//! Hot-path microbenchmarks for the perf pass (EXPERIMENTS.md §Perf):
//!   L3-a  solver arithmetic per step (weighted_sum fusion vs naive axpy,
//!         arity 3 and the order-5/6 sweep arity 6, plus the in-place form)
//!   L3-b  coefficient solve (Vandermonde) cost per step
//!   L3-c  full UniPC-3 run, reference on-the-fly loop
//!   L3-d  full UniPC-3 run executed from a cached SamplePlan (+ the
//!         one-time plan-construction cost)
//!   L3-e  batched execution across requests sharing a plan
//!         (sample_batch_with_plan) vs the same requests run sequentially
//!   L3-f  non-UniPC families through the generalized plan compiler:
//!         naive reference loop vs plan-cached execution for the
//!         DPM-Solver++ multistep and DEIS families (DEIS pays a per-step
//!         Gauss–Legendre quadrature on the naive path)
//!   L3-g  per-member conditioning: one mixed-conditioning cohort run as a
//!         single slab-tiled lockstep batch vs the same members split into
//!         per-conditioning cohorts (the legacy batch-key behavior)
//!   L3-h  tracing overhead on the batched hot path: the same b=8 cohort
//!         run bare vs. instrumented exactly as the worker runs it at
//!         trace=steps (TimedModel wrap, per-step span pairs into a
//!         preallocated scratch vec, lifecycle events, one ring flush)
//!   L3-i  full telemetry plane on the batched hot path: L3-h's traced run
//!         plus per-step numerical health (HealthSpans), windowed
//!         time-series metrics records, and a no-subscriber EventHub
//!         publish — the worker's steady state with every PR-10 signal on
//!   RT-a  PJRT ε call latency vs batch size (batching amortization)
//!   RT-b  fused correct artifact vs eval + host update (round-trip saving)
//!
//! Emits `BENCH_hot_path.json` (bench name → ns/iter) so the perf
//! trajectory is machine-trackable across PRs.

use std::hint::black_box;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use unipc::analytic::datasets::{dataset, DatasetSpec};
use unipc::analytic::GmmModel;
use unipc::coordinator::{CohortModel, CondSlab, Conditioning, ModelBackend};
use unipc::json::Value;
use unipc::numerics::vandermonde::{unipc_coeffs, BFunction};
use unipc::rng::Rng;
use unipc::runtime::{EngineOptions, PjrtHandle};
use unipc::sched::VpLinear;
use unipc::solver::{
    sample_batch_with_plan, sample_batch_with_plan_observed, sample_unplanned, sample_with_plan,
    BatchWorkspace, Method, Model, Prediction, SampleOptions, SamplePlan, UniPcCoeffs,
};
use unipc::tensor::{weighted_sum, weighted_sum_into, Tensor};
use unipc::trace::{SpanEvent, Stage, StepSpans, TimedModel, TraceRing};

fn bench<F: FnMut()>(
    results: &mut Vec<(String, Duration)>,
    name: &str,
    iters: usize,
    mut f: F,
) -> Duration {
    // Warmup.
    for _ in 0..3 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed() / iters as u32;
    println!("{name:<48} {per:>12.2?}/iter  ({iters} iters)");
    results.push((name.to_string(), per));
    per
}

fn emit_json(results: &[(String, Duration)]) {
    let pairs: Vec<(&str, Value)> = results
        .iter()
        .map(|(n, d)| (n.as_str(), Value::from(d.as_nanos() as f64)))
        .collect();
    let _ = std::fs::write("BENCH_hot_path.json", Value::obj(pairs).to_string());
    println!("wrote BENCH_hot_path.json ({} entries)", results.len());
}

fn unipc3_opts(variant: UniPcCoeffs, steps: usize) -> SampleOptions {
    SampleOptions::new(
        Method::UniP { order: 3, variant, pred: Prediction::Noise, schedule: None },
        steps,
    )
    .with_unic(variant, false)
}

fn main() {
    let mut results: Vec<(String, Duration)> = Vec::new();
    let mut rng = Rng::seed_from(1);
    let (b, d, p) = (64usize, 16usize, 3usize);
    let tensors: Vec<Tensor> = (0..p).map(|_| rng.normal_tensor(&[b, d])).collect();
    let coeffs = [0.4, -0.2, 0.1];

    // L3-a: fused weighted sum vs naive repeated axpy.
    bench(&mut results, "L3-a weighted_sum fused (64x16, p=3)", 20_000, || {
        let refs: Vec<&Tensor> = tensors.iter().collect();
        black_box(weighted_sum(&coeffs, &refs));
    });
    bench(&mut results, "L3-a naive axpy chain   (64x16, p=3)", 20_000, || {
        let mut acc = tensors[0].scaled(coeffs[0]);
        for i in 1..p {
            acc.axpy(coeffs[i], &tensors[i]);
        }
        black_box(acc);
    });

    // L3-a: order-5/6 sweep arity (previously the slow generic loop) and
    // the zero-allocation workspace form.
    let six: Vec<Tensor> = (0..6).map(|_| rng.normal_tensor(&[b, d])).collect();
    let c6 = [0.4, -0.2, 0.1, 0.05, -0.03, 0.02];
    bench(&mut results, "L3-a weighted_sum fused (64x16, p=6)", 20_000, || {
        let refs: Vec<&Tensor> = six.iter().collect();
        black_box(weighted_sum(&c6, &refs));
    });
    let mut ws_out = Tensor::zeros(&[b, d]);
    bench(&mut results, "L3-a weighted_sum_into  (64x16, p=6)", 20_000, || {
        weighted_sum_into(&mut ws_out, &c6, &six);
        black_box(&ws_out);
    });

    // L3-b: coefficient solve.
    bench(&mut results, "L3-b unipc_coeffs p=3", 100_000, || {
        black_box(unipc_coeffs(&[-2.0, -1.0, 1.0], black_box(0.3), BFunction::Bh2));
    });
    bench(&mut results, "L3-b unipc_coeffs p=6", 50_000, || {
        black_box(unipc_coeffs(
            &[-5.0, -4.0, -3.0, -2.0, -1.0, 1.0],
            black_box(0.3),
            BFunction::Bh2,
        ));
    });

    // L3-c/d: full 8-step UniPC-3 runs — the on-the-fly reference loop vs
    // plan-cached execution. `vary` pays a per-step LU inversion on the
    // reference path, so the plan win there is the headline number. The
    // linear-model rows isolate solver arithmetic (the GMM ε* dominates the
    // analytic rows).
    let gm = dataset(DatasetSpec::Cifar10Like);
    let sched = VpLinear::default();
    let gmm_model = GmmModel { gm: &gm, sched: &sched };
    let lin_model: (Prediction, usize, fn(&Tensor, f64) -> Tensor) =
        (Prediction::Noise, d, |x, _t| x.scaled(0.3));
    let x_t = rng.normal_tensor(&[b, d]);

    for (model, model_tag) in [(&gmm_model as &dyn Model, "gmm"), (&lin_model, "linear")] {
        for (tag, variant) in
            [("bh2", UniPcCoeffs::Bh(BFunction::Bh2)), ("vary", UniPcCoeffs::Varying)]
        {
            let opts = unipc3_opts(variant, 8);
            let naive = bench(
                &mut results,
                &format!("L3-c UniPC-3 x8 naive ({tag}, {model_tag} 64x16)"),
                200,
                || {
                    black_box(sample_unplanned(model, &sched, &x_t, &opts));
                },
            );
            let plan = SamplePlan::build(&sched, &opts).expect("plannable");
            let planned = bench(
                &mut results,
                &format!("L3-d UniPC-3 x8 plan-cached ({tag}, {model_tag})"),
                200,
                || {
                    black_box(sample_with_plan(model, &sched, &x_t, &opts, &plan));
                },
            );
            println!(
                "{:<48} {:>11.2}x",
                format!("L3-d   speedup vs naive ({tag}, {model_tag})"),
                naive.as_secs_f64() / planned.as_secs_f64()
            );
        }
    }

    // L3-e: plan-aware batched execution across requests (serving-shaped
    // single-sample requests sharing one cached plan). The batched run
    // stacks member states and evaluates the model once per step for the
    // whole batch; sequential runs pay per-request model-call and
    // per-request solver overhead. Rows land in BENCH_hot_path.json so the
    // batched-vs-sequential ratio is tracked across PRs.
    {
        let opts = unipc3_opts(UniPcCoeffs::Bh(BFunction::Bh2), 8);
        let plan = SamplePlan::build(&sched, &opts).expect("plannable");
        for members in [2usize, 4, 8] {
            let inits: Vec<Tensor> = (0..members)
                .map(|i| Rng::seed_from(400 + i as u64).normal_tensor(&[1, gm.dim]))
                .collect();
            let seq = bench(
                &mut results,
                &format!("L3-e sequential {members}x UniPC-3 x8 (gmm n=1)"),
                500,
                || {
                    for x in &inits {
                        black_box(sample_with_plan(&gmm_model, &sched, x, &opts, &plan));
                    }
                },
            );
            let refs: Vec<&Tensor> = inits.iter().collect();
            let mut bw = BatchWorkspace::new();
            let bat = bench(
                &mut results,
                &format!("L3-e batched batch={members} UniPC-3 x8 (gmm n=1)"),
                500,
                || {
                    black_box(sample_batch_with_plan(
                        &gmm_model, &sched, &refs, &opts, &plan, &mut bw,
                    ));
                },
            );
            println!(
                "{:<48} {:>11.2}x",
                format!("L3-e   batched throughput vs sequential (b={members})"),
                seq.as_secs_f64() / bat.as_secs_f64()
            );
        }
    }

    // L3-g: per-member conditioning (PR 8). Eight serving-shaped n=1
    // members over 4 distinct (class, guidance) views: the collapsed batch
    // key runs them as ONE slab-tiled lockstep batch; the legacy key would
    // run 4 separate per-conditioning cohorts. Same arithmetic per row
    // (bit-identical outputs) — the delta is batching: fewer runs, fewer
    // model dispatches, better per-step amortization.
    {
        let spec = DatasetSpec::Cifar10Like;
        let backend = ModelBackend::Analytic {
            gm: Arc::new(dataset(spec)),
            class_components: Arc::new(
                (0..spec.n_classes()).map(|c| spec.class_components(c)).collect(),
            ),
        };
        let opts = unipc3_opts(UniPcCoeffs::Bh(BFunction::Bh2), 8);
        let plan = SamplePlan::build(&sched, &opts).expect("plannable");
        let mut members: Vec<(Tensor, Conditioning)> = (0..8usize)
            .map(|i| {
                let cond = Conditioning {
                    class: Some(i % 4),
                    guidance: (i % 2 == 0).then_some(2.0),
                };
                (Rng::seed_from(500 + i as u64).normal_tensor(&[1, gm.dim]), cond)
            })
            .collect();
        // Stack in conditioning order, as the worker does before coalescing.
        members.sort_by_key(|(_, c)| c.order_key());
        let slabs = CondSlab::coalesce(members.iter().map(|(x, c)| (x.shape()[0], *c)));
        assert_eq!(slabs.len(), 4, "8 members over 4 distinct conditionings");
        let refs: Vec<&Tensor> = members.iter().map(|(x, _)| x).collect();
        let mut bw = BatchWorkspace::new();
        let cohort = CohortModel::new(&backend, &sched, slabs.clone());
        let mixed = bench(
            &mut results,
            "L3-g mixed-cond batched b=8 UniPC-3 x8 (gmm)",
            500,
            || {
                black_box(sample_batch_with_plan(
                    &cohort, &sched, &refs, &opts, &plan, &mut bw,
                ));
            },
        );
        let split = bench(
            &mut results,
            "L3-g cond-split cohorts 4x2 UniPC-3 x8 (gmm)",
            500,
            || {
                for slab in &slabs {
                    let solo = CohortModel::solo(&backend, &sched, slab.cond, slab.rows);
                    let group = &refs[slab.start..slab.start + slab.rows];
                    black_box(sample_batch_with_plan(
                        &solo, &sched, group, &opts, &plan, &mut bw,
                    ));
                }
            },
        );
        println!(
            "{:<48} {:>11.2}x",
            "L3-g   mixed cohort vs cond-split",
            split.as_secs_f64() / mixed.as_secs_f64()
        );
    }

    // L3-h: tracing overhead on the batched hot path (PR 9). The "trace
    // on" row reproduces the worker's steady state at trace=steps: wrap
    // the model in TimedModel, reserve + fill a reusable scratch vec with
    // the cohort lifecycle events and a model_eval/solver_step pair per
    // planned step via StepSpans, then flush once into a shard ring. The
    // delta vs the bare L3-e-shaped run is the full cost of tracing, and
    // the invariant EXPERIMENTS.md tracks is that it stays under ~2%.
    {
        let opts = unipc3_opts(UniPcCoeffs::Bh(BFunction::Bh2), 8);
        let plan = SamplePlan::build(&sched, &opts).expect("plannable");
        let members = 8usize;
        let inits: Vec<Tensor> = (0..members)
            .map(|i| Rng::seed_from(600 + i as u64).normal_tensor(&[1, gm.dim]))
            .collect();
        let refs: Vec<&Tensor> = inits.iter().collect();
        let mut bw = BatchWorkspace::new();
        let off = bench(
            &mut results,
            "L3-h batched b=8 UniPC-3 x8 trace=off (gmm)",
            500,
            || {
                black_box(sample_batch_with_plan(
                    &gmm_model, &sched, &refs, &opts, &plan, &mut bw,
                ));
            },
        );
        // Long-lived per-shard state: ring + scratch survive across batch
        // runs, exactly as in the worker loop.
        let mut ring = TraceRing::new(4096);
        let mut spans: Vec<SpanEvent> = Vec::new();
        let epoch = Instant::now();
        let on = bench(
            &mut results,
            "L3-h batched b=8 UniPC-3 x8 trace=steps (gmm)",
            500,
            || {
                spans.clear();
                spans.reserve(2 * plan.len() + 3 * members + 2);
                spans.push(SpanEvent {
                    trace_id: 1,
                    stage: Stage::Assemble,
                    a: members as u64,
                    b: 1,
                    ..Default::default()
                });
                for i in 0..members {
                    spans.push(SpanEvent {
                        trace_id: 2 + i as u64,
                        parent: 1,
                        stage: Stage::CohortLink,
                        a: i as u64,
                        b: 1,
                        ..Default::default()
                    });
                }
                let timed = TimedModel::new(&gmm_model);
                {
                    let mut obs =
                        StepSpans::new(&mut spans, &timed, epoch, 1, 0, 0, members as u64);
                    black_box(sample_batch_with_plan_observed(
                        &timed,
                        &sched,
                        &refs,
                        &opts,
                        &plan,
                        &mut bw,
                        Some(&mut obs),
                    ));
                }
                for i in 0..members {
                    spans.push(SpanEvent {
                        trace_id: 2 + i as u64,
                        stage: Stage::Respond,
                        b: 8,
                        ..Default::default()
                    });
                }
                ring.record_all(&spans);
            },
        );
        println!(
            "{:<48} {:>10.2}%",
            "L3-h   tracing overhead (steps vs off)",
            100.0 * (on.as_secs_f64() / off.as_secs_f64() - 1.0)
        );

        // L3-i: the full telemetry plane on the same cohort — everything
        // the L3-h traced row does, plus the PR-10 signals the worker adds
        // in steady state: HealthSpans accumulating the per-step corrector
        // delta + finiteness, a Metrics record set (windowed slot updates
        // at a fixed now_s, batch/health/completion records), and an
        // EventHub publish with no subscriber (one relaxed atomic load).
        // The tracked invariant matches L3-h: under ~2% over the bare run.
        use unipc::coordinator::Metrics;
        use unipc::telemetry::{EventHub, HealthAccum, HealthSpans};
        let mut metrics = Metrics::default();
        let hub = EventHub::new();
        let mut health = HealthAccum::default();
        let mut iter = 0u64;
        let full = bench(
            &mut results,
            "L3-i batched b=8 UniPC-3 x8 telemetry=full (gmm)",
            500,
            || {
                // Advance one second per iteration so slot recycling (the
                // steady-state path, not first-touch zeroing) is measured.
                iter += 1;
                let now_s = iter;
                spans.clear();
                spans.reserve(2 * plan.len() + 3 * members + 2);
                spans.push(SpanEvent {
                    trace_id: 1,
                    stage: Stage::Assemble,
                    a: members as u64,
                    b: 1,
                    ..Default::default()
                });
                for i in 0..members {
                    spans.push(SpanEvent {
                        trace_id: 2 + i as u64,
                        parent: 1,
                        stage: Stage::CohortLink,
                        a: i as u64,
                        b: 1,
                        ..Default::default()
                    });
                }
                let timed = TimedModel::new(&gmm_model);
                health.reset();
                {
                    let mut obs = HealthSpans {
                        spans: Some(StepSpans::new(
                            &mut spans,
                            &timed,
                            epoch,
                            1,
                            0,
                            0,
                            members as u64,
                        )),
                        accum: &mut health,
                    };
                    black_box(sample_batch_with_plan_observed(
                        &timed,
                        &sched,
                        &refs,
                        &opts,
                        &plan,
                        &mut bw,
                        Some(&mut obs),
                    ));
                }
                for i in 0..members {
                    spans.push(SpanEvent {
                        trace_id: 2 + i as u64,
                        stage: Stage::Respond,
                        b: 8,
                        ..Default::default()
                    });
                }
                metrics.record_batch(now_s, members, 1, members as u64);
                metrics.record_health(health.mean_delta(), health.first_nonfinite);
                for i in 0..members {
                    metrics.record_completion(
                        now_s,
                        1,
                        8,
                        Duration::from_micros(50),
                        Duration::from_micros(400),
                        Duration::from_micros(300),
                        2 + i as u64,
                    );
                }
                ring.record_all(&spans);
                hub.publish_spans(&spans);
                black_box(hub.dropped());
            },
        );
        println!(
            "{:<48} {:>10.2}%",
            "L3-i   telemetry overhead (full vs bare)",
            100.0 * (full.as_secs_f64() / off.as_secs_f64() - 1.0)
        );
        // Paranoia: the no-subscriber publish really took the fast path.
        assert_eq!(hub.dropped(), 0, "no subscriber, nothing to drop");
        assert!(metrics.completed > 0, "telemetry rows must have recorded");
    }

    // L3-f: the plan compiler generalized to the whole zoo — naive
    // (reference loop, per-step coefficient math) vs plan-cached execution
    // for the DPM-Solver++ multistep and DEIS families. DEIS is the
    // headline: the reference loop pays a 16-point Gauss–Legendre kernel
    // quadrature per step, which the plan hoists to build time entirely.
    {
        let baselines: [(&str, Method); 4] = [
            ("dpmpp-2m", Method::DpmSolverPp { order: 2 }),
            ("dpmpp-3m", Method::DpmSolverPp { order: 3 }),
            ("deis-2", Method::Deis { order: 2 }),
            ("deis-3", Method::Deis { order: 3 }),
        ];
        for (tag, method) in baselines {
            let opts = SampleOptions::new(method, 8);
            let naive = bench(
                &mut results,
                &format!("L3-f {tag} x8 naive (gmm 64x16)"),
                200,
                || {
                    black_box(sample_unplanned(&gmm_model, &sched, &x_t, &opts));
                },
            );
            let plan = SamplePlan::build(&sched, &opts).expect("plannable");
            let planned = bench(
                &mut results,
                &format!("L3-f {tag} x8 plan-cached (gmm)"),
                200,
                || {
                    black_box(sample_with_plan(&gmm_model, &sched, &x_t, &opts, &plan));
                },
            );
            println!(
                "{:<48} {:>11.2}x",
                format!("L3-f   speedup vs naive ({tag})"),
                naive.as_secs_f64() / planned.as_secs_f64()
            );
        }
    }

    // L3-d: one-time plan-construction cost (what the coordinator's cache
    // amortizes across requests).
    for (tag, variant) in
        [("bh2", UniPcCoeffs::Bh(BFunction::Bh2)), ("vary", UniPcCoeffs::Varying)]
    {
        let opts = unipc3_opts(variant, 8);
        bench(
            &mut results,
            &format!("L3-d SamplePlan::build UniPC-3 x8 ({tag})"),
            5_000,
            || {
                black_box(SamplePlan::build(&sched, &opts));
            },
        );
    }

    emit_json(&results);

    // RT: PJRT path (requires artifacts).
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() || !dir.join("model.upw").exists() {
        println!("RT-*: artifacts missing — run `make artifacts` (skipped)");
        return;
    }
    let h = PjrtHandle::spawn(&dir, None, EngineOptions::default()).unwrap();
    let dim = h.dim;
    for rows in [1usize, 4, 16, 64] {
        let x = vec![0.1f32; rows * dim];
        let t = vec![0.5f32; rows];
        let y = vec![0i32; rows];
        let per = bench(&mut results, &format!("RT-a pjrt eps rows={rows}"), 50, || {
            black_box(h.eps(x.clone(), t.clone(), y.clone()).unwrap());
        });
        println!(
            "{:<48} {:>12.2?}/row",
            format!("RT-a   per-row at rows={rows}"),
            per / rows as u32
        );
    }

    // RT-b: fused correct vs eval + host combination.
    let rows = 16usize;
    let x_pred = vec![0.1f32; rows * dim];
    let t = vec![0.5f32; rows];
    let y = vec![0i32; rows];
    let x_prev = vec![0.2f32; rows * dim];
    let m0 = vec![0.0f32; rows * dim];
    let d1s = vec![0.05f32; 3 * rows * dim];
    let coeffs = vec![0.2f32, -0.1, 0.05, 0.3, 1.1, -0.4, 0.9];
    bench(&mut results, "RT-b fused correct (rows=16)", 50, || {
        black_box(
            h.fused_correct(
                x_pred.clone(),
                t.clone(),
                y.clone(),
                x_prev.clone(),
                m0.clone(),
                d1s.clone(),
                coeffs.clone(),
            )
            .unwrap(),
        );
    });
    bench(&mut results, "RT-b eval + host update (rows=16)", 50, || {
        let m_t = h.eps(x_pred.clone(), t.clone(), y.clone()).unwrap();
        // Host-side combination (what the fused artifact replaces).
        let mut out = vec![0.0f32; rows * dim];
        for i in 0..rows * dim {
            let mut res = 0.0f32;
            for pl in 0..3 {
                res += coeffs[pl] * d1s[pl * rows * dim + i];
            }
            res += coeffs[3] * (m_t[i] - m0[i]);
            out[i] = coeffs[4] * x_prev[i] + coeffs[5] * m0[i] + coeffs[6] * res;
        }
        black_box(out);
    });
    h.shutdown();

    // Re-emit with the RT rows included.
    emit_json(&results);
}
