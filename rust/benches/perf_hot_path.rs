//! Hot-path microbenchmarks for the perf pass (EXPERIMENTS.md §Perf):
//!   L3-a  solver arithmetic per step (weighted_sum fusion vs naive axpy)
//!   L3-b  coefficient solve (Vandermonde) cost per step
//!   L3-c  full UniPC-3 step on an analytic model (batch 64, dim 16)
//!   RT-a  PJRT ε call latency vs batch size (batching amortization)
//!   RT-b  fused correct artifact vs eval + host update (round-trip saving)

use std::hint::black_box;
use std::path::Path;
use std::time::{Duration, Instant};

use unipc::analytic::datasets::{dataset, DatasetSpec};
use unipc::analytic::GmmModel;
use unipc::numerics::vandermonde::{unipc_coeffs, BFunction};
use unipc::rng::Rng;
use unipc::runtime::{EngineOptions, PjrtHandle};
use unipc::sched::VpLinear;
use unipc::solver::{sample, SampleOptions, Prediction};
use unipc::tensor::{weighted_sum, Tensor};

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> Duration {
    // Warmup.
    for _ in 0..3 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed() / iters as u32;
    println!("{name:<44} {per:>12.2?}/iter  ({iters} iters)");
    per
}

fn main() {
    let mut rng = Rng::seed_from(1);
    let (b, d, p) = (64usize, 16usize, 3usize);
    let tensors: Vec<Tensor> = (0..p).map(|_| rng.normal_tensor(&[b, d])).collect();
    let coeffs = [0.4, -0.2, 0.1];

    // L3-a: fused weighted sum vs naive repeated axpy.
    bench("L3-a weighted_sum fused (64x16, p=3)", 20_000, || {
        let refs: Vec<&Tensor> = tensors.iter().collect();
        black_box(weighted_sum(&coeffs, &refs));
    });
    bench("L3-a naive axpy chain   (64x16, p=3)", 20_000, || {
        let mut acc = tensors[0].scaled(coeffs[0]);
        for i in 1..p {
            acc.axpy(coeffs[i], &tensors[i]);
        }
        black_box(acc);
    });

    // L3-b: coefficient solve.
    bench("L3-b unipc_coeffs p=3", 100_000, || {
        black_box(unipc_coeffs(&[-2.0, -1.0, 1.0], black_box(0.3), BFunction::Bh2));
    });
    bench("L3-b unipc_coeffs p=6", 50_000, || {
        black_box(unipc_coeffs(
            &[-5.0, -4.0, -3.0, -2.0, -1.0, 1.0],
            black_box(0.3),
            BFunction::Bh2,
        ));
    });

    // L3-c: a full 8-step UniPC-3 sampling run on the analytic model.
    let gm = dataset(DatasetSpec::Cifar10Like);
    let sched = VpLinear::default();
    let model = GmmModel { gm: &gm, sched: &sched };
    let x_t = rng.normal_tensor(&[b, d]);
    let opts = SampleOptions::unipc(3, BFunction::Bh2, Prediction::Noise, 8);
    bench("L3-c UniPC-3 x8 steps, analytic (64x16)", 200, || {
        black_box(sample(&model, &sched, &x_t, &opts));
    });

    // RT: PJRT path (requires artifacts).
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() || !dir.join("model.upw").exists() {
        println!("RT-*: artifacts missing — run `make artifacts` (skipped)");
        return;
    }
    let h = PjrtHandle::spawn(&dir, None, EngineOptions::default()).unwrap();
    let dim = h.dim;
    for rows in [1usize, 4, 16, 64] {
        let x = vec![0.1f32; rows * dim];
        let t = vec![0.5f32; rows];
        let y = vec![0i32; rows];
        let per = bench(&format!("RT-a pjrt eps rows={rows}"), 50, || {
            black_box(h.eps(x.clone(), t.clone(), y.clone()).unwrap());
        });
        println!("{:<44} {:>12.2?}/row", format!("RT-a   per-row at rows={rows}"), per / rows as u32);
    }

    // RT-b: fused correct vs eval + host combination.
    let rows = 16usize;
    let x_pred = vec![0.1f32; rows * dim];
    let t = vec![0.5f32; rows];
    let y = vec![0i32; rows];
    let x_prev = vec![0.2f32; rows * dim];
    let m0 = vec![0.0f32; rows * dim];
    let d1s = vec![0.05f32; 3 * rows * dim];
    let coeffs = vec![0.2f32, -0.1, 0.05, 0.3, 1.1, -0.4, 0.9];
    bench("RT-b fused correct (rows=16)", 50, || {
        black_box(
            h.fused_correct(
                x_pred.clone(),
                t.clone(),
                y.clone(),
                x_prev.clone(),
                m0.clone(),
                d1s.clone(),
                coeffs.clone(),
            )
            .unwrap(),
        );
    });
    bench("RT-b eval + host update (rows=16)", 50, || {
        let m_t = h.eps(x_pred.clone(), t.clone(), y.clone()).unwrap();
        // Host-side combination (what the fused artifact replaces).
        let mut out = vec![0.0f32; rows * dim];
        for i in 0..rows * dim {
            let mut res = 0.0f32;
            for pl in 0..3 {
                res += coeffs[pl] * d1s[pl * rows * dim + i];
            }
            res += coeffs[3] * (m_t[i] - m0[i]);
            out[i] = coeffs[4] * x_prev[i] + coeffs[5] * m0[i] + coeffs[6] * res;
        }
        black_box(out);
    });
    h.shutdown();
}
