//! Figure 3 reproduction: unconditional sampling quality vs NFE on the
//! three unconditional benchmarks (CIFAR10 / LSUN Bedroom / FFHQ stand-ins,
//! DESIGN.md §2). Series: DDIM, DPM-Solver++(3M), UniPC-3 (B₂) — the same
//! three the figure plots. Metric: mean ‖x₀ − x₀*‖₂/√D to the RK4 reference
//! (the discretization error FID proxies), plus a Fréchet column at the
//! extremes.
//!
//! Expected shape (paper): UniPC < DPM-Solver++ < DDIM at every NFE, with
//! the gap largest at 5–6 NFE.

use unipc::analytic::datasets::{dataset, DatasetSpec};
use unipc::analytic::GmmModel;
use unipc::evalharness::{gen_samples, quality, RefErr, ResultTable};
use unipc::numerics::vandermonde::BFunction;
use unipc::sched::VpLinear;
use unipc::solver::{Method, Prediction, SampleOptions};

fn main() {
    let nfes = [5usize, 6, 7, 8, 9, 10];
    for spec in [DatasetSpec::Cifar10Like, DatasetSpec::BedroomLike, DatasetSpec::FfhqLike] {
        let gm = dataset(spec);
        let sched = VpLinear::default();
        let model = GmmModel { gm: &gm, sched: &sched };
        let re = RefErr::new(&model, &sched, 16, 42, 1.0, 1e-3, 3000);

        let methods: Vec<(&str, Box<dyn Fn(usize) -> SampleOptions>)> = vec![
            (
                "DDIM",
                Box::new(|s| SampleOptions::new(Method::Ddim { pred: Prediction::Noise }, s)),
            ),
            (
                "DPM-Solver++(3M)",
                Box::new(|s| SampleOptions::new(Method::DpmSolverPp { order: 3 }, s)),
            ),
            (
                "UniPC-3 (ours)",
                Box::new(|s| SampleOptions::unipc(3, BFunction::Bh2, Prediction::Noise, s)),
            ),
        ];

        let mut table = ResultTable::new(
            &format!("Fig.3 {} — l2 to reference (lower = better FID proxy)", spec.name()),
            &nfes,
        );
        for (label, mk) in &methods {
            let vals = nfes.iter().map(|&n| re.err(&model, &sched, &mk(n))).collect();
            table.push(label, vals);
        }
        table.emit(&format!("fig3_{}.json", spec.name()));

        // Fréchet spot-check at the extremes (population-level quality).
        let mut fr = ResultTable::new(
            &format!("Fig.3 {} — Fréchet distance (data space)", spec.name()),
            &[5, 10],
        );
        for (label, mk) in &methods {
            let vals = [5usize, 10]
                .iter()
                .map(|&n| {
                    let (s, _) = gen_samples(&model, &sched, &mk(n), 1024, 7, 64);
                    quality(&gm, &s, 7).0
                })
                .collect();
            fr.push(label, vals);
        }
        fr.emit(&format!("fig3_frechet_{}.json", spec.name()));

        // The paper's headline shape must hold.
        for &n in &nfes {
            assert_eq!(
                table.winner(n),
                Some("UniPC-3 (ours)"),
                "UniPC should win at NFE={n} on {}",
                spec.name()
            );
        }
    }
}
