//! Table 4 reproduction: customizing the order schedule. UniPC with
//! per-step predictor orders on the CIFAR10-like benchmark at NFE 6 and 7
//! (the actual accuracy order is +1 from UniC, as in the paper).
//!
//! Expected shape (paper): a tuned schedule (123432 at NFE 6, 1223334 at 7)
//! beats the default ascending-then-capped one, and the max-order schedule
//! (123456 / 1234567) is clearly *harmful*.

use unipc::analytic::datasets::{dataset, DatasetSpec};
use unipc::analytic::GmmModel;
use unipc::evalharness::{RefErr, ResultTable};
use unipc::numerics::vandermonde::BFunction;
use unipc::sched::VpLinear;
use unipc::solver::unipc::CoeffVariant;
use unipc::solver::{Method, Prediction, SampleOptions};

fn run(re: &RefErr, model: &GmmModel, sched: &VpLinear, schedule: &[usize]) -> f64 {
    let steps = schedule.len();
    let max = *schedule.iter().max().unwrap();
    let opts = SampleOptions::new(
        Method::UniP {
            order: max,
            variant: CoeffVariant::Bh(BFunction::Bh1),
            pred: Prediction::Noise,
            schedule: Some(schedule.to_vec()),
        },
        steps,
    )
    .with_unic(CoeffVariant::Bh(BFunction::Bh1), false);
    re.err(model, sched, &opts)
}

fn main() {
    let gm = dataset(DatasetSpec::Cifar10Like);
    let sched = VpLinear::default();
    let model = GmmModel { gm: &gm, sched: &sched };
    let re = RefErr::new(&model, &sched, 16, 42, 1.0, 1e-3, 3000);

    let grids: Vec<(usize, Vec<(&str, Vec<usize>)>)> = vec![
        (
            6,
            vec![
                ("123321", vec![1, 2, 3, 3, 2, 1]),
                ("123432", vec![1, 2, 3, 4, 3, 2]),
                ("123443", vec![1, 2, 3, 4, 4, 3]),
                ("123456", vec![1, 2, 3, 4, 5, 6]),
                ("123333 (default)", vec![1, 2, 3, 3, 3, 3]),
            ],
        ),
        (
            7,
            vec![
                ("1233321", vec![1, 2, 3, 3, 3, 2, 1]),
                ("1223334", vec![1, 2, 2, 3, 3, 3, 4]),
                ("1234321", vec![1, 2, 3, 4, 3, 2, 1]),
                ("1234567", vec![1, 2, 3, 4, 5, 6, 7]),
                ("1233333 (default)", vec![1, 2, 3, 3, 3, 3, 3]),
            ],
        ),
    ];

    for (nfe, rows) in grids {
        let mut table = ResultTable::new(
            &format!("Table 4 cifar10-like — order schedules at NFE={nfe} (l2 to ref)"),
            &[nfe],
        );
        let mut max_order_err = 0.0;
        let mut best_other = f64::INFINITY;
        for (label, schedule) in &rows {
            let e = run(&re, &model, &sched, schedule);
            if label.starts_with(&"1234567"[..nfe.min(7)]) && schedule.windows(2).all(|w| w[1] == w[0] + 1)
            {
                max_order_err = e;
            } else {
                best_other = best_other.min(e);
            }
            table.push(label, vec![e]);
        }
        table.emit(&format!("table4_nfe{nfe}.json"));
        assert!(
            max_order_err > best_other,
            "max-order schedule must be harmful (paper): {max_order_err} vs {best_other}"
        );
    }
}
