//! Figure 4c reproduction: convergence error of classifier-free-guided
//! sampling against the **trained latent model** served through PJRT —
//! ‖x₀ − x₀*‖₂/√D where x₀* is 999-step DDIM from the same x_T (exactly the
//! paper's metric, guidance scale 1.5 as in stable-diffusion).
//!
//! Skipped (with a notice) when `make artifacts` hasn't run.
//!
//! Expected shape (paper): UniPC < DPM-Solver++ < DDIM at 5–10 NFE.

use std::path::Path;

use unipc::evalharness::{RefErr, ResultTable};
use unipc::numerics::vandermonde::BFunction;
use unipc::rng::Rng;
use unipc::runtime::{EngineOptions, PjrtHandle, PjrtModel};
use unipc::sched::VpLinear;
use unipc::solver::{sample, DynamicThresholding, Method, Model, Prediction, SampleOptions};

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() || !dir.join("model.upw").exists() {
        println!("fig4c: artifacts missing — run `make artifacts` first (skipped)");
        return;
    }
    let handle = PjrtHandle::spawn(&dir, None, EngineOptions::default()).expect("spawn pjrt");
    let model = PjrtModel::new(handle.clone()).with_class(2, Some(1.5));
    let sched = VpLinear::default();

    // Ground truth: 999-step DDIM from shared x_T (the paper's choice).
    let n_traj = 4;
    let mut rng = Rng::seed_from(31);
    let x_t = rng.normal_tensor(&[n_traj, model.dim()]);
    let truth = sample(
        &model,
        &sched,
        &x_t,
        &SampleOptions::new(Method::Ddim { pred: Prediction::Noise }, 999),
    )
    .x;
    let re = RefErr::with_truth(x_t, truth);

    let nfes = [5usize, 6, 7, 8, 9, 10];
    let rows: Vec<(&str, Box<dyn Fn(usize) -> SampleOptions>)> = vec![
        (
            "DDIM",
            Box::new(|s| SampleOptions::new(Method::Ddim { pred: Prediction::Noise }, s)),
        ),
        (
            "DPM-Solver++(2M)",
            Box::new(|s| {
                let mut o = SampleOptions::new(Method::DpmSolverPp { order: 2 }, s);
                o.thresholding = Some(DynamicThresholding::clip(6.0));
                o
            }),
        ),
        (
            "UniPC-2 (ours)",
            Box::new(|s| {
                // Data prediction + thresholding-clip: the paper's guided-
                // sampling configuration (§3.4/Appendix A); noise-pred
                // high-order solvers blow up on learned nets under guidance
                // (train-test mismatch), which this bench demonstrates if
                // you flip the parametrization back.
                let mut o = SampleOptions::unipc(2, BFunction::Bh2, Prediction::Data, s);
                o.thresholding = Some(DynamicThresholding::clip(6.0));
                o
            }),
        ),
    ];

    let mut table = ResultTable::new(
        "Fig.4c trained model (PJRT), CFG 1.5 — l2 to 999-step DDIM",
        &nfes,
    );
    for (label, mk) in &rows {
        table.push(label, nfes.iter().map(|&n| re.err(&model, &sched, &mk(n))).collect());
    }
    table.emit("fig4c_trained.json");
    handle.shutdown();

    // Shape: UniPC beats DPM-Solver++ (its high-order rival) at every NFE
    // and takes the lead as the budget grows; the 999-step-DDIM truth makes
    // the DDIM row favorable at the smallest budgets on this tiny model.
    for (i, &n) in nfes.iter().enumerate() {
        assert!(
            table.rows[2].1[i] < table.rows[1].1[i],
            "UniPC must beat DPM-Solver++(2M) at NFE={n}"
        );
    }
    assert_eq!(table.winner(10), Some("UniPC-2 (ours)"), "UniPC must win at NFE=10");
}
