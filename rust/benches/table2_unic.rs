//! Table 2 reproduction: UniC as a plug-in corrector for *any* solver.
//! Base solvers: DDIM (1, singlestep view), DPM-Solver++(2M), (3S), (3M);
//! each with and without UniC. CIFAR10-like benchmark, NFE ∈ {5, 6, 8, 10}.
//!
//! Expected shape (paper): "+UniC" improves every base solver at every NFE,
//! and multistep bases beat singlestep at these budgets.

use unipc::analytic::datasets::{dataset, DatasetSpec};
use unipc::analytic::GmmModel;
use unipc::evalharness::{RefErr, ResultTable};
use unipc::numerics::vandermonde::BFunction;
use unipc::sched::VpLinear;
use unipc::solver::unipc::CoeffVariant;
use unipc::solver::{Method, Prediction, SampleOptions};

fn main() {
    let nfes = [5usize, 6, 8, 10];
    let gm = dataset(DatasetSpec::Cifar10Like);
    let sched = VpLinear::default();
    let model = GmmModel { gm: &gm, sched: &sched };
    let re = RefErr::new(&model, &sched, 16, 42, 1.0, 1e-3, 3000);

    let bases: Vec<(&str, Method)> = vec![
        ("DDIM (data-pred)", Method::Ddim { pred: Prediction::Data }),
        ("DPM-Solver++(2M)", Method::DpmSolverPp { order: 2 }),
        ("DPM-Solver++(3S)", Method::DpmSolverPp3S),
        ("DPM-Solver++(3M)", Method::DpmSolverPp { order: 3 }),
    ];

    let mut table = ResultTable::new(
        "Table 2 cifar10-like — UniC on any solver (l2 to reference)",
        &nfes,
    );
    for (label, method) in &bases {
        let plain: Vec<f64> = nfes
            .iter()
            .map(|&n| re.err(&model, &sched, &SampleOptions::new(method.clone(), n)))
            .collect();
        let corrected: Vec<f64> = nfes
            .iter()
            .map(|&n| {
                let opts = SampleOptions::new(method.clone(), n)
                    .with_unic(CoeffVariant::Bh(BFunction::Bh2), false);
                re.err(&model, &sched, &opts)
            })
            .collect();
        table.push(label, plain);
        table.push(&format!("{label} +UniC"), corrected);
    }
    table.emit("table2_unic.json");

    // Shape check: the corrector helps each base at small NFE.
    for pair in table.rows.chunks(2) {
        let (base, plus) = (&pair[0], &pair[1]);
        let improved = base
            .1
            .iter()
            .zip(&plus.1)
            .filter(|(b, p)| p < b)
            .count();
        assert!(
            improved >= 2,
            "{}: +UniC should improve at least half the NFE budgets ({:?} -> {:?})",
            base.0,
            base.1,
            plus.1
        );
    }
}
