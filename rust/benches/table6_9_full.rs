//! Appendix Tables 6–8 reproduction: the full unconditional grids,
//! including DPM-Solver-3 (singlestep), UniPC_v (varying coefficients), and
//! the "+UniC" rows, at NFE 5–10 on all three unconditional stand-ins.
//!
//! Expected shape (paper): singlestep DPM-Solver-3 is erratic at 5–7 NFE;
//! UniPC variants lead; UniPC_v is competitive in the mid-NFE range.

use unipc::analytic::datasets::{dataset, DatasetSpec};
use unipc::analytic::GmmModel;
use unipc::evalharness::{RefErr, ResultTable};
use unipc::numerics::vandermonde::BFunction;
use unipc::sched::VpLinear;
use unipc::solver::unipc::CoeffVariant;
use unipc::solver::{Method, Prediction, SampleOptions};

fn main() {
    let nfes = [5usize, 6, 7, 8, 9, 10];
    for spec in [DatasetSpec::Cifar10Like, DatasetSpec::FfhqLike, DatasetSpec::BedroomLike] {
        let gm = dataset(spec);
        let sched = VpLinear::default();
        let model = GmmModel { gm: &gm, sched: &sched };
        let re = RefErr::new(&model, &sched, 16, 42, 1.0, 1e-3, 3000);

        let rows: Vec<(&str, Box<dyn Fn(usize) -> SampleOptions>)> = vec![
            (
                "DDIM",
                Box::new(|s| SampleOptions::new(Method::Ddim { pred: Prediction::Data }, s)),
            ),
            (
                "DDIM +UniC-1",
                Box::new(|s| {
                    SampleOptions::new(Method::Ddim { pred: Prediction::Data }, s)
                        .with_unic(CoeffVariant::Bh(BFunction::Bh2), false)
                }),
            ),
            (
                "DPM-Solver-3 (single)",
                Box::new(|s| SampleOptions::new(Method::DpmSolverSingle { order: 3 }, s)),
            ),
            (
                "DPM-Solver++(2M)",
                Box::new(|s| SampleOptions::new(Method::DpmSolverPp { order: 2 }, s)),
            ),
            (
                "DPM-Solver++(2M) +UniC",
                Box::new(|s| {
                    SampleOptions::new(Method::DpmSolverPp { order: 2 }, s)
                        .with_unic(CoeffVariant::Bh(BFunction::Bh2), false)
                }),
            ),
            (
                "DPM-Solver++(3M)",
                Box::new(|s| SampleOptions::new(Method::DpmSolverPp { order: 3 }, s)),
            ),
            (
                "DPM-Solver++(3M) +UniC",
                Box::new(|s| {
                    SampleOptions::new(Method::DpmSolverPp { order: 3 }, s)
                        .with_unic(CoeffVariant::Bh(BFunction::Bh2), false)
                }),
            ),
            (
                "UniPC-3-B1",
                Box::new(|s| SampleOptions::unipc(3, BFunction::Bh1, Prediction::Noise, s)),
            ),
            (
                "UniPC-3-B2",
                Box::new(|s| SampleOptions::unipc(3, BFunction::Bh2, Prediction::Noise, s)),
            ),
            (
                "UniPC_v-3",
                Box::new(|s| {
                    SampleOptions::new(
                        Method::UniP {
                            order: 3,
                            variant: CoeffVariant::Varying,
                            pred: Prediction::Noise,
                            schedule: None,
                        },
                        s,
                    )
                    .with_unic(CoeffVariant::Varying, false)
                }),
            ),
        ];

        let mut table = ResultTable::new(
            &format!("Tables 6-8 {} — full grid (l2 to reference)", spec.name()),
            &nfes,
        );
        for (label, mk) in &rows {
            table.push(label, nfes.iter().map(|&n| re.err(&model, &sched, &mk(n))).collect());
        }
        table.emit(&format!("table6_9_{}.json", spec.name()));

        // Shape: UniPC-3 must beat its direct rival DPM-Solver++(3M) at
        // every NFE (single-cell table winners can flip on estimator luck —
        // e.g. DPM-Solver++(2M)'s non-monotone NFE=5 cell).
        let dpmpp3m = &table.rows[5].1;
        let unipc3 = &table.rows[8].1;
        for (i, &n) in nfes.iter().enumerate() {
            assert!(
                unipc3[i] < dpmpp3m[i],
                "UniPC-3-B2 must beat DPM-Solver++(3M) at NFE={n}"
            );
        }
        // Paper Table 6 at NFE 10 has UniPC-B2 (3.87) and 3M+UniC (3.90)
        // essentially tied — accept any corrector-bearing winner.
        let w10 = table.winner(10).unwrap();
        assert!(
            w10.contains("UniPC") || w10.contains("UniC"),
            "expected a UniC-corrected method to win NFE=10, got {w10}"
        );
    }
}
