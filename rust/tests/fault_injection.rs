//! Chaos suite: fault injection against the full serving stack.
//!
//! [`ModelBackend::Chaos`] injects panics, NaN output rows, and latency
//! spikes on a seeded deterministic schedule. These tests prove the serving
//! invariants the fault-tolerant layer guarantees:
//!
//! * every well-formed request gets **exactly one** typed response —
//!   no hung receivers, no duplicates, no untyped errors;
//! * requests whose evaluations were fault-free produce output
//!   **bit-identical** to a clean (chaos-free) run, even when cohort
//!   members in the same lockstep batch panicked or NaN'd;
//! * the worker pool **never shrinks**: panicked workers retire and the
//!   supervisor respawns replacements (`worker_restarts`);
//! * expired jobs are **shed, not executed**, with typed
//!   `deadline_exceeded` responses, and shutdown drains or sheds every
//!   queued job so no receiver is left hanging;
//! * faults are **member-local**: in a mixed-conditioning cohort, chaos
//!   aimed at one conditioning (via `ChaosConfig::target_class`) fails only
//!   the targeted members — NaN'd rows quarantine individually, a mid-batch
//!   panic re-runs everyone solo — and the survivors stay bit-identical;
//! * faults are **shard-local**: chaos pinned to requests on one
//!   coordinator shard (shards split by *plan key* — conditioning no longer
//!   routes, so the tests split shards by step count and aim the chaos at a
//!   class carried only by that shard's requests) cannot stall, corrupt, or
//!   shrink the worker sub-pools of the others, and the multi-shard service
//!   keeps the same deadline/shutdown bounds as a single queue.

use std::sync::Arc;
use std::time::{Duration, Instant};

use unipc::analytic::datasets::{dataset, DatasetSpec};
use unipc::config::ServerConfig;
use unipc::coordinator::{
    silence_injected_panics, ChaosConfig, FailureKind, ModelBackend, SampleRequest, Service,
};

fn analytic_backend() -> ModelBackend {
    let spec = DatasetSpec::Cifar10Like;
    let gm = Arc::new(dataset(spec));
    let classes = (0..spec.n_classes()).map(|c| spec.class_components(c)).collect();
    ModelBackend::Analytic { gm, class_components: Arc::new(classes) }
}

fn chaos_backend(seed: u64, panic_rate: f64, nan_rate: f64) -> ModelBackend {
    ModelBackend::chaos(
        analytic_backend(),
        ChaosConfig {
            seed,
            panic_rate,
            nan_rate,
            latency_rate: 0.05,
            latency_us: 200,
            ..ChaosConfig::default()
        },
    )
}

/// Under injected faults, every request resolves to exactly one typed
/// response; fault-free requests are bit-identical to a clean run; the
/// pool self-heals after panics.
#[test]
fn chaos_typed_responses_bit_identical_and_pool_survives() {
    silence_injected_panics();
    const N: usize = 60;
    let mk_req = |seed: u64| SampleRequest { n: 1, steps: 8, seed, ..Default::default() };

    // Reference outputs from a fault-free service.
    let clean = Service::start(
        ServerConfig { workers: 2, queue_cap: 64, ..Default::default() },
        analytic_backend(),
    );
    let refs: Vec<Vec<f64>> = (0..N as u64)
        .map(|s| {
            let r = clean.sample_blocking(mk_req(s));
            assert!(r.ok, "clean run must succeed: {:?}", r.error);
            r.samples.unwrap()
        })
        .collect();
    clean.shutdown();

    // The same workload through a chaos backend.
    let svc = Service::start(
        ServerConfig { workers: 2, queue_cap: 64, ..Default::default() },
        chaos_backend(3, 0.04, 0.04),
    );
    let mut oks = 0u64;
    let mut fails = 0u64;
    for s in 0..N as u64 {
        let r = svc.sample_blocking(mk_req(s));
        if r.ok {
            assert_eq!(r.kind, None);
            assert_eq!(
                r.samples.as_ref(),
                Some(&refs[s as usize]),
                "fault-free request {s} must be bit-identical to the clean run"
            );
            oks += 1;
        } else {
            assert!(r.kind.is_some(), "failures must be typed: {:?}", r.error);
            fails += 1;
        }
    }
    assert_eq!(oks + fails, N as u64, "exactly one response per request");
    assert!(oks > 0, "some requests must dodge the faults");
    assert!(fails > 0, "some requests must hit the faults");

    let m = svc.metrics_json();
    let counter = |key: &str| m.get(key).and_then(|v| v.as_f64()).unwrap();
    assert_eq!(counter("completed"), oks as f64);
    assert_eq!(counter("failed"), fails as f64);
    assert_eq!(
        counter("worker_panic") + counter("non_finite_output"),
        fails as f64,
        "every failure is a typed panic or non-finite outcome: {m:?}"
    );
    assert!(counter("worker_restarts") > 0.0, "panics must have retired workers: {m:?}");

    // The supervisor restored the pool; the service still serves.
    std::thread::sleep(Duration::from_millis(300));
    assert!(svc.workers_alive() >= 2, "pool must never shrink");
    for s in 0..10u64 {
        let r = svc.sample_blocking(mk_req(1000 + s));
        assert!(r.ok || r.kind.is_some());
    }
    svc.shutdown();
}

/// A fault inside a lockstep batch must not poison the cohort: NaN'd
/// members fail individually; a mid-batch panic re-runs every member solo;
/// surviving members stay bit-identical to a clean run and every receiver
/// gets exactly one response.
#[test]
fn batch_quarantine_protects_cohort_members() {
    silence_injected_panics();
    const BATCH: usize = 12;
    let mk_req = |seed: u64| SampleRequest { n: 2, steps: 6, seed, ..Default::default() };

    let clean = Service::start(
        ServerConfig { workers: 1, queue_cap: 256, ..Default::default() },
        analytic_backend(),
    );
    let refs: Vec<Vec<f64>> = (0..BATCH as u64)
        .map(|s| clean.sample_blocking(mk_req(s)).samples.unwrap())
        .collect();
    clean.shutdown();

    // One worker with a generous linger window, so concurrent submissions
    // coalesce into one lockstep batch that the chaos backend then faults.
    let svc = Service::start(
        ServerConfig {
            workers: 1,
            queue_cap: 256,
            batch_linger_us: 50_000,
            ..Default::default()
        },
        chaos_backend(17, 0.05, 0.05),
    );

    let mut total_ok = 0u64;
    let mut saw_fault_in_batch = false;
    for _round in 0..20 {
        let rxs: Vec<_> = (0..BATCH as u64).map(|s| svc.submit(mk_req(s)).unwrap()).collect();
        for (s, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(60)).expect("response must arrive");
            assert!(
                rx.try_recv().is_err(),
                "exactly one response per request (member {s})"
            );
            if resp.ok {
                assert_eq!(
                    resp.samples.as_ref(),
                    Some(&refs[s]),
                    "surviving member {s} must be bit-identical to the clean run"
                );
                total_ok += 1;
            } else {
                assert!(resp.kind.is_some(), "member failures must be typed");
            }
        }
        let m = svc.metrics_json();
        let counter = |key: &str| m.get(key).and_then(|v| v.as_f64()).unwrap();
        if (counter("quarantined_members") > 0.0 || counter("batch_retries") > 0.0)
            && total_ok > 0
        {
            saw_fault_in_batch = true;
            break;
        }
    }
    assert!(
        saw_fault_in_batch,
        "chaos must have faulted at least one lockstep batch with survivors: {:?}",
        svc.metrics_json()
    );
    svc.shutdown();
}

/// The four conditionings of the mixed-cohort chaos tests: unconditional,
/// classed, and guided members that the collapsed batch key stacks into one
/// lockstep run.
const MIXED_MEMBERS: [(Option<usize>, Option<f64>); 4] =
    [(None, None), (Some(1), None), (Some(4), Some(2.0)), (Some(2), Some(0.5))];

fn mixed_member_req(i: usize) -> SampleRequest {
    SampleRequest {
        n: 2,
        steps: 6,
        class: MIXED_MEMBERS[i].0,
        guidance: MIXED_MEMBERS[i].1,
        seed: 30 + i as u64,
        ..Default::default()
    }
}

fn mixed_member_refs() -> Vec<Vec<f64>> {
    let clean = Service::start(
        ServerConfig { workers: 1, queue_cap: 64, ..Default::default() },
        analytic_backend(),
    );
    let refs = (0..MIXED_MEMBERS.len())
        .map(|i| {
            let r = clean.sample_blocking(mixed_member_req(i));
            assert!(r.ok, "clean run must succeed: {:?}", r.error);
            r.samples.unwrap()
        })
        .collect();
    clean.shutdown();
    refs
}

/// NaN chaos aimed at one conditioning of a mixed cohort quarantines only
/// the targeted member: the injected NaN row always lands inside a slab
/// conditioned on the target class, so the other members of the same
/// lockstep run survive bit-identical to a clean service.
#[test]
fn mixed_cohort_nan_quarantines_only_targeted_member() {
    silence_injected_panics();
    let refs = mixed_member_refs();

    // Every eval NaNs a row, but only inside rows conditioned on class 4.
    let svc = Service::start(
        ServerConfig {
            workers: 1,
            queue_cap: 256,
            batch_linger_us: 50_000,
            ..Default::default()
        },
        ModelBackend::chaos(
            analytic_backend(),
            ChaosConfig { seed: 11, nan_rate: 1.0, target_class: Some(4), ..ChaosConfig::default() },
        ),
    );
    let mut saw_mixed_quarantine = false;
    for _round in 0..20 {
        let rxs: Vec<_> =
            (0..MIXED_MEMBERS.len()).map(|i| svc.submit(mixed_member_req(i)).unwrap()).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv_timeout(Duration::from_secs(60)).expect("response must arrive");
            if MIXED_MEMBERS[i].0 == Some(4) {
                assert!(!r.ok, "targeted member must be quarantined");
                assert_eq!(r.kind, Some(FailureKind::NonFiniteOutput), "{:?}", r.error);
            } else {
                assert!(r.ok, "untargeted member {i} must survive: {:?}", r.error);
                assert_eq!(
                    r.samples.as_ref(),
                    Some(&refs[i]),
                    "survivor {i} must be bit-identical to the clean run"
                );
            }
        }
        let m = svc.metrics_json();
        let counter = |key: &str| m.get(key).and_then(|v| v.as_f64()).unwrap();
        if counter("mixed_cond_batches") > 0.0 && counter("quarantined_members") > 0.0 {
            saw_mixed_quarantine = true;
            break;
        }
    }
    assert!(
        saw_mixed_quarantine,
        "a mixed cohort must have formed and quarantined its targeted member: {:?}",
        svc.metrics_json()
    );
    svc.shutdown();
}

/// A mid-batch panic aimed at one conditioning fails only the targeted
/// members: the panicked cohort re-runs every member solo, where the
/// untargeted ones complete clean and bit-identical while the targeted one
/// panics again into a typed `worker_panic` response.
#[test]
fn mixed_cohort_panic_retry_fails_only_targeted_members() {
    silence_injected_panics();
    let refs = mixed_member_refs();

    let svc = Service::start(
        ServerConfig {
            workers: 1,
            queue_cap: 256,
            batch_linger_us: 50_000,
            ..Default::default()
        },
        ModelBackend::chaos(
            analytic_backend(),
            ChaosConfig {
                seed: 13,
                panic_rate: 1.0,
                target_class: Some(4),
                ..ChaosConfig::default()
            },
        ),
    );
    let mut saw_batch_retry = false;
    for _round in 0..20 {
        let rxs: Vec<_> =
            (0..MIXED_MEMBERS.len()).map(|i| svc.submit(mixed_member_req(i)).unwrap()).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv_timeout(Duration::from_secs(60)).expect("response must arrive");
            if MIXED_MEMBERS[i].0 == Some(4) {
                assert!(!r.ok, "targeted member must fail");
                assert_eq!(r.kind, Some(FailureKind::WorkerPanic), "{:?}", r.error);
            } else {
                assert!(r.ok, "untargeted member {i} must survive: {:?}", r.error);
                assert_eq!(
                    r.samples.as_ref(),
                    Some(&refs[i]),
                    "survivor {i} must be bit-identical to the clean run"
                );
            }
        }
        let m = svc.metrics_json();
        let counter = |key: &str| m.get(key).and_then(|v| v.as_f64()).unwrap();
        if counter("batch_retries") > 0.0 {
            saw_batch_retry = true;
            break;
        }
    }
    assert!(
        saw_batch_retry,
        "a mixed cohort must have panicked and re-run its members solo: {:?}",
        svc.metrics_json()
    );
    svc.shutdown();
}

/// Jobs still queued past their deadline are shed with a typed response
/// and never executed.
#[test]
fn expired_jobs_are_shed_with_typed_responses() {
    let svc = Service::start(
        ServerConfig { workers: 1, queue_cap: 64, ..Default::default() },
        analytic_backend(),
    );
    // Occupy the single worker with long-running work (generous deadline).
    let blockers: Vec<_> = (0..3u64)
        .map(|s| {
            svc.submit(SampleRequest {
                n: 8,
                steps: 800,
                seed: s,
                return_samples: false,
                deadline_ms: Some(120_000),
                ..Default::default()
            })
            .unwrap()
        })
        .collect();
    std::thread::sleep(Duration::from_millis(5));
    // These can't start before the blockers finish, and their 1 ms deadline
    // expires long before that.
    let doomed: Vec<_> = (0..5u64)
        .map(|s| {
            svc.submit(SampleRequest {
                n: 1,
                steps: 5,
                seed: 100 + s,
                return_samples: false,
                deadline_ms: Some(1),
                ..Default::default()
            })
            .unwrap()
        })
        .collect();

    for rx in doomed {
        let r = rx.recv_timeout(Duration::from_secs(120)).expect("shed response must arrive");
        assert!(!r.ok);
        assert_eq!(r.kind, Some(FailureKind::DeadlineExceeded));
        assert_eq!(r.nfe, 0, "expired jobs must never execute");
    }
    for rx in blockers {
        let r = rx.recv_timeout(Duration::from_secs(120)).expect("blocker response");
        assert!(r.ok, "{:?}", r.error);
    }
    let m = svc.metrics_json();
    assert_eq!(m.get("deadline_exceeded").unwrap().as_f64(), Some(5.0));
    svc.shutdown();
}

/// Shutdown drains what it can within the drain deadline, sheds the rest
/// with typed responses, and leaves no receiver hanging.
#[test]
fn shutdown_sheds_queued_jobs_and_answers_every_receiver() {
    let svc = Service::start(
        ServerConfig {
            workers: 1,
            queue_cap: 64,
            drain_deadline_ms: 1,
            ..Default::default()
        },
        analytic_backend(),
    );
    let blocker = svc
        .submit(SampleRequest {
            n: 8,
            steps: 1000,
            seed: 0,
            return_samples: false,
            ..Default::default()
        })
        .unwrap();
    std::thread::sleep(Duration::from_millis(5));
    // Distinct step counts ⇒ distinct plan keys, so the worker can't drain
    // them all as one batch inside the 1 ms window.
    let queued: Vec<_> = (0..6u64)
        .map(|s| {
            svc.submit(SampleRequest {
                n: 4,
                steps: 400 + s as usize * 7,
                seed: s,
                return_samples: false,
                ..Default::default()
            })
            .unwrap()
        })
        .collect();

    svc.shutdown();

    let r = blocker.recv_timeout(Duration::from_secs(120)).expect("blocker answered");
    assert!(r.ok || r.kind.is_some());
    let mut sheds = 0;
    for rx in queued {
        let r = rx.recv_timeout(Duration::from_secs(120)).expect("no receiver left hanging");
        if r.ok {
            continue; // drained before the deadline
        }
        assert_eq!(r.kind, Some(FailureKind::BackendError), "{:?}", r.error);
        sheds += 1;
    }
    assert!(sheds > 0, "the 1 ms drain window cannot drain six multi-step jobs");

    // Post-shutdown submits are rejected, typed; shutdown is idempotent.
    assert!(svc.submit(SampleRequest::default()).is_err());
    svc.shutdown();
}

/// `sample_blocking` must not hang past the request deadline even when the
/// job is stuck behind a long queue.
#[test]
fn sample_blocking_respects_deadline_under_queueing() {
    let svc = Service::start(
        ServerConfig { workers: 1, queue_cap: 64, ..Default::default() },
        analytic_backend(),
    );
    let blocker = svc
        .submit(SampleRequest {
            n: 8,
            steps: 1000,
            seed: 0,
            return_samples: false,
            ..Default::default()
        })
        .unwrap();
    std::thread::sleep(Duration::from_millis(5));

    let started = Instant::now();
    let r = svc.sample_blocking(SampleRequest {
        n: 1,
        steps: 5,
        seed: 9,
        return_samples: false,
        deadline_ms: Some(1),
        ..Default::default()
    });
    assert!(!r.ok);
    assert_eq!(r.kind, Some(FailureKind::DeadlineExceeded));
    assert!(
        started.elapsed() < Duration::from_secs(60),
        "blocking call must be bounded by the deadline"
    );
    let _ = blocker.recv_timeout(Duration::from_secs(120));
    svc.shutdown();
}

/// Pick two step counts whose requests route to different shards. The
/// batch key is the plan key alone (conditioning never splits or re-routes
/// a cohort), so distinct plans are the only way to exercise two shards —
/// and the FNV routing is a pure function of the key, so with ≥ 2 shards
/// some pair among 40 probed plans must land apart.
fn two_step_counts_on_distinct_shards(svc: &Service, base: usize) -> (usize, usize) {
    let route = |steps: usize| {
        svc.route_of(&SampleRequest { n: 1, steps, ..Default::default() })
            .expect("planned request routes")
    };
    let a = base;
    for b in base + 1..base + 40 {
        if route(b) != route(a) {
            return (a, b);
        }
    }
    panic!("40 plans must not all hash to one of {} shards", svc.shards());
}

/// Chaos aimed at one shard (every targeted evaluation panics) must not
/// stall the other shards: untargeted requests keep completing
/// bit-identically to a clean run, and the supervisor restores every
/// shard's worker sub-pool.
#[test]
fn shard_poisoned_by_panics_does_not_stall_the_others() {
    silence_injected_panics();
    let cfg = ServerConfig { workers: 4, queue_cap: 256, ..Default::default() };

    // Shards split by plan key (step count); the chaos aims at a class
    // carried only by the doomed plan's requests.
    let clean = Service::start(cfg.clone(), analytic_backend());
    assert_eq!(clean.shards(), 4);
    let (doomed_steps, healthy_steps) = two_step_counts_on_distinct_shards(&clean, 8);
    let (doomed_class, healthy_class) = (0usize, 1usize);
    let mk_req = |class: usize, steps: usize, seed: u64| SampleRequest {
        n: 1,
        steps,
        class: Some(class),
        seed,
        ..Default::default()
    };
    let refs: Vec<Vec<f64>> = (0..20u64)
        .map(|s| {
            let r = clean.sample_blocking(mk_req(healthy_class, healthy_steps, s));
            assert!(r.ok, "{:?}", r.error);
            r.samples.unwrap()
        })
        .collect();
    clean.shutdown();

    let svc = Service::start(
        cfg,
        ModelBackend::chaos(
            analytic_backend(),
            ChaosConfig {
                seed: 7,
                panic_rate: 1.0,
                target_class: Some(doomed_class),
                ..ChaosConfig::default()
            },
        ),
    );
    let doomed_shard = svc
        .route_of(&mk_req(doomed_class, doomed_steps, 0))
        .expect("planned request routes");
    let healthy_shard = svc
        .route_of(&mk_req(healthy_class, healthy_steps, 0))
        .expect("planned request routes");
    assert_ne!(doomed_shard, healthy_shard, "plans must exercise two shards");

    // Interleave: every targeted request panics (typed), every untargeted
    // one must still complete bit-identically despite sharing the pool.
    for s in 0..20u64 {
        let bad = svc.sample_blocking(mk_req(doomed_class, doomed_steps, s));
        assert!(!bad.ok);
        assert_eq!(bad.kind, Some(FailureKind::WorkerPanic), "{:?}", bad.error);
        let good = svc.sample_blocking(mk_req(healthy_class, healthy_steps, s));
        assert!(good.ok, "healthy shard stalled at {s}: {:?}", good.error);
        assert_eq!(
            good.samples.as_ref(),
            Some(&refs[s as usize]),
            "untargeted request {s} must be bit-identical to the clean run"
        );
    }

    // Per-shard attribution: every panic landed on the doomed shard's
    // metrics, none on the healthy shard's.
    let shards = svc.shard_metrics_json();
    let counter = |shard: usize, key: &str| {
        shards[shard].get(key).and_then(|v| v.as_f64()).unwrap()
    };
    assert_eq!(counter(doomed_shard, "worker_panic"), 20.0);
    assert_eq!(counter(healthy_shard, "worker_panic"), 0.0);
    assert_eq!(counter(healthy_shard, "completed"), 20.0);

    // Supervision is per worker, and each worker homes on one shard: after
    // the panic storm settles, every shard must still field its full
    // sub-pool (workers=4 across 4 shards ⇒ exactly one each).
    std::thread::sleep(Duration::from_millis(300));
    assert!(svc.workers_alive() >= 4, "pool must never shrink");
    for shard in 0..svc.shards() {
        assert!(
            svc.shard_workers_alive(shard) >= 1,
            "shard {shard} lost its home worker"
        );
    }
    let m = svc.metrics_json();
    assert!(m.get("worker_restarts").unwrap().as_f64().unwrap() > 0.0, "{m:?}");
    svc.shutdown();
}

/// Deadline shedding holds with multiple shards: when every worker is
/// pinned (stealing can't help), queued jobs past their deadline are shed
/// typed and never executed, wherever they routed.
#[test]
fn expired_jobs_are_shed_across_shards() {
    let svc = Service::start(
        ServerConfig { workers: 2, queue_cap: 64, ..Default::default() },
        analytic_backend(),
    );
    assert_eq!(svc.shards(), 2);
    // Distinct step counts ⇒ distinct plan keys: the blockers can't
    // coalesce into one batch, so both workers stay busy and no idle
    // worker exists to steal the doomed jobs before they expire.
    let blockers: Vec<_> = (0..4u64)
        .map(|s| {
            svc.submit(SampleRequest {
                n: 8,
                steps: 800 + s as usize * 7,
                seed: s,
                return_samples: false,
                deadline_ms: Some(120_000),
                ..Default::default()
            })
            .unwrap()
        })
        .collect();
    std::thread::sleep(Duration::from_millis(5));
    // Fan the doomed jobs across both shards via their step counts (the
    // plan key routes; conditioning wouldn't split them anymore).
    let (sa, sb) = two_step_counts_on_distinct_shards(&svc, 5);
    let doomed: Vec<_> = (0..6u64)
        .map(|s| {
            svc.submit(SampleRequest {
                n: 1,
                steps: if s % 2 == 0 { sa } else { sb },
                seed: 100 + s,
                return_samples: false,
                deadline_ms: Some(1),
                ..Default::default()
            })
            .unwrap()
        })
        .collect();

    for rx in doomed {
        let r = rx.recv_timeout(Duration::from_secs(120)).expect("shed response must arrive");
        assert!(!r.ok);
        assert_eq!(r.kind, Some(FailureKind::DeadlineExceeded));
        assert_eq!(r.nfe, 0, "expired jobs must never execute");
    }
    for rx in blockers {
        let r = rx.recv_timeout(Duration::from_secs(120)).expect("blocker response");
        assert!(r.ok, "{:?}", r.error);
    }
    let m = svc.metrics_json();
    assert_eq!(m.get("deadline_exceeded").unwrap().as_f64(), Some(6.0));
    // Both shards saw sheds (the aggregate alone could hide a stuck shard).
    let shed_shards = svc
        .shard_metrics_json()
        .iter()
        .filter(|s| s.get("deadline_exceeded").unwrap().as_f64().unwrap() > 0.0)
        .count();
    assert_eq!(shed_shards, 2, "doomed jobs were fanned across both shards");
    svc.shutdown();
}

/// Bounded shutdown holds with multiple shards: one drain window covers
/// all shards concurrently, stragglers on every shard are shed typed, and
/// no receiver is left hanging.
#[test]
fn multi_shard_shutdown_is_bounded_and_answers_every_receiver() {
    let svc = Service::start(
        ServerConfig {
            workers: 4,
            queue_cap: 256,
            drain_deadline_ms: 1,
            ..Default::default()
        },
        analytic_backend(),
    );
    assert_eq!(svc.shards(), 4);
    // Pin all four workers, then queue work behind them on every shard.
    let blockers: Vec<_> = (0..4u64)
        .map(|s| {
            svc.submit(SampleRequest {
                n: 8,
                steps: 900 + s as usize * 7,
                seed: s,
                return_samples: false,
                ..Default::default()
            })
            .unwrap()
        })
        .collect();
    std::thread::sleep(Duration::from_millis(5));
    let queued: Vec<_> = (0..12u64)
        .map(|s| {
            svc.submit(SampleRequest {
                n: 4,
                steps: 300 + s as usize * 7,
                seed: s,
                return_samples: false,
                ..Default::default()
            })
            .unwrap()
        })
        .collect();

    let started = Instant::now();
    svc.shutdown();
    assert!(
        started.elapsed() < Duration::from_secs(120),
        "shutdown must stay bounded with shards"
    );

    for rx in blockers {
        let r = rx.recv_timeout(Duration::from_secs(120)).expect("blocker answered");
        assert!(r.ok || r.kind.is_some());
    }
    let mut sheds = 0;
    for rx in queued {
        let r = rx.recv_timeout(Duration::from_secs(120)).expect("no receiver left hanging");
        if r.ok {
            continue; // drained before the deadline
        }
        assert_eq!(r.kind, Some(FailureKind::BackendError), "{:?}", r.error);
        sheds += 1;
    }
    assert!(sheds > 0, "a 1 ms window cannot drain twelve multi-step jobs");
    assert!(svc.submit(SampleRequest::default()).is_err());
    svc.shutdown();
}
