//! End-to-end runtime tests against the real AOT artifacts.
//!
//! These run only when `make artifacts` has produced `artifacts/` (they are
//! skipped otherwise so `cargo test` stays green on a fresh checkout).

use std::path::{Path, PathBuf};
use std::time::Duration;

use unipc::json;
use unipc::runtime::{EngineOptions, PjrtHandle, PjrtModel};
use unipc::solver::{sample, Method, Model, Prediction, SampleOptions};
use unipc::tensor::Tensor;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    (dir.join("manifest.json").exists() && dir.join("model.upw").exists()).then_some(dir)
}

fn spawn(dir: &Path) -> PjrtHandle {
    PjrtHandle::spawn(dir, None, EngineOptions::default()).expect("spawn engine")
}

#[test]
fn golden_eps_matches_python() {
    let Some(dir) = artifacts_dir() else { return };
    let golden_path = dir.join("golden.json");
    if !golden_path.exists() {
        return;
    }
    let g = json::parse(&std::fs::read_to_string(&golden_path).unwrap()).unwrap();
    // Golden is only valid when it was generated with the trained weights.
    if g.get("weights").and_then(json::Value::as_str) != Some("trained") {
        return;
    }
    let b = g.get("batch").unwrap().as_usize().unwrap();
    let xs: Vec<f32> = g
        .get("x")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect();
    let want: Vec<f32> = g
        .get("eps")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect();
    let want_cfg: Vec<f32> = g
        .get("eps_cfg")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect();
    let scale = g.get("cfg_scale").unwrap().as_f64().unwrap() as f32;

    let h = spawn(&dir);
    let t = vec![0.5f32; b];
    let y = vec![0i32; b];
    let got = h.eps(xs.clone(), t.clone(), y.clone()).unwrap();
    assert_eq!(got.len(), want.len());
    for (a, w) in got.iter().zip(&want) {
        assert!((a - w).abs() < 2e-4, "eps mismatch: {a} vs {w}");
    }
    let got_cfg = h.eps_cfg(xs, t, y, scale).unwrap();
    for (a, w) in got_cfg.iter().zip(&want_cfg) {
        assert!((a - w).abs() < 5e-4, "cfg mismatch: {a} vs {w}");
    }
    h.shutdown();
}

#[test]
fn batching_is_transparent() {
    // One call with 3 rows == three 1-row calls (same weights, same math).
    let Some(dir) = artifacts_dir() else { return };
    let h = spawn(&dir);
    let d = h.dim;
    let x: Vec<f32> = (0..3 * d).map(|i| (i as f32 / (3 * d) as f32) - 0.5).collect();
    let t = vec![0.7f32, 0.5, 0.3];
    let y = vec![0i32, 1, 2];
    let joint = h.eps(x.clone(), t.clone(), y.clone()).unwrap();
    for r in 0..3 {
        let solo = h
            .eps(x[r * d..(r + 1) * d].to_vec(), vec![t[r]], vec![y[r]])
            .unwrap();
        for (a, b) in solo.iter().zip(&joint[r * d..(r + 1) * d]) {
            assert!((a - b).abs() < 1e-5, "row {r}: {a} vs {b}");
        }
    }
    h.shutdown();
}

#[test]
fn concurrent_evals_coalesce() {
    let Some(dir) = artifacts_dir() else { return };
    let h = PjrtHandle::spawn(
        &dir,
        None,
        EngineOptions { max_batch: 64, batch_wait: Duration::from_millis(5) },
    )
    .unwrap();
    let d = h.dim;
    // Warm up/compile outside the measured region.
    let _ = h.eps(vec![0.0; d], vec![0.5], vec![0]).unwrap();
    let before = h.stats().unwrap();

    let threads: Vec<_> = (0..8)
        .map(|i| {
            let h = h.clone();
            std::thread::spawn(move || {
                let x = vec![0.1 * i as f32; d];
                h.eps(x, vec![0.5], vec![0]).unwrap()
            })
        })
        .collect();
    for th in threads {
        th.join().unwrap();
    }
    let after = h.stats().unwrap();
    let calls = after.calls - before.calls;
    let jobs = after.coalesced_jobs - before.coalesced_jobs;
    assert!(jobs >= 8, "jobs {jobs}");
    assert!(calls < 8, "batching should coalesce 8 jobs into <8 calls, got {calls}");
    h.shutdown();
}

#[test]
fn pjrt_model_runs_unipc_sampler() {
    // The full stack: UniPC-3 against the learned model via PJRT.
    let Some(dir) = artifacts_dir() else { return };
    let h = spawn(&dir);
    let model = PjrtModel::new(h.clone()).with_class(3, Some(1.5));
    assert_eq!(model.prediction(), Prediction::Noise);

    let mut rng = unipc::rng::Rng::seed_from(7);
    let x_t = rng.normal_tensor(&[4, model.dim()]);
    let opts = SampleOptions::unipc(
        3,
        unipc::numerics::vandermonde::BFunction::Bh2,
        Prediction::Noise,
        8,
    );
    let r = sample(&model, &unipc::sched::VpLinear::default(), &x_t, &opts);
    assert_eq!(r.nfe, 8);
    assert!(r.x.data().iter().all(|v| v.is_finite()));
    // Samples should be in the data region (mixture radius 3 ± spread),
    // not at noise scale.
    // (guidance pushes samples outward, so allow a generous upper bound).
    let rms = r.x.rms();
    assert!(rms > 0.2 && rms < 6.0, "rms {rms}");
    h.shutdown();
}

#[test]
fn fused_correct_matches_host_math() {
    // The fused correct artifact must equal: m_t = eps(x_pred); then the
    // affine combination done on the host.
    let Some(dir) = artifacts_dir() else { return };
    let h = spawn(&dir);
    let d = h.dim;
    let p = h.fused_p;
    let rows = 2;
    let mut rng = unipc::rng::Rng::seed_from(3);
    let rnd = |rng: &mut unipc::rng::Rng, n: usize| -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * 0.3).collect()
    };
    let x_pred = rnd(&mut rng, rows * d);
    let t = vec![0.5f32; rows];
    let y = vec![1i32; rows];
    let x_prev = rnd(&mut rng, rows * d);
    let m0 = rnd(&mut rng, rows * d);
    let d1s = rnd(&mut rng, p * rows * d);
    // coeffs: c_1..c_p, c_{p+1} (current point), a, b, s
    let mut coeffs = vec![0.2f32, -0.1, 0.05, 0.3];
    coeffs.extend([1.1f32, -0.4, 0.9]);

    let (x_c, m_t) = h
        .fused_correct(
            x_pred.clone(),
            t.clone(),
            y.clone(),
            x_prev.clone(),
            m0.clone(),
            d1s.clone(),
            coeffs.clone(),
        )
        .unwrap();

    // m_t must equal a plain eps call at the same point.
    let m_ref = h.eps(x_pred, t, y).unwrap();
    for (a, b) in m_t.iter().zip(&m_ref) {
        assert!((a - b).abs() < 1e-5, "{a} vs {b}");
    }
    // x_c = a*x_prev + b*m0 + s*(sum_i c_i d1s_i + c_{p+1} (m_t - m0)).
    for r in 0..rows {
        for j in 0..d {
            let idx = r * d + j;
            let mut res = 0.0f32;
            for i in 0..p {
                res += coeffs[i] * d1s[i * rows * d + idx];
            }
            res += coeffs[p] * (m_t[idx] - m0[idx]);
            let want = coeffs[p + 1] * x_prev[idx] + coeffs[p + 2] * m0[idx]
                + coeffs[p + 3] * res;
            assert!((x_c[idx] - want).abs() < 1e-4, "{} vs {want}", x_c[idx]);
        }
    }
    h.shutdown();
}

#[test]
fn oversized_batch_chunks() {
    let Some(dir) = artifacts_dir() else { return };
    let h = spawn(&dir);
    let d = h.dim;
    let rows = 70; // > max compiled batch (64) -> two chunks
    let x: Vec<f32> = (0..rows * d).map(|i| ((i % 17) as f32) * 0.01).collect();
    let t = vec![0.4f32; rows];
    let y = vec![0i32; rows];
    let out = h.eps(x, t, y).unwrap();
    assert_eq!(out.len(), rows * d);
    assert!(out.iter().all(|v| v.is_finite()));
    h.shutdown();
}
