//! Acceptance suite for the continuous telemetry plane (PR 10).
//!
//! Five contracts:
//!
//! * **windowed rates match ground truth** — a deterministic 200-second
//!   replay drives the slot rings and an independent event-log model;
//!   every queried window (seconds ring, minute rollup, idle tail after
//!   slot recycling) must agree field-for-field;
//! * **exactly once or counted** — under ~10% injected faults, every span
//!   published while a subscription is live is either delivered to its
//!   queue exactly once or counted in `sub_dropped`: `delivered +
//!   sub_dropped == trace_recorded`, with both a lossless (large-cap) and
//!   a deliberately overflowing (cap-4) subscriber;
//! * **one breach per evaluation window** — the burn-rate monitor under
//!   synthetic time, and a configured SLO breached end to end through the
//!   service, each emit exactly one `slo_breach` per window id no matter
//!   how often they are evaluated;
//! * **corrector deltas shrink with step count** — the per-response mean
//!   predictor→corrector relative delta (UniPC §3.2: UniC reuses the
//!   current model eval, so the delta is a zero-extra-NFE local error
//!   estimate) decreases monotonically on the analytic backend, and is
//!   only stamped under `trace=steps`;
//! * **merge is a lawful aggregation** — `Metrics::merge` is commutative,
//!   associative, and identity-preserving across every field, including
//!   windowed slots and the slowest-K exemplar store (satellite of the
//!   sharded snapshot path).

use std::sync::Arc;
use std::time::Duration;

use unipc::analytic::datasets::{dataset, DatasetSpec};
use unipc::config::ServerConfig;
use unipc::coordinator::{
    silence_injected_panics, ChaosConfig, FailureKind, Metrics, ModelBackend, SampleRequest,
    Service,
};
use unipc::json::Value;
use unipc::telemetry::{
    parse_exposition, BurnRateMonitor, SloSpec, TelemetryEvent, WindowStore, WindowTotals,
    E2E_LE_US,
};
use unipc::trace::{Stage, TraceLevel};

fn analytic_backend() -> ModelBackend {
    let spec = DatasetSpec::Cifar10Like;
    let gm = Arc::new(dataset(spec));
    let classes = (0..spec.n_classes()).map(|c| spec.class_components(c)).collect();
    ModelBackend::Analytic { gm, class_components: Arc::new(classes) }
}

/// Deterministic PRNG for replays (splitmix-style LCG).
fn lcg(state: &mut u64) -> u64 {
    *state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    *state >> 33
}

fn num(v: &Value, key: &str) -> f64 {
    v.get(key).and_then(Value::as_f64).unwrap_or_else(|| panic!("missing numeric {key:?}: {v:?}"))
}

/// Spans are flushed by workers just after the reply is delivered, so a
/// joined submitter does not imply a quiet ring. Wait until the recorded
/// count is stable across a full poll interval (the service is idle — no
/// request is in flight when this is called).
fn quiesce(svc: &Service) -> u64 {
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    let mut last = num(&svc.metrics_json(), "trace_recorded") as u64;
    loop {
        std::thread::sleep(Duration::from_millis(100));
        let now = num(&svc.metrics_json(), "trace_recorded") as u64;
        if now == last || std::time::Instant::now() > deadline {
            return now;
        }
        last = now;
    }
}

// ---------------------------------------------------------------------------
// Windowed rates vs. deterministic replay
// ---------------------------------------------------------------------------

/// One replayed event, timestamped on the synthetic service clock.
enum Op {
    Comp { at: u64, n: usize, nfe: usize, e2e: u64 },
    Fail { at: u64, kind: FailureKind },
    Batch { at: u64, members: usize },
    Depth { at: u64, depth: usize },
    Steal { at: u64 },
}

impl Op {
    fn at(&self) -> u64 {
        match *self {
            Op::Comp { at, .. }
            | Op::Fail { at, .. }
            | Op::Batch { at, .. }
            | Op::Depth { at, .. }
            | Op::Steal { at } => at,
        }
    }
}

/// Ground truth straight from the documented window semantics: a sub-60s
/// window sums events with second in `(now − w, now]`; a longer window
/// sums whole minutes in `(now_m − ceil(w/60), now_m]`. Computed from the
/// raw event log, independent of the ring implementation.
fn naive_totals(ops: &[Op], now_s: u64, window_s: u64) -> WindowTotals {
    let mut t = WindowTotals { window_s, ..WindowTotals::default() };
    let in_window = |at: u64| {
        if window_s <= 60 {
            at as i64 > now_s as i64 - window_s as i64 && at <= now_s
        } else {
            let (m, now_m) = (at / 60, now_s / 60);
            m as i64 > now_m as i64 - window_s.div_ceil(60) as i64 && m <= now_m
        }
    };
    let bucket =
        |us: u64| E2E_LE_US.iter().position(|&le| us <= le).unwrap_or(E2E_LE_US.len());
    for op in ops.iter().filter(|o| in_window(o.at())) {
        match *op {
            Op::Comp { n, nfe, e2e, .. } => {
                t.completed += 1;
                t.samples_out += n as u64;
                t.nfe_total += nfe as u64;
                t.e2e_sum_us += e2e;
                t.e2e_max_us = t.e2e_max_us.max(e2e);
                t.e2e_hist[bucket(e2e)] += 1;
            }
            Op::Fail { kind, .. } => {
                t.failed += 1;
                t.failures_by_kind[kind.index()] += 1;
            }
            Op::Batch { members, .. } => {
                t.batched_runs += 1;
                t.batch_members += members as u64;
            }
            Op::Depth { depth, .. } => {
                t.depth_sum += depth as u64;
                t.depth_obs += 1;
            }
            Op::Steal { .. } => t.steals += 1,
        }
    }
    t
}

#[test]
fn windowed_rates_match_deterministic_replay() {
    let mut store = WindowStore::default();
    let mut ops: Vec<Op> = Vec::new();
    let mut st = 0x9e37_79b9_7f4a_7c15u64;
    // 200 virtual seconds: the seconds ring recycles more than three times
    // over, and the replay crosses four minute boundaries. Queries run
    // interleaved, at the virtual instant they would be served — a slot
    // ring only answers for the trailing ring span, so querying second 30
    // after second 90 has recycled its slot would be asking about history
    // the store (correctly) no longer holds.
    for s in 0..200u64 {
        let r = lcg(&mut st);
        let (n, nfe) = (1 + (r % 3) as usize, 4 + (r % 5) as usize);
        let e2e = 400 + (r % 64) * 700; // spans several histogram buckets
        store.record_completion(s, n, nfe, e2e);
        ops.push(Op::Comp { at: s, n, nfe, e2e });
        if s % 7 == 3 {
            let kind = FailureKind::ALL[(r % 6) as usize];
            store.record_failure(s, kind);
            ops.push(Op::Fail { at: s, kind });
        }
        if s % 5 == 0 {
            let members = 2 + (r % 7) as usize;
            store.record_batch(s, members);
            ops.push(Op::Batch { at: s, members });
        }
        if s % 3 == 1 {
            let depth = (r % 9) as usize;
            store.record_depth(s, depth);
            ops.push(Op::Depth { at: s, depth });
        }
        if s % 11 == 5 {
            store.record_steal(s);
            ops.push(Op::Steal { at: s });
        }

        // Seconds ring at full resolution, including the boot edge (a
        // window larger than the elapsed time must still see second 0).
        if [0u64, 1, 30, 59, 120, 199].contains(&s) {
            for window in [1u64, 5, 30, 60] {
                let got = store.totals(s, window);
                let want = naive_totals(&ops, s, window);
                assert_eq!(got, want, "seconds ring, now={s} window={window}");
            }
        }
        // Minute rollup for windows past the seconds horizon.
        if [59u64, 61, 150, 199].contains(&s) {
            for window in [61u64, 120, 180, 3_600] {
                let got = store.totals(s, window);
                let want = naive_totals(&ops, s, window);
                assert_eq!(got, want, "minute ring, now={s} window={window}");
            }
        }
    }
    // Idle tail: querying after the replay stopped must exclude recycled
    // slots — a 30 s window 31 s after the last event is empty.
    let tail = store.totals(230, 30);
    assert_eq!(tail, WindowTotals { window_s: 30, ..WindowTotals::default() });
    assert_eq!(store.totals(230, 60), naive_totals(&ops, 230, 60));
}

#[test]
fn live_windowed_stats_count_traffic_and_rejections() {
    let svc = Service::start(
        ServerConfig { workers: 2, queue_cap: 64, ..Default::default() },
        analytic_backend(),
    );
    let mut nfe_total = 0u64;
    for i in 0..4u64 {
        let r = svc.sample_blocking(SampleRequest {
            n: 2,
            steps: 6,
            class: Some((i % 4) as usize),
            seed: i,
            ..Default::default()
        });
        assert!(r.ok, "{:?}", r.error);
        nfe_total += r.nfe as u64;
    }
    // Rejections burn windowed failure budget without polluting the
    // cumulative completion/failure counters of admitted work.
    for _ in 0..2 {
        let r = svc.sample_blocking(SampleRequest { n: 0, ..Default::default() });
        assert!(!r.ok);
        assert_eq!(r.kind, Some(FailureKind::InvalidRequest));
    }

    let s = svc.windowed_stats_json(60);
    assert_eq!(num(&s, "window_s"), 60.0);
    assert_eq!(num(&s, "completed"), 4.0);
    assert_eq!(num(&s, "samples_out"), 8.0);
    assert_eq!(num(&s, "nfe_total"), nfe_total as f64);
    assert_eq!(num(&s, "failed"), 2.0);
    assert_eq!(num(&s, "invalid_request"), 2.0);
    assert!((num(&s, "completed_per_sec") - 4.0 / 60.0).abs() < 1e-12);
    assert!(num(&s, "e2e_mean_us") > 0.0);
    let hist = s.get("e2e_hist").and_then(Value::as_arr).expect("e2e_hist array");
    let hist_n: f64 = hist.iter().map(|v| v.as_f64().unwrap_or(0.0)).sum();
    assert_eq!(hist_n, 4.0, "one histogram observation per completion");

    let m = svc.metrics_json();
    assert_eq!(num(&m, "completed"), 4.0);
    assert_eq!(num(&m, "failed"), 0.0, "rejections are not admitted failures");
    assert_eq!(num(&m, "rejected"), 2.0);
    svc.shutdown();
}

#[test]
fn prometheus_exposition_round_trips_against_live_metrics() {
    let svc = Service::start(
        ServerConfig { workers: 2, queue_cap: 64, ..Default::default() },
        analytic_backend(),
    );
    for i in 0..3u64 {
        let r = svc.sample_blocking(SampleRequest {
            n: 1,
            steps: 5,
            seed: i,
            ..Default::default()
        });
        assert!(r.ok, "{:?}", r.error);
    }
    let text = svc.prometheus_text();
    let parsed = parse_exposition(&text).expect("exposition must parse");
    assert_eq!(parsed.value("unipc_completed_total", &[]), Some(3.0));
    assert_eq!(parsed.value("unipc_failed_total", &[]), Some(0.0));
    assert_eq!(
        parsed.value("unipc_failures_total", &[("kind", "worker_panic")]),
        Some(0.0)
    );
    assert_eq!(parsed.value("unipc_sub_dropped_total", &[]), Some(0.0));
    assert_eq!(parsed.value("unipc_slo_breaches_total", &[]), Some(0.0));
    assert_eq!(parsed.value("unipc_subscribers", &[]), Some(0.0));
    assert_eq!(
        parsed.value("unipc_workers_alive", &[]),
        Some(svc.workers_alive() as f64)
    );
    assert_eq!(parsed.value("unipc_e2e_us_count", &[]), Some(3.0));
    assert_eq!(
        parsed.value("unipc_trace_dropped_total", &[]),
        Some(0.0),
        "nothing fell off the ring in a 3-request run"
    );
    svc.shutdown();
}

// ---------------------------------------------------------------------------
// Push channel: exactly once or counted
// ---------------------------------------------------------------------------

#[test]
fn every_span_is_delivered_exactly_once_or_counted_under_chaos() {
    silence_injected_panics();
    let svc = Service::start(
        ServerConfig {
            workers: 4,
            shards: 2,
            queue_cap: 4096,
            trace_buf: 1 << 16,
            ..Default::default()
        },
        ModelBackend::chaos(
            analytic_backend(),
            ChaosConfig { seed: 31, panic_rate: 0.05, nan_rate: 0.05, ..ChaosConfig::default() },
        ),
    );
    // Subscribed before the first request with room for every span the
    // run can produce: this subscriber must see a lossless feed.
    let sub = svc.subscribe(1 << 16);

    let threads = 4usize;
    let per_thread = 16usize;
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let svc = svc.clone();
            std::thread::spawn(move || {
                (0..per_thread)
                    .map(|i| {
                        let k = (t * per_thread + i) as u64;
                        let r = svc.sample_blocking(SampleRequest {
                            n: 1 + (k % 2) as usize,
                            steps: 5 + (k % 4) as usize,
                            class: Some((k % 8) as usize),
                            seed: k,
                            return_samples: false,
                            ..Default::default()
                        });
                        r.trace_id
                    })
                    .collect::<Vec<u64>>()
            })
        })
        .collect();
    let mut ids = Vec::new();
    for h in handles {
        ids.extend(h.join().expect("submitter panicked"));
    }
    let recorded = quiesce(&svc);

    let mut events = Vec::new();
    sub.drain_into(&mut events);
    let delivered = events.len() as u64;
    assert_eq!(
        delivered + svc.sub_dropped(),
        recorded,
        "every recorded span is delivered or counted dropped"
    );
    assert_eq!(svc.sub_dropped(), 0, "a 64Ki queue must not overflow here");
    // Exactly once: with zero drops, each request's terminal respond span
    // arrives exactly one time.
    for &id in &ids {
        let n = events
            .iter()
            .filter(|e| {
                matches!(e, TelemetryEvent::Span(sp)
                    if sp.trace_id == id && sp.stage == Stage::Respond)
            })
            .count();
        assert_eq!(n, 1, "trace {id}: one delivered respond span");
    }
    svc.unsubscribe(&sub);

    // A cap-4 subscriber that never drains: the overflow is counted, and
    // the ledger still balances exactly.
    let r0 = num(&svc.metrics_json(), "trace_recorded") as u64;
    let d0 = svc.sub_dropped();
    let sub2 = svc.subscribe(4);
    for k in 0..8u64 {
        let _ = svc.sample_blocking(SampleRequest {
            n: 1,
            steps: 5,
            class: Some((k % 8) as usize),
            seed: 1_000 + k,
            ..Default::default()
        });
    }
    let r1 = quiesce(&svc);
    let d1 = svc.sub_dropped();
    let mut tail = Vec::new();
    sub2.drain_into(&mut tail);
    assert_eq!(
        tail.len() as u64 + (d1 - d0),
        r1 - r0,
        "overflowing subscriber: delivered + dropped == published"
    );
    assert!(d1 > d0, "eight requests must overflow a cap-4 queue");
    svc.unsubscribe(&sub2);
    svc.shutdown();
}

// ---------------------------------------------------------------------------
// SLO burn-rate monitors
// ---------------------------------------------------------------------------

#[test]
fn burn_rate_monitor_fires_once_per_evaluation_window() {
    let spec = SloSpec::parse("deadline_exceeded<1%/10s").expect("valid spec");
    assert_eq!(spec.budget_ppm, 10_000);
    assert_eq!(spec.window_s, 10);
    let mut mon = BurnRateMonitor::new(vec![spec]);
    let totals = |completed: u64, deadline_failed: u64| {
        move |w: u64| {
            let mut t = WindowTotals { window_s: w, completed, ..WindowTotals::default() };
            t.failed = deadline_failed;
            t.failures_by_kind[FailureKind::DeadlineExceeded.index()] = deadline_failed;
            t
        }
    };
    let mut out = Vec::new();

    // Below budget: 5 of 1005 is under 1%.
    mon.evaluate(100, totals(1_000, 5), &mut out);
    assert!(out.is_empty(), "below-budget burn must not alert");
    // Breach fires once…
    mon.evaluate(100, totals(1_000, 11), &mut out);
    assert_eq!(out.len(), 1);
    match out[0] {
        TelemetryEvent::SloBreach { kind, window_s, window_id, failed, total, budget_ppm } => {
            assert_eq!(kind, FailureKind::DeadlineExceeded);
            assert_eq!((window_s, window_id), (10, 10));
            assert_eq!((failed, total), (11, 1_011));
            assert_eq!(budget_ppm, 10_000);
        }
        TelemetryEvent::Span(_) => panic!("expected a breach event"),
    }
    // …and stays silent for the rest of window id 10, sustained burn or not.
    mon.evaluate(105, totals(1_000, 11), &mut out);
    mon.evaluate(109, totals(1_000, 40), &mut out);
    assert_eq!(out.len(), 1, "at most one breach per evaluation window");
    // The next window re-alerts.
    mon.evaluate(110, totals(1_000, 11), &mut out);
    assert_eq!(out.len(), 2);
    // Recovery inside a window does not reset its dedup.
    mon.evaluate(115, totals(1_000, 0), &mut out);
    mon.evaluate(119, totals(1_000, 11), &mut out);
    assert_eq!(out.len(), 2);
    mon.evaluate(120, totals(1_000, 11), &mut out);
    assert_eq!(out.len(), 3);

    // A zero-percent budget alerts on any failure at all.
    let strict = SloSpec::parse("worker_panic<0%/1m").expect("valid spec");
    let mut mon = BurnRateMonitor::new(vec![strict]);
    let mut out = Vec::new();
    let one_panic = |w: u64| {
        let mut t = WindowTotals { window_s: w, completed: 10_000, ..WindowTotals::default() };
        t.failed = 1;
        t.failures_by_kind[FailureKind::WorkerPanic.index()] = 1;
        t
    };
    mon.evaluate(30, one_panic, &mut out);
    assert_eq!(out.len(), 1, "zero budget: one failure in 10k breaches");
}

#[test]
fn configured_slo_breach_emits_one_event_end_to_end() {
    let mut cfg = ServerConfig { workers: 2, queue_cap: 64, ..Default::default() };
    cfg.slos = vec![SloSpec::parse("invalid_request<0.5%/60s").expect("valid spec")];
    let svc = Service::start(cfg, analytic_backend());
    let sub = svc.subscribe(1024);

    for i in 0..3u64 {
        let r = svc.sample_blocking(SampleRequest {
            n: 1,
            steps: 5,
            seed: i,
            ..Default::default()
        });
        assert!(r.ok, "{:?}", r.error);
    }
    for _ in 0..2 {
        assert!(!svc.sample_blocking(SampleRequest { n: 0, ..Default::default() }).ok);
    }
    // Evaluate repeatedly — poked and via the background monitor thread —
    // all inside evaluation window 0 of the 60 s objective (the service
    // clock starts at zero, and this test runs in well under a minute).
    for _ in 0..3 {
        svc.poke_slos();
    }
    std::thread::sleep(Duration::from_millis(300));
    svc.poke_slos();
    assert_eq!(svc.slo_breaches(), 1, "exactly one breach per evaluation window");

    let mut events = Vec::new();
    sub.drain_into(&mut events);
    let breaches: Vec<_> = events
        .iter()
        .filter_map(|e| match *e {
            TelemetryEvent::SloBreach { kind, window_s, window_id, failed, total, budget_ppm } => {
                Some((kind, window_s, window_id, failed, total, budget_ppm))
            }
            TelemetryEvent::Span(_) => None,
        })
        .collect();
    assert_eq!(breaches.len(), 1, "one slo_breach on the push channel: {breaches:?}");
    let (kind, window_s, window_id, failed, total, budget_ppm) = breaches[0];
    assert_eq!(kind, FailureKind::InvalidRequest);
    assert_eq!((window_s, window_id), (60, 0));
    assert_eq!(budget_ppm, 5_000);
    assert!(failed >= 1 && total >= failed, "breach carries its evidence");
    svc.unsubscribe(&sub);
    svc.shutdown();
}

// ---------------------------------------------------------------------------
// Solver numerical health
// ---------------------------------------------------------------------------

#[test]
fn corrector_delta_shrinks_with_step_count_on_the_analytic_backend() {
    let svc = Service::start(
        ServerConfig {
            workers: 1,
            queue_cap: 16,
            trace: TraceLevel::Steps,
            ..Default::default()
        },
        analytic_backend(),
    );
    let mut means = Vec::new();
    for &steps in &[4usize, 8, 16, 32] {
        let r = svc.sample_blocking(SampleRequest {
            n: 2,
            steps,
            class: Some(1),
            seed: 7,
            ..Default::default()
        });
        assert!(r.ok, "{:?}", r.error);
        assert_eq!(r.first_nonfinite_step, None, "analytic flow stays finite");
        let mean = r.corrector_delta_mean.expect("steps-level trace stamps health");
        let max = r.corrector_delta_max.expect("steps-level trace stamps health");
        assert!(mean.is_finite() && mean > 0.0, "corrector moved the state: {mean}");
        assert!(max >= mean, "max delta bounds the mean: {max} < {mean}");
        means.push(mean);
    }
    // The predictor→corrector delta is a local error estimate: finer grids
    // (more steps, smaller h) must shrink it monotonically.
    for pair in means.windows(2) {
        assert!(
            pair[1] < pair[0],
            "mean corrector delta must shrink as steps double: {means:?}"
        );
    }
    svc.shutdown();

    // Gating: below trace=steps the health fields stay unset.
    let svc = Service::start(
        ServerConfig { workers: 1, queue_cap: 16, ..Default::default() },
        analytic_backend(),
    );
    let r = svc.sample_blocking(SampleRequest { n: 1, steps: 8, seed: 7, ..Default::default() });
    assert!(r.ok);
    assert_eq!(r.corrector_delta_mean, None, "health costs an observer; lifecycle skips it");
    assert_eq!(r.first_nonfinite_step, None);
    svc.shutdown();
}

// ---------------------------------------------------------------------------
// Merge laws (satellite d)
// ---------------------------------------------------------------------------

/// A deterministic random metrics store: `ops` events spread over 150
/// virtual seconds, so slots collide across ring spans and both rings and
/// the exemplar store carry state.
fn replay_metrics(seed: u64, ops: usize) -> Metrics {
    let mut m = Metrics::default();
    let mut st = seed.wrapping_mul(0x2545_f491_4f6c_dd1d) ^ 0xdead_beef;
    for i in 0..ops {
        let r = lcg(&mut st);
        let now = r % 150;
        match r % 7 {
            0..=2 => m.record_completion(
                now,
                1 + (r % 4) as usize,
                4 + (r % 8) as usize,
                Duration::from_micros(r % 3_000),
                Duration::from_micros(100 + r % 9_000),
                Duration::from_micros(r % 90),
                1 + seed * 10_000 + i as u64,
            ),
            3 => m.record_failure(now, FailureKind::ALL[(r % 6) as usize]),
            4 => {
                let members = 1 + (r % 8) as usize;
                let distinct = 1 + (r as usize % members);
                m.record_batch(now, members, distinct, r % 4);
            }
            5 => m.record_depth(now, (r % 40) as usize),
            _ => {
                m.record_steal(now);
                m.record_health(
                    (r % 2 == 0).then(|| 1e-6 * (1 + r % 1_000) as f64),
                    (r % 5 == 0).then(|| (r % 30) as u32),
                );
            }
        }
    }
    m
}

#[test]
fn metrics_merge_is_commutative_associative_and_identity_preserving() {
    for seed in 0..8u64 {
        let (sa, sb, sc) = (3 * seed + 1, 3 * seed + 2, 3 * seed + 3);

        // Commutativity: a⊕b == b⊕a.
        let mut ab = replay_metrics(sa, 60);
        ab.merge(&replay_metrics(sb, 60));
        let mut ba = replay_metrics(sb, 60);
        ba.merge(&replay_metrics(sa, 60));
        assert_eq!(ab.fingerprint(), ba.fingerprint(), "seed {seed}: merge must commute");

        // Associativity: (a⊕b)⊕c == a⊕(b⊕c).
        let mut left = replay_metrics(sa, 60);
        left.merge(&replay_metrics(sb, 60));
        left.merge(&replay_metrics(sc, 60));
        let mut bc = replay_metrics(sb, 60);
        bc.merge(&replay_metrics(sc, 60));
        let mut right = replay_metrics(sa, 60);
        right.merge(&bc);
        assert_eq!(
            left.fingerprint(),
            right.fingerprint(),
            "seed {seed}: merge must associate"
        );

        // Identity: default ⊕ a == a ⊕ default == a.
        let want = replay_metrics(sa, 60).fingerprint();
        let mut lhs = Metrics::default();
        lhs.merge(&replay_metrics(sa, 60));
        assert_eq!(lhs.fingerprint(), want, "seed {seed}: left identity");
        let mut rhs = replay_metrics(sa, 60);
        rhs.merge(&Metrics::default());
        assert_eq!(rhs.fingerprint(), want, "seed {seed}: right identity");
    }
}
