//! End-to-end span-tree suite for the request tracing subsystem (PR 9).
//!
//! The coordinator mints a `trace_id` at admission and records a span
//! event at every lifecycle stage into per-shard rings. These tests pin
//! the contracts that make the trace trustworthy as an audit log:
//!
//! * **exactly one complete tree per admitted request** — under ~10%
//!   injected faults, every request's trace carries exactly one `admit`,
//!   exactly one `queue` (popped or absorbed, never both), and exactly one
//!   terminal `respond` whose ok/failure code matches the typed response
//!   the client saw;
//! * **steals are attributed to the victim shard** — every `route` event
//!   with a steal origin was recorded on the shard that owned the queue,
//!   names a different stealer home, and the count equals the `steals`
//!   metric exactly;
//! * **quarantined members carry a `quarantine` span** — a NaN-targeted
//!   member of a surviving cohort gets the span; its unharmed cohort mates
//!   do not;
//! * **trace ids round-trip the wire** — a client-chosen id comes back on
//!   the response and keys the span tree served by the `trace` op.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use unipc::analytic::datasets::{dataset, DatasetSpec};
use unipc::config::ServerConfig;
use unipc::coordinator::{
    silence_injected_panics, ChaosConfig, FailureKind, ModelBackend, SampleRequest, Service,
};
use unipc::json::Value;
use unipc::server::{Client, Server};
use unipc::trace::{SpanEvent, Stage};

fn analytic_backend() -> ModelBackend {
    let spec = DatasetSpec::Cifar10Like;
    let gm = Arc::new(dataset(spec));
    let classes = (0..spec.n_classes()).map(|c| spec.class_components(c)).collect();
    ModelBackend::Analytic { gm, class_components: Arc::new(classes) }
}

/// Count events of `stage` belonging to `id`.
fn count(events: &[SpanEvent], id: u64, stage: Stage) -> usize {
    events.iter().filter(|e| e.trace_id == id && e.stage == stage).count()
}

/// Every admitted request yields exactly one complete span tree even when
/// ~10% of model evals panic or NaN: one admit, one queue (worker pop or
/// batch absorption), one terminal respond agreeing with the typed
/// response. Retries and quarantines add spans; they never duplicate or
/// drop the terminal.
#[test]
fn every_admitted_request_yields_one_complete_tree_under_chaos() {
    silence_injected_panics();
    let svc = Service::start(
        ServerConfig {
            workers: 4,
            shards: 2,
            queue_cap: 4096,
            trace_buf: 1 << 16, // nothing may fall off the ring mid-test
            ..Default::default()
        },
        ModelBackend::chaos(
            analytic_backend(),
            ChaosConfig {
                seed: 23,
                panic_rate: 0.05,
                nan_rate: 0.05,
                ..ChaosConfig::default()
            },
        ),
    );

    let threads = 4usize;
    let per_thread = 16usize;
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let svc = svc.clone();
            std::thread::spawn(move || {
                (0..per_thread)
                    .map(|i| {
                        let k = (t * per_thread + i) as u64;
                        let r = svc.sample_blocking(SampleRequest {
                            n: 1,
                            steps: 5 + (k % 4) as usize,
                            class: Some((k % 8) as usize),
                            seed: k,
                            return_samples: false,
                            ..Default::default()
                        });
                        (r.trace_id, r.ok, r.kind)
                    })
                    .collect::<Vec<_>>()
            })
        })
        .collect();
    let mut outcomes = Vec::new();
    for h in handles {
        outcomes.extend(h.join().expect("submitter thread panicked"));
    }
    let total = threads * per_thread;
    assert_eq!(outcomes.len(), total);

    // Minted ids are nonzero and unique per request.
    let ids: std::collections::BTreeSet<u64> = outcomes.iter().map(|&(id, _, _)| id).collect();
    assert!(!ids.contains(&0), "0 is the unset sentinel, never a minted id");
    assert_eq!(ids.len(), total, "every request gets its own trace id");

    // Nothing was dropped, so the ring is a complete record.
    let m = svc.metrics_json();
    assert_eq!(m.get("trace_dropped").and_then(|v| v.as_f64()), Some(0.0));

    let events = svc.trace_events();
    for &(id, ok, kind) in &outcomes {
        assert_eq!(count(&events, id, Stage::Admit), 1, "trace {id}: one admit");
        assert_eq!(
            count(&events, id, Stage::Queue),
            1,
            "trace {id}: exactly one queue span (popped xor absorbed)"
        );
        let respond: Vec<&SpanEvent> = events
            .iter()
            .filter(|e| e.trace_id == id && e.stage == Stage::Respond)
            .collect();
        assert_eq!(respond.len(), 1, "trace {id}: exactly one terminal respond");
        let want = match kind {
            None => 0,
            Some(k) => k.index() as u64 + 1,
        };
        assert_eq!(
            respond[0].a, want,
            "trace {id}: respond outcome must match the typed response (ok={ok})"
        );
    }
    svc.shutdown();
}

/// Work stealing leaves an audit trail on the *victim* shard: every route
/// event with a steal origin (`b != 0`) was recorded on the shard it names
/// as owner, points at a different stealer home, and the event count
/// equals the `steals` counter exactly.
#[test]
fn steals_are_attributed_to_the_victim_shard() {
    let svc = Service::start(
        ServerConfig {
            workers: 4,
            shards: 4,
            queue_cap: 4096,
            // No batch absorption: every job is a leader pop, so the hot
            // shard can only drain through pops — most of them steals.
            max_batch: 1,
            trace_buf: 1 << 16,
            ..Default::default()
        },
        analytic_backend(),
    );
    // One batch key: everything routes to a single hot shard, so the three
    // workers homed elsewhere can only make progress by stealing.
    let rxs: Vec<_> = (0..96u64)
        .map(|i| {
            svc.submit(SampleRequest {
                n: 1,
                steps: 5,
                seed: i,
                return_samples: false,
                ..Default::default()
            })
            .unwrap()
        })
        .collect();
    for rx in rxs {
        assert!(rx.recv_timeout(Duration::from_secs(120)).expect("response").ok);
    }

    let m = svc.metrics_json();
    let steals = m.get("steals").and_then(|v| v.as_f64()).unwrap();
    assert!(steals > 0.0, "a single hot key over 4 shards must force steals");
    assert_eq!(m.get("trace_dropped").and_then(|v| v.as_f64()), Some(0.0));

    let events = svc.trace_events();
    let stolen: Vec<&SpanEvent> = events
        .iter()
        .filter(|e| e.stage == Stage::Route && e.b != 0)
        .collect();
    assert_eq!(
        stolen.len() as f64,
        steals,
        "one steal-marked route event per counted steal"
    );
    for e in stolen {
        assert_eq!(
            e.shard as u64, e.a,
            "steal must be recorded on the victim (owner) shard"
        );
        assert_ne!(
            e.b - 1,
            e.a,
            "stealer home must differ from the victim shard"
        );
    }
    svc.shutdown();
}

/// A NaN-targeted member of a surviving cohort carries a `quarantine` span
/// (with the non-finite failure code) while its unharmed cohort mates
/// respond ok without one.
#[test]
fn quarantined_members_carry_a_quarantine_span() {
    silence_injected_panics();
    let svc = Service::start(
        ServerConfig {
            workers: 1,
            queue_cap: 256,
            batch_linger_us: 50_000,
            trace_buf: 1 << 16,
            ..Default::default()
        },
        ModelBackend::chaos(
            analytic_backend(),
            ChaosConfig {
                seed: 11,
                nan_rate: 1.0,
                target_class: Some(4),
                ..ChaosConfig::default()
            },
        ),
    );
    // Same plan key: the doomed class-4 member and three healthy members
    // linger into one cohort.
    let classes = [4usize, 0, 1, 2];
    let rxs: Vec<_> = classes
        .iter()
        .map(|&c| {
            svc.submit(SampleRequest {
                n: 1,
                steps: 5,
                class: Some(c),
                seed: c as u64,
                return_samples: false,
                ..Default::default()
            })
            .unwrap()
        })
        .collect();
    let mut doomed_id = 0u64;
    let mut healthy_ids = Vec::new();
    for (&c, rx) in classes.iter().zip(rxs) {
        let r = rx.recv_timeout(Duration::from_secs(60)).expect("response");
        if c == 4 {
            assert!(!r.ok, "targeted member must be quarantined");
            assert_eq!(r.kind, Some(FailureKind::NonFiniteOutput), "{:?}", r.error);
            doomed_id = r.trace_id;
        } else {
            assert!(r.ok, "untargeted member must survive: {:?}", r.error);
            healthy_ids.push(r.trace_id);
        }
    }
    let events = svc.trace_events();
    let quarantines: Vec<&SpanEvent> = events
        .iter()
        .filter(|e| e.trace_id == doomed_id && e.stage == Stage::Quarantine)
        .collect();
    assert_eq!(quarantines.len(), 1, "doomed member must carry one quarantine span");
    assert_eq!(
        quarantines[0].b,
        FailureKind::NonFiniteOutput.index() as u64,
        "quarantine span carries the failure code"
    );
    for id in healthy_ids {
        assert_eq!(
            count(&events, id, Stage::Quarantine),
            0,
            "healthy cohort mates never carry a quarantine span"
        );
        assert_eq!(count(&events, id, Stage::Respond), 1);
    }
    svc.shutdown();
}

/// Trace ids round-trip the wire: the client's id comes back on the
/// response and keys the span tree served by the `trace` op; requests
/// without one get a server-minted id. Trees read admit-first,
/// respond-last.
#[test]
fn trace_ids_round_trip_the_wire() {
    let svc = Service::start(
        ServerConfig { workers: 2, queue_cap: 256, ..Default::default() },
        analytic_backend(),
    );
    let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
    let mut c = Client::connect(&server.addr.to_string()).unwrap();

    let chosen = c
        .sample(&SampleRequest {
            n: 1,
            steps: 5,
            trace_id: Some(777),
            return_samples: false,
            ..Default::default()
        })
        .unwrap();
    assert!(chosen.ok, "{:?}", chosen.error);
    assert_eq!(chosen.trace_id, 777, "client-chosen id must round-trip");

    let minted = c
        .sample(&SampleRequest { n: 1, steps: 5, seed: 9, return_samples: false, ..Default::default() })
        .unwrap();
    assert!(minted.ok, "{:?}", minted.error);
    assert_ne!(minted.trace_id, 0, "server must mint an id when the client sends none");
    assert_ne!(minted.trace_id, 777);

    // The trace op serves both trees; spans are ordered admit -> respond.
    let traces = c.trace(16).unwrap();
    let arr = traces.as_arr().expect("traces is an array");
    let mut by_id: BTreeMap<u64, &Value> = BTreeMap::new();
    for t in arr {
        let id = t.get("trace_id").and_then(|v| v.as_f64()).expect("tree id") as u64;
        by_id.insert(id, t);
    }
    for id in [777, minted.trace_id] {
        let tree = by_id.get(&id).unwrap_or_else(|| panic!("tree {id} missing: {traces:?}"));
        let spans = tree.get("spans").and_then(|v| v.as_arr()).expect("spans");
        assert!(spans.len() >= 4, "admit/route/queue/respond at minimum: {spans:?}");
        assert_eq!(spans[0].get("stage").and_then(|v| v.as_str()), Some("admit"));
        assert_eq!(
            spans.last().unwrap().get("stage").and_then(|v| v.as_str()),
            Some("respond")
        );
    }
    server.stop();
    svc.shutdown();
}
