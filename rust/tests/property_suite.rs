//! Cross-module property tests (DESIGN.md §6) on the from-scratch
//! [`unipc::testing`] harness — the offline stand-in for proptest.

use unipc::analytic::datasets::{dataset, DatasetSpec};
use unipc::analytic::GmmModel;
use unipc::json::{self, Value};
use unipc::numerics::phi::{factorial, phi, psi};
use unipc::numerics::vandermonde::{unipc_coeffs, vandermonde_matrix, BFunction};
use unipc::rng::Rng;
use unipc::sched::{timesteps, NoiseSchedule, TimeSpacing, VpCosine, VpLinear};
use unipc::solver::unipc::CoeffVariant;
use unipc::solver::{sample, DynamicThresholding, Method, Prediction, SampleOptions};
use unipc::tensor::Tensor;
use unipc::testing::check;
use unipc::weights::{WeightTensor, WeightsFile};

#[test]
fn prop_phi_psi_mirror() {
    // ψ_k(h) = φ_k(−h) across random orders and step sizes.
    check("phi/psi mirror", 300, |g| {
        let k = g.usize_in(0, 7);
        let h = g.f64_in(-3.0, 3.0);
        let a = psi(k, h);
        let b = phi(k, -h);
        assert!((a - b).abs() <= 1e-12 * (1.0 + a.abs()), "k={k} h={h}: {a} vs {b}");
    });
}

#[test]
fn prop_phi_recurrence_everywhere() {
    check("phi recurrence", 300, |g| {
        let k = g.usize_in(0, 5);
        let h = g.f64_in(-2.5, 2.5);
        if h.abs() < 1e-3 {
            return; // recurrence itself is ill-conditioned there by design
        }
        let lhs = phi(k + 1, h);
        let rhs = (phi(k, h) - 1.0 / factorial(k)) / h;
        assert!((lhs - rhs).abs() < 1e-7 * (1.0 + lhs.abs()), "k={k} h={h}");
    });
}

#[test]
fn prop_vandermonde_solve_satisfies_rows() {
    // For random strictly increasing node sets, the solved coefficients
    // satisfy every row of Theorem 3.1's system.
    check("vandermonde rows", 150, |g| {
        let q = g.usize_in(2, 5);
        let mut rks = g.increasing_f64(q - 1, -4.0, -0.05);
        rks.push(1.0);
        let hh = g.f64_in(0.05, 2.0) * if g.bool() { 1.0 } else { -1.0 };
        let b = *g.pick(&[BFunction::Bh1, BFunction::Bh2]);
        let a = unipc_coeffs(&rks, hh, b);
        let v = vandermonde_matrix(&rks);
        let bh = b.eval(hh);
        for k in 1..=q {
            let lhs: f64 = (0..q).map(|m| v[(k - 1) * q + m] * a[m]).sum::<f64>() * bh;
            let rhs = hh * factorial(k) * phi(k + 1, hh);
            assert!(
                (lhs - rhs).abs() < 1e-8 * (1.0 + rhs.abs()),
                "q={q} k={k} hh={hh}: {lhs} vs {rhs}"
            );
        }
    });
}

#[test]
fn prop_schedule_roundtrip_and_monotone() {
    check("schedule λ roundtrip", 200, |g| {
        let lin = VpLinear::default();
        let cos = VpCosine::default();
        let t = g.f64_in(1e-3, 0.98);
        for sched in [&lin as &dyn NoiseSchedule, &cos] {
            let lam = sched.lambda(t);
            let t2 = sched.t_of_lambda(lam);
            assert!((t2 - t).abs() < 1e-5, "{} t={t} -> {t2}", sched.name());
            // α² + σ² = 1 (VP).
            let (a, s) = (sched.alpha(t), sched.sigma(t));
            assert!((a * a + s * s - 1.0).abs() < 1e-9);
        }
    });
}

#[test]
fn prop_timesteps_valid_for_random_grids() {
    check("timestep grids", 200, |g| {
        let sched = VpLinear::default();
        let steps = g.usize_in(1, 40);
        let t_end = g.f64_in(5e-4, 0.05);
        let t_start = g.f64_in(0.5, 1.0);
        let spacing = *g.pick(&[TimeSpacing::LogSnr, TimeSpacing::Uniform, TimeSpacing::Quadratic]);
        let ts = timesteps(&sched, spacing, t_start, t_end, steps);
        assert_eq!(ts.len(), steps + 1);
        assert_eq!(ts[0], t_start);
        assert!((ts[steps] - t_end).abs() < 1e-12);
        for w in ts.windows(2) {
            assert!(w[1] < w[0], "{spacing:?} not strictly decreasing");
        }
    });
}

#[test]
fn prop_method_string_roundtrip() {
    // Round-trip contract of the method registry: every zoo entry survives
    // `parse(id())` and `parse(cache_key())`; random order-scheduled UniP
    // variants (whose display id is lossy by design) survive
    // `parse(cache_key())` with the schedule contents intact.
    for m in Method::zoo() {
        assert_eq!(Method::parse(&m.id()).as_ref(), Some(&m), "id {}", m.id());
        assert_eq!(
            Method::parse(&m.cache_key()).as_ref(),
            Some(&m),
            "cache_key {}",
            m.cache_key()
        );
    }
    check("scheduled-method cache_key roundtrip", 100, |g| {
        let order = g.usize_in(1, 4);
        let len = g.usize_in(1, 8);
        let schedule: Vec<usize> = (0..len).map(|_| g.usize_in(1, order)).collect();
        let variant = *g.pick(&[
            CoeffVariant::Bh(BFunction::Bh1),
            CoeffVariant::Bh(BFunction::Bh2),
            CoeffVariant::Varying,
        ]);
        let pred = if g.bool() { Prediction::Noise } else { Prediction::Data };
        let m = Method::UniP { order, variant, pred, schedule: Some(schedule) };
        let parsed = Method::parse(&m.cache_key());
        assert_eq!(parsed.as_ref(), Some(&m), "{}", m.cache_key());
    });
}

#[test]
fn prop_sampler_nfe_accounting_and_determinism() {
    // Across random methods/steps: NFE matches the documented contract and
    // sampling is deterministic in (seed, config).
    let gm = dataset(DatasetSpec::BedroomLike);
    let sched = VpLinear::default();
    let model = GmmModel { gm: &gm, sched: &sched };
    check("sampler NFE + determinism", 40, |g| {
        let steps = g.usize_in(2, 12);
        let method = match g.usize_in(0, 5) {
            0 => Method::Ddim { pred: Prediction::Noise },
            1 => Method::unip(g.usize_in(1, 3), BFunction::Bh2, Prediction::Noise),
            2 => Method::DpmSolverPp { order: g.usize_in(1, 3) },
            3 => Method::Plms,
            4 => Method::Deis { order: g.usize_in(1, 3) },
            _ => Method::DpmSolverSingle { order: 3 },
        };
        let unic = g.bool() && !method.is_singlestep();
        let mut opts = SampleOptions::new(method.clone(), steps);
        if unic {
            opts = opts.with_unic(CoeffVariant::Bh(BFunction::Bh2), false);
        }
        let seed = g.usize_in(0, 1_000_000) as u64;
        let x_t = Rng::seed_from(seed).normal_tensor(&[2, gm.dim]);
        let r1 = sample(&model, &sched, &x_t, &opts);
        let r2 = sample(&model, &sched, &x_t, &opts);
        assert_eq!(r1.x, r2.x, "determinism for {}", opts.id());
        assert_eq!(r1.nfe, steps, "NFE contract for {}", opts.id());
        assert!(r1.x.data().iter().all(|v| v.is_finite()), "{}", opts.id());
    });
}

#[test]
fn prop_corrector_never_changes_nfe() {
    let gm = dataset(DatasetSpec::BedroomLike);
    let sched = VpLinear::default();
    let model = GmmModel { gm: &gm, sched: &sched };
    check("UniC is NFE-neutral", 30, |g| {
        let steps = g.usize_in(2, 10);
        let order = g.usize_in(1, 3);
        let x_t = Rng::seed_from(7).normal_tensor(&[1, gm.dim]);
        let base = SampleOptions::new(Method::unip(order, BFunction::Bh1, Prediction::Noise), steps);
        let with = base.clone().with_unic(CoeffVariant::Bh(BFunction::Bh1), false);
        assert_eq!(
            sample(&model, &sched, &x_t, &base).nfe,
            sample(&model, &sched, &x_t, &with).nfe
        );
    });
}

#[test]
fn prop_json_roundtrip_random_documents() {
    fn random_value(g: &mut unipc::testing::Gen, depth: usize) -> Value {
        match if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) } {
            0 => Value::Null,
            1 => Value::Bool(g.bool()),
            2 => Value::Num((g.f64_in(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => {
                let n = g.usize_in(0, 8);
                let s: String = (0..n)
                    .map(|_| *g.pick(&['a', 'β', '"', '\\', '\n', '😀', ' ', 'z']))
                    .collect();
                Value::Str(s)
            }
            4 => Value::Arr((0..g.usize_in(0, 4)).map(|_| random_value(g, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..g.usize_in(0, 4) {
                    m.insert(format!("k{i}"), random_value(g, depth - 1));
                }
                Value::Obj(m)
            }
        }
    }
    check("json roundtrip", 300, |g| {
        let v = random_value(g, 3);
        let text = v.to_string();
        let back = json::parse(&text).unwrap_or_else(|e| panic!("{e}: {text}"));
        assert_eq!(v, back, "{text}");
    });
}

#[test]
fn prop_weights_roundtrip_random_files() {
    check("weights roundtrip", 100, |g| {
        let n = g.usize_in(1, 6);
        let tensors: Vec<WeightTensor> = (0..n)
            .map(|i| {
                let ndim = g.usize_in(0, 3);
                let dims: Vec<usize> = (0..ndim).map(|_| g.usize_in(1, 5)).collect();
                let numel = dims.iter().product::<usize>().max(1);
                WeightTensor {
                    name: format!("t{i}"),
                    dims: if ndim == 0 { vec![1] } else { dims },
                    data: g.vec_f64(numel, -10.0, 10.0).iter().map(|&v| v as f32).collect(),
                }
            })
            .map(|mut t| {
                // keep numel consistent when ndim == 0 path produced [1]
                t.data.truncate(t.dims.iter().product());
                while t.data.len() < t.dims.iter().product() {
                    t.data.push(0.0);
                }
                t
            })
            .collect();
        let wf = WeightsFile::new(tensors).unwrap();
        let back = WeightsFile::from_bytes(&wf.to_bytes()).unwrap();
        assert_eq!(wf.tensors(), back.tensors());
    });
}

#[test]
fn prop_thresholding_bounds_and_idempotence() {
    check("thresholding clip", 200, |g| {
        let n = g.usize_in(1, 4);
        let d = g.usize_in(2, 16);
        let bound = g.f64_in(0.5, 5.0);
        let th = DynamicThresholding::clip(bound);
        let mut x = Tensor::from_vec(&[n, d], g.vec_f64(n * d, -20.0, 20.0));
        let before = x.max_abs();
        th.apply(&mut x);
        // Clipping never grows magnitudes, never drops below the scale
        // floor's reach, and repeated application keeps shrinking toward
        // the floor (quantile-based clipping is contractive, not
        // idempotent — re-clipping re-estimates the quantile).
        let after1 = x.max_abs();
        assert!(after1 <= before + 1e-12);
        th.apply(&mut x);
        assert!(x.max_abs() <= after1 + 1e-12, "clip must be contractive");
        assert!(x.max_abs() + 1e-12 >= bound.min(after1), "never clips below the floor");
    });
}

#[test]
fn prop_rng_split_streams_do_not_collide() {
    check("rng stream independence", 50, |g| {
        let seed = g.usize_in(0, u32::MAX as usize) as u64;
        let root = Rng::seed_from(seed);
        let a: Vec<u64> = {
            let mut s = root.split(1);
            (0..8).map(|_| s.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut s = root.split(2);
            (0..8).map(|_| s.next_u64()).collect()
        };
        assert_ne!(a, b, "seed {seed}");
    });
}
