//! Counting-allocator proof of the plan executor's zero-allocation
//! invariant: a steady-state UniPC step driven by a [`SamplePlan`] +
//! [`StepWorkspace`] must not touch the heap in the solver arithmetic
//! (model evaluations are outside the claim — they produce fresh output
//! tensors by contract).
//!
//! This lives in its own integration-test binary so no concurrently
//! running test can allocate inside the counting window; the counter is
//! additionally thread-local so harness threads cannot pollute it.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

struct CountingAlloc;

thread_local! {
    static ARMED: Cell<bool> = const { Cell::new(false) };
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = ARMED.try_with(|armed| {
            if armed.get() {
                let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
            }
        });
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

use std::time::Instant;

use unipc::numerics::vandermonde::BFunction;
use unipc::rng::Rng;
use unipc::sched::{NoiseSchedule, VpLinear};
use unipc::solver::{
    History, Method, Prediction, SampleOptions, SamplePlan, StepObserver, StepWorkspace,
    UniPcCoeffs,
};
use unipc::tensor::Tensor;
use unipc::trace::{SpanEvent, Stage, StepSpans, TimedModel, TraceRing};

#[test]
fn steady_state_unipc_step_is_allocation_free() {
    let sched = VpLinear::default();
    let configs = [
        SampleOptions::unipc(3, BFunction::Bh2, Prediction::Noise, 8),
        SampleOptions::new(
            Method::UniP {
                order: 3,
                variant: UniPcCoeffs::Varying,
                pred: Prediction::Noise,
                schedule: None,
            },
            8,
        )
        .with_unic(UniPcCoeffs::Varying, false),
    ];
    for opts in configs {
        let plan = SamplePlan::build(&sched, &opts).expect("plannable config");
        let shape = [16usize, 8];
        let mut rng = Rng::seed_from(9);

        // Seed a full-order history, as the warm-up steps would have.
        let mut hist = History::new(3);
        for t in [0.9f64, 0.8, 0.7] {
            hist.push(t, sched.lambda(t), rng.normal_tensor(&shape));
        }
        let mut x = rng.normal_tensor(&shape);
        let m_t = rng.normal_tensor(&shape);
        let mut ws = StepWorkspace::new(&shape, plan.ws_rows());

        // A steady-state step: order-3 predictor + corrector, mid-run.
        let k = 5;
        assert_eq!(plan.steps()[k].order, 3);
        assert!(plan.has_corrector(k));

        // Warm once outside the window (nothing should allocate even here,
        // but the claim is about steady state).
        plan.predict_into(k, &hist, &x, &mut ws);
        plan.correct_into(k, &hist, &m_t, &mut ws, &mut x);

        ALLOCS.with(|c| c.set(0));
        ARMED.with(|a| a.set(true));
        for _ in 0..64 {
            plan.predict_into(k, &hist, &x, &mut ws);
            let applied = plan.correct_into(k, &hist, &m_t, &mut ws, &mut x);
            assert!(applied);
        }
        ARMED.with(|a| a.set(false));
        let n = ALLOCS.with(|c| c.get());
        assert_eq!(
            n, 0,
            "steady-state planned step allocated {n} times ({})",
            plan.key()
        );
    }
}

/// The tentpole's zero-alloc claim across the newly planned non-UniPC
/// multistep families: a steady-state DPM-Solver++ (2M/3M), DEIS, PNDM, or
/// DDIM step — predictor plus UniC corrector — driven from a plan must not
/// touch the heap in the solver arithmetic. (Singlestep groups evaluate the
/// model at interior nodes mid-step, which allocates by the model contract,
/// so they are exercised by the conformance suite instead.)
#[test]
fn steady_state_baseline_steps_are_allocation_free() {
    let sched = VpLinear::default();
    let methods = [
        Method::Ddim { pred: Prediction::Noise },
        Method::DpmSolverPp { order: 2 },
        Method::DpmSolverPp { order: 3 },
        Method::Plms,
        Method::Deis { order: 3 },
    ];
    for method in methods {
        let opts =
            SampleOptions::new(method, 8).with_unic(UniPcCoeffs::Bh(BFunction::Bh2), false);
        let plan = SamplePlan::build(&sched, &opts).expect("plannable config");
        let shape = [16usize, 8];
        let mut rng = Rng::seed_from(31);

        // Seed a full history buffer, as the warm-up steps would have.
        let cap = plan.history_cap();
        let mut hist = History::new(cap);
        for j in 0..cap {
            let t = 0.95 - 0.07 * j as f64;
            hist.push(t, sched.lambda(t), rng.normal_tensor(&shape));
        }
        let mut x = rng.normal_tensor(&shape);
        let m_t = rng.normal_tensor(&shape);
        let mut ws = StepWorkspace::new(&shape, plan.ws_rows());

        // A steady-state mid-run step with an active corrector.
        let k = 5;
        assert!(plan.has_corrector(k), "{}", plan.key());

        plan.predict_into(k, &hist, &x, &mut ws);
        plan.correct_into(k, &hist, &m_t, &mut ws, &mut x);

        ALLOCS.with(|c| c.set(0));
        ARMED.with(|a| a.set(true));
        for _ in 0..64 {
            plan.predict_into(k, &hist, &x, &mut ws);
            let applied = plan.correct_into(k, &hist, &m_t, &mut ws, &mut x);
            assert!(applied);
        }
        ARMED.with(|a| a.set(false));
        let n = ALLOCS.with(|c| c.get());
        assert_eq!(
            n, 0,
            "steady-state planned step allocated {n} times ({})",
            plan.key()
        );
    }
}

/// The workspace-pooling contract behind batched serving: once a worker's
/// buffers have warmed up at their largest batch shape, re-acquiring the
/// workspace for equal or smaller batches ([`StepWorkspace::ensure`]) and
/// assembling member states into the stacked batch tensor
/// ([`Tensor::resize_to`] + [`Tensor::copy_rows_from`]) never touch the
/// allocator — so a steady-state batched run starts allocation-free.
#[test]
fn pooled_workspace_and_batch_assembly_are_allocation_free_after_warmup() {
    let mut rng = Rng::seed_from(17);
    let member_a = rng.normal_tensor(&[4, 8]);
    let member_b = rng.normal_tensor(&[8, 8]);

    // Warm up at the largest shape this "worker" will see.
    let mut ws = StepWorkspace::new(&[12, 8], 3);
    let mut stacked = Tensor::zeros(&[12, 8]);

    ALLOCS.with(|c| c.set(0));
    ARMED.with(|a| a.set(true));
    for _ in 0..32 {
        // Same-shape reacquisition (the common steady-state case)…
        assert!(ws.ensure(&[12, 8], 3), "warm ensure must reuse");
        assert!(stacked.resize_to(&[12, 8]));
        stacked.copy_rows_from(0, &member_a);
        stacked.copy_rows_from(4, &member_b);
        // …and shrink + regrow within pooled capacity.
        assert!(ws.ensure(&[4, 8], 3));
        assert!(stacked.resize_to(&[4, 8]));
        stacked.copy_rows_from(0, &member_a);
        assert!(ws.ensure(&[12, 8], 3));
        assert!(stacked.resize_to(&[12, 8]));
    }
    ARMED.with(|a| a.set(false));
    let n = ALLOCS.with(|c| c.get());
    assert_eq!(n, 0, "pooled workspace reacquisition allocated {n} times");
}

/// The tracing subsystem's zero-allocation claim: once a worker's span
/// scratch has warmed to the per-batch reservation bound and the shard
/// ring exists (preallocated at construction), recording a full batch's
/// worth of spans — assemble, cohort links, per-step model/solver pairs
/// via [`StepSpans`], terminal responds, and the single
/// [`TraceRing::record_all`] flush — never touches the heap, even as the
/// ring wraps (overwrite, not growth).
#[test]
fn steady_state_trace_recording_is_allocation_free() {
    let model = (Prediction::Noise, 4usize, |x: &Tensor, _t: f64| x.clone());
    let timed = TimedModel::new(&model);
    let epoch = Instant::now();
    let mut ring = TraceRing::new(256);
    let mut spans: Vec<SpanEvent> = Vec::new();
    let steps = 8usize;
    let members = 4usize;

    // One batch's worth of recording, shaped exactly like the worker's
    // execute_batch at trace=steps (same reservation bound, same event
    // mix, one ring flush at the end).
    let run = |spans: &mut Vec<SpanEvent>, ring: &mut TraceRing| {
        spans.clear();
        spans.reserve(2 * steps + 3 * members + 2);
        spans.push(SpanEvent {
            trace_id: 1,
            stage: Stage::Assemble,
            a: members as u64,
            b: 1,
            ..Default::default()
        });
        for i in 0..members {
            spans.push(SpanEvent {
                trace_id: 2 + i as u64,
                parent: 1,
                stage: Stage::CohortLink,
                a: i as u64,
                b: 1,
                ..Default::default()
            });
        }
        {
            let health = unipc::solver::StepHealth::default();
            let mut obs = StepSpans::new(&mut *spans, &timed, epoch, 1, 0, 0, members as u64);
            for k in 0..steps {
                obs.on_step(k, &health);
            }
        }
        for i in 0..members {
            spans.push(SpanEvent {
                trace_id: 2 + i as u64,
                stage: Stage::Respond,
                b: steps as u64,
                ..Default::default()
            });
        }
        ring.record_all(spans);
    };

    // Warm batch: grows the scratch to the steady-state capacity, exactly
    // as a worker's first batch does.
    run(&mut spans, &mut ring);

    ALLOCS.with(|c| c.set(0));
    ARMED.with(|a| a.set(true));
    for _ in 0..64 {
        run(&mut spans, &mut ring);
    }
    ARMED.with(|a| a.set(false));
    let n = ALLOCS.with(|c| c.get());
    assert_eq!(n, 0, "steady-state span recording allocated {n} times");
    assert!(
        ring.dropped() > 0,
        "65 batches x 25 events must wrap a 256-slot ring — overwrite, never grow"
    );
}

/// The telemetry plane's windowed time-series store is fixed-size arrays
/// end to end: recording completions, failures, batches, depths, and
/// steals into the 60×1s + 60×1m rings — including slot recycling as the
/// clock advances past a full ring span — and querying window totals never
/// touch the heap.
#[test]
fn windowed_metrics_recording_is_allocation_free() {
    use unipc::coordinator::FailureKind;
    use unipc::telemetry::WindowStore;

    let mut w = WindowStore::default();
    // Warm nothing: the store is inline arrays from construction. Arm
    // immediately and drive synthetic time far enough to recycle every
    // slot in both rings several times over.
    ALLOCS.with(|c| c.set(0));
    ARMED.with(|a| a.set(true));
    let mut acc = 0u64;
    for now_s in 0..10_000u64 {
        w.record_completion(now_s, 2, 16, 1_500 + now_s % 7_000);
        if now_s % 11 == 0 {
            w.record_failure(now_s, FailureKind::DeadlineExceeded);
        }
        w.record_batch(now_s, 4);
        w.record_depth(now_s, (now_s % 40) as usize);
        w.record_steal(now_s);
        if now_s % 100 == 0 {
            let t = w.totals(now_s, 60);
            acc += t.completed + t.e2e_hist[0];
        }
    }
    ARMED.with(|a| a.set(false));
    let n = ALLOCS.with(|c| c.get());
    assert_eq!(n, 0, "windowed recording allocated {n} times (acc={acc})");
}

/// The subscription flush path's zero-allocation claim: with no subscriber,
/// publishing is a single atomic load; with a subscriber whose bounded
/// queue has warmed to capacity, publishing span batches pushes into
/// preallocated storage and counts overflow — no heap traffic either way.
#[test]
fn event_hub_publish_is_allocation_free() {
    use unipc::telemetry::EventHub;

    let hub = EventHub::new();
    let spans: Vec<SpanEvent> = (0..25)
        .map(|i| SpanEvent { trace_id: i as u64 + 1, ..Default::default() })
        .collect();

    // No subscriber: the hot path every worker pays by default.
    ALLOCS.with(|c| c.set(0));
    ARMED.with(|a| a.set(true));
    for _ in 0..256 {
        hub.publish_spans(&spans);
    }
    ARMED.with(|a| a.set(false));
    let n = ALLOCS.with(|c| c.get());
    assert_eq!(n, 0, "no-subscriber publish allocated {n} times");

    // Active subscriber: queue preallocated at subscribe time; publishing
    // into it (including overflow past cap) must not allocate. Draining is
    // the subscriber's cost, outside the worker-side claim.
    let sub = hub.subscribe(64);
    let mut drained = Vec::with_capacity(256);
    ALLOCS.with(|c| c.set(0));
    ARMED.with(|a| a.set(true));
    for _ in 0..64 {
        hub.publish_spans(&spans); // 25 events: fills, then overflows
    }
    ARMED.with(|a| a.set(false));
    let n = ALLOCS.with(|c| c.get());
    assert_eq!(n, 0, "subscribed publish allocated {n} times");
    assert!(hub.dropped() > 0, "64x25 events past a 64-cap queue must count drops");
    sub.drain_into(&mut drained);
    assert_eq!(drained.len(), 64);
    hub.unsubscribe(&sub);
}
