//! Concurrency/invariant suite for the sharded coordinator.
//!
//! The service partitions its queue into N shards routed by
//! `hash(batch_key) % N` with cross-shard work stealing. These tests pin
//! the invariants that make the partitioning invisible to clients:
//!
//! * **routing is deterministic** — the same batch key always lands on the
//!   same shard, and seeds (not part of the key) never change the route;
//! * **conditioning never splits a cohort** — the batch key is the plan key
//!   alone, so any class/guidance mix shares its plan's route and stacks
//!   into one lockstep cohort; the `split_cond_batches` ablation restores
//!   the legacy per-conditioning keys and demonstrably smaller batches;
//! * **results are shard-count-independent** — a workload run against an
//!   N-shard service is bit-identical to the same workload against a
//!   1-shard service;
//! * **exactly one typed response per request** under a multi-threaded
//!   submitter storm with ~10% injected faults (set `UNIPC_STRESS=1` for
//!   elevated thread/request counts — `make stress`);
//! * **aggregation is exact** — the global metrics snapshot equals the
//!   field-wise sum of the per-shard snapshots for every counter and
//!   histogram bucket (percentiles are recomputed from merged raw samples,
//!   never summed).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use unipc::analytic::datasets::{dataset, DatasetSpec};
use unipc::config::ServerConfig;
use unipc::coordinator::{
    shard_for_key, silence_injected_panics, ChaosConfig, ModelBackend, SampleRequest,
    Service,
};
use unipc::server::{run_load, LoadConfig, Server};

fn analytic_backend() -> ModelBackend {
    let spec = DatasetSpec::Cifar10Like;
    let gm = Arc::new(dataset(spec));
    let classes = (0..spec.n_classes()).map(|c| spec.class_components(c)).collect();
    ModelBackend::Analytic { gm, class_components: Arc::new(classes) }
}

fn service(workers: usize, shards: usize) -> Service {
    let cfg = ServerConfig { workers, shards, queue_cap: 4096, ..Default::default() };
    Service::start(cfg, analytic_backend())
}

/// A workload template that fans across batch keys: the batch key is the
/// plan key alone, so the step count cycles to produce distinct keys that
/// route to (generally) distinct shards. The class label cycles too —
/// conditioning rides along inside cohorts without touching the route.
fn mixed_request(i: u64) -> SampleRequest {
    SampleRequest {
        n: 1,
        steps: 5 + (i % 8) as usize,
        class: Some((i % 8) as usize),
        seed: i,
        ..Default::default()
    }
}

/// Stress knobs: `UNIPC_STRESS=1` (see `make stress`) raises the storm
/// from a CI-friendly smoke to an actual contention test.
fn stress_level() -> (usize, usize) {
    if std::env::var("UNIPC_STRESS").is_ok_and(|v| v != "0") {
        (16, 64) // threads, requests per thread
    } else {
        (4, 16)
    }
}

/// Same batch key ⇒ same shard, for any shard count; the seed is not part
/// of the key and never changes the route.
#[test]
fn routing_is_deterministic_per_batch_key() {
    // The pure hash itself is stable and in range.
    for shards in 1..=8 {
        for steps in 5..13usize {
            let key = format!("vp|unipc-3|steps={steps}");
            let s = shard_for_key(&key, shards);
            assert!(s < shards);
            assert_eq!(s, shard_for_key(&key, shards));
        }
    }

    // End to end: route_of is pure in everything but the batch key.
    let svc = service(4, 4);
    assert_eq!(svc.shards(), 4);
    for i in 0..32u64 {
        let route = svc.route_of(&mixed_request(i));
        assert!(route.is_some(), "plannable request must route by key");
        for seed in [7u64, 1 << 40, u64::MAX] {
            let mut same_key = mixed_request(i);
            same_key.seed = seed;
            assert_eq!(svc.route_of(&same_key), route, "seed must not change the route");
        }
    }
    // With 8 distinct plans over 4 shards, more than one shard is hit
    // (the hash would have to be degenerate to collapse them all).
    let distinct: std::collections::BTreeSet<usize> =
        (0..8u64).filter_map(|i| svc.route_of(&mixed_request(i))).collect();
    assert!(distinct.len() > 1, "key fan-out must spread across shards: {distinct:?}");
    svc.shutdown();
}

/// A sharded service must produce bit-identical samples to a 1-shard
/// service for the same workload: routing and stealing change *where*
/// work runs, never *what* it computes.
#[test]
fn sharded_outputs_bit_identical_to_single_shard() {
    const N: u64 = 48;
    let single = service(4, 1);
    assert_eq!(single.shards(), 1);
    let refs: Vec<Option<Vec<f64>>> = (0..N)
        .map(|i| {
            let r = single.sample_blocking(mixed_request(i));
            assert!(r.ok, "{:?}", r.error);
            r.samples
        })
        .collect();
    single.shutdown();

    let sharded = service(4, 4);
    assert_eq!(sharded.shards(), 4);
    // Submit concurrently so batching and stealing actually engage.
    let rxs: Vec<_> =
        (0..N).map(|i| sharded.submit(mixed_request(i)).unwrap()).collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv_timeout(Duration::from_secs(120)).expect("response");
        assert!(r.ok, "request {i}: {:?}", r.error);
        assert_eq!(r.samples, refs[i], "request {i} must be shard-count-independent");
    }
    sharded.shutdown();
}

/// Submitter storm with ~10% injected faults: every request resolves to
/// exactly one response, and every failure is typed. The accounting must
/// close exactly — submitted = completed + failed + rejected across all
/// shards, with no request double-counted or dropped.
#[test]
fn storm_every_request_gets_exactly_one_typed_response() {
    silence_injected_panics();
    let (threads, per_thread) = stress_level();
    let cfg = ServerConfig { workers: 4, queue_cap: 4096, ..Default::default() };
    let svc = Service::start(
        cfg,
        ModelBackend::chaos(
            analytic_backend(),
            ChaosConfig {
                seed: 11,
                panic_rate: 0.05,
                nan_rate: 0.05,
                ..ChaosConfig::default()
            },
        ),
    );

    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let svc = svc.clone();
            std::thread::spawn(move || {
                let mut ok = 0u64;
                let mut typed_fail = 0u64;
                for i in 0..per_thread {
                    let mut req = mixed_request((t * per_thread + i) as u64);
                    req.return_samples = false;
                    let r = svc.sample_blocking(req);
                    if r.ok {
                        assert_eq!(r.kind, None);
                        ok += 1;
                    } else {
                        assert!(r.kind.is_some(), "untyped failure: {:?}", r.error);
                        typed_fail += 1;
                    }
                }
                (ok, typed_fail)
            })
        })
        .collect();
    let (mut ok, mut typed_fail) = (0u64, 0u64);
    for h in handles {
        let (o, f) = h.join().expect("submitter thread panicked");
        ok += o;
        typed_fail += f;
    }
    let total = (threads * per_thread) as u64;
    assert_eq!(ok + typed_fail, total, "exactly one response per request");
    assert!(ok > 0, "some requests must dodge 10% faults");

    let m = svc.metrics_json();
    let counter = |key: &str| m.get(key).and_then(|v| v.as_f64()).unwrap();
    assert_eq!(counter("submitted"), total as f64);
    assert_eq!(counter("completed"), ok as f64);
    // Typed client-side failures are failures or admission rejections
    // server-side; both sum to the same total, so nothing is lost or
    // double-counted.
    assert_eq!(counter("failed") + counter("rejected"), typed_fail as f64);
    svc.shutdown();
}

/// The global snapshot equals the field-wise sum of per-shard snapshots
/// for every counter and histogram bucket — including the shard-level
/// `steals` and `shard_depth_hist` — after a workload that exercises
/// completions, batching, stealing, and failures.
#[test]
fn global_metrics_equal_sum_of_shard_snapshots() {
    let svc = service(4, 4);
    // Mixed outcomes: successes across many keys plus invalid rejections.
    let rxs: Vec<_> = (0..64u64)
        .map(|i| {
            let mut req = mixed_request(i);
            req.return_samples = false;
            svc.submit(req).unwrap()
        })
        .collect();
    for rx in rxs {
        assert!(rx.recv_timeout(Duration::from_secs(120)).expect("response").ok);
    }
    let _ = svc.sample_blocking(SampleRequest { n: 0, ..Default::default() });
    let _ = svc.sample_blocking(SampleRequest { method: "nope".into(), ..Default::default() });

    let global = svc.metrics_json();
    let shards = svc.shard_metrics_json();
    assert_eq!(shards.len(), svc.shards());
    assert_eq!(global.get("shards").unwrap().as_f64(), Some(4.0));
    assert_eq!(global.get("shard_depths").unwrap().as_arr().unwrap().len(), 4);

    let scalar_counters = [
        "submitted", "rejected", "completed", "failed", "samples_out", "nfe_total",
        "plan_builds", "plan_hits", "batched_runs", "mixed_cond_batches",
        "workspace_reuses", "steals", "worker_restarts", "quarantined_members",
        "batch_retries",
        // per-kind failure counters
        "invalid_request", "queue_full", "deadline_exceeded", "non_finite_output",
        "worker_panic", "backend_error",
    ];
    let mut sums: BTreeMap<&str, f64> = BTreeMap::new();
    let mut hist_sums: BTreeMap<&str, Vec<f64>> = BTreeMap::new();
    for snap in &shards {
        for key in scalar_counters {
            let v = snap.get(key).and_then(|v| v.as_f64()).expect(key);
            *sums.entry(key).or_insert(0.0) += v;
        }
        for key in ["batch_size_hist", "cond_distinct_hist", "shard_depth_hist"] {
            let arr = snap.get(key).unwrap().as_arr().unwrap();
            let acc = hist_sums.entry(key).or_insert_with(|| vec![0.0; arr.len()]);
            for (a, v) in acc.iter_mut().zip(arr) {
                *a += v.as_f64().unwrap();
            }
        }
    }
    for key in scalar_counters {
        assert_eq!(
            global.get(key).and_then(|v| v.as_f64()),
            Some(sums[key]),
            "global '{key}' must be the sum of shard snapshots"
        );
    }
    for key in ["batch_size_hist", "cond_distinct_hist", "shard_depth_hist"] {
        let g: Vec<f64> = global
            .get(key)
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        assert_eq!(g, hist_sums[key], "global '{key}' must sum bucket-for-bucket");
    }
    // Sanity on the workload itself: everything completed and the depth
    // histogram saw every enqueue.
    assert_eq!(sums["completed"], 64.0);
    assert_eq!(sums["rejected"], 2.0);
    let depth_total: f64 = hist_sums["shard_depth_hist"].iter().sum();
    assert_eq!(depth_total, 64.0, "one depth observation per accepted enqueue");
    svc.shutdown();
}

/// The collapsed batch key is the plan key alone: no class/guidance
/// combination moves a request off its plan's shard, while the
/// `split_cond_batches` ablation restores the legacy per-conditioning
/// fan-out.
#[test]
fn conditioning_does_not_change_the_route() {
    let svc = service(4, 4);
    for steps in [5usize, 8, 13] {
        let base = SampleRequest { n: 1, steps, ..Default::default() };
        let home = svc.route_of(&base).expect("planned request routes");
        for class in 0..8usize {
            for guidance in [None, Some(1.5), Some(7.0)] {
                let req = SampleRequest {
                    n: 1,
                    steps,
                    class: Some(class),
                    guidance,
                    ..Default::default()
                };
                assert_eq!(
                    svc.route_of(&req),
                    Some(home),
                    "steps {steps} class {class} guidance {guidance:?} must keep the plan's route"
                );
            }
        }
    }
    svc.shutdown();

    // The ablation switch re-appends the conditioning to the key, so the
    // same classes fan out across shards again (formerly split cohorts).
    let split = Service::start(
        ServerConfig {
            workers: 4,
            shards: 4,
            queue_cap: 4096,
            split_cond_batches: true,
            ..Default::default()
        },
        analytic_backend(),
    );
    let routes: std::collections::BTreeSet<usize> = (0..8usize)
        .filter_map(|class| {
            split.route_of(&SampleRequest {
                n: 1,
                steps: 5,
                class: Some(class),
                ..Default::default()
            })
        })
        .collect();
    assert!(routes.len() > 1, "split keys must fan conditionings out again: {routes:?}");
    split.shutdown();
}

/// Formerly split cohorts colocate: under the load generator's mixed
/// class/guidance `key_mix` on one plan key, the collapsed batch key forms
/// strictly larger steady-state cohorts — the member-weighted mean of
/// `batch_size_hist` shifts upward — and mixes conditionings inside them,
/// while the `split_cond_batches` baseline can never mix at all.
#[test]
fn mixed_conditioning_batches_grow_vs_split_baseline() {
    let run = |split: bool| -> (f64, f64) {
        let svc = Service::start(
            ServerConfig {
                workers: 1,
                shards: 1,
                queue_cap: 4096,
                batch_linger_us: 20_000,
                split_cond_batches: split,
                ..Default::default()
            },
            analytic_backend(),
        );
        let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
        let cfg = LoadConfig {
            rps: 100_000.0, // no pacing: four blocking connections saturate
            total: 60,
            connections: 4,
            template: SampleRequest {
                n: 1,
                steps: 5,
                return_samples: false,
                ..Default::default()
            },
            seed: 3,
            key_mix: 8,
            mix_guidance: Some(2.0),
            plan_mix: 1,
        };
        let report = run_load(&server.addr.to_string(), &cfg).unwrap();
        assert_eq!(report.ok, 60, "clean run must succeed end to end");
        let m = svc.metrics_json();
        let hist: Vec<f64> = m
            .get("batch_size_hist")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
            .expect("batch_size_hist");
        let runs: f64 = hist.iter().sum();
        let members: f64 = hist.iter().enumerate().map(|(i, c)| (i + 1) as f64 * c).sum();
        let mixed = m.get("mixed_cond_batches").and_then(|v| v.as_f64()).unwrap();
        server.stop();
        svc.shutdown();
        (members / runs.max(1.0), mixed)
    };
    let (mean_split, mixed_split) = run(true);
    let (mean_collapsed, mixed_collapsed) = run(false);
    assert_eq!(mixed_split, 0.0, "per-conditioning keys can never form a mixed cohort");
    assert!(
        mixed_collapsed >= 1.0,
        "the collapsed key must form mixed cohorts (mean batch {mean_collapsed:.2})"
    );
    assert!(
        mean_collapsed > mean_split,
        "collapsed-key cohorts must be larger: {mean_collapsed:.2} vs split {mean_split:.2}"
    );
}
