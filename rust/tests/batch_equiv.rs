//! Equivalence proof for the batched execution layer (PR 2 tentpole):
//! `sample_batch_with_plan` over a batch of N requests must be
//! **bit-identical** to N sequential `sample_with_plan` runs with the same
//! per-request initial states, across methods, coefficient variants,
//! parametrizations, and UniC settings — plus the workspace-pool reuse
//! contract (no per-run buffer growth after warm-up).

use std::sync::Arc;

use unipc::analytic::datasets::{dataset, DatasetSpec};
use unipc::analytic::GmmModel;
use unipc::coordinator::{CohortModel, CondSlab, Conditioning, ModelBackend};
use unipc::numerics::vandermonde::BFunction;
use unipc::rng::Rng;
use unipc::sched::VpLinear;
use unipc::solver::unipc::CoeffVariant;
use unipc::solver::{
    sample, sample_batch, sample_batch_with_plan, sample_with_plan, BatchWorkspace, Method,
    Model, Prediction, SampleOptions, SamplePlan,
};
use unipc::tensor::Tensor;

fn bits(t: &Tensor) -> Vec<u64> {
    t.data().iter().map(|v| v.to_bits()).collect()
}

/// Mixed-size members (n = 1, 2, 3, 1) with distinct seeds, like a real
/// batch assembled from independent requests.
fn member_inits(dim: usize) -> Vec<Tensor> {
    [1usize, 2, 3, 1]
        .iter()
        .enumerate()
        .map(|(i, &n)| Rng::seed_from(40 + i as u64).normal_tensor(&[n, dim]))
        .collect()
}

#[test]
fn batched_run_is_bit_identical_to_sequential_across_variants() {
    let sched = VpLinear::default();
    let gm = dataset(DatasetSpec::Cifar10Like);
    let model = GmmModel { gm: &gm, sched: &sched };
    let mut bw = BatchWorkspace::new();
    for order in [2usize, 3] {
        for variant in [
            CoeffVariant::Bh(BFunction::Bh1),
            CoeffVariant::Bh(BFunction::Bh2),
            CoeffVariant::Varying,
        ] {
            for pred in [Prediction::Noise, Prediction::Data] {
                for with_unic in [false, true] {
                    let mut opts = SampleOptions::new(
                        Method::UniP { order, variant, pred, schedule: None },
                        6,
                    );
                    if with_unic {
                        opts = opts.with_unic(variant, false);
                    }
                    let plan = SamplePlan::build(&sched, &opts).expect("plannable");
                    let inits = member_inits(gm.dim);
                    let solo: Vec<_> = inits
                        .iter()
                        .map(|x| sample_with_plan(&model, &sched, x, &opts, &plan))
                        .collect();
                    let refs: Vec<&Tensor> = inits.iter().collect();
                    let batched =
                        sample_batch_with_plan(&model, &sched, &refs, &opts, &plan, &mut bw);
                    assert_eq!(batched.len(), inits.len());
                    let tag = format!(
                        "order {order} {variant:?} {pred:?} unic {with_unic}"
                    );
                    for (i, (a, b)) in solo.iter().zip(&batched).enumerate() {
                        assert_eq!(a.nfe, b.nfe, "nfe member {i}: {tag}");
                        assert_eq!(a.x.shape(), b.x.shape(), "shape member {i}: {tag}");
                        assert_eq!(bits(&a.x), bits(&b.x), "state bits member {i}: {tag}");
                    }
                }
            }
        }
    }
}

#[test]
fn batch_of_one_matches_sample_with_plan() {
    let sched = VpLinear::default();
    let gm = dataset(DatasetSpec::Cifar10Like);
    let model = GmmModel { gm: &gm, sched: &sched };
    let opts = SampleOptions::unipc(3, BFunction::Bh2, Prediction::Noise, 8);
    let plan = SamplePlan::build(&sched, &opts).unwrap();
    let x0 = Rng::seed_from(3).normal_tensor(&[2, gm.dim]);
    let solo = sample_with_plan(&model, &sched, &x0, &opts, &plan);
    let mut bw = BatchWorkspace::new();
    let batched = sample_batch_with_plan(&model, &sched, &[&x0], &opts, &plan, &mut bw);
    assert_eq!(batched.len(), 1);
    assert_eq!(solo.nfe, batched[0].nfe);
    assert_eq!(bits(&solo.x), bits(&batched[0].x));
}

#[test]
fn oracle_batches_match_sequential() {
    let sched = VpLinear::default();
    let gm = dataset(DatasetSpec::Cifar10Like);
    let model = GmmModel { gm: &gm, sched: &sched };
    let opts = SampleOptions::new(
        Method::unip(2, BFunction::Bh2, Prediction::Noise),
        5,
    )
    .with_unic(CoeffVariant::Bh(BFunction::Bh2), true);
    let plan = SamplePlan::build(&sched, &opts).unwrap();
    let inits = member_inits(gm.dim);
    let refs: Vec<&Tensor> = inits.iter().collect();
    let mut bw = BatchWorkspace::new();
    let batched = sample_batch_with_plan(&model, &sched, &refs, &opts, &plan, &mut bw);
    for (x0, b) in inits.iter().zip(&batched) {
        let a = sample_with_plan(&model, &sched, x0, &opts, &plan);
        assert_eq!(a.nfe, b.nfe, "oracle doubles NFE identically");
        assert_eq!(bits(&a.x), bits(&b.x));
    }
}

#[test]
fn workspace_pool_reuses_after_warmup() {
    let sched = VpLinear::default();
    let gm = dataset(DatasetSpec::Cifar10Like);
    let model = GmmModel { gm: &gm, sched: &sched };
    let opts = SampleOptions::unipc(3, BFunction::Bh2, Prediction::Noise, 6);
    let plan = SamplePlan::build(&sched, &opts).unwrap();
    let inits = member_inits(gm.dim);
    let refs: Vec<&Tensor> = inits.iter().collect();

    let mut bw = BatchWorkspace::new();
    for _ in 0..5 {
        let _ = sample_batch_with_plan(&model, &sched, &refs, &opts, &plan, &mut bw);
    }
    assert_eq!(bw.allocs(), 1, "only the first run may grow the pool");
    assert_eq!(bw.reuses(), 4, "identical shapes must reuse pooled buffers");

    // A smaller batch fits the warmed pool.
    let small = Rng::seed_from(9).normal_tensor(&[2, gm.dim]);
    let _ = sample_batch_with_plan(&model, &sched, &[&small], &opts, &plan, &mut bw);
    assert_eq!(bw.reuses(), 5, "smaller batches must reuse pooled capacity");

    // Regrowing to the original size still fits (capacity was retained).
    let _ = sample_batch_with_plan(&model, &sched, &refs, &opts, &plan, &mut bw);
    assert_eq!(bw.reuses(), 6);

    // A larger batch forces one growth, after which it too is pooled.
    let big = Rng::seed_from(10).normal_tensor(&[32, gm.dim]);
    let _ = sample_batch_with_plan(&model, &sched, &[&big], &opts, &plan, &mut bw);
    assert_eq!(bw.allocs(), 2, "growth past pooled capacity allocates once");
    let _ = sample_batch_with_plan(&model, &sched, &[&big], &opts, &plan, &mut bw);
    assert_eq!(bw.reuses(), 7);
}

/// The tentpole's batching claim across the whole zoo: batched lockstep
/// execution of any registry method — multistep baselines and singlestep
/// NFE-budget solvers alike — is bit-identical to solo planned runs, with
/// and without UniC.
#[test]
fn whole_zoo_batches_bit_identically() {
    let sched = VpLinear::default();
    let gm = dataset(DatasetSpec::BedroomLike);
    let model = GmmModel { gm: &gm, sched: &sched };
    let mut bw = BatchWorkspace::new();
    for method in [
        Method::Ddim { pred: Prediction::Noise },
        Method::DpmSolverPp { order: 2 },
        Method::DpmSolverPp { order: 3 },
        Method::Plms,
        Method::Deis { order: 2 },
        Method::DpmSolverSingle { order: 3 },
        Method::DpmSolverPp3S,
    ] {
        for with_unic in [false, true] {
            let mut opts = SampleOptions::new(method.clone(), 7);
            if with_unic {
                opts = opts.with_unic(CoeffVariant::Bh(BFunction::Bh2), false);
            }
            let plan = SamplePlan::build(&sched, &opts)
                .unwrap_or_else(|| panic!("{} must be plannable", opts.id()));
            let inits = member_inits(gm.dim);
            let solo: Vec<_> = inits
                .iter()
                .map(|x| sample_with_plan(&model, &sched, x, &opts, &plan))
                .collect();
            let refs: Vec<&Tensor> = inits.iter().collect();
            let batched = sample_batch_with_plan(&model, &sched, &refs, &opts, &plan, &mut bw);
            assert_eq!(batched.len(), inits.len());
            for (i, (a, b)) in solo.iter().zip(&batched).enumerate() {
                let tag = format!("{} member {i} unic {with_unic}", opts.id());
                assert_eq!(a.nfe, b.nfe, "nfe: {tag}");
                assert_eq!(bits(&a.x), bits(&b.x), "state bits: {tag}");
            }
        }
    }
}

#[test]
fn sample_batch_falls_back_for_unplannable_configs() {
    // Every method now compiles to a plan; the only unplannable
    // configuration left is the exact-warmup experiment mode, which falls
    // back to independent reference runs.
    let sched = VpLinear::default();
    let gm = dataset(DatasetSpec::BedroomLike);
    let model = GmmModel { gm: &gm, sched: &sched };
    let mut opts = SampleOptions::new(
        Method::UniP {
            order: 2,
            variant: CoeffVariant::Bh(BFunction::Bh2),
            pred: Prediction::Noise,
            schedule: None,
        },
        5,
    );
    opts.exact_warmup = true;
    assert!(SamplePlan::build(&sched, &opts).is_none(), "exact-warmup has no plan");
    let inits = member_inits(gm.dim);
    let refs: Vec<&Tensor> = inits.iter().collect();
    let batched = sample_batch(&model, &sched, &refs, &opts);
    for (x0, b) in inits.iter().zip(&batched) {
        let a = sample(&model, &sched, x0, &opts);
        assert_eq!(a.nfe, b.nfe);
        assert_eq!(bits(&a.x), bits(&b.x));
    }
}

// ---- mixed-conditioning cohorts (PR 8 tentpole) --------------------------
//
// The coordinator now stacks requests with *different* class/guidance
// conditioning into one lockstep run over a row-conditioned `CohortModel`.
// These tests prove the slab-evaluated mixed cohort is bit-identical — state
// bits and NFE — to solo runs of each member under its own uniform view.

fn analytic_backend(spec: DatasetSpec) -> ModelBackend {
    let gm = Arc::new(dataset(spec));
    let classes = (0..spec.n_classes()).map(|c| spec.class_components(c)).collect();
    ModelBackend::Analytic { gm, class_components: Arc::new(classes) }
}

/// Mixed-size, mixed-conditioning members like a cohort the collapsed batch
/// key admits: unconditional, classed, and guided rows side by side.
fn mixed_members(dim: usize) -> Vec<(Tensor, Conditioning)> {
    [
        (1usize, Conditioning::default()),
        (2, Conditioning { class: Some(1), guidance: None }),
        (3, Conditioning { class: Some(4), guidance: Some(2.0) }),
        (1, Conditioning { class: Some(1), guidance: Some(0.5) }),
    ]
    .iter()
    .enumerate()
    .map(|(i, &(n, cond))| (Rng::seed_from(70 + i as u64).normal_tensor(&[n, dim]), cond))
    .collect()
}

#[test]
fn mixed_conditioning_batch_is_bit_identical_to_solo_across_variants() {
    let sched = VpLinear::default();
    let spec = DatasetSpec::Cifar10Like;
    let backend = analytic_backend(spec);
    let dim = dataset(spec).dim;
    let mut bw = BatchWorkspace::new();
    for order in [2usize, 3] {
        for variant in [CoeffVariant::Bh(BFunction::Bh2), CoeffVariant::Varying] {
            for pred in [Prediction::Noise, Prediction::Data] {
                for with_unic in [false, true] {
                    let mut opts = SampleOptions::new(
                        Method::UniP { order, variant, pred, schedule: None },
                        6,
                    );
                    if with_unic {
                        opts = opts.with_unic(variant, false);
                    }
                    let plan = SamplePlan::build(&sched, &opts).expect("plannable");
                    let members = mixed_members(dim);
                    // Solo reference: each member under its own uniform
                    // (single-slab, whole-tensor fast path) model view.
                    let solo: Vec<_> = members
                        .iter()
                        .map(|(x, cond)| {
                            let m = CohortModel::solo(&backend, &sched, *cond, x.shape()[0]);
                            sample_with_plan(&m, &sched, x, &opts, &plan)
                        })
                        .collect();
                    // Batched: one stacked run over the slab-tiled cohort.
                    let slabs = CondSlab::coalesce(
                        members.iter().map(|(x, cond)| (x.shape()[0], *cond)),
                    );
                    assert_eq!(slabs.len(), 4, "all four conditionings are distinct");
                    let cohort = CohortModel::new(&backend, &sched, slabs);
                    let refs: Vec<&Tensor> = members.iter().map(|(x, _)| x).collect();
                    let batched =
                        sample_batch_with_plan(&cohort, &sched, &refs, &opts, &plan, &mut bw);
                    assert_eq!(batched.len(), members.len());
                    let tag =
                        format!("order {order} {variant:?} {pred:?} unic {with_unic}");
                    for (i, (a, b)) in solo.iter().zip(&batched).enumerate() {
                        assert_eq!(a.nfe, b.nfe, "nfe member {i}: {tag}");
                        assert_eq!(bits(&a.x), bits(&b.x), "state bits member {i}: {tag}");
                    }
                }
            }
        }
    }
}

/// The same claim across the whole registry: every plannable method runs
/// mixed-conditioning cohorts bit-identically to solo runs.
#[test]
fn mixed_conditioning_zoo_batches_bit_identically() {
    let sched = VpLinear::default();
    let spec = DatasetSpec::Cifar10Like;
    let backend = analytic_backend(spec);
    let dim = dataset(spec).dim;
    let mut bw = BatchWorkspace::new();
    for method in [
        Method::Ddim { pred: Prediction::Noise },
        Method::DpmSolverPp { order: 2 },
        Method::DpmSolverPp { order: 3 },
        Method::Plms,
        Method::Deis { order: 2 },
        Method::DpmSolverSingle { order: 3 },
        Method::DpmSolverPp3S,
    ] {
        for with_unic in [false, true] {
            let mut opts = SampleOptions::new(method.clone(), 7);
            if with_unic {
                opts = opts.with_unic(CoeffVariant::Bh(BFunction::Bh2), false);
            }
            let plan = SamplePlan::build(&sched, &opts)
                .unwrap_or_else(|| panic!("{} must be plannable", opts.id()));
            let members = mixed_members(dim);
            let solo: Vec<_> = members
                .iter()
                .map(|(x, cond)| {
                    let m = CohortModel::solo(&backend, &sched, *cond, x.shape()[0]);
                    sample_with_plan(&m, &sched, x, &opts, &plan)
                })
                .collect();
            let slabs =
                CondSlab::coalesce(members.iter().map(|(x, cond)| (x.shape()[0], *cond)));
            let cohort = CohortModel::new(&backend, &sched, slabs);
            let refs: Vec<&Tensor> = members.iter().map(|(x, _)| x).collect();
            let batched = sample_batch_with_plan(&cohort, &sched, &refs, &opts, &plan, &mut bw);
            for (i, (a, b)) in solo.iter().zip(&batched).enumerate() {
                let tag = format!("{} member {i} unic {with_unic}", opts.id());
                assert_eq!(a.nfe, b.nfe, "nfe: {tag}");
                assert_eq!(bits(&a.x), bits(&b.x), "state bits: {tag}");
            }
        }
    }
}

/// The uniform-cohort fast path (single slab ⇒ whole-tensor eval) and the
/// slab loop compute the same bits: artificially splitting one conditioning
/// into two slabs changes nothing about a direct model eval.
#[test]
fn uniform_cohort_fast_path_matches_artificial_slab_split() {
    let sched = VpLinear::default();
    let spec = DatasetSpec::Cifar10Like;
    let backend = analytic_backend(spec);
    let dim = dataset(spec).dim;
    let x = Rng::seed_from(77).normal_tensor(&[5, dim]);
    for cond in [
        Conditioning::default(),
        Conditioning { class: Some(3), guidance: None },
        Conditioning { class: Some(3), guidance: Some(2.0) },
    ] {
        let fast = CohortModel::solo(&backend, &sched, cond, 5);
        let split = CohortModel::new(
            &backend,
            &sched,
            vec![
                CondSlab { start: 0, rows: 2, cond },
                CondSlab { start: 2, rows: 3, cond },
            ],
        );
        for t in [0.9, 0.4, 0.05] {
            let a = fast.eval(&x, t);
            let b = split.eval(&x, t);
            assert_eq!(bits(&a), bits(&b), "cond {cond:?} t {t}");
        }
    }
}
