//! Empirical convergence-order suite: regress log-error against log-steps
//! on an analytic reference and assert every solver's observed order of
//! accuracy matches its [`Method::order`] claim — the property UniPC's
//! whole design argument rests on (Thm 3.1 / Cor 3.2, Props D.5–D.6), here
//! verified for the full baseline zoo, not just UniPC:
//!
//! * DDIM is first order; DPM-Solver++(2M/3M) and tAB-DEIS-q hit their
//!   nominal orders; the singlestep DPM-Solver-2S/3S and DPM-Solver++(3S)
//!   hit theirs on the NFE axis; PNDM is **second**-order convergent (Liu
//!   et al. 2022 prove exactly this for pseudo linear multistep — the AB
//!   window is 4 entries, but the DDIM-transfer kernel mismatch and the
//!   non-uniform grid cap the global order at 2, which `Method::order`
//!   reflects).
//! * The paper's §3.1 claim: applying UniC after *any* p-order solver
//!   raises the observed order by ~1 **without extra model evaluations** —
//!   asserted for UniC-after-DDIM and UniC-after-DPM-Solver++(2M).
//!
//! Model: ε(x, t) = c·x keeps the probability-flow ODE linear, so a
//! 8000-step RK4 integration is machine-precision ground truth and every
//! solver is deep in its asymptotic regime on the sweep grids.
//!
//! Methodology matches the in-crate UniPC order test
//! (`solver::runner::tests::empirical_convergence_orders`): least-squares
//! slope of log2(error) against log2(steps) over a dyadic sweep, with
//! `exact_warmup` (RK4-accurate starting values) for multistep orders ≥ 2
//! so warm-up error does not pollute the slope. Tolerance windows are
//! generous on the high side — superconvergence on smooth problems is
//! common — while the low side enforces the order claim.

use unipc::analytic::reference_solution;
use unipc::numerics::vandermonde::BFunction;
use unipc::sched::VpLinear;
use unipc::solver::unipc::CoeffVariant;
use unipc::solver::{sample, Method, Model, Prediction, SampleOptions};
use unipc::tensor::Tensor;

const C: f64 = 0.5;

fn linear_model() -> impl Model {
    (Prediction::Noise, 2, move |x: &Tensor, _t: f64| x.scaled(C))
}

fn x0() -> Tensor {
    Tensor::from_vec(&[1, 2], vec![0.8, -0.6])
}

struct Harness {
    sched: VpLinear,
    truth: Tensor,
}

impl Harness {
    fn new() -> Harness {
        let sched = VpLinear::default();
        let m = linear_model();
        let truth = reference_solution(&m, &sched, &x0(), 1.0, 1e-3, 8000);
        Harness { sched, truth }
    }

    fn error(&self, opts: &SampleOptions) -> f64 {
        let m = linear_model();
        sample(&m, &self.sched, &x0(), opts).x.sub(&self.truth).norm()
    }

    /// Least-squares slope of −log2(error) against log2(steps).
    fn slope(&self, grid: &[usize], mk: &dyn Fn(usize) -> SampleOptions) -> f64 {
        let xs: Vec<f64> = grid.iter().map(|&s| (s as f64).log2()).collect();
        let ys: Vec<f64> = grid.iter().map(|&s| self.error(&mk(s)).log2()).collect();
        let n = grid.len() as f64;
        let mx = xs.iter().sum::<f64>() / n;
        let my = ys.iter().sum::<f64>() / n;
        let num: f64 = xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum();
        let den: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
        -num / den
    }
}

fn opts_for(method: Method, steps: usize, exact_warmup: bool) -> SampleOptions {
    let mut o = SampleOptions::new(method, steps);
    o.exact_warmup = exact_warmup;
    o
}

/// steps-grid for multistep methods (halving the step shrinks the error by
/// ~2^p); DEIS uses a coarser grid so its β(t) finite-difference noise
/// floor (~1e-9 relative) stays far below the measured errors.
const GRID: [usize; 4] = [160, 320, 640, 1280];
const GRID_DEIS: [usize; 4] = [80, 160, 320, 640];
/// NFE grids for singlestep solvers: even budgets split into clean [2,2,…]
/// groups; budgets ≡ 2 (mod 3) split into [3,…,3,2] — no first-order tail
/// group to degrade the asymptotic slope.
const GRID_NFE2: [usize; 4] = [80, 160, 320, 640];
const GRID_NFE3: [usize; 4] = [83, 164, 326, 647];

fn assert_order(name: &str, observed: f64, claimed: usize) {
    let lo = claimed as f64 - 0.6;
    let hi = claimed as f64 + 1.4;
    assert!(
        (lo..=hi).contains(&observed),
        "{name}: observed order {observed:.2} outside [{lo:.1}, {hi:.1}] (claimed {claimed})"
    );
}

#[test]
fn multistep_baselines_hit_their_claimed_orders() {
    let h = Harness::new();

    let cases: Vec<(&str, Method, &[usize], bool)> = vec![
        ("ddim", Method::Ddim { pred: Prediction::Noise }, &GRID, false),
        ("dpmpp-2m", Method::DpmSolverPp { order: 2 }, &GRID, true),
        ("dpmpp-3m", Method::DpmSolverPp { order: 3 }, &GRID, true),
        ("deis-2", Method::Deis { order: 2 }, &GRID_DEIS, true),
        ("deis-3", Method::Deis { order: 3 }, &GRID_DEIS, true),
        ("pndm", Method::Plms, &GRID, false),
        (
            "unip-3",
            Method::unip(3, BFunction::Bh2, Prediction::Noise),
            &GRID,
            true,
        ),
    ];

    let mut observed = Vec::new();
    for (name, method, grid, warm) in cases {
        let claimed = method.order();
        let m = method.clone();
        let s = h.slope(grid, &move |steps| opts_for(m.clone(), steps, warm));
        println!("{name}: observed order {s:.2} (claimed {claimed})");
        assert_order(name, s, claimed);
        observed.push((name, s));
    }

    // Relative ordering is the sharper check: third-order methods must
    // visibly beat second-order ones, which must beat DDIM.
    let get = |n: &str| observed.iter().find(|(k, _)| *k == n).unwrap().1;
    assert!(get("dpmpp-3m") > get("dpmpp-2m") + 0.5, "3M must outrank 2M");
    assert!(get("dpmpp-2m") > get("ddim") + 0.5, "2M must outrank DDIM");
    assert!(get("deis-3") > get("deis-2") + 0.5, "DEIS-3 must outrank DEIS-2");
}

#[test]
fn singlestep_solvers_hit_their_claimed_orders_on_the_nfe_axis() {
    let h = Harness::new();

    let s2 = h.slope(&GRID_NFE2, &|nfe| {
        opts_for(Method::DpmSolverSingle { order: 2 }, nfe, false)
    });
    println!("dpm-solver-2s: observed order {s2:.2}");
    assert_order("dpm-solver-2s", s2, 2);

    let s3 = h.slope(&GRID_NFE3, &|nfe| {
        opts_for(Method::DpmSolverSingle { order: 3 }, nfe, false)
    });
    println!("dpm-solver-3s: observed order {s3:.2}");
    assert_order("dpm-solver-3s", s3, 3);

    let s3pp = h.slope(&GRID_NFE3, &|nfe| opts_for(Method::DpmSolverPp3S, nfe, false));
    println!("dpmpp-3s: observed order {s3pp:.2}");
    assert_order("dpmpp-3s", s3pp, 3);

    assert!(s3 > s2 + 0.5, "third-order singlestep must outrank second-order");
}

/// Paper §3.1: UniC after *any* p-order solver yields order p+1 — at the
/// same NFE, because the corrector reuses the evaluation at the predicted
/// point. Asserted for a first-order base (DDIM) and for the paper's
/// strongest baseline (DPM-Solver++ 2M, data prediction).
#[test]
fn unic_raises_observed_order_of_any_base_solver_without_extra_nfe() {
    let h = Harness::new();
    let unic = CoeffVariant::Bh(BFunction::Bh2);

    // --- UniC after DDIM: 1 → ~2. ---
    let base = h.slope(&GRID, &|steps| {
        opts_for(Method::Ddim { pred: Prediction::Noise }, steps, false)
    });
    let lifted = h.slope(&GRID, &|steps| {
        opts_for(Method::Ddim { pred: Prediction::Noise }, steps, false).with_unic(unic, false)
    });
    println!("ddim: {base:.2} -> +unic {lifted:.2}");
    assert_order("ddim+unic", lifted, 2);
    assert!(
        lifted > base + 0.5,
        "UniC must raise DDIM's order: {base:.2} -> {lifted:.2}"
    );

    // --- UniC after DPM-Solver++(2M): 2 → ~3. ---
    let base2 = h.slope(&GRID, &|steps| {
        opts_for(Method::DpmSolverPp { order: 2 }, steps, true)
    });
    let lifted2 = h.slope(&GRID, &|steps| {
        opts_for(Method::DpmSolverPp { order: 2 }, steps, true).with_unic(unic, false)
    });
    println!("dpmpp-2m: {base2:.2} -> +unic {lifted2:.2}");
    assert_order("dpmpp-2m+unic", lifted2, 3);
    assert!(
        lifted2 > base2 + 0.5,
        "UniC must raise 2M's order: {base2:.2} -> {lifted2:.2}"
    );

    // --- No extra model evaluations (the §4.2 NFE rule). ---
    let m = linear_model();
    let steps = 160;
    for (name, base_opts) in [
        ("ddim", opts_for(Method::Ddim { pred: Prediction::Noise }, steps, false)),
        ("dpmpp-2m", opts_for(Method::DpmSolverPp { order: 2 }, steps, true)),
    ] {
        let plain = sample(&m, &h.sched, &x0(), &base_opts);
        let corrected = sample(&m, &h.sched, &x0(), &base_opts.clone().with_unic(unic, false));
        assert_eq!(
            plain.nfe, corrected.nfe,
            "{name}: UniC must not add model evaluations"
        );
        assert_eq!(plain.nfe, steps, "{name}: NFE convention");
    }
}
