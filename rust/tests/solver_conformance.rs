//! Cross-solver conformance suite (the tentpole's acceptance gate): for
//! **every** parseable method in the registry, across every prediction the
//! method admits, every [`TimeSpacing`], and several step counts, the
//! plan-compiled execution path ([`sample_with_plan`]) must be
//! **bit-identical** — state bits and NFE — to the per-method reference
//! loop ([`sample_unplanned`]), on the analytic GMM backend.
//!
//! `sample_unplanned` is the oracle: it re-derives every scalar on the fly
//! with the original per-family step functions, so agreement down to the
//! last bit proves the plan compiler resolved the exact same arithmetic.
//!
//! Runtime note: the sweep is sized to stay cheap in debug builds (8-d
//! mixture, 2-row states); `make test-full` additionally runs it under
//! `--release` together with the numerically heavy convergence suite.

use unipc::analytic::datasets::{dataset, DatasetSpec};
use unipc::analytic::GmmModel;
use unipc::numerics::vandermonde::BFunction;
use unipc::rng::Rng;
use unipc::sched::{TimeSpacing, VpLinear};
use unipc::solver::unipc::CoeffVariant;
use unipc::solver::{
    sample_unplanned, sample_with_plan, Method, SampleOptions, SamplePlan,
};
use unipc::tensor::Tensor;

fn bits(t: &Tensor) -> Vec<u64> {
    t.data().iter().map(|v| v.to_bits()).collect()
}

/// Planned-vs-reference bit-identity over method × spacing × steps × UniC.
#[test]
fn planned_execution_is_bit_identical_for_every_method() {
    let sched = VpLinear::default();
    let gm = dataset(DatasetSpec::BedroomLike);
    let model = GmmModel { gm: &gm, sched: &sched };
    let x0 = Rng::seed_from(42).normal_tensor(&[2, gm.dim]);

    let mut swept = 0usize;
    for method in Method::zoo() {
        for spacing in [TimeSpacing::LogSnr, TimeSpacing::Uniform, TimeSpacing::Quadratic] {
            for steps in [5usize, 10, 20] {
                for with_unic in [false, true] {
                    let mut opts = SampleOptions::new(method.clone(), steps);
                    opts.spacing = spacing;
                    if with_unic {
                        opts = opts.with_unic(CoeffVariant::Bh(BFunction::Bh2), false);
                    }
                    let plan = SamplePlan::build(&sched, &opts).unwrap_or_else(|| {
                        panic!("{} ({}) must be plannable", opts.id(), spacing.name())
                    });
                    let reference = sample_unplanned(&model, &sched, &x0, &opts);
                    let planned = sample_with_plan(&model, &sched, &x0, &opts, &plan);
                    let tag = format!(
                        "{} spacing {} steps {steps} unic {with_unic}",
                        opts.id(),
                        spacing.name()
                    );
                    assert_eq!(reference.nfe, planned.nfe, "nfe: {tag}");
                    assert_eq!(
                        bits(&reference.x),
                        bits(&planned.x),
                        "state bits: {tag}"
                    );
                    assert!(
                        planned.x.data().iter().all(|v| v.is_finite()),
                        "non-finite output: {tag}"
                    );
                    swept += 1;
                }
            }
        }
    }
    // The zoo currently holds 37 methods; 37 × 3 spacings × 3 step counts
    // × 2 UniC settings = 666 configurations. Guard against the sweep
    // silently shrinking if the zoo or the grids change shape.
    assert!(swept >= 650, "conformance sweep shrank to {swept} configs");
}

/// The `Method::parse`-able surface and the zoo agree: every zoo entry
/// round-trips through its id, and every id the sweep uses parses back to
/// the same method (so the conformance coverage statement "every parseable
/// method" is anchored to the registry itself).
#[test]
fn zoo_is_the_parseable_surface() {
    let zoo = Method::zoo();
    for m in &zoo {
        assert_eq!(Method::parse(&m.id()).as_ref(), Some(m), "{}", m.id());
        assert_eq!(Method::parse(&m.cache_key()).as_ref(), Some(m), "{}", m.cache_key());
    }
    // No duplicates: each id appears once.
    let mut ids: Vec<String> = zoo.iter().map(|m| m.id()).collect();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), zoo.len(), "duplicate ids in the zoo");
}

/// NFE accounting survives planning for both step conventions: multistep
/// methods cost exactly `steps` NFE, singlestep methods exactly their
/// budget, and UniC adds none — for every method in the zoo.
#[test]
fn nfe_conventions_hold_through_plans() {
    let sched = VpLinear::default();
    let gm = dataset(DatasetSpec::BedroomLike);
    let model = GmmModel { gm: &gm, sched: &sched };
    let x0 = Rng::seed_from(5).normal_tensor(&[1, gm.dim]);
    for method in Method::zoo() {
        for with_unic in [false, true] {
            let steps = 9;
            let mut opts = SampleOptions::new(method.clone(), steps);
            if with_unic {
                opts = opts.with_unic(CoeffVariant::Bh(BFunction::Bh2), false);
            }
            let plan = SamplePlan::build(&sched, &opts).expect("plannable");
            let r = sample_with_plan(&model, &sched, &x0, &opts, &plan);
            assert_eq!(
                r.nfe,
                steps,
                "{} unic {with_unic}: steps/budget must equal NFE",
                opts.id()
            );
        }
    }
}
