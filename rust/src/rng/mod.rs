//! Deterministic PRNG substrate (xoshiro256++ + splitmix64 seeding).
//!
//! The offline registry carries no `rand` implementation crates, so the
//! sampler/workload stack uses this small generator. It is splittable
//! (for per-request independent streams) and produces Gaussian variates via
//! Box–Muller — everything the paper's experiments need (x_T draws, mixture
//! sampling, Poisson arrivals).

/// splitmix64 — used for seeding and stream splitting.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    spare: Option<f64>,
}

impl Rng {
    /// Seed deterministically from a u64.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream for `stream_id` (per-request RNGs).
    pub fn split(&self, stream_id: u64) -> Rng {
        // Mix the current state with the stream id through splitmix.
        let mut sm = self.s[0] ^ self.s[2].rotate_left(17) ^ stream_id.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Next raw u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> double in [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Rejection-free multiply-shift; bias negligible for our n.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Fill a vector with standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Standard normal tensor of the given shape.
    pub fn normal_tensor(&mut self, shape: &[usize]) -> crate::tensor::Tensor {
        let n: usize = shape.iter().product();
        crate::tensor::Tensor::from_vec(shape, self.normal_vec(n))
    }

    /// Exponential variate with the given rate (Poisson inter-arrivals).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let u = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / rate
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical weights must have positive mass");
        let mut u = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            if u < w {
                return i;
            }
            u -= w;
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn split_streams_are_independent_and_deterministic() {
        let root = Rng::seed_from(7);
        let mut s1 = root.split(1);
        let mut s1b = root.split(1);
        let mut s2 = root.split(2);
        assert_eq!(s1.next_u64(), s1b.next_u64());
        assert_ne!(s1.next_u64(), s2.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::seed_from(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(11);
        let n = 200_000;
        let xs = r.normal_vec(n);
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::seed_from(13);
        let n = 100_000;
        let m: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn categorical_frequencies() {
        let mut r = Rng::seed_from(17);
        let w = [1.0, 3.0];
        let mut counts = [0usize; 2];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        let frac = counts[1] as f64 / 40_000.0;
        assert!((frac - 0.75).abs() < 0.02, "frac {frac}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::seed_from(19);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
