//! Minimal property-testing harness (proptest is not in the offline
//! registry). Seeded generators + bounded shrinking over a failure's
//! "size" knob. Used for coordinator/solver invariants.
//!
//! ```no_run
//! // (no_run: doctest binaries don't inherit the rpath to the PJRT libs)
//! use unipc::testing::{Gen, check};
//! check("sum is commutative", 200, |g| {
//!     let a = g.f64_in(-1e3, 1e3);
//!     let b = g.f64_in(-1e3, 1e3);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::rng::Rng;

/// Value generator handed to each property iteration.
pub struct Gen {
    rng: Rng,
    /// Log of drawn values for failure reports.
    log: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen { rng: Rng::seed_from(seed), log: Vec::new() }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let v = lo + self.rng.below(hi - lo + 1);
        self.log.push(format!("usize {v}"));
        v
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.uniform_in(lo, hi);
        self.log.push(format!("f64 {v}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.uniform() < 0.5;
        self.log.push(format!("bool {v}"));
        v
    }

    /// Strictly increasing f64 sequence of length n in (lo, hi).
    pub fn increasing_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        let mut v: Vec<f64> = (0..n).map(|_| self.rng.uniform_in(lo, hi)).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // De-duplicate by nudging.
        for i in 1..v.len() {
            if v[i] <= v[i - 1] {
                v[i] = v[i - 1] + 1e-9;
            }
        }
        self.log.push(format!("increasing {v:?}"));
        v
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.rng.uniform_in(lo, hi)).collect()
    }

    /// Raw RNG access for building domain objects.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `iters` iterations of a property with deterministic per-iteration
/// seeds. On panic, re-raises with the failing seed and the generator log so
/// the case can be replayed with [`check_seed`].
pub fn check<F: FnMut(&mut Gen)>(name: &str, iters: u64, mut prop: F) {
    for i in 0..iters {
        let seed = 0x5EED_0000 + i;
        let mut g = Gen::new(seed);
        let result =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(payload) = result {
            // panic_any keeps the report downcastable to String regardless of
            // how the toolchain boxes formatted panic payloads.
            std::panic::panic_any(format!(
                "property '{name}' failed at iter {i} (seed {seed:#x})\n  drawn: {:?}\n  cause: {}",
                g.log,
                panic_message(payload.as_ref())
            ));
        }
    }
}

/// Replay a single seed (debugging a failure from [`check`]'s report).
pub fn check_seed<F: FnMut(&mut Gen)>(seed: u64, mut prop: F) {
    let mut g = Gen::new(seed);
    prop(&mut g);
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_iters() {
        let count = std::cell::Cell::new(0u64);
        check("trivial", 50, |g| {
            let _ = g.usize_in(0, 10);
            count.set(count.get() + 1);
        });
        assert_eq!(count.get(), 50);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check("always fails", 3, |g| {
                let v = g.usize_in(0, 100);
                assert!(v > 1000, "v was {v}");
            });
        });
        let msg = panic_message(result.unwrap_err().as_ref());
        assert!(msg.contains("seed"), "{msg}");
        assert!(msg.contains("drawn"), "{msg}");
    }

    #[test]
    fn generators_in_bounds() {
        check("bounds", 100, |g| {
            let u = g.usize_in(3, 7);
            assert!((3..=7).contains(&u));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
            let inc = g.increasing_f64(5, 0.0, 1.0);
            for w in inc.windows(2) {
                assert!(w[1] > w[0]);
            }
        });
    }

    #[test]
    fn seeds_are_deterministic() {
        let mut a = Vec::new();
        check_seed(42, |g| a.push(g.f64_in(0.0, 1.0)));
        let mut b = Vec::new();
        check_seed(42, |g| b.push(g.f64_in(0.0, 1.0)));
        assert_eq!(a, b);
    }
}
