//! Minimal leveled logging to stderr.
//!
//! The offline registry carries no `log`/`tracing` crates, so the serving
//! stack uses this shim: `log::info!` / `log::warn!` with the familiar
//! `format!` interface, written straight to stderr with a level prefix.
//! Call sites import it with `use crate::log;` (or `use unipc::log;` from
//! binaries) and read exactly like the ecosystem macros.

/// Write one formatted record to stderr (macro plumbing; prefer the
/// [`info!`](crate::__log_info) / [`warn!`](crate::__log_warn) macros).
pub fn emit(level: &str, args: std::fmt::Arguments<'_>) {
    eprintln!("[{level}] {args}");
}

/// `log::info!` — informational record to stderr.
#[macro_export]
macro_rules! __log_info {
    ($($arg:tt)*) => {
        $crate::log::emit("INFO", format_args!($($arg)*))
    };
}

/// `log::warn!` — warning record to stderr.
#[macro_export]
macro_rules! __log_warn {
    ($($arg:tt)*) => {
        $crate::log::emit("WARN", format_args!($($arg)*))
    };
}

pub use crate::{__log_info as info, __log_warn as warn};

#[cfg(test)]
mod tests {
    #[test]
    fn macros_format_without_panicking() {
        crate::log::info!("value = {}", 42);
        crate::log::warn!("{} + {} = {}", 1, 2, 1 + 2);
    }
}
