//! Shared experiment harness for the paper-reproduction benches
//! (DESIGN.md §3). Every `rust/benches/*` target is a thin driver over
//! these helpers.
//!
//! Metrics (FID substitutes on analytic benchmarks — DESIGN.md §2):
//! * `l2_ref` — mean ‖x₀ − x₀*‖₂/√D against a machine-precision RK4
//!   reference from the *same* x_T (the paper's own Fig. 4c metric);
//!   deterministic given a seed, so it resolves small solver differences.
//! * `frechet` — the FID formula evaluated in data space against the
//!   analytic mixture moments.
//! * `sw2` — sliced 2-Wasserstein distance to fresh mixture samples.

use crate::analytic::{reference_solution, GaussianMixture};
use crate::json::Value;
use crate::rng::Rng;
use crate::sched::NoiseSchedule;
use crate::solver::{sample, Model, SampleOptions};
use crate::stats::{frechet_distance, gaussian_fit, sliced_wasserstein2};
use crate::tensor::Tensor;

/// Generate `n` samples by running the sampler in chunks of `chunk`.
pub fn gen_samples(
    model: &dyn Model,
    sched: &dyn NoiseSchedule,
    opts: &SampleOptions,
    n: usize,
    seed: u64,
    chunk: usize,
) -> (Tensor, usize) {
    let dim = model.dim();
    let mut rng = Rng::seed_from(seed);
    let mut parts: Vec<Tensor> = Vec::new();
    let mut nfe = 0;
    let mut left = n;
    while left > 0 {
        let b = left.min(chunk);
        let x_t = rng.normal_tensor(&[b, dim]);
        let r = sample(model, sched, &x_t, opts);
        nfe = r.nfe; // per-chunk NFE (identical across chunks)
        parts.push(r.x);
        left -= b;
    }
    let refs: Vec<&Tensor> = parts.iter().collect();
    (Tensor::concat_rows(&refs), nfe)
}

/// Mean ‖x₀ − x₀*‖₂/√D over `n_traj` trajectories with shared x_T
/// (Fig. 4c metric). `ref_steps` RK4 steps define the ground truth.
pub fn l2_to_reference(
    model: &dyn Model,
    sched: &dyn NoiseSchedule,
    opts: &SampleOptions,
    n_traj: usize,
    seed: u64,
    ref_steps: usize,
) -> f64 {
    let dim = model.dim();
    let mut rng = Rng::seed_from(seed);
    let x_t = rng.normal_tensor(&[n_traj, dim]);
    let truth = reference_solution(model, sched, &x_t, opts.t_start, opts.t_end, ref_steps);
    let got = sample(model, sched, &x_t, opts).x;
    let diff = got.sub(&truth);
    // Mean over trajectories of the per-row RMS.
    let mut total = 0.0;
    for i in 0..n_traj {
        let row = diff.row(i);
        let ss: f64 = row.iter().map(|v| v * v).sum();
        total += (ss / dim as f64).sqrt();
    }
    total / n_traj as f64
}

/// (frechet, sw2) of generated samples against the analytic mixture.
pub fn quality(gm: &GaussianMixture, samples: &Tensor, seed: u64) -> (f64, f64) {
    let (mu_s, cov_s) = gaussian_fit(samples);
    let frechet = frechet_distance(&mu_s, &cov_s, &gm.mean(), &gm.covariance());
    let mut rng = Rng::seed_from(seed ^ 0xABCD);
    let truth = gm.sample(&mut rng, samples.shape()[0]);
    let mut prng = Rng::seed_from(seed ^ 0x1234);
    let sw2 = sliced_wasserstein2(samples, &truth, 32, &mut prng);
    (frechet, sw2)
}

/// Precomputed ground truth for l2-to-reference sweeps: one RK4 reference
/// per (dataset, seed), shared across every method/NFE cell of a table.
pub struct RefErr {
    pub x_t: Tensor,
    pub truth: Tensor,
}

impl RefErr {
    pub fn new(
        model: &dyn Model,
        sched: &dyn NoiseSchedule,
        n_traj: usize,
        seed: u64,
        t_start: f64,
        t_end: f64,
        ref_steps: usize,
    ) -> Self {
        let mut rng = Rng::seed_from(seed);
        let x_t = rng.normal_tensor(&[n_traj, model.dim()]);
        let truth = reference_solution(model, sched, &x_t, t_start, t_end, ref_steps);
        RefErr { x_t, truth }
    }

    /// Use an explicit truth (e.g. 999-step DDIM, the paper's Fig. 4c).
    pub fn with_truth(x_t: Tensor, truth: Tensor) -> Self {
        RefErr { x_t, truth }
    }

    /// Mean per-trajectory ‖x₀ − x₀*‖₂/√D for a sampler configuration.
    pub fn err(&self, model: &dyn Model, sched: &dyn NoiseSchedule, opts: &SampleOptions) -> f64 {
        let got = sample(model, sched, &self.x_t, opts).x;
        let diff = got.sub(&self.truth);
        let (n, d) = (diff.shape()[0], diff.shape()[1]);
        (0..n)
            .map(|i| {
                let ss: f64 = diff.row(i).iter().map(|v| v * v).sum();
                (ss / d as f64).sqrt()
            })
            .sum::<f64>()
            / n as f64
    }
}

/// A rendered results table (paper-style: methods × NFE grid).
pub struct ResultTable {
    pub title: String,
    pub nfes: Vec<usize>,
    pub rows: Vec<(String, Vec<f64>)>,
}

impl ResultTable {
    pub fn new(title: &str, nfes: &[usize]) -> Self {
        ResultTable { title: title.to_string(), nfes: nfes.to_vec(), rows: Vec::new() }
    }

    pub fn push(&mut self, label: &str, values: Vec<f64>) {
        assert_eq!(values.len(), self.nfes.len());
        self.rows.push((label.to_string(), values));
    }

    /// Paper-style fixed-width text rendering.
    pub fn render(&self) -> String {
        let mut s = format!("== {} ==\n", self.title);
        s.push_str(&format!("{:<28}", "method \\ NFE"));
        for n in &self.nfes {
            s.push_str(&format!("{n:>12}"));
        }
        s.push('\n');
        for (label, vals) in &self.rows {
            s.push_str(&format!("{label:<28}"));
            for v in vals {
                if *v >= 100.0 {
                    s.push_str(&format!("{v:>12.1}"));
                } else {
                    s.push_str(&format!("{v:>12.4}"));
                }
            }
            s.push('\n');
        }
        s
    }

    /// Machine-readable form for `bench_out/`.
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("title", Value::from(self.title.as_str())),
            (
                "nfes",
                Value::Arr(self.nfes.iter().map(|&n| Value::from(n)).collect()),
            ),
            (
                "rows",
                Value::Arr(
                    self.rows
                        .iter()
                        .map(|(l, vs)| {
                            Value::obj(vec![
                                ("label", Value::from(l.as_str())),
                                (
                                    "values",
                                    Value::Arr(vs.iter().map(|&v| Value::Num(v)).collect()),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Print to stdout and append to `bench_out/<file>.json`.
    pub fn emit(&self, file: &str) {
        println!("{}", self.render());
        let dir = std::path::Path::new("bench_out");
        if std::fs::create_dir_all(dir).is_ok() {
            let _ = std::fs::write(dir.join(file), self.to_json().to_string());
        }
    }

    /// Winner-per-column check: the label that minimizes each NFE column.
    pub fn winner(&self, nfe: usize) -> Option<&str> {
        let col = self.nfes.iter().position(|&n| n == nfe)?;
        self.rows
            .iter()
            .min_by(|a, b| a.1[col].partial_cmp(&b.1[col]).unwrap())
            .map(|(l, _)| l.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::datasets::{dataset, DatasetSpec};
    use crate::analytic::GmmModel;
    use crate::numerics::vandermonde::BFunction;
    use crate::sched::VpLinear;
    use crate::solver::{Method, Prediction};

    #[test]
    fn gen_samples_shapes_and_chunks() {
        let gm = dataset(DatasetSpec::BedroomLike);
        let sched = VpLinear::default();
        let model = GmmModel { gm: &gm, sched: &sched };
        let opts = SampleOptions::new(Method::Ddim { pred: Prediction::Noise }, 4);
        let (samples, nfe) = gen_samples(&model, &sched, &opts, 10, 3, 4);
        assert_eq!(samples.shape(), &[10, gm.dim]);
        assert_eq!(nfe, 4);
    }

    #[test]
    fn l2_ref_orders_methods_correctly() {
        let gm = dataset(DatasetSpec::BedroomLike);
        let sched = VpLinear::default();
        let model = GmmModel { gm: &gm, sched: &sched };
        let ddim = SampleOptions::new(Method::Ddim { pred: Prediction::Noise }, 8);
        let unipc = SampleOptions::unipc(3, BFunction::Bh2, Prediction::Noise, 8);
        let e_ddim = l2_to_reference(&model, &sched, &ddim, 4, 11, 1500);
        let e_unipc = l2_to_reference(&model, &sched, &unipc, 4, 11, 1500);
        assert!(
            e_unipc < e_ddim,
            "UniPC-3 ({e_unipc}) must beat DDIM ({e_ddim}) at 8 NFE"
        );
    }

    #[test]
    fn quality_improves_with_more_steps() {
        let gm = dataset(DatasetSpec::BedroomLike);
        let sched = VpLinear::default();
        let model = GmmModel { gm: &gm, sched: &sched };
        let coarse = SampleOptions::new(Method::Ddim { pred: Prediction::Noise }, 3);
        let fine = SampleOptions::new(Method::Ddim { pred: Prediction::Noise }, 60);
        let (s_coarse, _) = gen_samples(&model, &sched, &coarse, 512, 5, 64);
        let (s_fine, _) = gen_samples(&model, &sched, &fine, 512, 5, 64);
        let (f_coarse, _) = quality(&gm, &s_coarse, 5);
        let (f_fine, _) = quality(&gm, &s_fine, 5);
        assert!(f_fine < f_coarse, "frechet: fine {f_fine} vs coarse {f_coarse}");
    }

    #[test]
    fn table_renders_and_picks_winner() {
        let mut t = ResultTable::new("demo", &[5, 10]);
        t.push("a", vec![2.0, 1.0]);
        t.push("b", vec![1.0, 3.0]);
        assert_eq!(t.winner(5), Some("b"));
        assert_eq!(t.winner(10), Some("a"));
        let r = t.render();
        assert!(r.contains("demo") && r.contains("a") && r.contains("12") == false || true);
        assert!(crate::json::parse(&t.to_json().to_string()).is_ok());
    }
}
