//! Dynamic thresholding (Saharia et al. 2022), used by data-prediction
//! solvers in guided sampling to mitigate train–test mismatch (paper §3.4).
//!
//! Per sample: s = max(quantile(|x₀|, p), max_val); x₀ ← clamp(x₀, −s, s)/s.

use crate::tensor::Tensor;

/// Dynamic thresholding configuration.
#[derive(Clone, Copy, Debug)]
pub struct DynamicThresholding {
    /// Quantile of |x₀| used as the clamp scale (paper/Imagen use 0.995).
    pub quantile: f64,
    /// Lower bound on the clamp scale (1.0 keeps in-range samples intact).
    pub max_val: f64,
    /// Divide by the clamp scale after clamping (the Imagen convention,
    /// which assumes data normalized to [-1, 1]). For *unbounded* data —
    /// our analytic mixtures — set `rescale: false` to get the honest
    /// analog: clip the wild x₀ extrapolations that large guidance scales
    /// produce, without renormalizing the data range.
    pub rescale: bool,
}

impl Default for DynamicThresholding {
    fn default() -> Self {
        DynamicThresholding { quantile: 0.995, max_val: 1.0, rescale: true }
    }
}

impl DynamicThresholding {
    /// Clip-only variant for unbounded data with the given scale floor.
    pub fn clip(max_val: f64) -> Self {
        DynamicThresholding { quantile: 0.995, max_val, rescale: false }
    }

    /// Apply in place to a `[n, d]` batch of x₀ predictions.
    pub fn apply(&self, x0: &mut Tensor) {
        assert_eq!(x0.shape().len(), 2, "thresholding expects [n, d]");
        let n = x0.shape()[0];
        let mut mag = Vec::new();
        for i in 0..n {
            let row = x0.row(i);
            mag.clear();
            mag.extend(row.iter().map(|v| v.abs()));
            let s = quantile_in_place(&mut mag, self.quantile).max(self.max_val);
            for v in x0.row_mut(i) {
                *v = v.clamp(-s, s);
                if self.rescale {
                    *v /= s;
                }
            }
        }
    }
}

/// Linear-interpolated quantile; sorts its scratch input.
fn quantile_in_place(xs: &mut [f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    assert!((0.0..=1.0).contains(&q));
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (xs.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        xs[lo]
    } else {
        let w = pos - lo as f64;
        xs[lo] * (1.0 - w) + xs[hi] * w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantile_basics() {
        let mut xs = vec![3.0, 1.0, 2.0];
        assert_eq!(quantile_in_place(&mut xs, 0.0), 1.0);
        assert_eq!(quantile_in_place(&mut xs, 1.0), 3.0);
        assert_eq!(quantile_in_place(&mut xs, 0.5), 2.0);
    }

    #[test]
    fn in_range_samples_pass_through() {
        // All |x| ≤ 1 → s = max_val = 1 → x/1 unchanged.
        let th = DynamicThresholding::default();
        let mut x = Tensor::from_vec(&[1, 4], vec![0.5, -0.9, 0.0, 1.0]);
        let orig = x.clone();
        th.apply(&mut x);
        assert_eq!(x, orig);
    }

    #[test]
    fn outliers_are_clamped_and_rescaled() {
        let th = DynamicThresholding { quantile: 0.5, max_val: 1.0, rescale: true };
        // Row: |x| values 0.0, 2.0, 4.0 → median 2.0 → s = 2.
        let mut x = Tensor::from_vec(&[1, 3], vec![0.0, -2.0, 4.0]);
        th.apply(&mut x);
        assert_eq!(x.data(), &[0.0, -1.0, 1.0]);
    }

    #[test]
    fn rows_thresholded_independently() {
        let th = DynamicThresholding { quantile: 1.0, max_val: 1.0, rescale: true };
        let mut x = Tensor::from_vec(&[2, 2], vec![0.5, 0.5, 4.0, -4.0]);
        th.apply(&mut x);
        // Row 0 untouched (s=1); row 1 scaled by 4.
        assert_eq!(x.data(), &[0.5, 0.5, 1.0, -1.0]);
    }
}
