//! DPM-Solver++ (Lu et al. 2022b) — data-prediction solvers: multistep 2M /
//! 3M and singlestep 3S. The paper's strongest baseline (Tables 1–3, 5–9).
//!
//! Formulas follow the official `multistep_dpm_solver_{second,third}_update`
//! and `singlestep_dpm_solver_third_update` (algorithm_type="dpmsolver++",
//! solver_type="dpmsolver").

use super::history::History;
use super::{Evaluator, Prediction};
use crate::numerics::phi::psi;
use crate::sched::NoiseSchedule;
use crate::tensor::Tensor;

/// Multistep DPM-Solver++(2M) step t_prev → t. Needs 2 buffered outputs.
pub fn dpmpp_2m_step(
    ev: &Evaluator,
    sched: &dyn NoiseSchedule,
    hist: &History,
    x: &Tensor,
    t: f64,
) -> Tensor {
    assert_eq!(ev.prediction(), Prediction::Data, "DPM-Solver++ is data-prediction");
    assert!(hist.len() >= 2);
    let p0 = hist.last();
    let p1 = hist.back(1);
    let h = sched.lambda(t) - p0.lambda;
    let h0 = p0.lambda - p1.lambda;
    let r0 = h0 / h;

    // D1_0 = (m0 − m1)/r0
    let d1 = p0.m.sub(&p1.m).scaled(1.0 / r0);
    let phi_1 = (-h).exp_m1(); // = e^{−h} − 1 (negative)

    // x_t = (σ_t/σ_0) x − α_t φ₁ m0 − 0.5 α_t φ₁ D1_0
    let mut out = Tensor::lincomb(
        sched.sigma(t) / sched.sigma(p0.t),
        x,
        -sched.alpha(t) * phi_1,
        &p0.m,
    );
    out.axpy(-0.5 * sched.alpha(t) * phi_1, &d1);
    out
}

/// Multistep DPM-Solver++(3M) step t_prev → t. Needs 3 buffered outputs.
pub fn dpmpp_3m_step(
    ev: &Evaluator,
    sched: &dyn NoiseSchedule,
    hist: &History,
    x: &Tensor,
    t: f64,
) -> Tensor {
    assert_eq!(ev.prediction(), Prediction::Data, "DPM-Solver++ is data-prediction");
    assert!(hist.len() >= 3);
    let p0 = hist.last();
    let p1 = hist.back(1);
    let p2 = hist.back(2);
    let h = sched.lambda(t) - p0.lambda;
    let h0 = p0.lambda - p1.lambda;
    let h1 = p1.lambda - p2.lambda;
    let (r0, r1) = (h0 / h, h1 / h);

    let d1_0 = p0.m.sub(&p1.m).scaled(1.0 / r0);
    let d1_1 = p1.m.sub(&p2.m).scaled(1.0 / r1);
    // D1 = D1_0 + r0/(r0+r1) (D1_0 − D1_1); D2 = (D1_0 − D1_1)/(r0+r1)
    let diff = d1_0.sub(&d1_1);
    let mut d1 = d1_0.clone();
    d1.axpy(r0 / (r0 + r1), &diff);
    let d2 = diff.scaled(1.0 / (r0 + r1));

    let phi_1 = (-h).exp_m1();
    // Reference expressions: phi_2 = φ₁/h + 1 = h·ψ₂(h), phi_3 = φ₂/h − ½
    // (evaluated through the stable ψ forms to avoid cancellation).
    let phi_2 = h * psi(2, h);
    let phi_3 = -h * psi(3, h);
    debug_assert!((phi_2 - (phi_1 / h + 1.0)).abs() < 1e-9);
    debug_assert!((phi_3 - (phi_2 / h - 0.5)).abs() < 1e-9);

    let mut out = Tensor::lincomb(
        sched.sigma(t) / sched.sigma(p0.t),
        x,
        -sched.alpha(t) * phi_1,
        &p0.m,
    );
    out.axpy(sched.alpha(t) * phi_2, &d1);
    out.axpy(-sched.alpha(t) * phi_3, &d2);
    out
}

/// DPM-Solver++ singlestep second-order update (reference `2S`) s → t with
/// the interior node at r1 of the λ interval: used for 2-interval tail
/// groups of the 3S budget split. Costs 1 extra NFE beyond `m_s`.
pub fn dpmpp_2s_step(
    ev: &Evaluator,
    sched: &dyn NoiseSchedule,
    x: &Tensor,
    s: f64,
    t: f64,
    m_s: &Tensor,
    r1: f64,
) -> Tensor {
    let (ls, lt) = (sched.lambda(s), sched.lambda(t));
    let h = lt - ls;
    let s1 = sched.t_of_lambda(ls + r1 * h);
    let phi_11 = (-r1 * h).exp_m1();
    let phi_1 = (-h).exp_m1();

    let x_s1 = Tensor::lincomb(
        sched.sigma(s1) / sched.sigma(s),
        x,
        -sched.alpha(s1) * phi_11,
        m_s,
    );
    let m_s1 = ev.eval(&x_s1, s1);
    let d1 = m_s1.sub(m_s);
    let mut out = Tensor::lincomb(
        sched.sigma(t) / sched.sigma(s),
        x,
        -sched.alpha(t) * phi_1,
        m_s,
    );
    out.axpy(-sched.alpha(t) * phi_1 / (2.0 * r1), &d1);
    out
}

/// Singlestep DPM-Solver++(3S) update s → t with interior nodes at r1, r2 of
/// the λ interval (reference defaults r1 = 1/3, r2 = 2/3). Costs 2 extra NFE
/// beyond the boundary output `m_s`.
#[allow(clippy::too_many_arguments)]
pub fn dpmpp_3s_step(
    ev: &Evaluator,
    sched: &dyn NoiseSchedule,
    x: &Tensor,
    s: f64,
    t: f64,
    m_s: &Tensor,
    r1: f64,
    r2: f64,
) -> Tensor {
    assert_eq!(ev.prediction(), Prediction::Data, "DPM-Solver++ is data-prediction");
    let (ls, lt) = (sched.lambda(s), sched.lambda(t));
    let h = lt - ls;
    let s1 = sched.t_of_lambda(ls + r1 * h);
    let s2 = sched.t_of_lambda(ls + r2 * h);

    let phi_11 = (-r1 * h).exp_m1();
    let phi_12 = (-r2 * h).exp_m1();
    let phi_1 = (-h).exp_m1();
    let phi_22 = phi_12 / (r2 * h) + 1.0;
    let phi_2 = phi_1 / h + 1.0;

    // x_{s1} = (σ_{s1}/σ_s) x − α_{s1} φ₁₁ m_s
    let x_s1 = Tensor::lincomb(
        sched.sigma(s1) / sched.sigma(s),
        x,
        -sched.alpha(s1) * phi_11,
        m_s,
    );
    let m_s1 = ev.eval(&x_s1, s1);
    let d1 = m_s1.sub(m_s);

    // x_{s2} = (σ_{s2}/σ_s) x − α_{s2} φ₁₂ m_s + (r2/r1) α_{s2} φ₂₂ D1
    let mut x_s2 = Tensor::lincomb(
        sched.sigma(s2) / sched.sigma(s),
        x,
        -sched.alpha(s2) * phi_12,
        m_s,
    );
    x_s2.axpy(sched.alpha(s2) * (r2 / r1) * phi_22, &d1);
    let m_s2 = ev.eval(&x_s2, s2);
    let d2 = m_s2.sub(m_s);

    // x_t = (σ_t/σ_s) x − α_t φ₁ m_s + (1/r2) α_t φ₂ D2
    let mut out = Tensor::lincomb(
        sched.sigma(t) / sched.sigma(s),
        x,
        -sched.alpha(t) * phi_1,
        m_s,
    );
    out.axpy(sched.alpha(t) * phi_2 / r2, &d2);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::VpLinear;
    use crate::solver::Model;

    fn data_model(c: f64) -> impl Model {
        (Prediction::Data, 2, move |x: &Tensor, _t: f64| x.scaled(c))
    }

    fn hist_for(ev: &Evaluator, sched: &dyn NoiseSchedule, pts: &[(f64, Tensor)]) -> History {
        let mut h = History::new(4);
        for (t, x) in pts {
            h.push(*t, sched.lambda(*t), ev.eval(x, *t));
        }
        h
    }

    #[test]
    fn constant_model_reduces_all_orders_to_ddim() {
        let sched = VpLinear::default();
        let m: (Prediction, usize, _) = (
            Prediction::Data,
            2,
            |x: &Tensor, _t: f64| Tensor::full(x.shape(), 0.2),
        );
        let ev = Evaluator::new(&m, &sched, Prediction::Data, None);
        let x = Tensor::from_vec(&[1, 2], vec![0.4, 0.4]);
        let pts = [(0.8, x.clone()), (0.7, x.clone()), (0.6, x.clone())];
        let hist = hist_for(&ev, &sched, &pts);
        let t = 0.5;
        let two = dpmpp_2m_step(&ev, &sched, &hist, &x, t);
        let three = dpmpp_3m_step(&ev, &sched, &hist, &x, t);
        for (a, b) in two.data().iter().zip(three.data()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn dpmpp_2m_matches_hand_formula() {
        let sched = VpLinear::default();
        let m = data_model(0.3);
        let ev = Evaluator::new(&m, &sched, Prediction::Data, None);
        let xa = Tensor::from_vec(&[1, 2], vec![1.0, 2.0]);
        let xb = Tensor::from_vec(&[1, 2], vec![0.9, 1.9]);
        let (ta, tb, t) = (0.7, 0.62, 0.55);
        let hist = hist_for(&ev, &sched, &[(ta, xa.clone()), (tb, xb.clone())]);
        let out = dpmpp_2m_step(&ev, &sched, &hist, &xb, t);

        let (la, lb, ltv) = (sched.lambda(ta), sched.lambda(tb), sched.lambda(t));
        let h = ltv - lb;
        let r0 = (lb - la) / h;
        let m0 = xb.scaled(0.3);
        let m1 = xa.scaled(0.3);
        let d1 = m0.sub(&m1).scaled(1.0 / r0);
        let phi_1 = (-h).exp_m1();
        let mut expect = Tensor::lincomb(
            sched.sigma(t) / sched.sigma(tb),
            &xb,
            -sched.alpha(t) * phi_1,
            &m0,
        );
        expect.axpy(-0.5 * sched.alpha(t) * phi_1, &d1);
        for (o, e) in out.data().iter().zip(expect.data()) {
            assert!((o - e).abs() < 1e-12);
        }
    }

    #[test]
    fn singlestep_3s_runs_and_counts_nfe() {
        let sched = VpLinear::default();
        let m = data_model(0.25);
        let ev = Evaluator::new(&m, &sched, Prediction::Data, None);
        let x = Tensor::from_vec(&[1, 2], vec![0.5, -0.5]);
        let (s, t) = (0.8, 0.4);
        let m_s = ev.eval(&x, s);
        assert_eq!(ev.nfe(), 1);
        let _ = dpmpp_3s_step(&ev, &sched, &x, s, t, &m_s, 1.0 / 3.0, 2.0 / 3.0);
        assert_eq!(ev.nfe(), 3, "3S consumes two interior evaluations");
    }
}
