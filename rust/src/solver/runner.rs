//! The sampling loop: drives any [`Method`] over a timestep grid, optionally
//! wrapping every step with the UniC corrector (Algorithm 5/7), with warm-up,
//! order schedules, oracle mode, NFE accounting, and trajectory capture.
//!
//! NFE conventions (paper §4, Appendix F.1):
//! * multistep methods: `steps` solver steps cost exactly `steps` NFE
//!   (one evaluation at t_0..t_{M−1}; none at t_M);
//! * UniC adds **no** NFE: the evaluation at the predicted point is reused
//!   as the next step's buffer entry, and the corrector is skipped after the
//!   final predictor step;
//! * UniC-oracle re-evaluates at the corrected point (≈2× NFE, Table 3);
//! * singlestep methods interpret `steps` as an NFE budget, split into
//!   groups via [`super::method::singlestep_orders`].

use super::ddim::{ddim_step, ddim_transfer};
use super::deis::deis_step;
use super::dpm_solver::{dpm_solver_2_step, dpm_solver_3_step};
use super::dpm_solverpp::{dpmpp_2m_step, dpmpp_2s_step, dpmpp_3m_step, dpmpp_3s_step};
use super::history::History;
use super::method::{singlestep_orders, Method};
use super::plan::{sample_batch_with_plan, sample_with_plan, BatchWorkspace, SamplePlan};
use super::pndm::plms_step;
use super::thresholding::DynamicThresholding;
use super::unipc::{unic_correct_with, unip_predict, CoeffVariant};
use super::{Evaluator, Model, Prediction};
use crate::numerics::vandermonde::BFunction;
use crate::sched::{timesteps, NoiseSchedule, TimeSpacing};
use crate::tensor::Tensor;

/// UniC configuration (applied after any base method).
#[derive(Clone, Copy, Debug)]
pub struct UniCOptions {
    pub variant: CoeffVariant,
    /// Re-evaluate the model at the corrected point for the buffer
    /// (UniC-oracle, Table 3). Costs one extra NFE per corrected step.
    pub oracle: bool,
}

impl Default for UniCOptions {
    fn default() -> Self {
        UniCOptions { variant: CoeffVariant::Bh(BFunction::Bh2), oracle: false }
    }
}

/// Full sampling configuration.
#[derive(Clone, Debug)]
pub struct SampleOptions {
    /// Solver steps (multistep) or NFE budget (singlestep).
    pub steps: usize,
    pub t_start: f64,
    pub t_end: f64,
    pub spacing: TimeSpacing,
    pub method: Method,
    /// Apply UniC after every base step ("+UniC" / UniPC).
    pub unic: Option<UniCOptions>,
    /// Dynamic thresholding for data-prediction evaluations (§3.4).
    pub thresholding: Option<DynamicThresholding>,
    /// Record (t, x_t) after every step.
    pub capture_trajectory: bool,
    /// Replace the first p−1 steps with a high-accuracy RK4 sub-integration
    /// (Assumption D.4's O(h^k)-accurate starting values). Production
    /// sampling uses the standard low-order warm-up exactly like the
    /// official implementation; this mode exists for the order-of-convergence
    /// experiments, where warm-up error would otherwise dominate the slope.
    /// RK4 sub-steps are not counted in `nfe`.
    pub exact_warmup: bool,
}

impl SampleOptions {
    pub fn new(method: Method, steps: usize) -> Self {
        SampleOptions {
            steps,
            t_start: 1.0,
            t_end: 1e-3,
            spacing: TimeSpacing::LogSnr,
            method,
            unic: None,
            thresholding: None,
            capture_trajectory: false,
            exact_warmup: false,
        }
    }

    /// The paper's UniPC-p: UniP-p + UniC-p with the same coefficients.
    pub fn unipc(order: usize, b: BFunction, pred: Prediction, steps: usize) -> Self {
        let mut o = SampleOptions::new(Method::unip(order, b, pred), steps);
        o.unic = Some(UniCOptions { variant: CoeffVariant::Bh(b), oracle: false });
        o
    }

    pub fn with_unic(mut self, variant: CoeffVariant, oracle: bool) -> Self {
        self.unic = Some(UniCOptions { variant, oracle });
        self
    }

    pub fn with_range(mut self, t_start: f64, t_end: f64) -> Self {
        self.t_start = t_start;
        self.t_end = t_end;
        self
    }

    /// A descriptive id for logs/benches, e.g. `unip-3-bh2-noise+unic`.
    pub fn id(&self) -> String {
        let mut s = self.method.id();
        if let Some(u) = &self.unic {
            s.push_str(if u.oracle { "+unic-oracle" } else { "+unic" });
        }
        s
    }
}

/// Result of a sampling run.
#[derive(Clone, Debug)]
pub struct SampleResult {
    /// State at t_end.
    pub x: Tensor,
    /// Model evaluations actually performed.
    pub nfe: usize,
    /// (t, x_t) after every solver step, if requested.
    pub trajectory: Option<Vec<(f64, Tensor)>>,
}

/// Run the configured sampler from `x_init` (at `t_start`) down to `t_end`.
///
/// Plannable configurations — **every method in the registry**; only
/// `exact_warmup` runs are excluded (see [`SamplePlan::supports`]) —
/// execute from a [`SamplePlan`]: all per-step coefficient math is resolved
/// up front and the steady-state step is pure in-place tensor arithmetic.
/// The result is bit-identical to [`sample_unplanned`] (proven per method ×
/// parametrization × spacing by `tests/solver_conformance.rs`). Callers
/// issuing many identically-configured runs (the coordinator) should
/// build/cache the plan themselves and call [`sample_with_plan`] directly
/// to amortize even the one-time build.
pub fn sample(
    model: &dyn Model,
    sched: &dyn NoiseSchedule,
    x_init: &Tensor,
    opts: &SampleOptions,
) -> SampleResult {
    if let Some(plan) = SamplePlan::build(sched, opts) {
        return sample_with_plan(model, sched, x_init, opts, &plan);
    }
    sample_unplanned(model, sched, x_init, opts)
}

/// Run several requests that share one configuration, in lockstep over a
/// stacked batch ([`sample_batch_with_plan`]): one model evaluation per
/// step for the whole batch. Results are bit-identical to calling
/// [`sample`] once per entry of `x_inits` whenever the model evaluates
/// batch rows independently (true for the analytic backends).
///
/// Configurations plans don't cover (`exact_warmup` runs) and
/// trajectory-capture runs — which are inherently per-request — fall back
/// to independent sequential runs. Callers issuing many batches (the
/// coordinator) should build/cache the plan and keep a pooled
/// [`BatchWorkspace`] themselves and call [`sample_batch_with_plan`]
/// directly.
pub fn sample_batch(
    model: &dyn Model,
    sched: &dyn NoiseSchedule,
    x_inits: &[&Tensor],
    opts: &SampleOptions,
) -> Vec<SampleResult> {
    if !opts.capture_trajectory {
        if let Some(plan) = SamplePlan::build(sched, opts) {
            let mut bw = BatchWorkspace::new();
            return sample_batch_with_plan(model, sched, x_inits, opts, &plan, &mut bw);
        }
    }
    x_inits.iter().map(|x| sample(model, sched, x, opts)).collect()
}

/// The on-the-fly reference loop: step geometry and combination
/// coefficients recomputed at every step. Kept (a) as the only path for
/// `exact_warmup` runs (which a [`SamplePlan`] does not cover) and (b) as
/// the **oracle** the planned path is tested bit-identical against, per
/// method family (`solver::plan` tests + `tests/solver_conformance.rs`).
pub fn sample_unplanned(
    model: &dyn Model,
    sched: &dyn NoiseSchedule,
    x_init: &Tensor,
    opts: &SampleOptions,
) -> SampleResult {
    let ev = Evaluator::new(model, sched, opts.method.prediction(), opts.thresholding);
    if opts.method.is_singlestep() {
        sample_singlestep(&ev, sched, x_init, opts)
    } else {
        sample_multistep(model, &ev, sched, x_init, opts)
    }
}

/// Effective UniP order at step `i` (1-based) given warm-up and an optional
/// custom order schedule (Table 4). The final-step damping to lower orders
/// follows the DPM-Solver++ convention: the default schedule keeps `order`
/// until the last steps where fewer future steps remain.
///
/// Shared with [`SamplePlan::build`], which resolves the same clamping for
/// the whole run up front — a single definition keeps the planned path's
/// bit-identical contract with this loop from drifting.
pub(super) fn effective_order(
    method_order: usize,
    schedule: Option<&[usize]>,
    i: usize,
    hist_len: usize,
) -> usize {
    let want = schedule
        .and_then(|s| s.get(i - 1).copied())
        .unwrap_or(method_order);
    want.max(1).min(hist_len).min(i)
}

fn sample_multistep(
    model: &dyn Model,
    ev: &Evaluator,
    sched: &dyn NoiseSchedule,
    x_init: &Tensor,
    opts: &SampleOptions,
) -> SampleResult {
    let m_steps = opts.steps;
    let ts = timesteps(sched, opts.spacing, opts.t_start, opts.t_end, m_steps);
    let mut hist = History::new(opts.method.history_needed().max(
        opts.unic.map(|_| opts.method.order()).unwrap_or(0),
    ));
    let mut traj = opts.capture_trajectory.then(Vec::new);

    let mut x = x_init.clone();
    hist.push(ts[0], sched.lambda(ts[0]), ev.eval(&x, ts[0]));

    // Exact warm-up (order experiments): advance the first p−1 steps along a
    // high-accuracy trajectory so the multistep buffer starts O(h^p)-accurate.
    let mut start = 1usize;
    if opts.exact_warmup && model.prediction() == Prediction::Noise {
        let p = opts.method.order().min(m_steps);
        for i in 1..p {
            x = crate::analytic::reference_solution(model, sched, &x, ts[i - 1], ts[i], 64);
            hist.push(ts[i], sched.lambda(ts[i]), ev.eval(&x, ts[i]));
            if let Some(tr) = traj.as_mut() {
                tr.push((ts[i], x.clone()));
            }
        }
        start = p;
    }

    for i in start..=m_steps {
        let t = ts[i];
        let last_step = i == m_steps;

        let p_i = effective_order(
            opts.method.order(),
            match &opts.method {
                Method::UniP { schedule, .. } => schedule.as_deref(),
                _ => None,
            },
            i,
            hist.len(),
        );

        let x_pred = match &opts.method {
            Method::Ddim { .. } => ddim_step(ev, sched, &hist, &x, t),
            Method::UniP { variant, .. } => unip_predict(ev, sched, &hist, &x, t, p_i, *variant),
            Method::DpmSolverPp { .. } => match p_i {
                1 => ddim_step(ev, sched, &hist, &x, t),
                2 => dpmpp_2m_step(ev, sched, &hist, &x, t),
                _ => dpmpp_3m_step(ev, sched, &hist, &x, t),
            },
            Method::Plms => plms_step(ev, sched, &hist, &x, t),
            Method::Deis { order } => deis_step(ev, sched, &hist, &x, t, (*order).min(i)),
            m => unreachable!("singlestep method {m:?} in multistep loop"),
        };

        x = match (&opts.unic, last_step) {
            (Some(unic), false) => {
                // Corrector order matches the base step's effective order
                // (Theorem 3.1 then gives accuracy p_i + 1).
                let m_t = ev.eval(&x_pred, t);
                let x_c =
                    unic_correct_with(ev, sched, &hist, &x, &m_t, t, p_i, unic.variant);
                let m_buf = if unic.oracle { ev.eval(&x_c, t) } else { m_t };
                hist.push(t, sched.lambda(t), m_buf);
                x_c
            }
            _ => {
                if !last_step {
                    hist.push(t, sched.lambda(t), ev.eval(&x_pred, t));
                }
                x_pred
            }
        };

        if let Some(tr) = traj.as_mut() {
            tr.push((t, x.clone()));
        }
    }

    SampleResult { x, nfe: ev.nfe(), trajectory: traj }
}

fn sample_singlestep(
    ev: &Evaluator,
    sched: &dyn NoiseSchedule,
    x_init: &Tensor,
    opts: &SampleOptions,
) -> SampleResult {
    let nfe_budget = opts.steps;
    let max_order = opts.method.order();
    let orders = singlestep_orders(max_order, nfe_budget);
    // Fine grid with one interval per NFE; groups span `k` intervals, so the
    // interior nodes coincide with fine-grid points (λ-uniform spacing gives
    // the canonical r1 = 1/3, r2 = 2/3).
    let fine = timesteps(sched, opts.spacing, opts.t_start, opts.t_end, nfe_budget);
    let mut traj = opts.capture_trajectory.then(Vec::new);

    let mut x = x_init.clone();
    let mut hist = History::new(max_order + 1); // group-boundary outputs for UniC
    let mut idx = 0usize;
    let mut m_s: Option<Tensor> = None;

    for (g, &k) in orders.iter().enumerate() {
        let t_s = fine[idx];
        let t_t = fine[idx + k];
        let last_group = g + 1 == orders.len();

        let m_start = match m_s.take() {
            Some(m) => m,
            None => ev.eval(&x, t_s),
        };
        if hist.is_empty() || hist.last().t > t_s {
            hist.push(t_s, sched.lambda(t_s), m_start.clone());
        }

        let h = sched.lambda(t_t) - sched.lambda(t_s);
        let rs: Vec<f64> = (1..k)
            .map(|j| (sched.lambda(fine[idx + j]) - sched.lambda(t_s)) / h)
            .collect();

        let x_pred = match (&opts.method, k) {
            (_, 1) => ddim_transfer(ev.prediction(), sched, &x, t_s, t_t, &m_start),
            (Method::DpmSolverSingle { .. }, 2) => {
                dpm_solver_2_step(ev, sched, &x, t_s, t_t, &m_start, rs[0])
            }
            (Method::DpmSolverSingle { .. }, _) => {
                dpm_solver_3_step(ev, sched, &x, t_s, t_t, &m_start, rs[0], rs[1])
            }
            (Method::DpmSolverPp3S, 2) => {
                // 2-interval tail group: second-order singlestep via the
                // data-prediction midpoint form (reference 2S with r1 = rs[0]).
                dpmpp_2s_step(ev, sched, &x, t_s, t_t, &m_start, rs[0])
            }
            (Method::DpmSolverPp3S, _) => {
                dpmpp_3s_step(ev, sched, &x, t_s, t_t, &m_start, rs[0], rs[1])
            }
            (m, _) => unreachable!("multistep method {m:?} in singlestep loop"),
        };

        x = match (&opts.unic, last_group) {
            (Some(unic), false) => {
                let p = k.min(hist.len());
                let m_t = ev.eval(&x_pred, t_t);
                let x_c =
                    unic_correct_with(ev, sched, &hist, &x, &m_t, t_t, p, unic.variant);
                let m_next = if unic.oracle { ev.eval(&x_c, t_t) } else { m_t };
                hist.push(t_t, sched.lambda(t_t), m_next.clone());
                m_s = Some(m_next);
                x_c
            }
            _ => {
                if !last_group {
                    let m_next = ev.eval(&x_pred, t_t);
                    hist.push(t_t, sched.lambda(t_t), m_next.clone());
                    m_s = Some(m_next);
                }
                x_pred
            }
        };

        if let Some(tr) = traj.as_mut() {
            tr.push((t_t, x.clone()));
        }
        idx += k;
    }

    SampleResult { x, nfe: ev.nfe(), trajectory: traj }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// ε(x,t) = c·x keeps the ODE linear so every method must land near the
    /// exact solution for enough steps.
    fn linear_model(c: f64) -> impl Model {
        (Prediction::Noise, 2, move |x: &Tensor, _t: f64| x.scaled(c))
    }

    fn x0() -> Tensor {
        Tensor::from_vec(&[1, 2], vec![0.8, -0.6])
    }

    #[test]
    fn multistep_nfe_equals_steps() {
        let sched = crate::sched::VpLinear::default();
        let m = linear_model(0.3);
        for steps in [1usize, 2, 5, 10] {
            let opts = SampleOptions::new(
                Method::unip(3, BFunction::Bh2, Prediction::Noise),
                steps,
            );
            let r = sample(&m, &sched, &x0(), &opts);
            assert_eq!(r.nfe, steps, "steps {steps}");
        }
    }

    #[test]
    fn unic_adds_no_nfe() {
        let sched = crate::sched::VpLinear::default();
        let m = linear_model(0.3);
        let steps = 8;
        let base = SampleOptions::new(Method::unip(3, BFunction::Bh2, Prediction::Noise), steps);
        let with_c = base.clone().with_unic(CoeffVariant::Bh(BFunction::Bh2), false);
        assert_eq!(sample(&m, &sched, &x0(), &base).nfe, steps);
        assert_eq!(sample(&m, &sched, &x0(), &with_c).nfe, steps);
    }

    #[test]
    fn oracle_roughly_doubles_nfe() {
        let sched = crate::sched::VpLinear::default();
        let m = linear_model(0.3);
        let steps = 6;
        let opts = SampleOptions::new(Method::unip(2, BFunction::Bh2, Prediction::Noise), steps)
            .with_unic(CoeffVariant::Bh(BFunction::Bh2), true);
        let r = sample(&m, &sched, &x0(), &opts);
        assert_eq!(r.nfe, 2 * steps - 1, "oracle re-evaluates all but the last step");
    }

    #[test]
    fn singlestep_nfe_equals_budget() {
        let sched = crate::sched::VpLinear::default();
        let m = linear_model(0.3);
        for nfe in [3usize, 5, 6, 8, 10] {
            for method in [Method::DpmSolverSingle { order: 3 }, Method::DpmSolverPp3S] {
                let opts = SampleOptions::new(method.clone(), nfe);
                let r = sample(&m, &sched, &x0(), &opts);
                assert_eq!(r.nfe, nfe, "{} nfe {nfe}", method.id());
            }
        }
    }

    #[test]
    fn all_methods_run_and_stay_finite() {
        let sched = crate::sched::VpLinear::default();
        let m = linear_model(0.4);
        let methods = [
            Method::Ddim { pred: Prediction::Noise },
            Method::Ddim { pred: Prediction::Data },
            Method::unip(2, BFunction::Bh1, Prediction::Noise),
            Method::unip(3, BFunction::Bh2, Prediction::Data),
            Method::UniP {
                order: 3,
                variant: CoeffVariant::Varying,
                pred: Prediction::Noise,
                schedule: None,
            },
            Method::DpmSolverSingle { order: 2 },
            Method::DpmSolverSingle { order: 3 },
            Method::DpmSolverPp { order: 2 },
            Method::DpmSolverPp { order: 3 },
            Method::DpmSolverPp3S,
            Method::Plms,
            Method::Deis { order: 2 },
        ];
        for method in methods {
            let opts = SampleOptions::new(method.clone(), 8);
            let r = sample(&m, &sched, &x0(), &opts);
            assert!(
                r.x.data().iter().all(|v| v.is_finite()),
                "{} produced non-finite output",
                method.id()
            );
        }
    }

    #[test]
    fn order_schedule_is_respected_via_trajectory_shape() {
        // A schedule of all-ones must reproduce DDIM exactly.
        let sched = crate::sched::VpLinear::default();
        let m = linear_model(0.35);
        let steps = 6;
        let sched_opts = SampleOptions::new(
            Method::UniP {
                order: 3,
                variant: CoeffVariant::Bh(BFunction::Bh2),
                pred: Prediction::Noise,
                schedule: Some(vec![1; steps]),
            },
            steps,
        );
        let ddim_opts = SampleOptions::new(Method::Ddim { pred: Prediction::Noise }, steps);
        let a = sample(&m, &sched, &x0(), &sched_opts);
        let b = sample(&m, &sched, &x0(), &ddim_opts);
        for (av, bv) in a.x.data().iter().zip(b.x.data()) {
            assert!((av - bv).abs() < 1e-12);
        }
    }

    #[test]
    fn trajectory_capture_length() {
        let sched = crate::sched::VpLinear::default();
        let m = linear_model(0.3);
        let mut opts = SampleOptions::new(Method::Ddim { pred: Prediction::Noise }, 5);
        opts.capture_trajectory = true;
        let r = sample(&m, &sched, &x0(), &opts);
        assert_eq!(r.trajectory.unwrap().len(), 5);
    }

    #[test]
    fn linear_ode_exact_solution_reached() {
        // For ε = c·x the λ-domain ODE is linear; the RK4 reference is
        // machine-precision truth. UniPC-3 @ 32 steps must beat DDIM @ 32
        // by a wide margin.
        let sched = crate::sched::VpLinear::default();
        let m = linear_model(0.5);
        let truth = crate::analytic::reference_solution(&m, &sched, &x0(), 1.0, 1e-3, 4000);
        let ddim32 = sample(
            &m,
            &sched,
            &x0(),
            &SampleOptions::new(Method::Ddim { pred: Prediction::Noise }, 32),
        )
        .x;
        let unipc32 = sample(
            &m,
            &sched,
            &x0(),
            &SampleOptions::unipc(3, BFunction::Bh2, Prediction::Noise, 32),
        )
        .x;
        let e_ddim = ddim32.sub(&truth).norm();
        let e_unipc = unipc32.sub(&truth).norm();
        assert!(
            e_unipc < e_ddim / 25.0,
            "unipc {e_unipc} should beat ddim {e_ddim} by ≫"
        );
    }

    #[test]
    fn empirical_convergence_orders() {
        // Thm 3.1 / Cor 3.2 / Prop D.5–D.6: with exact warm-up, doubling the
        // step count should shrink the error by ~2^p (UniP-p) and ~2^{p+1}
        // (UniPC-p). Slopes are measured over a dyadic sweep in the
        // asymptotic regime.
        let sched = crate::sched::VpLinear::default();
        let m = linear_model(0.5);
        let truth = crate::analytic::reference_solution(&m, &sched, &x0(), 1.0, 1e-3, 8000);

        let err = |opts: &SampleOptions| sample(&m, &sched, &x0(), opts).x.sub(&truth).norm();
        let slope = |mk: &dyn Fn(usize) -> SampleOptions| -> f64 {
            let grid = [160usize, 320, 640, 1280];
            let es: Vec<f64> = grid.iter().map(|&s| err(&mk(s))).collect();
            // Least-squares slope of log2(e) against log2(steps).
            let n = grid.len() as f64;
            let xs: Vec<f64> = grid.iter().map(|&s| (s as f64).log2()).collect();
            let ys: Vec<f64> = es.iter().map(|e| e.log2()).collect();
            let mx = xs.iter().sum::<f64>() / n;
            let my = ys.iter().sum::<f64>() / n;
            let num: f64 = xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum();
            let den: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
            -num / den
        };

        let unip = |p: usize| {
            move |steps: usize| {
                let mut o = SampleOptions::new(
                    Method::unip(p, BFunction::Bh2, Prediction::Noise),
                    steps,
                );
                o.exact_warmup = true;
                o
            }
        };
        let unipc = |p: usize| {
            move |steps: usize| {
                let mut o = SampleOptions::unipc(p, BFunction::Bh2, Prediction::Noise, steps);
                o.exact_warmup = true;
                o
            }
        };

        let s_p2 = slope(&unip(2));
        let s_p3 = slope(&unip(3));
        let s_pc2 = slope(&unipc(2));
        assert!((1.6..=2.6).contains(&s_p2), "UniP-2 slope {s_p2}");
        assert!((2.5..=3.7).contains(&s_p3), "UniP-3 slope {s_p3}");
        assert!((2.5..=3.8).contains(&s_pc2), "UniPC-2 slope {s_pc2}");
        assert!(s_pc2 > s_p2 + 0.5, "corrector must raise the order: {s_p2} -> {s_pc2}");
    }
}
