//! Precomputed sampling plans + zero-allocation step execution for the
//! solver hot path — **every** method in the registry, not just UniPC.
//!
//! # Why plans
//!
//! Every scalar a sampling run needs — the timestep grid, the per-step
//! effective order (warm-up ramp + optional Table-4 schedule), the signed
//! step `hh`, the node ratios r_m, the linear-part scalars (α_t/α_s,
//! −σ_t·(eʰ−1), …) and the method's combination coefficients (Theorem-3.1 /
//! Appendix-C systems for UniPC, φ-function coefficients for the
//! DPM-Solver families, Adams–Bashforth weights for PNDM, kernel-quadrature
//! integrals for DEIS) — is a pure function of `(NoiseSchedule,
//! SampleOptions)`. The reference loop
//! ([`super::runner::sample_unplanned`]) re-derives all of it at every
//! step; DEIS even re-runs a 16-point Gauss–Legendre quadrature per step
//! and the `Varying` UniPC variant a full LU inversion. A [`SamplePlan`]
//! hoists that work out of the loop: built once, it reduces the
//! steady-state step to pure tensor arithmetic with zero coefficient math.
//!
//! # Lifecycle: build → cache → execute
//!
//! 1. **Build** — [`SamplePlan::build`] resolves the whole run up front.
//!    Each multistep family lowers through its [`CompileStep`] compiler
//!    into per-step [`StepOp`]s; singlestep methods (DPM-Solver-2S/3S,
//!    DPM-Solver++-3S) compile their NFE-budget group split the same way.
//!    Only `exact_warmup` runs (an experiments-only mode) keep using the
//!    reference loop.
//! 2. **Cache** — a plan is immutable and model-independent, so identically
//!    configured requests share one `Arc<SamplePlan>`. The coordinator
//!    ([`crate::coordinator`]) keys its cache by [`plan_key`], which folds
//!    in every input the plan depends on: the noise schedule's name, the
//!    canonical method form including order-schedule contents
//!    ([`Method::cache_key`]), step count, spacing, the exact
//!    `t_start`/`t_end` bits, and the UniC variant / oracle flag.
//!    Execute-time settings the plan does not bake in (thresholding,
//!    trajectory capture) deliberately don't key it.
//! 3. **Execute** — [`sample_with_plan`] drives the run from the plan using
//!    a [`StepWorkspace`] of preallocated buffers. It is bit-identical to
//!    the reference loop for every method (asserted per-family by the tests
//!    below and exhaustively by `tests/solver_conformance.rs`): same
//!    operations, same accumulation order, same NFE accounting.
//!
//! # The zero-allocation invariant
//!
//! A steady-state planned multistep step performs **zero heap allocations**
//! in the solver arithmetic: [`SamplePlan::predict_into`] and
//! [`SamplePlan::correct_into`] write only into the workspace and the state
//! tensor (`assign_*` kernels + [`crate::tensor::weighted_sum_into`]), the
//! history ring buffer is preallocated and merely rotates ownership of the
//! model-output tensors, and the state advance is a pointer swap. The only
//! allocations left in the loop are the model evaluations themselves, which
//! by contract produce a fresh output tensor (singlestep groups additionally
//! clone one boundary output into the history buffer, mirroring the
//! reference loop). `tests/plan_alloc.rs` proves the invariant with a
//! counting global allocator across the UniPC, DPM-Solver++, DEIS, and PNDM
//! families.
//!
//! # Batched execution across requests
//!
//! A plan is shared by every identically-configured request, so requests
//! can also *execute* together: [`sample_batch_with_plan`] stacks member
//! initial states into one batch-major tensor, advances all of them through
//! the timestep grid in lockstep, and evaluates the model once per step on
//! the stacked batch. Outputs are bit-identical to solo runs (all kernels
//! are row-independent), and a per-worker [`BatchWorkspace`] pools the
//! stacked state and the [`StepWorkspace`] across runs so steady-state
//! batches start without allocating. The coordinator's batch assembler
//! ([`crate::coordinator`]) groups queued requests by plan key alone —
//! model conditioning is carried per *row* by the row-conditioned
//! [`crate::coordinator::CohortModel`] view, so mixed class/guidance
//! requests share one lockstep run — and drives this entry point for
//! every method in the registry, with no special-casing.
//!
//! # Example
//!
//! Build a plan once, then execute any number of runs from it:
//!
//! ```
//! use unipc::analytic::datasets::{dataset, DatasetSpec};
//! use unipc::analytic::GmmModel;
//! use unipc::numerics::vandermonde::BFunction;
//! use unipc::rng::Rng;
//! use unipc::sched::VpLinear;
//! use unipc::solver::{sample_with_plan, Prediction, SampleOptions, SamplePlan};
//!
//! let sched = VpLinear::default();
//! let gm = dataset(DatasetSpec::Cifar10Like);
//! let model = GmmModel { gm: &gm, sched: &sched };
//!
//! // UniPC-3 with the B2(h) choice at 8 steps — the paper's low-NFE regime.
//! let opts = SampleOptions::unipc(3, BFunction::Bh2, Prediction::Noise, 8);
//! let plan = SamplePlan::build(&sched, &opts).expect("plannable");
//!
//! let x_t = Rng::seed_from(7).normal_tensor(&[4, gm.dim]);
//! let result = sample_with_plan(&model, &sched, &x_t, &opts, &plan);
//! assert_eq!(result.nfe, 8); // UniC reuses evaluations: steps == NFE
//! assert!(result.x.data().iter().all(|v| v.is_finite()));
//! ```

use super::deis::deis_weights;
use super::history::History;
use super::method::{singlestep_orders, Method};
use super::pndm::ab_weights;
use super::runner::{effective_order, SampleOptions, SampleResult};
use super::unipc::{residual_coeffs, CoeffVariant};
use super::{Evaluator, Model, Prediction};
use crate::numerics::phi::{phi, psi};
use crate::sched::{timesteps, NoiseSchedule};
use crate::tensor::{weighted_sum_into, Tensor};
use std::collections::VecDeque;

/// Cache key for a plan: every input [`SamplePlan::build`] reads, and
/// nothing else. Two requests with equal keys can share one plan — in
/// particular, options that differ only in execute-time settings the plan
/// does not bake in (thresholding, trajectory capture) share a plan.
///
/// The schedule enters through [`NoiseSchedule::cache_key`], which folds
/// in the schedule's parameters, so same-name schedules with different
/// parameters never share a plan.
pub fn plan_key(sched: &dyn NoiseSchedule, opts: &SampleOptions) -> String {
    use std::fmt::Write;
    let mut key = String::new();
    let _ = write!(
        key,
        "{}|{}|steps={}|{}|{:x}..{:x}|{}",
        sched.cache_key(),
        opts.method.cache_key(),
        opts.steps,
        opts.spacing.name(),
        opts.t_start.to_bits(),
        opts.t_end.to_bits(),
        match &opts.unic {
            Some(u) => format!(
                "unic-{}{}",
                u.variant.name(),
                if u.oracle { "-oracle" } else { "" }
            ),
            None => "nounic".to_string(),
        },
    );
    key
}

/// Grid geometry handed to a [`CompileStep`] implementation for one
/// multistep solver step: the full resolved timestep grid plus this step's
/// index and effective order. Compilers read `ts[i-1] → ts[i]` as the step
/// and `lams[i-1-m]` as the history node λ's — exactly what the reference
/// loop's `History` would hold at this point of the run.
pub struct StepCx<'a> {
    /// Noise schedule the run samples under.
    pub sched: &'a dyn NoiseSchedule,
    /// Decreasing grid `t_0 = t_start > … > t_M = t_end`.
    pub ts: &'a [f64],
    /// λ(t) for every grid point.
    pub lams: &'a [f64],
    /// 1-based step index: this step advances `ts[i-1] → ts[i]`.
    pub i: usize,
    /// Effective order p_i (warm-up ramp / order schedule applied).
    pub order: usize,
    /// Buffered history length at this step (`min(i, cap)`).
    pub hist_len: usize,
    /// The parametrization the method consumes.
    pub pred: Prediction,
}

/// A per-family **plan compiler**: lowers one multistep solver step into
/// the precomputed [`StepOp`] that [`sample_with_plan`] executes with zero
/// solver-side allocations. One implementation exists per method family
/// (UniP/UniPC, DDIM, DPM-Solver++ multistep, PNDM, DEIS); singlestep
/// methods compile through the NFE-budget group compiler inside
/// [`SamplePlan::build`] instead. The contract is **bit-identity**: the
/// compiled op must perform the same floating-point operations in the same
/// order as the family's reference step function, with every scalar
/// resolved at build time.
///
/// # Example — planning a non-UniPC baseline
///
/// ```
/// use unipc::sched::VpLinear;
/// use unipc::solver::{
///     sample_unplanned, sample_with_plan, Method, Prediction, SampleOptions, SamplePlan,
/// };
/// use unipc::tensor::Tensor;
///
/// let sched = VpLinear::default();
/// // DPM-Solver++(2M) — the paper's strongest baseline — compiles to a
/// // plan just like UniPC does.
/// let opts = SampleOptions::new(Method::DpmSolverPp { order: 2 }, 8);
/// let plan = SamplePlan::build(&sched, &opts).expect("every method is plannable");
///
/// let model = (Prediction::Noise, 2, |x: &Tensor, _t: f64| x.scaled(0.4));
/// let x0 = Tensor::from_vec(&[1, 2], vec![0.6, -0.3]);
/// let planned = sample_with_plan(&model, &sched, &x0, &opts, &plan);
/// let reference = sample_unplanned(&model, &sched, &x0, &opts);
/// assert_eq!(planned.nfe, reference.nfe);
/// assert_eq!(planned.x.data(), reference.x.data()); // bit-identical
/// ```
pub trait CompileStep {
    /// Compile step `cx.i` into its precomputed op.
    fn compile(&self, cx: &StepCx<'_>) -> StepOp;
}

/// One interior model evaluation of a singlestep group: the node state is
/// `x_coef·x + m_coef·m_s` (plus `d_coef·D_prev` for the second node of a
/// third-order group), evaluated at `t`.
#[derive(Clone, Debug)]
pub struct SingleNode {
    pub t: f64,
    pub x_coef: f64,
    pub m_coef: f64,
    /// Coefficient on the previous node's difference D (third-order groups).
    pub d_coef: Option<f64>,
}

/// A compiled singlestep group (DPM-Solver-2S/3S, DPM-Solver++-2S/3S, or a
/// first-order DDIM-transfer tail group): interior nodes plus the final
/// combination `x_coef·x + m_coef·m_s (+ d_coef·D_last)`.
#[derive(Clone, Debug)]
pub struct SingleOp {
    /// Group start t_s (the boundary the reused model output lives at).
    pub t_s: f64,
    /// λ(t_s).
    pub lambda_s: f64,
    /// Interior evaluations, in execution order (0, 1, or 2 of them).
    pub nodes: Vec<SingleNode>,
    pub x_coef: f64,
    pub m_coef: f64,
    /// Coefficient on the last interior difference, if any.
    pub d_coef: Option<f64>,
}

/// The compiled base step of one plan entry — everything the executor needs
/// that does not depend on the model outputs. Each variant mirrors its
/// family's reference step function operation-for-operation.
#[derive(Clone, Debug)]
pub enum StepOp {
    /// First-order exponential step `pred = x_coef·x + m0_coef·m₀`: DDIM,
    /// UniP-1, DPM-Solver++(1M), and warm-up-clamped first steps.
    FirstOrder { x_coef: f64, m0_coef: f64 },
    /// UniP-p, p ≥ 2 (Corollary 3.2): linear part, D_m/r_m rows, and the
    /// fully-solved residual combination coefficients.
    UniP {
        x_coef: f64,
        m0_coef: f64,
        /// −σ_t (noise) or −α_t (data): multiplies the residual combination.
        residual_scale: f64,
        /// 1/r_m for the historical nodes m = 1..p−1.
        inv_r: Vec<f64>,
        /// Residual coefficients c_m (p−1 entries).
        coeffs: Vec<f64>,
    },
    /// Multistep DPM-Solver++(2M).
    Dpmpp2M { x_coef: f64, m0_coef: f64, inv_r0: f64, d1_coef: f64 },
    /// Multistep DPM-Solver++(3M).
    Dpmpp3M {
        x_coef: f64,
        m0_coef: f64,
        inv_r0: f64,
        inv_r1: f64,
        /// r0/(r0+r1): mixes D1_0 with (D1_0 − D1_1) into D1.
        mix: f64,
        /// 1/(r0+r1): scales (D1_0 − D1_1) into D2.
        inv_r01: f64,
        d1_coef: f64,
        d2_coef: f64,
    },
    /// PNDM/PLMS: Adams–Bashforth combination of the last k ε outputs fed
    /// through the DDIM transfer map.
    Plms { x_coef: f64, comb_coef: f64, weights: Vec<f64> },
    /// tAB-DEIS: precomputed kernel-quadrature weights on the last q
    /// outputs, added to the rescaled state.
    Deis { x_coef: f64, weights: Vec<f64> },
    /// A singlestep NFE-budget group (executed by the singlestep driver,
    /// not by [`SamplePlan::predict_into`]).
    Single(SingleOp),
}

/// Scratch rows the op consumes at execution time (sizes the workspace).
fn op_rows(op: &StepOp) -> usize {
    match op {
        StepOp::FirstOrder { .. } => 0,
        StepOp::UniP { inv_r, .. } => inv_r.len(),
        StepOp::Dpmpp2M { .. } => 1,
        StepOp::Dpmpp3M { .. } => 5,
        StepOp::Plms { .. } | StepOp::Deis { .. } => 0,
        StepOp::Single(s) => s.nodes.len(),
    }
}

/// The UniC corrector of one step, fully resolved: linear-part scalars,
/// node ratios, and the full p-node system coefficients (r_p = 1). Applied
/// after **any** base op — the §3.1 claim that UniC composes with any
/// solver is structural here.
#[derive(Clone, Debug)]
pub struct CorrectorStep {
    pub x_coef: f64,
    pub m0_coef: f64,
    pub residual_scale: f64,
    /// 1/r_m for the historical nodes m = 1..p−1.
    pub inv_r: Vec<f64>,
    /// Full p-node system coefficients (`coeffs.len()` = corrector order p).
    pub coeffs: Vec<f64>,
}

/// Everything step `k` needs that does not depend on the model outputs.
#[derive(Clone, Debug)]
pub struct PlannedStep {
    /// Target timestep t (group end for singlestep methods).
    pub t: f64,
    /// λ_t (pushed into the history buffer with the step's output).
    pub lambda: f64,
    /// Effective order p_i of this step (warm-up ramp / order schedule /
    /// singlestep group order applied); the corrector, if any, runs at this
    /// order.
    pub order: usize,
    /// The compiled base step.
    pub op: StepOp,
    /// The compiled UniC corrector (`None` when no UniC is configured or on
    /// the final step, which skips correction by convention).
    pub corrector: Option<CorrectorStep>,
}

/// A complete precomputed run: grid, orders, and coefficients for every
/// step. Immutable and model-independent — share via `Arc` across requests.
#[derive(Clone, Debug)]
pub struct SamplePlan {
    key: String,
    prediction: Prediction,
    oracle: bool,
    singlestep: bool,
    history_cap: usize,
    max_order: usize,
    ws_rows: usize,
    t0: f64,
    lambda0: f64,
    steps: Vec<PlannedStep>,
}

/// Preallocated buffers for plan execution. One workspace serves a whole
/// run (or any number of runs with the same batch shape); steady-state
/// steps write into it without touching the allocator.
pub struct StepWorkspace {
    /// Scratch rows: D_m/r_m rows for the multistep families (slot `p−1`
    /// doubles as the corrector's D_p = m_t − m₀ row), the derived
    /// D1/D2/diff rows of DPM-Solver++(3M), and the interior-node
    /// differences of singlestep groups.
    d: Vec<Tensor>,
    /// The residual combination Σ_m c_m · D_m/r_m (also the PLMS/DEIS
    /// history combination).
    res: Tensor,
    /// The linear part x^{(1)} shared by the corrector, and the interior
    /// node state of singlestep groups.
    lin: Tensor,
    /// Predictor output x_pred (swapped into the state when no corrector
    /// applies).
    pred: Tensor,
}

impl StepWorkspace {
    /// Buffers for batch shape `shape` with `rows` scratch rows (size with
    /// [`SamplePlan::ws_rows`]).
    pub fn new(shape: &[usize], rows: usize) -> StepWorkspace {
        StepWorkspace {
            d: (0..rows.max(1)).map(|_| Tensor::zeros(shape)).collect(),
            res: Tensor::zeros(shape),
            lin: Tensor::zeros(shape),
            pred: Tensor::zeros(shape),
        }
    }

    /// The predictor output written by [`SamplePlan::predict_into`].
    pub fn pred(&self) -> &Tensor {
        &self.pred
    }

    /// Resize every buffer for `shape` and `rows` scratch rows, reusing
    /// the existing allocations whenever their capacity allows
    /// ([`Tensor::resize_to`]). This is what lets one workspace per worker
    /// serve runs of varying batch size: after warm-up at the largest shape,
    /// `ensure` never touches the allocator. Returns `true` when no buffer
    /// had to grow.
    pub fn ensure(&mut self, shape: &[usize], rows: usize) -> bool {
        let mut reused = true;
        while self.d.len() < rows.max(1) {
            self.d.push(Tensor::zeros(shape));
            reused = false;
        }
        for t in &mut self.d {
            reused &= t.resize_to(shape);
        }
        reused &= self.res.resize_to(shape);
        reused &= self.lin.resize_to(shape);
        reused &= self.pred.resize_to(shape);
        reused
    }
}

/// Per-worker pooled execution state for [`sample_batch_with_plan`]: the
/// stacked batch-major state tensor plus one [`StepWorkspace`], both reused
/// across runs. After warm-up at a worker's largest batch shape, starting a
/// new batched run performs no solver-side allocations (the
/// `workspace_reuses` serving metric counts exactly this).
pub struct BatchWorkspace {
    x: Tensor,
    ws: StepWorkspace,
    allocs: u64,
    reuses: u64,
}

impl BatchWorkspace {
    /// An empty pool; buffers grow on first use.
    pub fn new() -> BatchWorkspace {
        BatchWorkspace {
            x: Tensor::zeros(&[0, 1]),
            ws: StepWorkspace::new(&[0, 1], 1),
            allocs: 0,
            reuses: 0,
        }
    }

    /// Runs that had to grow at least one pooled buffer (including the
    /// first run through an empty pool).
    pub fn allocs(&self) -> u64 {
        self.allocs
    }

    /// Runs served entirely from pooled capacity — no allocator traffic.
    pub fn reuses(&self) -> u64 {
        self.reuses
    }

    /// The stacked `[Σ nᵢ, d]` state tensor of the most recent run. The
    /// serving layer validates each member's row range on it
    /// ([`crate::tensor::Tensor::rows_finite`]) to quarantine non-finite
    /// members without failing their batch cohort.
    pub fn stacked(&self) -> &Tensor {
        &self.x
    }

    fn ensure(&mut self, shape: &[usize], rows: usize) {
        let mut reused = self.x.resize_to(shape);
        reused &= self.ws.ensure(shape, rows);
        if reused {
            self.reuses += 1;
        } else {
            self.allocs += 1;
        }
    }
}

impl Default for BatchWorkspace {
    fn default() -> Self {
        BatchWorkspace::new()
    }
}

/// `pred = x_coef·x + m0_coef·m₀` scalars of a first-order exponential
/// transfer (the DDIM map), in either parametrization. Shared by DDIM,
/// UniP-1, warm-up-clamped DPM-Solver++ steps, and first-order singlestep
/// tail groups — the expressions mirror `ddim_step`/`ddim_transfer` exactly.
fn first_order_coefs(
    sched: &dyn NoiseSchedule,
    pred: Prediction,
    t0: f64,
    t: f64,
    h: f64,
) -> (f64, f64) {
    match pred {
        Prediction::Noise => (
            sched.alpha(t) / sched.alpha(t0),
            -sched.sigma(t) * h.exp_m1(),
        ),
        Prediction::Data => (
            sched.sigma(t) / sched.sigma(t0),
            sched.alpha(t) * (-(-h).exp_m1()),
        ),
    }
}

/// The shared per-step linear-part scalars of the UniP/UniC update
/// (`step_geometry`'s `(hh, x^{(1)} coefficients, residual scale)`), in
/// either parametrization. One definition serves both the UniP base-step
/// compiler and the corrector compiler so their arithmetic cannot drift —
/// the planned corrector must stay bit-identical to `unic_correct_with`.
fn linear_part_coefs(
    sched: &dyn NoiseSchedule,
    pred: Prediction,
    t0: f64,
    t: f64,
    h: f64,
) -> (f64, f64, f64, f64) {
    match pred {
        Prediction::Noise => {
            let (a_t, s_t) = (sched.alpha(t), sched.sigma(t));
            (h, a_t / sched.alpha(t0), -s_t * h.exp_m1(), -s_t)
        }
        Prediction::Data => {
            let (a_t, s_t) = (sched.alpha(t), sched.sigma(t));
            (-h, s_t / sched.sigma(t0), a_t * (-(-h).exp_m1()), -a_t)
        }
    }
}

/// Plan compiler for DDIM (and any first-order exponential step).
pub struct FirstOrderCompiler;

impl CompileStep for FirstOrderCompiler {
    fn compile(&self, cx: &StepCx<'_>) -> StepOp {
        let (t0, t) = (cx.ts[cx.i - 1], cx.ts[cx.i]);
        let h = cx.lams[cx.i] - cx.lams[cx.i - 1];
        let (x_coef, m0_coef) = first_order_coefs(cx.sched, cx.pred, t0, t, h);
        StepOp::FirstOrder { x_coef, m0_coef }
    }
}

/// Plan compiler for the UniP/UniPC multistep family (both coefficient
/// variants, both parametrizations).
pub struct UniPCompiler {
    pub variant: CoeffVariant,
}

impl CompileStep for UniPCompiler {
    fn compile(&self, cx: &StepCx<'_>) -> StepOp {
        let p = cx.order;
        let (t0, t) = (cx.ts[cx.i - 1], cx.ts[cx.i]);
        let (l0, lt) = (cx.lams[cx.i - 1], cx.lams[cx.i]);
        let h = lt - l0;
        debug_assert!(h > 0.0, "sampling must increase λ");
        if p == 1 {
            let (x_coef, m0_coef) = first_order_coefs(cx.sched, cx.pred, t0, t, h);
            return StepOp::FirstOrder { x_coef, m0_coef };
        }
        let mut rks = Vec::with_capacity(p);
        let mut inv_r = Vec::with_capacity(p - 1);
        for m in 1..p {
            let r = (cx.lams[cx.i - 1 - m] - l0) / h;
            rks.push(r);
            inv_r.push(1.0 / r);
        }
        rks.push(1.0);
        let (hh, x_coef, m0_coef, residual_scale) =
            linear_part_coefs(cx.sched, cx.pred, t0, t, h);
        let coeffs = residual_coeffs(&rks[..p - 1], hh, self.variant);
        StepOp::UniP { x_coef, m0_coef, residual_scale, inv_r, coeffs }
    }
}

/// Plan compiler for multistep DPM-Solver++ (1M/2M/3M by effective order).
pub struct DpmSolverPpCompiler;

impl CompileStep for DpmSolverPpCompiler {
    fn compile(&self, cx: &StepCx<'_>) -> StepOp {
        let (t0, t) = (cx.ts[cx.i - 1], cx.ts[cx.i]);
        let (l0, lt) = (cx.lams[cx.i - 1], cx.lams[cx.i]);
        let h = lt - l0;
        match cx.order {
            1 => {
                let (x_coef, m0_coef) = first_order_coefs(cx.sched, cx.pred, t0, t, h);
                StepOp::FirstOrder { x_coef, m0_coef }
            }
            2 => {
                let h0 = l0 - cx.lams[cx.i - 2];
                let r0 = h0 / h;
                let phi_1 = (-h).exp_m1();
                StepOp::Dpmpp2M {
                    x_coef: cx.sched.sigma(t) / cx.sched.sigma(t0),
                    m0_coef: -cx.sched.alpha(t) * phi_1,
                    inv_r0: 1.0 / r0,
                    d1_coef: -0.5 * cx.sched.alpha(t) * phi_1,
                }
            }
            _ => {
                let h0 = l0 - cx.lams[cx.i - 2];
                let h1 = cx.lams[cx.i - 2] - cx.lams[cx.i - 3];
                let (r0, r1) = (h0 / h, h1 / h);
                let phi_1 = (-h).exp_m1();
                let phi_2 = h * psi(2, h);
                let phi_3 = -h * psi(3, h);
                StepOp::Dpmpp3M {
                    x_coef: cx.sched.sigma(t) / cx.sched.sigma(t0),
                    m0_coef: -cx.sched.alpha(t) * phi_1,
                    inv_r0: 1.0 / r0,
                    inv_r1: 1.0 / r1,
                    mix: r0 / (r0 + r1),
                    inv_r01: 1.0 / (r0 + r1),
                    d1_coef: cx.sched.alpha(t) * phi_2,
                    d2_coef: -cx.sched.alpha(t) * phi_3,
                }
            }
        }
    }
}

/// Plan compiler for PNDM/PLMS (Adams–Bashforth window of up to 4 outputs,
/// independent of the corrector-facing effective order).
pub struct PlmsCompiler;

impl CompileStep for PlmsCompiler {
    fn compile(&self, cx: &StepCx<'_>) -> StepOp {
        let k = cx.hist_len.min(4);
        let (t0, t) = (cx.ts[cx.i - 1], cx.ts[cx.i]);
        let h = cx.lams[cx.i] - cx.lams[cx.i - 1];
        // PLMS combines ε outputs: noise-prediction transfer map.
        StepOp::Plms {
            x_coef: cx.sched.alpha(t) / cx.sched.alpha(t0),
            comb_coef: -cx.sched.sigma(t) * h.exp_m1(),
            weights: ab_weights(k).to_vec(),
        }
    }
}

/// Plan compiler for tAB-DEIS: the per-step kernel quadrature (the costly
/// part of the reference loop) runs once here, at build time.
pub struct DeisCompiler;

impl CompileStep for DeisCompiler {
    fn compile(&self, cx: &StepCx<'_>) -> StepOp {
        let q = cx.order;
        let (t0, t) = (cx.ts[cx.i - 1], cx.ts[cx.i]);
        let nodes: Vec<f64> = (0..q).map(|m| cx.ts[cx.i - 1 - m]).collect();
        let weights = deis_weights(cx.sched, &nodes, t0, t);
        StepOp::Deis { x_coef: cx.sched.alpha(t) / cx.sched.alpha(t0), weights }
    }
}

/// The compiler for a multistep method (`None` for singlestep methods,
/// which compile through the group compiler in [`SamplePlan::build`]).
fn multistep_compiler(method: &Method) -> Option<Box<dyn CompileStep>> {
    match method {
        Method::Ddim { .. } => Some(Box::new(FirstOrderCompiler)),
        Method::UniP { variant, .. } => Some(Box::new(UniPCompiler { variant: *variant })),
        Method::DpmSolverPp { .. } => Some(Box::new(DpmSolverPpCompiler)),
        Method::Plms => Some(Box::new(PlmsCompiler)),
        Method::Deis { .. } => Some(Box::new(DeisCompiler)),
        Method::DpmSolverSingle { .. } | Method::DpmSolverPp3S => None,
    }
}

/// Resolve one UniC corrector: node ratios against the buffered history
/// (λ's newest-first in `lam_back`), linear-part scalars, and the full
/// p-node system coefficients. Mirrors `unic_correct_with`'s
/// `step_geometry` expression-for-expression.
#[allow(clippy::too_many_arguments)]
fn compile_corrector(
    sched: &dyn NoiseSchedule,
    t: f64,
    lt: f64,
    t0: f64,
    l0: f64,
    lam_back: &[f64],
    p: usize,
    pred: Prediction,
    variant: CoeffVariant,
) -> CorrectorStep {
    let h = lt - l0;
    let mut rks = Vec::with_capacity(p);
    let mut inv_r = Vec::with_capacity(p.saturating_sub(1));
    for m in 1..p {
        let r = (lam_back[m - 1] - l0) / h;
        rks.push(r);
        inv_r.push(1.0 / r);
    }
    rks.push(1.0);
    let (hh, x_coef, m0_coef, residual_scale) = linear_part_coefs(sched, pred, t0, t, h);
    let coeffs = residual_coeffs(&rks, hh, variant);
    CorrectorStep { x_coef, m0_coef, residual_scale, inv_r, coeffs }
}

/// Compile one singlestep NFE-budget group (k fine-grid intervals) into a
/// [`SingleOp`], mirroring `dpm_solver_{2,3}_step` / `dpmpp_{2s,3s}_step` /
/// `ddim_transfer` scalar-for-scalar.
#[allow(clippy::too_many_arguments)]
fn compile_single_group(
    sched: &dyn NoiseSchedule,
    method: &Method,
    pred: Prediction,
    t_s: f64,
    t_t: f64,
    ls: f64,
    h: f64,
    rs: &[f64],
    k: usize,
) -> SingleOp {
    match (method, k) {
        (_, 1) => {
            let (x_coef, m_coef) = first_order_coefs(sched, pred, t_s, t_t, h);
            SingleOp { t_s, lambda_s: ls, nodes: Vec::new(), x_coef, m_coef, d_coef: None }
        }
        (Method::DpmSolverSingle { .. }, 2) => {
            let r1 = rs[0];
            let s1 = sched.t_of_lambda(ls + r1 * h);
            SingleOp {
                t_s,
                lambda_s: ls,
                nodes: vec![SingleNode {
                    t: s1,
                    x_coef: sched.alpha(s1) / sched.alpha(t_s),
                    m_coef: -sched.sigma(s1) * (r1 * h).exp_m1(),
                    d_coef: None,
                }],
                x_coef: sched.alpha(t_t) / sched.alpha(t_s),
                m_coef: -sched.sigma(t_t) * h.exp_m1(),
                d_coef: Some(-sched.sigma(t_t) * h.exp_m1() / (2.0 * r1)),
            }
        }
        (Method::DpmSolverSingle { .. }, _) => {
            let (r1, r2) = (rs[0], rs[1]);
            let s1 = sched.t_of_lambda(ls + r1 * h);
            let s2 = sched.t_of_lambda(ls + r2 * h);
            let phi_11 = (r1 * h).exp_m1();
            let phi_12 = (r2 * h).exp_m1();
            let phi_1 = h.exp_m1();
            let phi_22 = r2 * h * phi(2, r2 * h);
            let phi_2 = h * phi(2, h);
            SingleOp {
                t_s,
                lambda_s: ls,
                nodes: vec![
                    SingleNode {
                        t: s1,
                        x_coef: sched.alpha(s1) / sched.alpha(t_s),
                        m_coef: -sched.sigma(s1) * phi_11,
                        d_coef: None,
                    },
                    SingleNode {
                        t: s2,
                        x_coef: sched.alpha(s2) / sched.alpha(t_s),
                        m_coef: -sched.sigma(s2) * phi_12,
                        d_coef: Some(-sched.sigma(s2) * (r2 / r1) * phi_22),
                    },
                ],
                x_coef: sched.alpha(t_t) / sched.alpha(t_s),
                m_coef: -sched.sigma(t_t) * phi_1,
                d_coef: Some(-sched.sigma(t_t) * phi_2 / r2),
            }
        }
        (Method::DpmSolverPp3S, 2) => {
            let r1 = rs[0];
            let s1 = sched.t_of_lambda(ls + r1 * h);
            let phi_11 = (-r1 * h).exp_m1();
            let phi_1 = (-h).exp_m1();
            SingleOp {
                t_s,
                lambda_s: ls,
                nodes: vec![SingleNode {
                    t: s1,
                    x_coef: sched.sigma(s1) / sched.sigma(t_s),
                    m_coef: -sched.alpha(s1) * phi_11,
                    d_coef: None,
                }],
                x_coef: sched.sigma(t_t) / sched.sigma(t_s),
                m_coef: -sched.alpha(t_t) * phi_1,
                d_coef: Some(-sched.alpha(t_t) * phi_1 / (2.0 * r1)),
            }
        }
        (Method::DpmSolverPp3S, _) => {
            let (r1, r2) = (rs[0], rs[1]);
            let s1 = sched.t_of_lambda(ls + r1 * h);
            let s2 = sched.t_of_lambda(ls + r2 * h);
            let phi_11 = (-r1 * h).exp_m1();
            let phi_12 = (-r2 * h).exp_m1();
            let phi_1 = (-h).exp_m1();
            let phi_22 = phi_12 / (r2 * h) + 1.0;
            let phi_2 = phi_1 / h + 1.0;
            SingleOp {
                t_s,
                lambda_s: ls,
                nodes: vec![
                    SingleNode {
                        t: s1,
                        x_coef: sched.sigma(s1) / sched.sigma(t_s),
                        m_coef: -sched.alpha(s1) * phi_11,
                        d_coef: None,
                    },
                    SingleNode {
                        t: s2,
                        x_coef: sched.sigma(s2) / sched.sigma(t_s),
                        m_coef: -sched.alpha(s2) * phi_12,
                        d_coef: Some(sched.alpha(s2) * (r2 / r1) * phi_22),
                    },
                ],
                x_coef: sched.sigma(t_t) / sched.sigma(t_s),
                m_coef: -sched.alpha(t_t) * phi_1,
                d_coef: Some(sched.alpha(t_t) * phi_2 / r2),
            }
        }
        (m, _) => unreachable!("multistep method {m:?} in singlestep compiler"),
    }
}

impl SamplePlan {
    /// Whether this configuration is plannable. Every method in the
    /// registry compiles to a plan; only `exact_warmup` runs (the
    /// order-of-convergence experiment mode, which sub-integrates with RK4)
    /// keep using the reference loop.
    pub fn supports(opts: &SampleOptions) -> bool {
        opts.steps >= 1 && !opts.exact_warmup
    }

    /// Resolve the whole run: grid, warm-up order ramp (or singlestep
    /// NFE-budget group split), node ratios, linear-part scalars, and
    /// per-method combination coefficients for every step. Returns `None`
    /// for configurations plans don't cover (see [`SamplePlan::supports`]).
    pub fn build(sched: &dyn NoiseSchedule, opts: &SampleOptions) -> Option<SamplePlan> {
        if !Self::supports(opts) {
            return None;
        }
        if opts.method.is_singlestep() {
            Some(Self::build_singlestep(sched, opts))
        } else {
            Self::build_multistep(sched, opts)
        }
    }

    fn build_multistep(sched: &dyn NoiseSchedule, opts: &SampleOptions) -> Option<SamplePlan> {
        let compiler = multistep_compiler(&opts.method)?;
        let pred = opts.method.prediction();
        let schedule = match &opts.method {
            Method::UniP { schedule, .. } => schedule.as_deref(),
            _ => None,
        };
        let m_steps = opts.steps;
        let ts = timesteps(sched, opts.spacing, opts.t_start, opts.t_end, m_steps);
        let lams: Vec<f64> = ts.iter().map(|&t| sched.lambda(t)).collect();
        // Mirrors the reference loop's buffer sizing exactly: in steady
        // state the history holds min(i, cap) entries when stepping to t_i.
        let cap = opts
            .method
            .history_needed()
            .max(opts.unic.map(|_| opts.method.order()).unwrap_or(0))
            .max(1);

        let mut steps = Vec::with_capacity(m_steps);
        let mut max_order = 1usize;
        let mut ws_rows = 1usize;
        for i in 1..=m_steps {
            let hist_len = i.min(cap);
            let p = effective_order(opts.method.order(), schedule, i, hist_len);
            max_order = max_order.max(p);

            let cx = StepCx { sched, ts: &ts, lams: &lams, i, order: p, hist_len, pred };
            let op = compiler.compile(&cx);

            let corrector = match (&opts.unic, i == m_steps) {
                (Some(u), false) => {
                    let lam_back: Vec<f64> = (1..p).map(|m| lams[i - 1 - m]).collect();
                    Some(compile_corrector(
                        sched,
                        ts[i],
                        lams[i],
                        ts[i - 1],
                        lams[i - 1],
                        &lam_back,
                        p,
                        pred,
                        u.variant,
                    ))
                }
                _ => None,
            };

            ws_rows = ws_rows
                .max(op_rows(&op))
                .max(corrector.as_ref().map(|c| c.coeffs.len()).unwrap_or(0));
            steps.push(PlannedStep { t: ts[i], lambda: lams[i], order: p, op, corrector });
        }

        Some(SamplePlan {
            key: plan_key(sched, opts),
            prediction: pred,
            oracle: opts.unic.map(|u| u.oracle).unwrap_or(false),
            singlestep: false,
            history_cap: cap,
            max_order,
            ws_rows,
            t0: ts[0],
            lambda0: lams[0],
            steps,
        })
    }

    /// Compile a singlestep method: split the NFE budget into groups
    /// (mirroring `singlestep_orders`), resolve every group's interior-node
    /// scalars, and simulate the group-boundary history timeline so UniC
    /// correctors see exactly the λ's the reference loop's buffer holds.
    fn build_singlestep(sched: &dyn NoiseSchedule, opts: &SampleOptions) -> SamplePlan {
        let pred = opts.method.prediction();
        let nfe = opts.steps;
        let max = opts.method.order();
        let orders = singlestep_orders(max, nfe);
        let fine = timesteps(sched, opts.spacing, opts.t_start, opts.t_end, nfe);
        let flams: Vec<f64> = fine.iter().map(|&t| sched.lambda(t)).collect();
        let cap = max + 1; // group-boundary outputs for UniC

        let mut steps = Vec::with_capacity(orders.len());
        // Simulated group-boundary history: (t, λ) pairs, oldest first,
        // evicted past `cap` exactly like the reference `History`.
        let mut bounds: VecDeque<(f64, f64)> = VecDeque::new();
        let mut max_order = 1usize;
        let mut ws_rows = 1usize;
        let mut idx = 0usize;
        let n_groups = orders.len();
        for (g, &k) in orders.iter().enumerate() {
            let (t_s, t_t) = (fine[idx], fine[idx + k]);
            let (ls, lt) = (flams[idx], flams[idx + k]);
            let last = g + 1 == n_groups;
            if bounds.back().map_or(true, |b| b.0 > t_s) {
                bounds.push_back((t_s, ls));
                while bounds.len() > cap {
                    bounds.pop_front();
                }
            }
            let h = lt - ls;
            let rs: Vec<f64> = (1..k).map(|j| (flams[idx + j] - ls) / h).collect();
            let op = StepOp::Single(compile_single_group(
                sched,
                &opts.method,
                pred,
                t_s,
                t_t,
                ls,
                h,
                &rs,
                k,
            ));

            let corrector = match (&opts.unic, last) {
                (Some(u), false) => {
                    let p = k.min(bounds.len());
                    let lam_back: Vec<f64> =
                        (1..p).map(|m| bounds[bounds.len() - 1 - m].1).collect();
                    Some(compile_corrector(
                        sched, t_t, lt, t_s, ls, &lam_back, p, pred, u.variant,
                    ))
                }
                _ => None,
            };

            max_order = max_order.max(k);
            ws_rows = ws_rows
                .max(op_rows(&op))
                .max(corrector.as_ref().map(|c| c.coeffs.len()).unwrap_or(0));
            steps.push(PlannedStep { t: t_t, lambda: lt, order: k, op, corrector });

            if !last {
                bounds.push_back((t_t, lt));
                while bounds.len() > cap {
                    bounds.pop_front();
                }
            }
            idx += k;
        }

        SamplePlan {
            key: plan_key(sched, opts),
            prediction: pred,
            oracle: opts.unic.map(|u| u.oracle).unwrap_or(false),
            singlestep: true,
            history_cap: cap,
            max_order,
            ws_rows,
            t0: fine[0],
            lambda0: flams[0],
            steps,
        }
    }

    /// The cache key this plan was built under (equals [`plan_key`] of the
    /// originating options).
    pub fn key(&self) -> &str {
        &self.key
    }

    /// Number of plan steps (solver steps, or singlestep groups).
    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// Largest effective order across the run.
    pub fn max_order(&self) -> usize {
        self.max_order
    }

    /// Scratch rows a [`StepWorkspace`] needs to execute this plan.
    pub fn ws_rows(&self) -> usize {
        self.ws_rows
    }

    /// History-buffer capacity the executor allocates (mirrors the
    /// reference loop's sizing exactly).
    pub fn history_cap(&self) -> usize {
        self.history_cap
    }

    /// Whether this plan drives the singlestep (NFE-budget group) executor.
    pub fn is_singlestep(&self) -> bool {
        self.singlestep
    }

    /// The resolved per-step schedule (read-only; benches and tests).
    pub fn steps(&self) -> &[PlannedStep] {
        &self.steps
    }

    /// Whether the corrector applies at step `k` (0-based).
    pub fn has_corrector(&self, k: usize) -> bool {
        self.steps[k].corrector.is_some()
    }

    /// Stage 1 of multistep step `k`: compute the base method's predicted
    /// state into `ws.pred` from the buffered history. Zero heap
    /// allocations. Panics for singlestep plans, whose groups evaluate the
    /// model at interior nodes and execute through
    /// [`sample_with_plan`] directly.
    pub fn predict_into(&self, k: usize, hist: &History, x: &Tensor, ws: &mut StepWorkspace) {
        let sp = &self.steps[k];
        match &sp.op {
            StepOp::FirstOrder { x_coef, m0_coef } => {
                ws.pred.assign_lincomb(*x_coef, x, *m0_coef, hist.last_m());
            }
            StepOp::UniP { x_coef, m0_coef, residual_scale, inv_r, coeffs } => {
                let m0 = hist.last_m();
                ws.lin.assign_lincomb(*x_coef, x, *m0_coef, m0);
                let p = inv_r.len() + 1;
                for m in 1..p {
                    ws.d[m - 1].assign_sub_scaled(hist.m_back(m), m0, inv_r[m - 1]);
                }
                weighted_sum_into(&mut ws.res, coeffs, &ws.d[..p - 1]);
                ws.pred.assign_lincomb(1.0, &ws.lin, *residual_scale, &ws.res);
            }
            StepOp::Dpmpp2M { x_coef, m0_coef, inv_r0, d1_coef } => {
                let m0 = hist.last_m();
                ws.d[0].assign_sub_scaled(m0, hist.m_back(1), *inv_r0);
                ws.pred.assign_lincomb(*x_coef, x, *m0_coef, m0);
                ws.pred.axpy(*d1_coef, &ws.d[0]);
            }
            StepOp::Dpmpp3M {
                x_coef,
                m0_coef,
                inv_r0,
                inv_r1,
                mix,
                inv_r01,
                d1_coef,
                d2_coef,
            } => {
                let m0 = hist.last_m();
                ws.d[0].assign_sub_scaled(m0, hist.m_back(1), *inv_r0); // D1_0
                ws.d[1].assign_sub_scaled(hist.m_back(1), hist.m_back(2), *inv_r1); // D1_1
                let (head, tail) = ws.d.split_at_mut(2);
                tail[0].assign_sub(&head[0], &head[1]); // diff = D1_0 − D1_1
                let (diff, rest) = tail.split_at_mut(1);
                rest[0].copy_from(&head[0]);
                rest[0].axpy(*mix, &diff[0]); // D1
                rest[1].assign_scaled(&diff[0], *inv_r01); // D2
                ws.pred.assign_lincomb(*x_coef, x, *m0_coef, m0);
                ws.pred.axpy(*d1_coef, &rest[0]);
                ws.pred.axpy(*d2_coef, &rest[1]);
            }
            StepOp::Plms { x_coef, comb_coef, weights } => {
                let k_ = weights.len();
                debug_assert!(k_ <= MAX_COMB);
                let mut refs: [&Tensor; MAX_COMB] = [hist.last_m(); MAX_COMB];
                for (m, slot) in refs.iter_mut().enumerate().take(k_).skip(1) {
                    *slot = hist.m_back(m);
                }
                weighted_sum_into(&mut ws.res, weights, &refs[..k_]);
                ws.pred.assign_lincomb(*x_coef, x, *comb_coef, &ws.res);
            }
            StepOp::Deis { x_coef, weights } => {
                let q = weights.len();
                debug_assert!(q <= MAX_COMB);
                let mut refs: [&Tensor; MAX_COMB] = [hist.last_m(); MAX_COMB];
                for (m, slot) in refs.iter_mut().enumerate().take(q).skip(1) {
                    *slot = hist.m_back(m);
                }
                weighted_sum_into(&mut ws.res, weights, &refs[..q]);
                ws.pred.assign_scaled(x, *x_coef);
                ws.pred.axpy(1.0, &ws.res);
            }
            StepOp::Single(_) => {
                panic!("singlestep groups evaluate interior nodes; use sample_with_plan")
            }
        }
    }

    /// Stage 2 of step `k`: given the model output `m_t` at the predicted
    /// point, write the UniC-corrected state into `x`. Returns `false`
    /// (leaving `x` untouched) when the plan has no corrector at this step.
    /// Zero heap allocations. Self-contained: recomputes the corrector's
    /// linear part and D rows from the history, so it composes with any
    /// base op (UniC-after-anything, §3.1).
    pub fn correct_into(
        &self,
        k: usize,
        hist: &History,
        m_t: &Tensor,
        ws: &mut StepWorkspace,
        x: &mut Tensor,
    ) -> bool {
        let sp = &self.steps[k];
        let c = match &sp.corrector {
            Some(c) => c,
            None => return false,
        };
        let p = c.coeffs.len();
        let m0 = hist.last_m();
        ws.lin.assign_lincomb(c.x_coef, x, c.m0_coef, m0);
        for m in 1..p {
            ws.d[m - 1].assign_sub_scaled(hist.m_back(m), m0, c.inv_r[m - 1]);
        }
        // Full p-node system with r_p = 1; D_p / r_p = m_t − m₀.
        ws.d[p - 1].assign_sub(m_t, m0);
        weighted_sum_into(&mut ws.res, &c.coeffs, &ws.d[..p]);
        x.assign_lincomb(1.0, &ws.lin, c.residual_scale, &ws.res);
        true
    }
}

/// Upper bound on history-combination arity (PLMS window 4, DEIS order ≤ 4,
/// UniP order ≤ 6 via `Method::parse`): sizes the stack-allocated ref array
/// the executor uses to combine history outputs without heap traffic.
const MAX_COMB: usize = 8;

/// Per-step numerical-health signal handed to a [`StepObserver`].
///
/// The UniC corrector reuses the model evaluation the *next* predictor
/// step needs (§3.2 of the paper), so the relative predictor→corrector
/// delta ‖x̃ᶜ − x̃ᵖ‖/‖x̃ᶜ‖ is a **zero-extra-NFE local error estimate** —
/// the same signal DC-Solver exploits for dynamic compensation and
/// DPM-Solver bounds analytically for its order claims. On corrector-less
/// steps there is nothing to compare, so `corrector_delta` is `None`.
///
/// Computing the payload costs two passes over the state tensor per step,
/// so executors only do it when [`StepObserver::wants_health`] says the
/// observer will look at it; otherwise they pass [`StepHealth::default`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StepHealth {
    /// ‖x̃ᶜ − x̃ᵖ‖ / ‖x̃ᶜ‖ for a corrected step; `None` on corrector-less
    /// steps, on non-finite states, and when health was not requested.
    pub corrector_delta: Option<f64>,
    /// Whether every element of the post-step state is finite. `true` when
    /// health was not requested (the unobserved paths assert nothing).
    pub finite: bool,
}

impl Default for StepHealth {
    fn default() -> Self {
        StepHealth { corrector_delta: None, finite: true }
    }
}

/// Scan the post-step state once: finiteness plus, when the predictor
/// state is supplied, the relative corrector delta — fused into a single
/// pass pair so the observed path touches each element at most twice and
/// never allocates.
fn step_health(corrected: &Tensor, predicted: Option<&Tensor>) -> StepHealth {
    let data = corrected.data();
    match predicted {
        Some(p) => {
            let mut finite = true;
            let (mut num, mut den) = (0.0f64, 0.0f64);
            for (a, b) in data.iter().zip(p.data()) {
                finite &= a.is_finite();
                let d = a - b;
                num += d * d;
                den += a * a;
            }
            let delta = if den > 0.0 { (num / den).sqrt() } else { 0.0 };
            StepHealth {
                corrector_delta: (finite && delta.is_finite()).then_some(delta),
                finite,
            }
        }
        None => StepHealth {
            corrector_delta: None,
            finite: data.iter().all(|v| v.is_finite()),
        },
    }
}

/// Per-step hook for the plan executors, called once after each planned
/// step completes (predictor, optional corrector, and any lookahead model
/// evaluation included). `k` is the step index into `plan.steps`;
/// `health` carries the step's numerical-health payload when the observer
/// opted in via [`StepObserver::wants_health`], and
/// [`StepHealth::default`] otherwise.
///
/// The executor stays timing-agnostic: an observer that wants wall-clock
/// attribution takes its own marks between calls (see
/// [`crate::trace::StepSpans`], which pairs this hook with a
/// [`crate::trace::TimedModel`] to split each step into model-eval vs.
/// solver-kernel time). The hook is behind an `Option` so the unobserved
/// paths pay one branch per step.
pub trait StepObserver {
    fn on_step(&mut self, k: usize, health: &StepHealth);

    /// Whether the executor should compute the [`StepHealth`] payload
    /// (two extra passes over the state per step). Defaults to `false` so
    /// purely timing-oriented observers stay free.
    fn wants_health(&self) -> bool {
        false
    }
}

/// Drive a full run from the plan, mutating `x` in place. Shared by the
/// solo and batched entry points so their step arithmetic cannot drift.
fn execute_plan(
    model: &dyn Model,
    sched: &dyn NoiseSchedule,
    opts: &SampleOptions,
    plan: &SamplePlan,
    x: &mut Tensor,
    ws: &mut StepWorkspace,
    mut traj: Option<&mut Vec<(f64, Tensor)>>,
    mut obs: Option<&mut dyn StepObserver>,
) -> usize {
    let ev = Evaluator::new(model, sched, plan.prediction, opts.thresholding);
    if plan.singlestep {
        return execute_singlestep_plan(&ev, plan, x, ws, traj, obs);
    }
    let mut hist = History::new(plan.history_cap);
    hist.push(plan.t0, plan.lambda0, ev.eval(x, plan.t0));

    let n = plan.steps.len();
    for k in 0..n {
        let sp = &plan.steps[k];
        let corrected = sp.corrector.is_some();
        plan.predict_into(k, &hist, x, ws);
        if corrected {
            let m_t = ev.eval(&ws.pred, sp.t);
            plan.correct_into(k, &hist, &m_t, ws, x);
            let m_buf = if plan.oracle { ev.eval(x, sp.t) } else { m_t };
            hist.push(sp.t, sp.lambda, m_buf);
        } else {
            if k + 1 < n {
                let m_next = ev.eval(&ws.pred, sp.t);
                hist.push(sp.t, sp.lambda, m_next);
            }
            std::mem::swap(x, &mut ws.pred);
        }
        if let Some(tr) = &mut traj {
            tr.push((sp.t, x.clone()));
        }
        if let Some(o) = obs.as_deref_mut() {
            // On a corrected step `x` holds x̃ᶜ and `ws.pred` still holds
            // the predictor state x̃ᵖ (correct_into reads it but writes only
            // lin/d/res), so the delta costs no extra storage.
            let health = if o.wants_health() {
                step_health(x, corrected.then_some(&ws.pred))
            } else {
                StepHealth::default()
            };
            o.on_step(k, &health);
        }
    }
    ev.nfe()
}

/// The singlestep driver: NFE-budget groups with interior model
/// evaluations, reusing each group's boundary output exactly like the
/// reference loop (`sample_unplanned`'s singlestep branch).
fn execute_singlestep_plan(
    ev: &Evaluator,
    plan: &SamplePlan,
    x: &mut Tensor,
    ws: &mut StepWorkspace,
    mut traj: Option<&mut Vec<(f64, Tensor)>>,
    mut obs: Option<&mut dyn StepObserver>,
) -> usize {
    let mut hist = History::new(plan.history_cap);
    let mut m_s: Option<Tensor> = None;
    let n = plan.steps.len();
    for k in 0..n {
        let sp = &plan.steps[k];
        let op = match &sp.op {
            StepOp::Single(op) => op,
            other => unreachable!("non-singlestep op {other:?} in singlestep plan"),
        };
        let m_start = match m_s.take() {
            Some(m) => m,
            None => ev.eval(x, op.t_s),
        };
        if hist.is_empty() || hist.last().t > op.t_s {
            hist.push(op.t_s, op.lambda_s, m_start.clone());
        }

        // Interior nodes, then the group's final combination into ws.pred.
        for (j, node) in op.nodes.iter().enumerate() {
            ws.lin.assign_lincomb(node.x_coef, x, node.m_coef, &m_start);
            if let Some(c) = node.d_coef {
                ws.lin.axpy(c, &ws.d[j - 1]);
            }
            let m_j = ev.eval(&ws.lin, node.t);
            ws.d[j].assign_sub(&m_j, &m_start);
        }
        ws.pred.assign_lincomb(op.x_coef, x, op.m_coef, &m_start);
        if let Some(c) = op.d_coef {
            ws.pred.axpy(c, &ws.d[op.nodes.len() - 1]);
        }

        let last = k + 1 == n;
        let corrected = sp.corrector.is_some();
        if corrected {
            let m_t = ev.eval(&ws.pred, sp.t);
            plan.correct_into(k, &hist, &m_t, ws, x);
            let m_next = if plan.oracle { ev.eval(x, sp.t) } else { m_t };
            hist.push(sp.t, sp.lambda, m_next.clone());
            m_s = Some(m_next);
        } else {
            if !last {
                let m_next = ev.eval(&ws.pred, sp.t);
                hist.push(sp.t, sp.lambda, m_next.clone());
                m_s = Some(m_next);
            }
            std::mem::swap(x, &mut ws.pred);
        }
        if let Some(tr) = &mut traj {
            tr.push((sp.t, x.clone()));
        }
        if let Some(o) = obs.as_deref_mut() {
            let health = if o.wants_health() {
                step_health(x, corrected.then_some(&ws.pred))
            } else {
                StepHealth::default()
            };
            o.on_step(k, &health);
        }
    }
    ev.nfe()
}

/// Run the sampler from a precomputed plan. Bit-identical to
/// [`super::runner::sample_unplanned`] on the same options — for **every**
/// method in the registry — but with all per-step coefficient math already
/// resolved and zero solver-side heap allocations in steady state.
pub fn sample_with_plan(
    model: &dyn Model,
    sched: &dyn NoiseSchedule,
    x_init: &Tensor,
    opts: &SampleOptions,
    plan: &SamplePlan,
) -> SampleResult {
    sample_with_plan_observed(model, sched, x_init, opts, plan, None)
}

/// [`sample_with_plan`] with a per-step [`StepObserver`] hook (tracing's
/// entry point; `None` is the unobserved fast path).
pub fn sample_with_plan_observed(
    model: &dyn Model,
    sched: &dyn NoiseSchedule,
    x_init: &Tensor,
    opts: &SampleOptions,
    plan: &SamplePlan,
    obs: Option<&mut dyn StepObserver>,
) -> SampleResult {
    debug_assert_eq!(
        plan.key(),
        plan_key(sched, opts),
        "plan built for a different schedule/config"
    );
    let mut x = x_init.clone();
    let mut ws = StepWorkspace::new(x.shape(), plan.ws_rows);
    let mut traj = opts.capture_trajectory.then(Vec::new);
    let nfe = execute_plan(model, sched, opts, plan, &mut x, &mut ws, traj.as_mut(), obs);
    SampleResult { x, nfe, trajectory: traj }
}

/// Run several same-configuration requests in lockstep from one shared
/// plan: member initial states are stacked into a single batch-major
/// `[Σnᵢ, d]` tensor, every solver step executes once on the stacked batch,
/// and — crucially — the model backend is evaluated **once per step** for
/// the whole batch instead of once per request.
///
/// Because every solver kernel is elementwise (row-independent) and all
/// members share the plan's per-step scalars, each member's output is
/// **bit-identical** to a solo [`sample_with_plan`] run from the same
/// initial state whenever the model also evaluates rows independently
/// (true for the analytic backends; asserted by `tests/batch_equiv.rs`
/// across the whole method zoo). Per-member `nfe` equals the solo run's
/// count: batching changes how many rows each evaluation carries, not how
/// many evaluations the schedule performs.
///
/// The members need not share model conditioning: `model` may be a
/// **row-conditioned** view (the coordinator's
/// [`crate::coordinator::CohortModel`]) that evaluates contiguous row
/// ranges under different class/guidance settings. The solver is agnostic
/// — it sees one `Model` — and the row-independence argument above carries
/// over unchanged, so mixed-conditioning cohorts stay bit-identical to
/// solo runs member by member.
///
/// `bw` is the caller's pooled workspace: the coordinator keeps one per
/// worker so steady-state runs start without allocating. Trajectory capture
/// is per-request by nature and not supported here — use
/// [`sample_with_plan`] (the coordinator never requests it).
///
/// Returns one [`SampleResult`] per entry of `x_inits`, in order.
pub fn sample_batch_with_plan(
    model: &dyn Model,
    sched: &dyn NoiseSchedule,
    x_inits: &[&Tensor],
    opts: &SampleOptions,
    plan: &SamplePlan,
    bw: &mut BatchWorkspace,
) -> Vec<SampleResult> {
    sample_batch_with_plan_observed(model, sched, x_inits, opts, plan, bw, None)
}

/// [`sample_batch_with_plan`] with a per-step [`StepObserver`] hook
/// (tracing's entry point; `None` is the unobserved fast path).
pub fn sample_batch_with_plan_observed(
    model: &dyn Model,
    sched: &dyn NoiseSchedule,
    x_inits: &[&Tensor],
    opts: &SampleOptions,
    plan: &SamplePlan,
    bw: &mut BatchWorkspace,
    obs: Option<&mut dyn StepObserver>,
) -> Vec<SampleResult> {
    assert!(!x_inits.is_empty(), "sample_batch_with_plan: empty batch");
    assert!(
        !opts.capture_trajectory,
        "trajectory capture is per-request; use sample_with_plan"
    );
    debug_assert_eq!(
        plan.key(),
        plan_key(sched, opts),
        "plan built for a different schedule/config"
    );
    assert_eq!(x_inits[0].shape().len(), 2, "batch members must be [n, d]");
    let d = x_inits[0].shape()[1];
    let mut rows = 0usize;
    for t in x_inits {
        assert_eq!(t.shape().len(), 2, "batch members must be [n, d]");
        assert_eq!(t.shape()[1], d, "batch members must share the feature dim");
        rows += t.shape()[0];
    }

    bw.ensure(&[rows, d], plan.ws_rows);
    let mut at = 0;
    for t in x_inits {
        bw.x.copy_rows_from(at, t);
        at += t.shape()[0];
    }

    let nfe = execute_plan(model, sched, opts, plan, &mut bw.x, &mut bw.ws, None, obs);

    let mut out = Vec::with_capacity(x_inits.len());
    let mut at = 0;
    for t in x_inits {
        let r = t.shape()[0];
        out.push(SampleResult { x: bw.x.slice_rows(at, r), nfe, trajectory: None });
        at += r;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::vandermonde::BFunction;
    use crate::rng::Rng;
    use crate::sched::VpLinear;
    use crate::solver::runner::{sample, sample_unplanned, UniCOptions};
    use crate::solver::unipc::CoeffVariant;

    fn bits(t: &Tensor) -> Vec<u64> {
        t.data().iter().map(|v| v.to_bits()).collect()
    }

    /// Nonlinear, t-dependent toy model (noise-native).
    fn toy_model() -> impl Model {
        (Prediction::Noise, 3, |x: &Tensor, t: f64| {
            let mut m = x.scaled(0.3 + 0.1 * t);
            for v in m.data_mut() {
                *v += (*v * 0.7).sin() * 0.05;
            }
            m
        })
    }

    #[test]
    fn planned_path_is_bit_identical_to_reference() {
        let sched = VpLinear::default();
        let model = toy_model();
        let x0 = Rng::seed_from(11).normal_tensor(&[4, 3]);
        let variants = [
            CoeffVariant::Bh(BFunction::Bh1),
            CoeffVariant::Bh(BFunction::Bh2),
            CoeffVariant::Varying,
        ];
        for order in [1usize, 2, 3, 4] {
            for variant in variants {
                for pred in [Prediction::Noise, Prediction::Data] {
                    for with_unic in [false, true] {
                        for steps in [1usize, 2, 3, 8] {
                            let mut opts = SampleOptions::new(
                                Method::UniP { order, variant, pred, schedule: None },
                                steps,
                            );
                            if with_unic {
                                opts.unic = Some(UniCOptions { variant, oracle: false });
                            }
                            let a = sample_unplanned(&model, &sched, &x0, &opts);
                            let plan =
                                SamplePlan::build(&sched, &opts).expect("plannable config");
                            let b = sample_with_plan(&model, &sched, &x0, &opts, &plan);
                            let tag = format!(
                                "order {order} {variant:?} {pred:?} unic {with_unic} steps {steps}"
                            );
                            assert_eq!(a.nfe, b.nfe, "nfe: {tag}");
                            assert_eq!(bits(&a.x), bits(&b.x), "state bits: {tag}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn baseline_methods_bit_identical_to_reference() {
        // The tentpole claim at unit level: every non-UniP family — DDIM,
        // DPM-Solver++ multistep, PNDM, DEIS, and both singlestep solvers —
        // compiles to a plan whose execution is bit-identical to its
        // hand-rolled reference loop, with and without UniC on top.
        let sched = VpLinear::default();
        let model = toy_model();
        let x0 = Rng::seed_from(23).normal_tensor(&[3, 3]);
        let methods = [
            Method::Ddim { pred: Prediction::Noise },
            Method::Ddim { pred: Prediction::Data },
            Method::DpmSolverPp { order: 1 },
            Method::DpmSolverPp { order: 2 },
            Method::DpmSolverPp { order: 3 },
            Method::Plms,
            Method::Deis { order: 1 },
            Method::Deis { order: 2 },
            Method::Deis { order: 3 },
            Method::DpmSolverSingle { order: 2 },
            Method::DpmSolverSingle { order: 3 },
            Method::DpmSolverPp3S,
        ];
        for method in methods {
            for with_unic in [false, true] {
                for steps in [1usize, 2, 5, 9] {
                    let mut opts = SampleOptions::new(method.clone(), steps);
                    if with_unic {
                        opts.unic = Some(UniCOptions::default());
                    }
                    let a = sample_unplanned(&model, &sched, &x0, &opts);
                    let plan = SamplePlan::build(&sched, &opts)
                        .unwrap_or_else(|| panic!("{} must be plannable", opts.id()));
                    let b = sample_with_plan(&model, &sched, &x0, &opts, &plan);
                    let tag = format!("{} steps {steps}", opts.id());
                    assert_eq!(a.nfe, b.nfe, "nfe: {tag}");
                    assert_eq!(bits(&a.x), bits(&b.x), "state bits: {tag}");
                }
            }
        }
    }

    #[test]
    fn gmm_model_bit_equivalence() {
        // The analytic GMM model, every variant, through the public
        // `sample` entry point (which routes plannable configs through the
        // plan).
        let gm = crate::analytic::datasets::dataset(
            crate::analytic::datasets::DatasetSpec::Cifar10Like,
        );
        let sched = VpLinear::default();
        let model = crate::analytic::GmmModel { gm: &gm, sched: &sched };
        let x0 = Rng::seed_from(3).normal_tensor(&[6, gm.dim]);
        for variant in [CoeffVariant::Bh(BFunction::Bh2), CoeffVariant::Varying] {
            for with_unic in [false, true] {
                let mut opts = SampleOptions::new(
                    Method::UniP {
                        order: 3,
                        variant,
                        pred: Prediction::Noise,
                        schedule: None,
                    },
                    7,
                );
                if with_unic {
                    opts.unic = Some(UniCOptions { variant, oracle: false });
                }
                let a = sample_unplanned(&model, &sched, &x0, &opts);
                let b = sample(&model, &sched, &x0, &opts);
                assert_eq!(a.nfe, b.nfe);
                assert_eq!(bits(&a.x), bits(&b.x), "{variant:?} unic {with_unic}");
            }
        }
    }

    #[test]
    fn oracle_and_order_schedule_match_reference() {
        let sched = VpLinear::default();
        let model = toy_model();
        let x0 = Rng::seed_from(7).normal_tensor(&[2, 3]);

        let mut oracle_opts = SampleOptions::new(
            Method::unip(2, BFunction::Bh2, Prediction::Noise),
            6,
        );
        oracle_opts.unic =
            Some(UniCOptions { variant: CoeffVariant::Bh(BFunction::Bh2), oracle: true });

        let sched_opts = SampleOptions::new(
            Method::UniP {
                order: 3,
                variant: CoeffVariant::Bh(BFunction::Bh2),
                pred: Prediction::Noise,
                schedule: Some(vec![1, 2, 3, 3, 2, 1]),
            },
            6,
        );

        // Oracle UniC after a singlestep solver exercises the simulated
        // boundary-history timeline.
        let mut single_oracle = SampleOptions::new(Method::DpmSolverSingle { order: 3 }, 7);
        single_oracle.unic =
            Some(UniCOptions { variant: CoeffVariant::Bh(BFunction::Bh2), oracle: true });

        for opts in [oracle_opts, sched_opts, single_oracle] {
            let a = sample_unplanned(&model, &sched, &x0, &opts);
            let plan = SamplePlan::build(&sched, &opts).expect("plannable");
            let b = sample_with_plan(&model, &sched, &x0, &opts, &plan);
            assert_eq!(a.nfe, b.nfe, "{}", opts.id());
            assert_eq!(bits(&a.x), bits(&b.x), "{}", opts.id());
        }
    }

    #[test]
    fn trajectory_capture_matches_reference() {
        let sched = VpLinear::default();
        let model = toy_model();
        let x0 = Rng::seed_from(9).normal_tensor(&[2, 3]);
        for method in [
            Method::unip(3, BFunction::Bh2, Prediction::Noise),
            Method::DpmSolverPp { order: 2 },
            Method::DpmSolverPp3S,
        ] {
            let mut opts = SampleOptions::new(method, 5);
            opts.capture_trajectory = true;
            let a = sample_unplanned(&model, &sched, &x0, &opts);
            let plan = SamplePlan::build(&sched, &opts).unwrap();
            let b = sample_with_plan(&model, &sched, &x0, &opts, &plan);
            let (ta, tb) = (a.trajectory.unwrap(), b.trajectory.unwrap());
            assert_eq!(ta.len(), tb.len());
            for ((t1, x1), (t2, x2)) in ta.iter().zip(&tb) {
                assert_eq!(t1, t2);
                assert_eq!(bits(x1), bits(x2));
            }
        }
    }

    #[test]
    fn only_exact_warmup_is_unplannable() {
        let sched = VpLinear::default();
        // Everything in the zoo builds …
        for method in Method::zoo() {
            let opts = SampleOptions::new(method.clone(), 6);
            assert!(
                SamplePlan::build(&sched, &opts).is_some(),
                "{} must be plannable",
                method.id()
            );
        }
        // … except the exact-warmup experiment mode.
        let mut warm = SampleOptions::unipc(3, BFunction::Bh2, Prediction::Noise, 8);
        warm.exact_warmup = true;
        assert!(SamplePlan::build(&sched, &warm).is_none());
    }

    #[test]
    fn plan_key_separates_configs() {
        let sched = VpLinear::default();
        let key = |o: &SampleOptions| plan_key(&sched, o);
        let base = SampleOptions::unipc(3, BFunction::Bh2, Prediction::Noise, 8);
        let mut other = base.clone();
        other.steps = 9;
        assert_ne!(key(&base), key(&other));
        let mut nounic = base.clone();
        nounic.unic = None;
        assert_ne!(key(&base), key(&nounic));
        let mut range = base.clone();
        range.t_end = 2e-3;
        assert_ne!(key(&base), key(&range));
        // Execute-time settings the plan does not bake in share a plan.
        let mut thr = base.clone();
        thr.thresholding = Some(crate::solver::DynamicThresholding::default());
        assert_eq!(key(&base), key(&thr));
        assert_eq!(key(&base), key(&base.clone()));
        // Different schedules never share a key.
        let cosine = crate::sched::VpCosine::default();
        assert_ne!(key(&base), plan_key(&cosine, &base));
        // Different methods never share a key.
        let dpmpp = SampleOptions::new(Method::DpmSolverPp { order: 2 }, 8);
        assert_ne!(key(&base), key(&dpmpp));
    }

    #[test]
    fn plan_resolves_warmup_orders_and_coeff_lengths() {
        let sched = VpLinear::default();
        let opts = SampleOptions::unipc(3, BFunction::Bh2, Prediction::Noise, 6);
        let plan = SamplePlan::build(&sched, &opts).unwrap();
        assert_eq!(plan.len(), 6);
        assert_eq!(plan.max_order(), 3);
        assert!(!plan.is_singlestep());
        let orders: Vec<usize> = plan.steps().iter().map(|s| s.order).collect();
        assert_eq!(orders, vec![1, 2, 3, 3, 3, 3], "warm-up ramp then steady state");
        for (k, sp) in plan.steps().iter().enumerate() {
            match &sp.op {
                StepOp::FirstOrder { .. } => assert_eq!(sp.order, 1),
                StepOp::UniP { inv_r, coeffs, .. } => {
                    assert_eq!(coeffs.len(), sp.order - 1);
                    assert_eq!(inv_r.len(), sp.order - 1);
                }
                other => panic!("unexpected op {other:?} in a UniPC plan"),
            }
            if k + 1 < plan.len() {
                let c = sp.corrector.as_ref().expect("corrector before final step");
                assert_eq!(c.coeffs.len(), sp.order);
                assert!(plan.has_corrector(k));
            } else {
                assert!(!plan.has_corrector(k), "corrector skipped after final step");
            }
        }
    }

    #[test]
    fn singlestep_plan_mirrors_budget_split() {
        let sched = VpLinear::default();
        let opts = SampleOptions::new(Method::DpmSolverSingle { order: 3 }, 10);
        let plan = SamplePlan::build(&sched, &opts).unwrap();
        assert!(plan.is_singlestep());
        // 10 = 3+3+3+1 per the official split.
        let orders: Vec<usize> = plan.steps().iter().map(|s| s.order).collect();
        assert_eq!(orders, vec![3, 3, 3, 1]);
        let evals: usize = plan
            .steps()
            .iter()
            .map(|s| match &s.op {
                StepOp::Single(op) => op.nodes.len(),
                _ => panic!("singlestep plan must hold Single ops"),
            })
            .sum();
        // Interior evals + one boundary eval per group boundary = NFE.
        assert_eq!(evals, 10 - orders.len());
    }
}
