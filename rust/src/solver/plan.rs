//! Precomputed sampling plans + zero-allocation step execution for the
//! UniPC hot path.
//!
//! # Why plans
//!
//! Every scalar a multistep UniPC run needs — the timestep grid, the
//! per-step effective order (warm-up ramp + optional Table-4 schedule), the
//! signed step `hh`, the node ratios r_m, the linear-part scalars
//! (α_t/α_s, −σ_t·(eʰ−1), …) and the Theorem-3.1 / Appendix-C combination
//! coefficients — is a pure function of `(NoiseSchedule, SampleOptions)`.
//! The reference loop ([`super::runner::sample_unplanned`]) re-derives all
//! of it at every step; the `Varying` coefficient variant even re-runs a
//! full LU inversion per step. A [`SamplePlan`] hoists that work out of the
//! loop: built once, it reduces the steady-state step to pure tensor
//! arithmetic with zero coefficient math.
//!
//! # Lifecycle: build → cache → execute
//!
//! 1. **Build** — [`SamplePlan::build`] resolves the whole run up front.
//!    It covers the multistep UniP/UniPC family (any order, both
//!    coefficient variants, both parametrizations, optional order schedule,
//!    optional UniC/oracle); it returns `None` for singlestep methods,
//!    non-UniP baselines, and `exact_warmup` runs, which keep using the
//!    reference loop.
//! 2. **Cache** — a plan is immutable and model-independent, so identically
//!    configured requests share one `Arc<SamplePlan>`. The coordinator
//!    ([`crate::coordinator`]) keys its cache by [`plan_key`], which folds
//!    in every input the plan depends on: the noise schedule's name, the
//!    canonical method form including order-schedule contents
//!    ([`Method::cache_key`]), step count, spacing, the exact
//!    `t_start`/`t_end` bits, and the UniC variant / oracle flag.
//!    Execute-time settings the plan does not bake in (thresholding,
//!    trajectory capture) deliberately don't key it.
//! 3. **Execute** — [`sample_with_plan`] drives the run from the plan using
//!    a [`StepWorkspace`] of preallocated buffers. It is bit-identical to
//!    the reference loop (asserted by the tests below and by
//!    `tests/plan_alloc.rs`): same operations, same accumulation order,
//!    same NFE accounting.
//!
//! # The zero-allocation invariant
//!
//! A steady-state planned step performs **zero heap allocations** in the
//! solver arithmetic: [`SamplePlan::predict_into`] and
//! [`SamplePlan::correct_into`] write only into the workspace and the state
//! tensor (`assign_*` kernels + [`crate::tensor::weighted_sum_into`]), the
//! history ring buffer is preallocated and merely rotates ownership of the
//! model-output tensors, and the state advance is a pointer swap. The only
//! allocations left in the loop are the model evaluations themselves, which
//! by contract produce a fresh output tensor. `tests/plan_alloc.rs` proves
//! the invariant with a counting global allocator.
//!
//! # Batched execution across requests
//!
//! A plan is shared by every identically-configured request, so requests
//! can also *execute* together: [`sample_batch_with_plan`] stacks member
//! initial states into one batch-major tensor, advances all of them through
//! the timestep grid in lockstep, and evaluates the model once per step on
//! the stacked batch. Outputs are bit-identical to solo runs (all kernels
//! are row-independent), and a per-worker [`BatchWorkspace`] pools the
//! stacked state and the [`StepWorkspace`] across runs so steady-state
//! batches start without allocating. The coordinator's batch assembler
//! ([`crate::coordinator`]) groups queued requests by plan key + model
//! conditioning and drives this entry point.
//!
//! # Example
//!
//! Build a plan once, then execute any number of runs from it:
//!
//! ```
//! use unipc::analytic::datasets::{dataset, DatasetSpec};
//! use unipc::analytic::GmmModel;
//! use unipc::numerics::vandermonde::BFunction;
//! use unipc::rng::Rng;
//! use unipc::sched::VpLinear;
//! use unipc::solver::{sample_with_plan, Prediction, SampleOptions, SamplePlan};
//!
//! let sched = VpLinear::default();
//! let gm = dataset(DatasetSpec::Cifar10Like);
//! let model = GmmModel { gm: &gm, sched: &sched };
//!
//! // UniPC-3 with the B2(h) choice at 8 steps — the paper's low-NFE regime.
//! let opts = SampleOptions::unipc(3, BFunction::Bh2, Prediction::Noise, 8);
//! let plan = SamplePlan::build(&sched, &opts).expect("multistep UniPC is plannable");
//!
//! let x_t = Rng::seed_from(7).normal_tensor(&[4, gm.dim]);
//! let result = sample_with_plan(&model, &sched, &x_t, &opts, &plan);
//! assert_eq!(result.nfe, 8); // UniC reuses evaluations: steps == NFE
//! assert!(result.x.data().iter().all(|v| v.is_finite()));
//! ```

use super::history::History;
use super::method::Method;
use super::runner::{effective_order, SampleOptions, SampleResult};
use super::unipc::residual_coeffs;
use super::{Evaluator, Model, Prediction};
use crate::sched::{timesteps, NoiseSchedule};
use crate::tensor::{weighted_sum_into, Tensor};

/// Cache key for a plan: every input [`SamplePlan::build`] reads, and
/// nothing else. Two requests with equal keys can share one plan — in
/// particular, options that differ only in execute-time settings the plan
/// does not bake in (thresholding, trajectory capture) share a plan.
///
/// The schedule enters through [`NoiseSchedule::cache_key`], which folds
/// in the schedule's parameters, so same-name schedules with different
/// parameters never share a plan.
pub fn plan_key(sched: &dyn NoiseSchedule, opts: &SampleOptions) -> String {
    use std::fmt::Write;
    let mut key = String::new();
    let _ = write!(
        key,
        "{}|{}|steps={}|{}|{:x}..{:x}|{}",
        sched.cache_key(),
        opts.method.cache_key(),
        opts.steps,
        opts.spacing.name(),
        opts.t_start.to_bits(),
        opts.t_end.to_bits(),
        match &opts.unic {
            Some(u) => format!(
                "unic-{}{}",
                u.variant.name(),
                if u.oracle { "-oracle" } else { "" }
            ),
            None => "nounic".to_string(),
        },
    );
    key
}

/// Everything step `i` needs that does not depend on the model outputs.
#[derive(Clone, Debug)]
pub struct PlannedStep {
    /// Target timestep t_i.
    pub t: f64,
    /// λ_{t_i} (pushed into the history buffer with the step's output).
    pub lambda: f64,
    /// Effective UniP order p_i (warm-up ramp / order schedule applied).
    pub order: usize,
    /// 1/r_m for the historical nodes m = 1..p_i−1 (D_m/r_m scaling).
    pub inv_r: Vec<f64>,
    /// α_t/α_s (noise prediction) or σ_t/σ_s (data prediction).
    pub a_ratio: f64,
    /// −σ_t·(eʰ−1) (noise) or α_t·(1−e^{−h}) (data): multiplies m₀ in the
    /// linear part x^{(1)}.
    pub m0_coef: f64,
    /// −σ_t (noise) or −α_t (data): multiplies the residual combination.
    pub residual_scale: f64,
    /// Fully-resolved predictor coefficients c_m (Corollary 3.2 system,
    /// p_i−1 nodes). Empty iff p_i = 1 (the DDIM-degenerate step).
    pub pred_coeffs: Vec<f64>,
    /// Fully-resolved corrector coefficients (full p_i-node system with
    /// r_p = 1). Empty iff the corrector is skipped at this step (no UniC
    /// configured, or the final step).
    pub corr_coeffs: Vec<f64>,
}

/// A complete precomputed run: grid, orders, and coefficients for every
/// step. Immutable and model-independent — share via `Arc` across requests.
#[derive(Clone, Debug)]
pub struct SamplePlan {
    key: String,
    prediction: Prediction,
    oracle: bool,
    history_cap: usize,
    max_order: usize,
    t0: f64,
    lambda0: f64,
    steps: Vec<PlannedStep>,
}

/// Preallocated buffers for plan execution. One workspace serves a whole
/// run (or any number of runs with the same batch shape); steady-state
/// steps write into it without touching the allocator.
pub struct StepWorkspace {
    /// D_m/r_m rows (index m−1); slot `p−1` doubles as the corrector's
    /// D_p = m_t − m₀ row.
    d: Vec<Tensor>,
    /// The residual combination Σ_m c_m · D_m/r_m.
    res: Tensor,
    /// The linear part x^{(1)}, shared by predictor and corrector.
    lin: Tensor,
    /// Predictor output x_pred (swapped into the state when no corrector
    /// applies).
    pred: Tensor,
}

impl StepWorkspace {
    /// Buffers for batch shape `shape` and plans up to `max_order`.
    pub fn new(shape: &[usize], max_order: usize) -> StepWorkspace {
        StepWorkspace {
            d: (0..max_order.max(1)).map(|_| Tensor::zeros(shape)).collect(),
            res: Tensor::zeros(shape),
            lin: Tensor::zeros(shape),
            pred: Tensor::zeros(shape),
        }
    }

    /// The predictor output written by [`SamplePlan::predict_into`].
    pub fn pred(&self) -> &Tensor {
        &self.pred
    }

    /// Resize every buffer for `shape` and plans up to `max_order`, reusing
    /// the existing allocations whenever their capacity allows
    /// ([`Tensor::resize_to`]). This is what lets one workspace per worker
    /// serve runs of varying batch size: after warm-up at the largest shape,
    /// `ensure` never touches the allocator. Returns `true` when no buffer
    /// had to grow.
    pub fn ensure(&mut self, shape: &[usize], max_order: usize) -> bool {
        let mut reused = true;
        while self.d.len() < max_order.max(1) {
            self.d.push(Tensor::zeros(shape));
            reused = false;
        }
        for t in &mut self.d {
            reused &= t.resize_to(shape);
        }
        reused &= self.res.resize_to(shape);
        reused &= self.lin.resize_to(shape);
        reused &= self.pred.resize_to(shape);
        reused
    }
}

/// Per-worker pooled execution state for [`sample_batch_with_plan`]: the
/// stacked batch-major state tensor plus one [`StepWorkspace`], both reused
/// across runs. After warm-up at a worker's largest batch shape, starting a
/// new batched run performs no solver-side allocations (the
/// `workspace_reuses` serving metric counts exactly this).
pub struct BatchWorkspace {
    x: Tensor,
    ws: StepWorkspace,
    allocs: u64,
    reuses: u64,
}

impl BatchWorkspace {
    /// An empty pool; buffers grow on first use.
    pub fn new() -> BatchWorkspace {
        BatchWorkspace {
            x: Tensor::zeros(&[0, 1]),
            ws: StepWorkspace::new(&[0, 1], 1),
            allocs: 0,
            reuses: 0,
        }
    }

    /// Runs that had to grow at least one pooled buffer (including the
    /// first run through an empty pool).
    pub fn allocs(&self) -> u64 {
        self.allocs
    }

    /// Runs served entirely from pooled capacity — no allocator traffic.
    pub fn reuses(&self) -> u64 {
        self.reuses
    }

    fn ensure(&mut self, shape: &[usize], max_order: usize) {
        let mut reused = self.x.resize_to(shape);
        reused &= self.ws.ensure(shape, max_order);
        if reused {
            self.reuses += 1;
        } else {
            self.allocs += 1;
        }
    }
}

impl Default for BatchWorkspace {
    fn default() -> Self {
        BatchWorkspace::new()
    }
}

impl SamplePlan {
    /// Whether this configuration is plannable: the multistep UniP/UniPC
    /// hot path. Everything else runs the reference loop.
    pub fn supports(opts: &SampleOptions) -> bool {
        matches!(opts.method, Method::UniP { .. }) && !opts.exact_warmup && opts.steps >= 1
    }

    /// Resolve the whole run: grid, warm-up order ramp, node ratios,
    /// linear-part scalars, and predictor/corrector coefficients for every
    /// step. Returns `None` for configurations plans don't cover.
    pub fn build(sched: &dyn NoiseSchedule, opts: &SampleOptions) -> Option<SamplePlan> {
        if !Self::supports(opts) {
            return None;
        }
        let (order, variant, pred, schedule) = match &opts.method {
            Method::UniP { order, variant, pred, schedule } => {
                (*order, *variant, *pred, schedule.as_deref())
            }
            _ => return None,
        };
        let m_steps = opts.steps;
        let ts = timesteps(sched, opts.spacing, opts.t_start, opts.t_end, m_steps);
        let lams: Vec<f64> = ts.iter().map(|&t| sched.lambda(t)).collect();
        // Mirrors the reference loop's buffer sizing exactly: in steady
        // state the history holds min(i, cap) entries when stepping to t_i.
        let cap = opts
            .method
            .history_needed()
            .max(opts.unic.map(|_| order).unwrap_or(0))
            .max(1);

        let mut steps = Vec::with_capacity(m_steps);
        let mut max_order = 1usize;
        for i in 1..=m_steps {
            let hist_len = i.min(cap);
            let p = effective_order(order, schedule, i, hist_len);
            max_order = max_order.max(p);

            let (t0, t) = (ts[i - 1], ts[i]);
            let (l0, lt) = (lams[i - 1], lams[i]);
            let h = lt - l0;
            debug_assert!(h > 0.0, "sampling must increase λ");

            let mut rks = Vec::with_capacity(p);
            let mut inv_r = Vec::with_capacity(p - 1);
            for m in 1..p {
                let r = (lams[i - 1 - m] - l0) / h;
                rks.push(r);
                inv_r.push(1.0 / r);
            }
            rks.push(1.0);

            let (hh, a_ratio, m0_coef, residual_scale) = match pred {
                Prediction::Noise => {
                    let (a_t, s_t) = (sched.alpha(t), sched.sigma(t));
                    (h, a_t / sched.alpha(t0), -s_t * h.exp_m1(), -s_t)
                }
                Prediction::Data => {
                    let (a_t, s_t) = (sched.alpha(t), sched.sigma(t));
                    (-h, s_t / sched.sigma(t0), a_t * (-(-h).exp_m1()), -a_t)
                }
            };

            let pred_coeffs = if p >= 2 {
                residual_coeffs(&rks[..p - 1], hh, variant)
            } else {
                Vec::new()
            };
            let corr_coeffs = match (&opts.unic, i == m_steps) {
                (Some(u), false) => residual_coeffs(&rks, hh, u.variant),
                _ => Vec::new(),
            };

            steps.push(PlannedStep {
                t,
                lambda: lt,
                order: p,
                inv_r,
                a_ratio,
                m0_coef,
                residual_scale,
                pred_coeffs,
                corr_coeffs,
            });
        }

        Some(SamplePlan {
            key: plan_key(sched, opts),
            prediction: pred,
            oracle: opts.unic.map(|u| u.oracle).unwrap_or(false),
            history_cap: cap,
            max_order,
            t0: ts[0],
            lambda0: lams[0],
            steps,
        })
    }

    /// The cache key this plan was built under (equals [`plan_key`] of the
    /// originating options).
    pub fn key(&self) -> &str {
        &self.key
    }

    /// Number of solver steps.
    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// Largest effective order across the run (sizes the workspace).
    pub fn max_order(&self) -> usize {
        self.max_order
    }

    /// The resolved per-step schedule (read-only; benches and tests).
    pub fn steps(&self) -> &[PlannedStep] {
        &self.steps
    }

    /// Whether the corrector applies at step `k` (0-based).
    pub fn has_corrector(&self, k: usize) -> bool {
        !self.steps[k].corr_coeffs.is_empty()
    }

    /// Stage 1 of step `k`: fill the workspace with the shared linear part
    /// x^{(1)}, the D_m/r_m rows, and the predictor output (`ws.pred`).
    /// Zero heap allocations.
    pub fn predict_into(&self, k: usize, hist: &History, x: &Tensor, ws: &mut StepWorkspace) {
        let sp = &self.steps[k];
        let m0 = hist.last_m();
        ws.lin.assign_lincomb(sp.a_ratio, x, sp.m0_coef, m0);
        for m in 1..sp.order {
            ws.d[m - 1].assign_sub_scaled(hist.m_back(m), m0, sp.inv_r[m - 1]);
        }
        if sp.order >= 2 {
            weighted_sum_into(&mut ws.res, &sp.pred_coeffs, &ws.d[..sp.order - 1]);
            ws.pred.assign_lincomb(1.0, &ws.lin, sp.residual_scale, &ws.res);
        } else {
            // p = 1 degenerates to DDIM: the linear part is the step.
            ws.pred.copy_from(&ws.lin);
        }
    }

    /// Stage 2 of step `k`: given the model output `m_t` at the predicted
    /// point, write the UniC-corrected state into `x`. Returns `false`
    /// (leaving `x` untouched) when the plan has no corrector at this step.
    /// Zero heap allocations. Requires a prior [`SamplePlan::predict_into`]
    /// for the same step (reuses the workspace's linear part and D rows).
    pub fn correct_into(
        &self,
        k: usize,
        hist: &History,
        m_t: &Tensor,
        ws: &mut StepWorkspace,
        x: &mut Tensor,
    ) -> bool {
        let sp = &self.steps[k];
        if sp.corr_coeffs.is_empty() {
            return false;
        }
        // Full p-node system with r_p = 1; D_p / r_p = m_t − m₀.
        ws.d[sp.order - 1].assign_sub(m_t, hist.last_m());
        weighted_sum_into(&mut ws.res, &sp.corr_coeffs, &ws.d[..sp.order]);
        x.assign_lincomb(1.0, &ws.lin, sp.residual_scale, &ws.res);
        true
    }
}

/// Run the sampler from a precomputed plan. Bit-identical to
/// [`super::runner::sample_unplanned`] on the same options, but with all
/// per-step coefficient math already resolved and zero solver-side heap
/// allocations in steady state.
pub fn sample_with_plan(
    model: &dyn Model,
    sched: &dyn NoiseSchedule,
    x_init: &Tensor,
    opts: &SampleOptions,
    plan: &SamplePlan,
) -> SampleResult {
    debug_assert_eq!(
        plan.key(),
        plan_key(sched, opts),
        "plan built for a different schedule/config"
    );
    let ev = Evaluator::new(model, sched, plan.prediction, opts.thresholding);
    let mut traj = opts.capture_trajectory.then(Vec::new);

    let mut x = x_init.clone();
    let mut hist = History::new(plan.history_cap);
    hist.push(plan.t0, plan.lambda0, ev.eval(&x, plan.t0));
    let mut ws = StepWorkspace::new(x.shape(), plan.max_order);

    let n = plan.steps.len();
    for k in 0..n {
        let sp = &plan.steps[k];
        plan.predict_into(k, &hist, &x, &mut ws);
        if plan.has_corrector(k) {
            let m_t = ev.eval(&ws.pred, sp.t);
            plan.correct_into(k, &hist, &m_t, &mut ws, &mut x);
            let m_buf = if plan.oracle { ev.eval(&x, sp.t) } else { m_t };
            hist.push(sp.t, sp.lambda, m_buf);
        } else {
            if k + 1 < n {
                let m_next = ev.eval(&ws.pred, sp.t);
                hist.push(sp.t, sp.lambda, m_next);
            }
            std::mem::swap(&mut x, &mut ws.pred);
        }
        if let Some(tr) = traj.as_mut() {
            tr.push((sp.t, x.clone()));
        }
    }

    SampleResult { x, nfe: ev.nfe(), trajectory: traj }
}

/// Run several same-configuration requests in lockstep from one shared
/// plan: member initial states are stacked into a single batch-major
/// `[Σnᵢ, d]` tensor, every solver step executes once on the stacked batch,
/// and — crucially — the model backend is evaluated **once per step** for
/// the whole batch instead of once per request.
///
/// Because every solver kernel is elementwise (row-independent) and all
/// members share the plan's per-step scalars, each member's output is
/// **bit-identical** to a solo [`sample_with_plan`] run from the same
/// initial state whenever the model also evaluates rows independently
/// (true for the analytic backends; asserted by `tests/batch_equiv.rs`).
/// Per-member `nfe` equals the solo run's count: batching changes how many
/// rows each evaluation carries, not how many evaluations the schedule
/// performs.
///
/// `bw` is the caller's pooled workspace: the coordinator keeps one per
/// worker so steady-state runs start without allocating. Trajectory capture
/// is per-request by nature and not supported here — use
/// [`sample_with_plan`] (the coordinator never requests it).
///
/// Returns one [`SampleResult`] per entry of `x_inits`, in order.
pub fn sample_batch_with_plan(
    model: &dyn Model,
    sched: &dyn NoiseSchedule,
    x_inits: &[&Tensor],
    opts: &SampleOptions,
    plan: &SamplePlan,
    bw: &mut BatchWorkspace,
) -> Vec<SampleResult> {
    assert!(!x_inits.is_empty(), "sample_batch_with_plan: empty batch");
    assert!(
        !opts.capture_trajectory,
        "trajectory capture is per-request; use sample_with_plan"
    );
    debug_assert_eq!(
        plan.key(),
        plan_key(sched, opts),
        "plan built for a different schedule/config"
    );
    assert_eq!(x_inits[0].shape().len(), 2, "batch members must be [n, d]");
    let d = x_inits[0].shape()[1];
    let mut rows = 0usize;
    for t in x_inits {
        assert_eq!(t.shape().len(), 2, "batch members must be [n, d]");
        assert_eq!(t.shape()[1], d, "batch members must share the feature dim");
        rows += t.shape()[0];
    }

    bw.ensure(&[rows, d], plan.max_order());
    let mut at = 0;
    for t in x_inits {
        bw.x.copy_rows_from(at, t);
        at += t.shape()[0];
    }

    let ev = Evaluator::new(model, sched, plan.prediction, opts.thresholding);
    let mut hist = History::new(plan.history_cap);
    hist.push(plan.t0, plan.lambda0, ev.eval(&bw.x, plan.t0));

    let n = plan.steps.len();
    for k in 0..n {
        let sp = &plan.steps[k];
        plan.predict_into(k, &hist, &bw.x, &mut bw.ws);
        if plan.has_corrector(k) {
            let m_t = ev.eval(&bw.ws.pred, sp.t);
            plan.correct_into(k, &hist, &m_t, &mut bw.ws, &mut bw.x);
            let m_buf = if plan.oracle { ev.eval(&bw.x, sp.t) } else { m_t };
            hist.push(sp.t, sp.lambda, m_buf);
        } else {
            if k + 1 < n {
                let m_next = ev.eval(&bw.ws.pred, sp.t);
                hist.push(sp.t, sp.lambda, m_next);
            }
            std::mem::swap(&mut bw.x, &mut bw.ws.pred);
        }
    }

    let nfe = ev.nfe();
    let mut out = Vec::with_capacity(x_inits.len());
    let mut at = 0;
    for t in x_inits {
        let r = t.shape()[0];
        out.push(SampleResult { x: bw.x.slice_rows(at, r), nfe, trajectory: None });
        at += r;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::vandermonde::BFunction;
    use crate::rng::Rng;
    use crate::sched::VpLinear;
    use crate::solver::runner::{sample, sample_unplanned, UniCOptions};
    use crate::solver::unipc::CoeffVariant;

    fn bits(t: &Tensor) -> Vec<u64> {
        t.data().iter().map(|v| v.to_bits()).collect()
    }

    /// Nonlinear, t-dependent toy model (noise-native).
    fn toy_model() -> impl Model {
        (Prediction::Noise, 3, |x: &Tensor, t: f64| {
            let mut m = x.scaled(0.3 + 0.1 * t);
            for v in m.data_mut() {
                *v += (*v * 0.7).sin() * 0.05;
            }
            m
        })
    }

    #[test]
    fn planned_path_is_bit_identical_to_reference() {
        let sched = VpLinear::default();
        let model = toy_model();
        let x0 = Rng::seed_from(11).normal_tensor(&[4, 3]);
        let variants = [
            CoeffVariant::Bh(BFunction::Bh1),
            CoeffVariant::Bh(BFunction::Bh2),
            CoeffVariant::Varying,
        ];
        for order in [1usize, 2, 3, 4] {
            for variant in variants {
                for pred in [Prediction::Noise, Prediction::Data] {
                    for with_unic in [false, true] {
                        for steps in [1usize, 2, 3, 8] {
                            let mut opts = SampleOptions::new(
                                Method::UniP { order, variant, pred, schedule: None },
                                steps,
                            );
                            if with_unic {
                                opts.unic = Some(UniCOptions { variant, oracle: false });
                            }
                            let a = sample_unplanned(&model, &sched, &x0, &opts);
                            let plan =
                                SamplePlan::build(&sched, &opts).expect("plannable config");
                            let b = sample_with_plan(&model, &sched, &x0, &opts, &plan);
                            let tag = format!(
                                "order {order} {variant:?} {pred:?} unic {with_unic} steps {steps}"
                            );
                            assert_eq!(a.nfe, b.nfe, "nfe: {tag}");
                            assert_eq!(bits(&a.x), bits(&b.x), "state bits: {tag}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn gmm_model_bit_equivalence() {
        // The ISSUE's acceptance setting: the analytic GMM model, every
        // variant, through the public `sample` entry point (which routes
        // plannable configs through the plan).
        let gm = crate::analytic::datasets::dataset(
            crate::analytic::datasets::DatasetSpec::Cifar10Like,
        );
        let sched = VpLinear::default();
        let model = crate::analytic::GmmModel { gm: &gm, sched: &sched };
        let x0 = Rng::seed_from(3).normal_tensor(&[6, gm.dim]);
        for variant in [CoeffVariant::Bh(BFunction::Bh2), CoeffVariant::Varying] {
            for with_unic in [false, true] {
                let mut opts = SampleOptions::new(
                    Method::UniP {
                        order: 3,
                        variant,
                        pred: Prediction::Noise,
                        schedule: None,
                    },
                    7,
                );
                if with_unic {
                    opts.unic = Some(UniCOptions { variant, oracle: false });
                }
                let a = sample_unplanned(&model, &sched, &x0, &opts);
                let b = sample(&model, &sched, &x0, &opts);
                assert_eq!(a.nfe, b.nfe);
                assert_eq!(bits(&a.x), bits(&b.x), "{variant:?} unic {with_unic}");
            }
        }
    }

    #[test]
    fn oracle_and_order_schedule_match_reference() {
        let sched = VpLinear::default();
        let model = toy_model();
        let x0 = Rng::seed_from(7).normal_tensor(&[2, 3]);

        let mut oracle_opts = SampleOptions::new(
            Method::unip(2, BFunction::Bh2, Prediction::Noise),
            6,
        );
        oracle_opts.unic =
            Some(UniCOptions { variant: CoeffVariant::Bh(BFunction::Bh2), oracle: true });

        let sched_opts = SampleOptions::new(
            Method::UniP {
                order: 3,
                variant: CoeffVariant::Bh(BFunction::Bh2),
                pred: Prediction::Noise,
                schedule: Some(vec![1, 2, 3, 3, 2, 1]),
            },
            6,
        );

        for opts in [oracle_opts, sched_opts] {
            let a = sample_unplanned(&model, &sched, &x0, &opts);
            let plan = SamplePlan::build(&sched, &opts).expect("plannable");
            let b = sample_with_plan(&model, &sched, &x0, &opts, &plan);
            assert_eq!(a.nfe, b.nfe);
            assert_eq!(bits(&a.x), bits(&b.x));
        }
    }

    #[test]
    fn trajectory_capture_matches_reference() {
        let sched = VpLinear::default();
        let model = toy_model();
        let x0 = Rng::seed_from(9).normal_tensor(&[2, 3]);
        let mut opts =
            SampleOptions::unipc(3, BFunction::Bh2, Prediction::Noise, 5);
        opts.capture_trajectory = true;
        let a = sample_unplanned(&model, &sched, &x0, &opts);
        let plan = SamplePlan::build(&sched, &opts).unwrap();
        let b = sample_with_plan(&model, &sched, &x0, &opts, &plan);
        let (ta, tb) = (a.trajectory.unwrap(), b.trajectory.unwrap());
        assert_eq!(ta.len(), tb.len());
        for ((t1, x1), (t2, x2)) in ta.iter().zip(&tb) {
            assert_eq!(t1, t2);
            assert_eq!(bits(x1), bits(x2));
        }
    }

    #[test]
    fn unsupported_configs_do_not_build() {
        let sched = VpLinear::default();
        let ddim = SampleOptions::new(Method::Ddim { pred: Prediction::Noise }, 5);
        assert!(SamplePlan::build(&sched, &ddim).is_none());
        let single = SampleOptions::new(Method::DpmSolverSingle { order: 3 }, 6);
        assert!(SamplePlan::build(&sched, &single).is_none());
        let mut warm = SampleOptions::unipc(3, BFunction::Bh2, Prediction::Noise, 8);
        warm.exact_warmup = true;
        assert!(SamplePlan::build(&sched, &warm).is_none());
    }

    #[test]
    fn plan_key_separates_configs() {
        let sched = VpLinear::default();
        let key = |o: &SampleOptions| plan_key(&sched, o);
        let base = SampleOptions::unipc(3, BFunction::Bh2, Prediction::Noise, 8);
        let mut other = base.clone();
        other.steps = 9;
        assert_ne!(key(&base), key(&other));
        let mut nounic = base.clone();
        nounic.unic = None;
        assert_ne!(key(&base), key(&nounic));
        let mut range = base.clone();
        range.t_end = 2e-3;
        assert_ne!(key(&base), key(&range));
        // Execute-time settings the plan does not bake in share a plan.
        let mut thr = base.clone();
        thr.thresholding = Some(crate::solver::DynamicThresholding::default());
        assert_eq!(key(&base), key(&thr));
        assert_eq!(key(&base), key(&base.clone()));
        // Different schedules never share a key.
        let cosine = crate::sched::VpCosine::default();
        assert_ne!(key(&base), plan_key(&cosine, &base));
    }

    #[test]
    fn plan_resolves_warmup_orders_and_coeff_lengths() {
        let sched = VpLinear::default();
        let opts = SampleOptions::unipc(3, BFunction::Bh2, Prediction::Noise, 6);
        let plan = SamplePlan::build(&sched, &opts).unwrap();
        assert_eq!(plan.len(), 6);
        assert_eq!(plan.max_order(), 3);
        let orders: Vec<usize> = plan.steps().iter().map(|s| s.order).collect();
        assert_eq!(orders, vec![1, 2, 3, 3, 3, 3], "warm-up ramp then steady state");
        for (k, sp) in plan.steps().iter().enumerate() {
            assert_eq!(sp.pred_coeffs.len(), sp.order - 1);
            assert_eq!(sp.inv_r.len(), sp.order - 1);
            if k + 1 < plan.len() {
                assert_eq!(sp.corr_coeffs.len(), sp.order);
                assert!(plan.has_corrector(k));
            } else {
                assert!(!plan.has_corrector(k), "corrector skipped after final step");
            }
        }
    }
}
