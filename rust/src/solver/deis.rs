//! DEIS (Zhang & Chen 2022) — exponential integrator with polynomial
//! extrapolation of ε_θ **in the time domain** (tAB-DEIS). Baseline for
//! Table 5/9. The paper's §3.3 point is precisely that these t-domain
//! integrals have no closed form — DEIS computes them numerically; we use
//! 32-point Gauss–Legendre quadrature per step, evaluated once per step at
//! schedule-build time.
//!
//! Update: x_{t_i} = (α_{t_i}/α_{t_{i-1}}) x + Σ_j C_j ε(x_{t_{i-1-j}}),
//! C_j = ∫_{t_{i-1}}^{t_i} (α_{t_i}/α_τ) · (β(τ)/(2σ_τ)) · L_j(τ) dτ,
//! with L_j the Lagrange basis over the previous q+1 timesteps. For the VP
//! probability-flow ODE, g²(τ) = β(τ) and dx/dτ = −½β x + β/(2σ) ε.

use super::history::History;
use super::{Evaluator, Prediction};
use crate::sched::NoiseSchedule;
use crate::tensor::{weighted_sum, Tensor};

/// 16-point Gauss–Legendre nodes/weights on [-1, 1] (symmetric halves).
const GL_X: [f64; 8] = [
    0.0950125098376374,
    0.2816035507792589,
    0.4580167776572274,
    0.6178762444026438,
    0.7554044083550030,
    0.8656312023878318,
    0.9445750230732326,
    0.9894009349916499,
];
const GL_W: [f64; 8] = [
    0.1894506104550685,
    0.1826034150449236,
    0.1691565193950025,
    0.1495959888165767,
    0.1246289712555339,
    0.0951585116824928,
    0.0622535239386479,
    0.0271524594117541,
];

/// ∫_a^b f dτ by 16-point Gauss–Legendre.
fn quad<F: Fn(f64) -> f64>(a: f64, b: f64, f: F) -> f64 {
    let c = 0.5 * (a + b);
    let r = 0.5 * (b - a);
    let mut s = 0.0;
    for i in 0..8 {
        s += GL_W[i] * (f(c + r * GL_X[i]) + f(c - r * GL_X[i]));
    }
    s * r
}

/// β(t) for the VP linear schedule, recovered from the schedule itself via
/// β(t) = −2 d(log α)/dt (central difference keeps this schedule-agnostic).
fn beta_of(sched: &dyn NoiseSchedule, t: f64) -> f64 {
    let dt = 1e-6;
    -2.0 * (sched.log_alpha(t + dt) - sched.log_alpha(t - dt)) / (2.0 * dt)
}

/// The tAB-DEIS combination weights C_j for one step `t_prev → t`: the
/// Lagrange basis over `nodes` (the previous `q` timesteps, newest first)
/// integrated against the exponential kernel. Pure function of the timestep
/// geometry — [`crate::solver::plan::SamplePlan::build`] precomputes these
/// once per plan with exactly this function, so the planned path is
/// bit-identical to [`deis_step`].
pub fn deis_weights(
    sched: &dyn NoiseSchedule,
    nodes: &[f64],
    t_prev: f64,
    t: f64,
) -> Vec<f64> {
    let alpha_t = sched.alpha(t);
    (0..nodes.len())
        .map(|j| {
            quad(t_prev, t, |tau| {
                let mut l = 1.0;
                for (k, &tk) in nodes.iter().enumerate() {
                    if k != j {
                        l *= (tau - tk) / (nodes[j] - tk);
                    }
                }
                let kern = (alpha_t / sched.alpha(tau)) * beta_of(sched, tau)
                    / (2.0 * sched.sigma(tau));
                kern * l
            })
        })
        .collect()
}

/// One tAB-DEIS step t_prev → t using `q+1 = min(order, hist.len())`
/// previous ε outputs.
pub fn deis_step(
    ev: &Evaluator,
    sched: &dyn NoiseSchedule,
    hist: &History,
    x: &Tensor,
    t: f64,
    order: usize,
) -> Tensor {
    assert_eq!(ev.prediction(), Prediction::Noise, "DEIS extrapolates ε in t");
    let q = order.min(hist.len());
    let t_prev = hist.last().t;
    let nodes: Vec<f64> = (0..q).map(|m| hist.back(m).t).collect();

    let alpha_t = sched.alpha(t);
    let coeffs = deis_weights(sched, &nodes, t_prev, t);

    let tensors: Vec<&Tensor> = (0..q).map(|m| &hist.back(m).m).collect();
    let integral = weighted_sum(&coeffs, &tensors);
    let mut out = x.scaled(alpha_t / sched.alpha(t_prev));
    out.axpy(1.0, &integral);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::VpLinear;
    use crate::solver::ddim::ddim_step;
    use crate::solver::Model;

    #[test]
    fn quad_integrates_polynomials_exactly() {
        let v = quad(0.0, 2.0, |x| 3.0 * x * x);
        assert!((v - 8.0).abs() < 1e-12);
        let c = quad(-1.0, 1.5, |x| x.cos());
        assert!((c - (1.5f64.sin() + 1.0f64.sin())).abs() < 1e-12);
    }

    #[test]
    fn beta_matches_linear_schedule() {
        let s = VpLinear::default();
        for &t in &[0.1, 0.5, 0.9] {
            let expect = 0.1 + t * 19.9;
            assert!((beta_of(&s, t) - expect).abs() < 1e-4, "t={t}");
        }
    }

    #[test]
    fn order1_deis_close_to_ddim() {
        // With a single node, DEIS integrates the exact exponential kernel
        // against a constant ε — equivalent to DDIM up to quadrature error.
        let sched = VpLinear::default();
        let m: (Prediction, usize, _) =
            (Prediction::Noise, 2, |x: &Tensor, _t: f64| x.scaled(0.5));
        let ev = Evaluator::new(&m, &sched, Prediction::Noise, None);
        let x = Tensor::from_vec(&[1, 2], vec![1.0, -2.0]);
        let mut hist = History::new(3);
        hist.push(0.7, sched.lambda(0.7), ev.eval(&x, 0.7));
        let a = deis_step(&ev, &sched, &hist, &x, 0.6, 1);
        let b = ddim_step(&ev, &sched, &hist, &x, 0.6);
        // DDIM *is* the exact constant-ε integral, so these agree closely.
        for (av, bv) in a.data().iter().zip(b.data()) {
            assert!((av - bv).abs() < 1e-8, "{av} vs {bv}");
        }
    }
}
