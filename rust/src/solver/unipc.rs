//! UniP-p / UniC-p / UniPC-p — the paper's unified predictor-corrector
//! (§3.1–3.2, Eq. 3/8/9, Algorithms 5–8), plus the varying-coefficient
//! variant UniPC_v (Appendix C).
//!
//! Both prediction parametrizations share one implementation through the
//! signed step `hh` (+h for noise prediction, −h for data prediction):
//! ψ_k(h) = φ_k(−h), so the data-prediction system of Proposition A.1 is
//! the noise-prediction system evaluated at −h, with the (α, σ) prefactors
//! swapped. This mirrors the official reference implementation and is
//! verified against the paper's explicit formulas in the tests below.
//!
//! Multistep node placement (§3.4): r_m = (λ_{t_{i−m−1}} − λ_{t_{i−1}})/h_i
//! for m = 1..p−1 (all negative), and r_p = 1 for the corrector.

use super::history::History;
use super::{Evaluator, Prediction};
use crate::numerics::phi::phi;
use crate::numerics::vandermonde::{unipc_coeffs, varying_coeff_matrix, BFunction};
use crate::sched::NoiseSchedule;
use crate::tensor::{weighted_sum, Tensor};

/// How the combination coefficients are derived.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CoeffVariant {
    /// Theorem 3.1: a_p = R_p⁻¹(h) φ_p(h) / B(h).
    Bh(BFunction),
    /// Appendix C (UniPC_v): A_p = C_p⁻¹, coefficients independent of h.
    Varying,
}

impl CoeffVariant {
    pub fn name(self) -> &'static str {
        match self {
            CoeffVariant::Bh(BFunction::Bh1) => "bh1",
            CoeffVariant::Bh(BFunction::Bh2) => "bh2",
            CoeffVariant::Varying => "vary",
        }
    }
}

/// Effective residual coefficients c_m such that the update subtracts
/// (σ_t or α_t) · Σ_m c_m · (D_m / r_m):
/// * Bh variant: c_m = B(hh) · a_m with a from Theorem 3.1;
/// * Varying variant: c_m = Σ_n hh φ_{n+1}(hh) A_p[n][m] with A_p = C_p⁻¹.
pub fn residual_coeffs(rks: &[f64], hh: f64, variant: CoeffVariant) -> Vec<f64> {
    let q = rks.len();
    match variant {
        CoeffVariant::Bh(b) => {
            let bh = b.eval(hh);
            unipc_coeffs(rks, hh, b).into_iter().map(|a| a * bh).collect()
        }
        CoeffVariant::Varying => {
            let a = varying_coeff_matrix(rks);
            // Eq. 12 / Appendix E.5: the D_m/r_m coefficient is
            // Σ_n hh φ_{n+1}(hh) A_{m,n} with A = C_p⁻¹ indexed (row m,
            // column n) — note the order: node index first, derivative
            // order second (the E.5 expansion needs Σ_m A_{m,k} r_m^{n−1}/n!
            // = δ_{kn}, i.e. Cᵀ-orientation of the identity).
            (0..q)
                .map(|m| {
                    (0..q)
                        .map(|n| hh * phi(n + 2, hh) * a[m * q + n])
                        .sum()
                })
                .collect()
        }
    }
}

/// Shared per-step geometry for a multistep UniPC update t_prev → t.
struct StepGeometry {
    /// Signed step: +h for noise prediction, −h for data prediction.
    hh: f64,
    /// Normalized previous-node positions r_1..r_{p−1} (negative), then 1.
    rks: Vec<f64>,
    /// D_m / r_m for the historical nodes (m = 1..p−1).
    d1s: Vec<Tensor>,
    /// The linear part x_t^{(1)} of Algorithms 5–8.
    x_linear: Tensor,
    /// −σ_t (noise) or −α_t (data): multiplies the residual sum.
    residual_scale: f64,
}

fn step_geometry(
    sched: &dyn NoiseSchedule,
    pred: Prediction,
    hist: &History,
    x: &Tensor,
    t: f64,
    p: usize,
) -> StepGeometry {
    assert!(p >= 1);
    assert!(hist.len() >= p, "order {p} needs {p} buffered evaluations");
    let prev = hist.last();
    let (t0, l0) = (prev.t, prev.lambda);
    let lt = sched.lambda(t);
    let h = lt - l0;
    debug_assert!(h > 0.0, "sampling must increase λ");

    let mut rks = Vec::with_capacity(p);
    let mut d1s = Vec::with_capacity(p - 1);
    for m in 1..p {
        let e = hist.back(m);
        let r = (e.lambda - l0) / h;
        rks.push(r);
        // D_m / r_m = (m_{i−m−1} − m₀) / r_m — fused single pass instead of
        // the old sub-then-scale pair (one traversal, one allocation).
        d1s.push(Tensor::sub_scaled(&e.m, &prev.m, 1.0 / r));
    }
    rks.push(1.0);

    let (hh, x_linear, residual_scale) = match pred {
        Prediction::Noise => {
            let (a_t, s_t) = (sched.alpha(t), sched.sigma(t));
            let a0 = sched.alpha(t0);
            // x^{(1)} = (α_t/α_s) x − σ_t (e^h − 1) ε₀     (Alg. 6)
            let xl = Tensor::lincomb(a_t / a0, x, -s_t * h.exp_m1(), &prev.m);
            (h, xl, -s_t)
        }
        Prediction::Data => {
            let (a_t, s_t) = (sched.alpha(t), sched.sigma(t));
            let s0 = sched.sigma(t0);
            // x^{(1)} = (σ_t/σ_s) x + α_t (1 − e^{−h}) x₀  (Alg. 8)
            let xl = Tensor::lincomb(s_t / s0, x, a_t * (-(-h).exp_m1()), &prev.m);
            (-h, xl, -a_t)
        }
    };
    StepGeometry { hh, rks, d1s, x_linear, residual_scale }
}

/// UniP-p multistep predictor (Algorithm 6/8): one step t_prev → t using
/// only the buffered history. `p = 1` reduces exactly to DDIM (§3.3).
pub fn unip_predict(
    ev: &Evaluator,
    sched: &dyn NoiseSchedule,
    hist: &History,
    x: &Tensor,
    t: f64,
    p: usize,
    variant: CoeffVariant,
) -> Tensor {
    let g = step_geometry(sched, ev.prediction(), hist, x, t, p);
    if p == 1 {
        return g.x_linear;
    }
    // Corollary 3.2: drop D_p — solve the (p−1)-node system.
    let coeffs = residual_coeffs(&g.rks[..p - 1], g.hh, variant);
    let refs: Vec<&Tensor> = g.d1s.iter().collect();
    let res = weighted_sum(&coeffs, &refs);
    let mut out = g.x_linear;
    out.axpy(g.residual_scale, &res);
    out
}

/// UniC-p corrector (Algorithm 5/7): refine `x_pred` (produced by *any*
/// p-order solver) using the model output at the current point. Returns the
/// corrected state and the model output `m_t` (evaluated at the predicted
/// point — feed it to the buffer, per §4.2's no-extra-NFE rule).
pub fn unic_correct(
    ev: &Evaluator,
    sched: &dyn NoiseSchedule,
    hist: &History,
    x: &Tensor,
    x_pred: &Tensor,
    t: f64,
    p: usize,
    variant: CoeffVariant,
) -> (Tensor, Tensor) {
    let m_t = ev.eval(x_pred, t);
    let x_c = unic_correct_with(ev, sched, hist, x, &m_t, t, p, variant);
    (x_c, m_t)
}

/// UniC-p given a precomputed model output at the current point (used by the
/// oracle variant and by tests).
pub fn unic_correct_with(
    ev: &Evaluator,
    sched: &dyn NoiseSchedule,
    hist: &History,
    x: &Tensor,
    m_t: &Tensor,
    t: f64,
    p: usize,
    variant: CoeffVariant,
) -> Tensor {
    let g = step_geometry(sched, ev.prediction(), hist, x, t, p);
    // Full p-node system with r_p = 1; D_p / r_p = m_t − m₀.
    let coeffs = residual_coeffs(&g.rks, g.hh, variant);
    let d1t = m_t.sub(&hist.last().m);
    let mut tensors: Vec<&Tensor> = g.d1s.iter().collect();
    tensors.push(&d1t);
    let res = weighted_sum(&coeffs, &tensors);
    let mut out = g.x_linear;
    out.axpy(g.residual_scale, &res);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{timesteps, TimeSpacing, VpLinear};
    use crate::solver::Model;

    /// Model ε(x,t) = c·x, which keeps everything analytic.
    fn linear_model(c: f64) -> impl Model {
        (Prediction::Noise, 2, move |x: &Tensor, _t: f64| x.scaled(c))
    }

    fn seeded_hist(
        ev: &Evaluator,
        sched: &dyn NoiseSchedule,
        xs: &[(f64, Tensor)],
    ) -> History {
        let mut h = History::new(8);
        for (t, x) in xs {
            h.push(*t, sched.lambda(*t), ev.eval(x, *t));
        }
        h
    }

    #[test]
    fn unip1_equals_ddim_formula() {
        // §3.3: UniP-1 is exactly DDIM.
        let sched = VpLinear::default();
        let m = linear_model(0.7);
        let ev = Evaluator::new(&m, &sched, Prediction::Noise, None);
        let x = Tensor::from_vec(&[1, 2], vec![0.3, -1.1]);
        let (t0, t) = (0.6, 0.5);
        let hist = seeded_hist(&ev, &sched, &[(t0, x.clone())]);
        let out = unip_predict(&ev, &sched, &hist, &x, t, 1, CoeffVariant::Bh(BFunction::Bh2));

        let h = sched.lambda(t) - sched.lambda(t0);
        let expect = Tensor::lincomb(
            sched.alpha(t) / sched.alpha(t0),
            &x,
            -sched.sigma(t) * h.exp_m1() * 0.7,
            &x,
        );
        for (o, e) in out.data().iter().zip(expect.data()) {
            assert!((o - e).abs() < 1e-12, "{o} vs {e}");
        }
    }

    #[test]
    fn unip2_matches_paper_closed_form() {
        // For p=2 the predictor is x⁽¹⁾ − σ_t B(h) a₁ D₁/r₁ with the
        // degenerate a₁ = 1/2 (Appendix F) → residual = −σ_t·½·B(h)·D₁/r₁.
        let sched = VpLinear::default();
        let m = linear_model(0.4);
        let ev = Evaluator::new(&m, &sched, Prediction::Noise, None);
        let x1 = Tensor::from_vec(&[1, 2], vec![1.0, 2.0]);
        let x0 = Tensor::from_vec(&[1, 2], vec![0.9, 1.8]);
        let (ta, tb, t) = (0.7, 0.6, 0.5);
        let hist = seeded_hist(&ev, &sched, &[(ta, x1.clone()), (tb, x0.clone())]);

        for b in [BFunction::Bh1, BFunction::Bh2] {
            let out = unip_predict(&ev, &sched, &hist, &x0, t, 2, CoeffVariant::Bh(b));
            // Hand-computed reference.
            let (l_a, l_b, l_t) = (sched.lambda(ta), sched.lambda(tb), sched.lambda(t));
            let h = l_t - l_b;
            let r1 = (l_a - l_b) / h;
            let eps_b = x0.scaled(0.4);
            let eps_a = x1.scaled(0.4);
            let d1 = eps_a.sub(&eps_b).scaled(1.0 / r1);
            let mut expect = Tensor::lincomb(
                sched.alpha(t) / sched.alpha(tb),
                &x0,
                -sched.sigma(t) * h.exp_m1(),
                &eps_b,
            );
            // a₁ B = ½ B(h)
            expect.axpy(-sched.sigma(t) * 0.5 * b.eval(h), &d1);
            for (o, e) in out.data().iter().zip(expect.data()) {
                assert!((o - e).abs() < 1e-10, "{b:?}: {o} vs {e}");
            }
        }
    }

    #[test]
    fn bh_variants_agree_to_leading_order() {
        // Different B(h) change the update only at O(h^{p+1}).
        let sched = VpLinear::default();
        let m = linear_model(0.5);
        let ev = Evaluator::new(&m, &sched, Prediction::Noise, None);
        let ts = timesteps(&sched, TimeSpacing::LogSnr, 0.9, 0.2, 64);
        let x0 = Tensor::from_vec(&[1, 2], vec![0.5, -0.5]);
        let x1 = Tensor::from_vec(&[1, 2], vec![0.49, -0.49]);
        let hist = seeded_hist(&ev, &sched, &[(ts[0], x0), (ts[1], x1.clone())]);
        let a = unip_predict(&ev, &sched, &hist, &x1, ts[2], 2, CoeffVariant::Bh(BFunction::Bh1));
        let b = unip_predict(&ev, &sched, &hist, &x1, ts[2], 2, CoeffVariant::Bh(BFunction::Bh2));
        let diff = a.sub(&b).max_abs();
        let h = sched.lambda(ts[2]) - sched.lambda(ts[1]);
        assert!(diff < h.powi(3), "diff {diff} vs h³ {}", h.powi(3));
    }

    #[test]
    fn varying_coeffs_match_bh_to_leading_order() {
        let sched = VpLinear::default();
        let m = linear_model(0.5);
        let ev = Evaluator::new(&m, &sched, Prediction::Noise, None);
        let ts = timesteps(&sched, TimeSpacing::LogSnr, 0.9, 0.2, 64);
        let x0 = Tensor::from_vec(&[1, 2], vec![0.5, -0.5]);
        let x1 = Tensor::from_vec(&[1, 2], vec![0.49, -0.49]);
        let hist = seeded_hist(&ev, &sched, &[(ts[0], x0), (ts[1], x1.clone())]);
        let a = unip_predict(&ev, &sched, &hist, &x1, ts[2], 2, CoeffVariant::Bh(BFunction::Bh1));
        let v = unip_predict(&ev, &sched, &hist, &x1, ts[2], 2, CoeffVariant::Varying);
        let h = sched.lambda(ts[2]) - sched.lambda(ts[1]);
        let diff = a.sub(&v).max_abs();
        assert!(diff < h.powi(3), "diff {diff}");
    }

    #[test]
    fn corrector_uses_current_point() {
        // With a constant model, D terms vanish and corrector == predictor.
        let sched = VpLinear::default();
        let dim = 2;
        let m: (Prediction, usize, _) = (
            Prediction::Noise,
            dim,
            |x: &Tensor, _t: f64| Tensor::full(x.shape(), 0.25),
        );
        let ev = Evaluator::new(&m, &sched, Prediction::Noise, None);
        let x0 = Tensor::from_vec(&[1, 2], vec![1.0, 1.0]);
        let x1 = Tensor::from_vec(&[1, 2], vec![0.9, 0.9]);
        let hist = seeded_hist(&ev, &sched, &[(0.7, x0), (0.6, x1.clone())]);
        let pred = unip_predict(&ev, &sched, &hist, &x1, 0.5, 2, CoeffVariant::Bh(BFunction::Bh2));
        let (corr, _) = unic_correct(
            &ev, &sched, &hist, &x1, &pred, 0.5, 2, CoeffVariant::Bh(BFunction::Bh2),
        );
        for (p, c) in pred.data().iter().zip(corr.data()) {
            assert!((p - c).abs() < 1e-12);
        }
    }

    #[test]
    fn data_prediction_path_matches_eq8() {
        // Hand-check Eq. 8 for p=1 (pure linear part).
        let sched = VpLinear::default();
        let m: (Prediction, usize, _) =
            (Prediction::Data, 2, |x: &Tensor, _t: f64| x.scaled(0.3));
        let ev = Evaluator::new(&m, &sched, Prediction::Data, None);
        let x = Tensor::from_vec(&[1, 2], vec![1.0, -1.0]);
        let (t0, t) = (0.6, 0.45);
        let hist = seeded_hist(&ev, &sched, &[(t0, x.clone())]);
        let out = unip_predict(&ev, &sched, &hist, &x, t, 1, CoeffVariant::Bh(BFunction::Bh2));
        let h = sched.lambda(t) - sched.lambda(t0);
        let expect = Tensor::lincomb(
            sched.sigma(t) / sched.sigma(t0),
            &x,
            sched.alpha(t) * (1.0 - (-h).exp()) * 0.3,
            &x,
        );
        for (o, e) in out.data().iter().zip(expect.data()) {
            assert!((o - e).abs() < 1e-12, "{o} vs {e}");
        }
    }

    #[test]
    fn varying_coeffs_hand_derived_q2() {
        // Asymmetric nodes expose the A_{m,n} orientation (regression test
        // for a transpose bug): r = [-2, 1] ⇒ C = [[1,1],[-1,1/2]],
        // C⁻¹ = [[1/3,-2/3],[2/3,2/3]], c_m = hh(φ₂ A_{m,1} + φ₃ A_{m,2}).
        let hh = 0.37;
        let c = residual_coeffs(&[-2.0, 1.0], hh, CoeffVariant::Varying);
        let (p2, p3) = (phi(2, hh), phi(3, hh));
        let expect0 = hh * (p2 / 3.0 - 2.0 * p3 / 3.0);
        let expect1 = hh * (2.0 * p2 / 3.0 + 2.0 * p3 / 3.0);
        assert!((c[0] - expect0).abs() < 1e-12, "{} vs {expect0}", c[0]);
        assert!((c[1] - expect1).abs() < 1e-12, "{} vs {expect1}", c[1]);
    }

    #[test]
    fn residual_coeffs_varying_independent_of_model() {
        // Appendix C: A_p depends only on {r_m}; effective coefficients are
        // hh φ_{n+1}(hh)-weighted rows of C_p⁻¹ — spot check q=1: c = hhφ₂.
        let c = residual_coeffs(&[1.0], 0.3, CoeffVariant::Varying);
        assert!((c[0] - 0.3 * phi(2, 0.3)).abs() < 1e-12);
        // The Bh variants use the degenerate a₁ = ½ at q=1, so c = ½B(hh);
        // all three agree to O(hh²) but not exactly.
        let cb = residual_coeffs(&[1.0], 0.3, CoeffVariant::Bh(BFunction::Bh1));
        assert!((cb[0] - 0.5 * 0.3).abs() < 1e-12);
        assert!((cb[0] - c[0]).abs() < 0.3 * 0.3, "agreement to O(h²)");
    }
}
