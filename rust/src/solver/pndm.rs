//! PNDM / PLMS (Liu et al. 2022) — pseudo linear multistep: a classical
//! Adams–Bashforth combination of the last four ε outputs fed through the
//! DDIM transfer map. Baseline for Table 5 (it degrades sharply at low NFE,
//! which the paper reports: 99.8 FID at NFE 10 on guided ImageNet).
//!
//! Warm-up uses the lower-order Adams–Bashforth combinations (the
//! latent-diffusion "PLMS" convention), so every step costs exactly one NFE.

use super::ddim::ddim_transfer;
use super::history::History;
use super::{Evaluator, Prediction};
use crate::sched::NoiseSchedule;
use crate::tensor::{weighted_sum, Tensor};

/// Adams–Bashforth weights for orders 1..4, newest-first.
const AB: [&[f64]; 4] = [
    &[1.0],
    &[3.0 / 2.0, -1.0 / 2.0],
    &[23.0 / 12.0, -16.0 / 12.0, 5.0 / 12.0],
    &[55.0 / 24.0, -59.0 / 24.0, 37.0 / 24.0, -9.0 / 24.0],
];

/// The Adams–Bashforth combination weights [`plms_step`] applies at window
/// size `k ∈ 1..=4` (newest-first). Exposed so the plan compiler bakes the
/// exact same table into [`crate::solver::plan::SamplePlan`]s.
pub fn ab_weights(k: usize) -> &'static [f64] {
    AB[k - 1]
}

/// One PLMS step t_prev → t with the effective order `min(4, hist.len())`.
pub fn plms_step(
    ev: &Evaluator,
    sched: &dyn NoiseSchedule,
    hist: &History,
    x: &Tensor,
    t: f64,
) -> Tensor {
    assert_eq!(ev.prediction(), Prediction::Noise, "PNDM combines ε outputs");
    let k = hist.len().min(4);
    let weights = AB[k - 1];
    let tensors: Vec<&Tensor> = (0..k).map(|m| &hist.back(m).m).collect();
    let eps = weighted_sum(weights, &tensors);
    ddim_transfer(Prediction::Noise, sched, x, hist.last().t, t, &eps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::VpLinear;
    use crate::solver::ddim::ddim_step;
    use crate::solver::Model;

    #[test]
    fn order1_equals_ddim() {
        let sched = VpLinear::default();
        let m: (Prediction, usize, _) =
            (Prediction::Noise, 2, |x: &Tensor, _t: f64| x.scaled(0.5));
        let ev = Evaluator::new(&m, &sched, Prediction::Noise, None);
        let x = Tensor::from_vec(&[1, 2], vec![1.0, -1.0]);
        let mut hist = History::new(4);
        hist.push(0.7, sched.lambda(0.7), ev.eval(&x, 0.7));
        let a = plms_step(&ev, &sched, &hist, &x, 0.6);
        let b = ddim_step(&ev, &sched, &hist, &x, 0.6);
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn ab_weights_sum_to_one() {
        for w in AB {
            let s: f64 = w.iter().sum();
            assert!((s - 1.0).abs() < 1e-12, "{w:?}");
        }
    }

    #[test]
    fn constant_eps_is_order_invariant() {
        // AB combination of identical tensors is the tensor itself.
        let sched = VpLinear::default();
        let m: (Prediction, usize, _) = (
            Prediction::Noise,
            2,
            |x: &Tensor, _t: f64| Tensor::full(x.shape(), 0.3),
        );
        let ev = Evaluator::new(&m, &sched, Prediction::Noise, None);
        let x = Tensor::from_vec(&[1, 2], vec![1.0, 1.0]);
        let mut hist = History::new(4);
        for (i, t) in [0.9, 0.8, 0.7, 0.6].iter().enumerate() {
            let _ = i;
            hist.push(*t, sched.lambda(*t), ev.eval(&x, *t));
        }
        let out4 = plms_step(&ev, &sched, &hist, &x, 0.5);
        let mut h1 = History::new(1);
        h1.push(0.6, sched.lambda(0.6), ev.eval(&x, 0.6));
        let out1 = plms_step(&ev, &sched, &h1, &x, 0.5);
        for (a, b) in out4.data().iter().zip(out1.data()) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}
