//! DDIM (Song et al. 2021a) — the deterministic first-order sampler; in the
//! exponential-integrator view it is exactly UniP-1 (paper §3.3).

use super::history::History;
use super::{Evaluator, Prediction};
use crate::sched::NoiseSchedule;
use crate::tensor::Tensor;

/// One DDIM step t_prev → t. `hist.last()` holds the model output at t_prev.
pub fn ddim_step(
    ev: &Evaluator,
    sched: &dyn NoiseSchedule,
    hist: &History,
    x: &Tensor,
    t: f64,
) -> Tensor {
    let prev = hist.last();
    let h = sched.lambda(t) - prev.lambda;
    match ev.prediction() {
        Prediction::Noise => Tensor::lincomb(
            sched.alpha(t) / sched.alpha(prev.t),
            x,
            -sched.sigma(t) * h.exp_m1(),
            &prev.m,
        ),
        Prediction::Data => Tensor::lincomb(
            sched.sigma(t) / sched.sigma(prev.t),
            x,
            sched.alpha(t) * (-(-h).exp_m1()),
            &prev.m,
        ),
    }
}

/// DDIM transfer given an explicit model output (used by PNDM, which feeds a
/// linear-multistep-combined ε through the DDIM map).
pub fn ddim_transfer(
    pred: Prediction,
    sched: &dyn NoiseSchedule,
    x: &Tensor,
    t_prev: f64,
    t: f64,
    m: &Tensor,
) -> Tensor {
    let h = sched.lambda(t) - sched.lambda(t_prev);
    match pred {
        Prediction::Noise => Tensor::lincomb(
            sched.alpha(t) / sched.alpha(t_prev),
            x,
            -sched.sigma(t) * h.exp_m1(),
            m,
        ),
        Prediction::Data => Tensor::lincomb(
            sched.sigma(t) / sched.sigma(t_prev),
            x,
            sched.alpha(t) * (-(-h).exp_m1()),
            m,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::VpLinear;

    #[test]
    fn noise_and_data_forms_agree() {
        // The two parametrizations of DDIM are algebraically identical when
        // the model outputs are consistent (x0 = (x − σε)/α).
        let sched = VpLinear::default();
        let c = 0.6;
        let m_noise: (Prediction, usize, _) =
            (Prediction::Noise, 2, move |x: &Tensor, _t: f64| x.scaled(c));
        let (t0, t) = (0.7, 0.55);
        let x = Tensor::from_vec(&[1, 2], vec![0.8, -0.4]);

        let ev_n = Evaluator::new(&m_noise, &sched, Prediction::Noise, None);
        let ev_d = Evaluator::new(&m_noise, &sched, Prediction::Data, None);

        let mut hist_n = History::new(2);
        hist_n.push(t0, sched.lambda(t0), ev_n.eval(&x, t0));
        let mut hist_d = History::new(2);
        hist_d.push(t0, sched.lambda(t0), ev_d.eval(&x, t0));

        let out_n = ddim_step(&ev_n, &sched, &hist_n, &x, t);
        let out_d = ddim_step(&ev_d, &sched, &hist_d, &x, t);
        for (a, b) in out_n.data().iter().zip(out_d.data()) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn zero_noise_contracts_by_alpha_ratio() {
        let sched = VpLinear::default();
        let m: (Prediction, usize, _) =
            (Prediction::Noise, 2, |x: &Tensor, _t: f64| x.zeros_like());
        let ev = Evaluator::new(&m, &sched, Prediction::Noise, None);
        let x = Tensor::from_vec(&[1, 2], vec![1.0, 2.0]);
        let (t0, t) = (0.6, 0.4);
        let mut hist = History::new(1);
        hist.push(t0, sched.lambda(t0), ev.eval(&x, t0));
        let out = ddim_step(&ev, &sched, &hist, &x, t);
        let ratio = sched.alpha(t) / sched.alpha(t0);
        for (o, xv) in out.data().iter().zip(x.data()) {
            assert!((o - ratio * xv).abs() < 1e-12);
        }
    }
}
