//! DPM-Solver (Lu et al. 2022a) — singlestep exponential-integrator solvers
//! for the *noise-prediction* model, orders 2 and 3. Baseline for Tables 5
//! and 6. DPM-Solver-2 coincides with UniP-2 using B₂(h) = e^h − 1 (§3.3).
//!
//! Formulas follow the official reference implementation
//! (`singlestep_dpm_solver_{second,third}_update`, solver_type="dpmsolver").

use super::{Evaluator, Prediction};
use crate::numerics::phi::phi;
use crate::sched::NoiseSchedule;
use crate::tensor::Tensor;

/// One singlestep DPM-Solver-2 update s → t with intermediate node at
/// λ_s + r1·h. Costs 1 extra NFE beyond the boundary evaluation `eps_s`.
pub fn dpm_solver_2_step(
    ev: &Evaluator,
    sched: &dyn NoiseSchedule,
    x: &Tensor,
    s: f64,
    t: f64,
    eps_s: &Tensor,
    r1: f64,
) -> Tensor {
    assert_eq!(ev.prediction(), Prediction::Noise, "DPM-Solver is noise-prediction");
    let (ls, lt) = (sched.lambda(s), sched.lambda(t));
    let h = lt - ls;
    let s1 = sched.t_of_lambda(ls + r1 * h);

    // x_{s1} = (α_{s1}/α_s) x − σ_{s1} (e^{r1 h} − 1) ε_s
    let x_s1 = Tensor::lincomb(
        sched.alpha(s1) / sched.alpha(s),
        x,
        -sched.sigma(s1) * (r1 * h).exp_m1(),
        eps_s,
    );
    let eps_s1 = ev.eval(&x_s1, s1);

    // x_t = (α_t/α_s) x − σ_t (e^h−1) ε_s − σ_t (e^h−1)/(2 r1) (ε_{s1} − ε_s)
    let mut out = Tensor::lincomb(
        sched.alpha(t) / sched.alpha(s),
        x,
        -sched.sigma(t) * h.exp_m1(),
        eps_s,
    );
    let d = eps_s1.sub(eps_s);
    out.axpy(-sched.sigma(t) * h.exp_m1() / (2.0 * r1), &d);
    out
}

/// One singlestep DPM-Solver-3 update s → t with nodes at r1, r2 of the λ
/// interval. Costs 2 extra NFE.
#[allow(clippy::too_many_arguments)]
pub fn dpm_solver_3_step(
    ev: &Evaluator,
    sched: &dyn NoiseSchedule,
    x: &Tensor,
    s: f64,
    t: f64,
    eps_s: &Tensor,
    r1: f64,
    r2: f64,
) -> Tensor {
    assert_eq!(ev.prediction(), Prediction::Noise, "DPM-Solver is noise-prediction");
    let (ls, lt) = (sched.lambda(s), sched.lambda(t));
    let h = lt - ls;
    let s1 = sched.t_of_lambda(ls + r1 * h);
    let s2 = sched.t_of_lambda(ls + r2 * h);

    let phi_11 = (r1 * h).exp_m1();
    let phi_12 = (r2 * h).exp_m1();
    let phi_1 = h.exp_m1();
    // φ₂-type terms (the reference writes them as expm1 ratios; we use the
    // stable φ evaluations: e.g. phi_22 = expm1(r2 h)/(r2 h) − 1 = r2 h φ₂(r2 h)).
    let phi_22 = r2 * h * phi(2, r2 * h);
    let phi_2 = h * phi(2, h);

    let x_s1 = Tensor::lincomb(
        sched.alpha(s1) / sched.alpha(s),
        x,
        -sched.sigma(s1) * phi_11,
        eps_s,
    );
    let eps_s1 = ev.eval(&x_s1, s1);
    let d1 = eps_s1.sub(eps_s);

    let mut x_s2 = Tensor::lincomb(
        sched.alpha(s2) / sched.alpha(s),
        x,
        -sched.sigma(s2) * phi_12,
        eps_s,
    );
    x_s2.axpy(-sched.sigma(s2) * (r2 / r1) * phi_22, &d1);
    let eps_s2 = ev.eval(&x_s2, s2);
    let d2 = eps_s2.sub(eps_s);

    let mut out = Tensor::lincomb(
        sched.alpha(t) / sched.alpha(s),
        x,
        -sched.sigma(t) * phi_1,
        eps_s,
    );
    out.axpy(-sched.sigma(t) * phi_2 / r2, &d2);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::VpLinear;
    use crate::solver::history::History;
    use crate::solver::unipc::{unip_predict, CoeffVariant};
    use crate::numerics::vandermonde::BFunction;
    use crate::solver::Model;

    #[test]
    fn order2_reduces_to_ddim_for_constant_eps() {
        // With a constant model the correction term vanishes.
        let sched = VpLinear::default();
        let m: (Prediction, usize, _) = (
            Prediction::Noise,
            2,
            |x: &Tensor, _t: f64| Tensor::full(x.shape(), 0.3),
        );
        let ev = Evaluator::new(&m, &sched, Prediction::Noise, None);
        let x = Tensor::from_vec(&[1, 2], vec![1.0, -0.5]);
        let (s, t) = (0.8, 0.5);
        let eps_s = ev.eval(&x, s);
        let out = dpm_solver_2_step(&ev, &sched, &x, s, t, &eps_s, 0.5);
        let h = sched.lambda(t) - sched.lambda(s);
        let expect = Tensor::lincomb(
            sched.alpha(t) / sched.alpha(s),
            &x,
            -sched.sigma(t) * h.exp_m1(),
            &eps_s,
        );
        for (o, e) in out.data().iter().zip(expect.data()) {
            assert!((o - e).abs() < 1e-12);
        }
    }

    #[test]
    fn singlestep2_close_to_multistep_unip2_small_h() {
        // Both are 2nd-order; for the same step they agree to O(h³).
        let sched = VpLinear::default();
        let c = 0.45;
        let m: (Prediction, usize, _) =
            (Prediction::Noise, 2, move |x: &Tensor, _t: f64| x.scaled(c));
        let ev = Evaluator::new(&m, &sched, Prediction::Noise, None);

        let (t2, t1, t) = (0.62, 0.6, 0.58);
        let x_at = |_tv: f64| Tensor::from_vec(&[1, 2], vec![0.7, -0.2]);
        let x1 = x_at(t1);

        // Multistep UniP-2 with history at t2, t1.
        let mut hist = History::new(4);
        hist.push(t2, sched.lambda(t2), ev.eval(&x_at(t2), t2));
        hist.push(t1, sched.lambda(t1), ev.eval(&x1, t1));
        let ms = unip_predict(&ev, &sched, &hist, &x1, t, 2, CoeffVariant::Bh(BFunction::Bh2));

        let eps1 = ev.eval(&x1, t1);
        let ss = dpm_solver_2_step(&ev, &sched, &x1, t1, t, &eps1, 0.5);
        let h = sched.lambda(t) - sched.lambda(t1);
        let diff = ms.sub(&ss).max_abs();
        assert!(diff < 10.0 * h.abs().powi(3), "diff {diff} h {h}");
    }
}
