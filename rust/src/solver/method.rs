//! Sampling-method registry: every solver the paper evaluates, with a
//! stable string form used by the CLI, the server protocol, and the bench
//! harness.

use super::unipc::CoeffVariant;
use super::Prediction;
use crate::numerics::vandermonde::BFunction;

pub use super::unipc::CoeffVariant as UniPcCoeffs;

/// A base sampling method (the optional UniC corrector is orthogonal — see
/// [`super::runner::SampleOptions::unic`]).
#[derive(Clone, Debug, PartialEq)]
pub enum Method {
    /// DDIM (Song et al. 2021a); first-order, either parametrization.
    Ddim { pred: Prediction },
    /// UniP-p multistep predictor (this paper). UniPC-p = UniP-p + UniC.
    /// `schedule`: optional per-step order schedule (Table 4); entries are
    /// clipped to what the warm-up buffer allows.
    UniP {
        order: usize,
        variant: CoeffVariant,
        pred: Prediction,
        schedule: Option<Vec<usize>>,
    },
    /// DPM-Solver (2022a) singlestep, order 2 or 3, noise prediction.
    DpmSolverSingle { order: usize },
    /// DPM-Solver++ multistep (2M for order 2, 3M for order 3), data
    /// prediction. Order 1 is DDIM-in-data-space.
    DpmSolverPp { order: usize },
    /// DPM-Solver++ singlestep order 3 (3S), data prediction.
    DpmSolverPp3S,
    /// PNDM/PLMS pseudo linear multistep, noise prediction.
    Plms,
    /// tAB-DEIS of the given order, noise prediction.
    Deis { order: usize },
}

impl Method {
    /// The standard UniPC-p configuration used in the paper's main results
    /// (pair with `SampleOptions::with_unic`).
    pub fn unip(order: usize, b: BFunction, pred: Prediction) -> Method {
        Method::UniP { order, variant: CoeffVariant::Bh(b), pred, schedule: None }
    }

    /// Which parametrization the evaluator must produce for this method.
    pub fn prediction(&self) -> Prediction {
        match self {
            Method::Ddim { pred } => *pred,
            Method::UniP { pred, .. } => *pred,
            Method::DpmSolverSingle { .. } => Prediction::Noise,
            Method::DpmSolverPp { .. } | Method::DpmSolverPp3S => Prediction::Data,
            Method::Plms => Prediction::Noise,
            Method::Deis { .. } => Prediction::Noise,
        }
    }

    /// Singlestep methods interpret `steps` as an NFE budget and take
    /// several model evaluations per solver step.
    pub fn is_singlestep(&self) -> bool {
        matches!(self, Method::DpmSolverSingle { .. } | Method::DpmSolverPp3S)
    }

    /// Nominal order of accuracy of the *base* method (UniC adds one).
    pub fn order(&self) -> usize {
        match self {
            Method::Ddim { .. } => 1,
            Method::UniP { order, .. } => *order,
            Method::DpmSolverSingle { order } => *order,
            Method::DpmSolverPp { order } => *order,
            Method::DpmSolverPp3S => 3,
            Method::Plms => 4,
            Method::Deis { order } => *order,
        }
    }

    /// How many history entries the base step can consume.
    pub fn history_needed(&self) -> usize {
        match self {
            Method::Plms => 4,
            m => m.order().max(1),
        }
    }

    /// Stable string form, e.g. `unipc-3-bh2`, `dpmpp-3m`, `deis-2`.
    pub fn id(&self) -> String {
        match self {
            Method::Ddim { pred } => format!("ddim-{}", pred.name()),
            Method::UniP { order, variant, pred, schedule } => {
                let base = format!("unip-{order}-{}-{}", variant.name(), pred.name());
                if schedule.is_some() {
                    format!("{base}-sched")
                } else {
                    base
                }
            }
            Method::DpmSolverSingle { order } => format!("dpm-solver-{order}s"),
            Method::DpmSolverPp { order } => format!("dpmpp-{order}m"),
            Method::DpmSolverPp3S => "dpmpp-3s".to_string(),
            Method::Plms => "pndm".to_string(),
            Method::Deis { order } => format!("deis-{order}"),
        }
    }

    /// Canonical form for plan-cache keys: like [`Method::id`], but with the
    /// order-schedule contents spelled out — two different Table-4 schedules
    /// produce different timestep-wise coefficients and must not collide in
    /// the coordinator's plan cache.
    pub fn cache_key(&self) -> String {
        match self {
            Method::UniP { schedule: Some(s), .. } => {
                let mut key = self.id();
                key.push('[');
                for (i, o) in s.iter().enumerate() {
                    if i > 0 {
                        key.push(',');
                    }
                    key.push_str(&o.to_string());
                }
                key.push(']');
                key
            }
            _ => self.id(),
        }
    }

    /// Parse the string form produced by [`Method::id`] (plus a few aliases
    /// used in configs: `ddim`, `unipc-3`, `dpmpp-2m`, …).
    pub fn parse(s: &str) -> Option<Method> {
        let parts: Vec<&str> = s.split('-').collect();
        match parts.as_slice() {
            ["ddim"] => Some(Method::Ddim { pred: Prediction::Noise }),
            ["ddim", "noise"] => Some(Method::Ddim { pred: Prediction::Noise }),
            ["ddim", "data"] => Some(Method::Ddim { pred: Prediction::Data }),
            ["pndm"] | ["plms"] => Some(Method::Plms),
            ["dpmpp", "3s"] => Some(Method::DpmSolverPp3S),
            ["dpmpp", om] if om.ends_with('m') => {
                let order: usize = om.trim_end_matches('m').parse().ok()?;
                (1..=3).contains(&order).then_some(Method::DpmSolverPp { order })
            }
            ["dpm", "solver", os] if os.ends_with('s') => {
                let order: usize = os.trim_end_matches('s').parse().ok()?;
                (2..=3).contains(&order).then_some(Method::DpmSolverSingle { order })
            }
            ["deis", o] => Some(Method::Deis { order: o.parse().ok()? }),
            ["unip", rest @ ..] | ["unipc", rest @ ..] => {
                let order: usize = rest.first()?.parse().ok()?;
                let mut variant = CoeffVariant::Bh(BFunction::Bh2);
                let mut pred = Prediction::Noise;
                for tok in &rest[1..] {
                    match *tok {
                        "bh1" => variant = CoeffVariant::Bh(BFunction::Bh1),
                        "bh2" => variant = CoeffVariant::Bh(BFunction::Bh2),
                        "vary" => variant = CoeffVariant::Varying,
                        "noise" => pred = Prediction::Noise,
                        "data" => pred = Prediction::Data,
                        _ => return None,
                    }
                }
                Some(Method::UniP { order, variant, pred, schedule: None })
            }
            _ => None,
        }
    }
}

/// Split an NFE budget into singlestep group orders, following the official
/// DPM-Solver `get_orders_and_timesteps_for_singlestep_solver`.
pub fn singlestep_orders(max_order: usize, nfe: usize) -> Vec<usize> {
    assert!(nfe >= 1);
    match max_order {
        3 => match nfe % 3 {
            0 => {
                let mut v = vec![3; nfe / 3 - 1];
                v.extend([2, 1]);
                v
            }
            1 => {
                let mut v = vec![3; nfe / 3];
                v.push(1);
                v
            }
            _ => {
                let mut v = vec![3; nfe / 3];
                v.push(2);
                v
            }
        },
        2 => {
            if nfe % 2 == 0 {
                vec![2; nfe / 2]
            } else {
                let mut v = vec![2; nfe / 2];
                v.push(1);
                v
            }
        }
        1 => vec![1; nfe],
        _ => panic!("singlestep orders supported up to 3"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn id_parse_roundtrip() {
        let methods = [
            Method::Ddim { pred: Prediction::Noise },
            Method::unip(3, BFunction::Bh1, Prediction::Noise),
            Method::unip(2, BFunction::Bh2, Prediction::Data),
            Method::UniP {
                order: 3,
                variant: CoeffVariant::Varying,
                pred: Prediction::Noise,
                schedule: None,
            },
            Method::DpmSolverSingle { order: 3 },
            Method::DpmSolverPp { order: 2 },
            Method::DpmSolverPp3S,
            Method::Plms,
            Method::Deis { order: 2 },
        ];
        for m in methods {
            let parsed = Method::parse(&m.id()).unwrap_or_else(|| panic!("parse {}", m.id()));
            assert_eq!(parsed, m, "{}", m.id());
        }
    }

    #[test]
    fn cache_key_distinguishes_schedules() {
        let mk = |schedule: Option<Vec<usize>>| Method::UniP {
            order: 3,
            variant: CoeffVariant::Bh(BFunction::Bh2),
            pred: Prediction::Noise,
            schedule,
        };
        let a = mk(Some(vec![1, 2, 3]));
        let b = mk(Some(vec![1, 2, 2]));
        let c = mk(None);
        assert_eq!(a.id(), b.id(), "id() alone cannot tell schedules apart");
        assert_ne!(a.cache_key(), b.cache_key());
        assert_ne!(a.cache_key(), c.cache_key());
        assert_eq!(c.cache_key(), c.id());
    }

    #[test]
    fn aliases_parse() {
        assert_eq!(Method::parse("ddim").unwrap(), Method::Ddim { pred: Prediction::Noise });
        assert_eq!(
            Method::parse("unipc-3").unwrap(),
            Method::unip(3, BFunction::Bh2, Prediction::Noise)
        );
        assert!(Method::parse("nope").is_none());
    }

    #[test]
    fn singlestep_orders_sum_to_nfe() {
        for nfe in 1..=30 {
            for order in 1..=3 {
                let v = singlestep_orders(order, nfe);
                assert_eq!(v.iter().sum::<usize>(), nfe, "order {order} nfe {nfe}: {v:?}");
                assert!(v.iter().all(|&k| k >= 1 && k <= order));
            }
        }
    }

    #[test]
    fn predictions_match_paper_conventions() {
        assert_eq!(Method::DpmSolverSingle { order: 2 }.prediction(), Prediction::Noise);
        assert_eq!(Method::DpmSolverPp { order: 3 }.prediction(), Prediction::Data);
        assert_eq!(Method::Plms.prediction(), Prediction::Noise);
    }
}
