//! Sampling-method registry: every solver the paper evaluates, with a
//! stable string form used by the CLI, the server protocol, and the bench
//! harness.

use super::unipc::CoeffVariant;
use super::Prediction;
use crate::numerics::vandermonde::BFunction;

pub use super::unipc::CoeffVariant as UniPcCoeffs;

/// A base sampling method (the optional UniC corrector is orthogonal — see
/// [`super::runner::SampleOptions::unic`]).
#[derive(Clone, Debug, PartialEq)]
pub enum Method {
    /// DDIM (Song et al. 2021a); first-order, either parametrization.
    Ddim { pred: Prediction },
    /// UniP-p multistep predictor (this paper). UniPC-p = UniP-p + UniC.
    /// `schedule`: optional per-step order schedule (Table 4); entries are
    /// clipped to what the warm-up buffer allows.
    UniP {
        order: usize,
        variant: CoeffVariant,
        pred: Prediction,
        schedule: Option<Vec<usize>>,
    },
    /// DPM-Solver (2022a) singlestep, order 2 or 3, noise prediction.
    DpmSolverSingle { order: usize },
    /// DPM-Solver++ multistep (2M for order 2, 3M for order 3), data
    /// prediction. Order 1 is DDIM-in-data-space.
    DpmSolverPp { order: usize },
    /// DPM-Solver++ singlestep order 3 (3S), data prediction.
    DpmSolverPp3S,
    /// PNDM/PLMS pseudo linear multistep, noise prediction.
    Plms,
    /// tAB-DEIS of the given order, noise prediction.
    Deis { order: usize },
}

impl Method {
    /// The standard UniPC-p configuration used in the paper's main results
    /// (pair with `SampleOptions::with_unic`).
    pub fn unip(order: usize, b: BFunction, pred: Prediction) -> Method {
        Method::UniP { order, variant: CoeffVariant::Bh(b), pred, schedule: None }
    }

    /// Which parametrization the evaluator must produce for this method.
    pub fn prediction(&self) -> Prediction {
        match self {
            Method::Ddim { pred } => *pred,
            Method::UniP { pred, .. } => *pred,
            Method::DpmSolverSingle { .. } => Prediction::Noise,
            Method::DpmSolverPp { .. } | Method::DpmSolverPp3S => Prediction::Data,
            Method::Plms => Prediction::Noise,
            Method::Deis { .. } => Prediction::Noise,
        }
    }

    /// Singlestep methods interpret `steps` as an NFE budget and take
    /// several model evaluations per solver step.
    pub fn is_singlestep(&self) -> bool {
        matches!(self, Method::DpmSolverSingle { .. } | Method::DpmSolverPp3S)
    }

    /// Nominal order of accuracy of the *base* method (UniC adds one).
    /// This is the global convergence order w.r.t. the probability-flow ODE,
    /// which the convergence suite (`tests/solver_convergence.rs`) verifies
    /// empirically. PNDM combines four ε outputs (see
    /// [`Method::history_needed`]) but is second-order convergent — Liu et
    /// al. (2022) prove exactly that for pseudo linear multistep, and the
    /// DDIM-transfer kernel mismatch caps the observed slope at 2.
    pub fn order(&self) -> usize {
        match self {
            Method::Ddim { .. } => 1,
            Method::UniP { order, .. } => *order,
            Method::DpmSolverSingle { order } => *order,
            Method::DpmSolverPp { order } => *order,
            Method::DpmSolverPp3S => 3,
            Method::Plms => 2,
            Method::Deis { order } => *order,
        }
    }

    /// How many history entries the base step can consume.
    pub fn history_needed(&self) -> usize {
        match self {
            Method::Plms => 4,
            m => m.order().max(1),
        }
    }

    /// Stable string form, e.g. `unipc-3-bh2`, `dpmpp-3m`, `deis-2`.
    pub fn id(&self) -> String {
        match self {
            Method::Ddim { pred } => format!("ddim-{}", pred.name()),
            Method::UniP { order, variant, pred, schedule } => {
                let base = format!("unip-{order}-{}-{}", variant.name(), pred.name());
                if schedule.is_some() {
                    format!("{base}-sched")
                } else {
                    base
                }
            }
            Method::DpmSolverSingle { order } => format!("dpm-solver-{order}s"),
            Method::DpmSolverPp { order } => format!("dpmpp-{order}m"),
            Method::DpmSolverPp3S => "dpmpp-3s".to_string(),
            Method::Plms => "pndm".to_string(),
            Method::Deis { order } => format!("deis-{order}"),
        }
    }

    /// Canonical form for plan-cache keys: like [`Method::id`], but with the
    /// order-schedule contents spelled out — two different Table-4 schedules
    /// produce different timestep-wise coefficients and must not collide in
    /// the coordinator's plan cache.
    pub fn cache_key(&self) -> String {
        match self {
            Method::UniP { schedule: Some(s), .. } => {
                let mut key = self.id();
                key.push('[');
                for (i, o) in s.iter().enumerate() {
                    if i > 0 {
                        key.push(',');
                    }
                    key.push_str(&o.to_string());
                }
                key.push(']');
                key
            }
            _ => self.id(),
        }
    }

    /// Parse the string form produced by [`Method::id`] / [`Method::cache_key`]
    /// (plus a few aliases used in configs: `ddim`, `unipc-3`, `dpmpp-2m`,
    /// `dpm-2s`, …).
    ///
    /// Round-trip contract (property-tested in `tests/property_suite.rs`):
    /// `Method::parse(&m.cache_key()) == Some(m)` for every method, and
    /// `Method::parse(&m.id()) == Some(m)` for every method without an order
    /// schedule. A scheduled UniP's `id()` is display-lossy (`…-sched`
    /// without the contents); its `cache_key()` spells the schedule out as
    /// `…-sched[1,2,3]`, which parses back exactly.
    pub fn parse(s: &str) -> Option<Method> {
        let parts: Vec<&str> = s.split('-').collect();
        match parts.as_slice() {
            ["ddim"] => Some(Method::Ddim { pred: Prediction::Noise }),
            ["ddim", "noise"] => Some(Method::Ddim { pred: Prediction::Noise }),
            ["ddim", "data"] => Some(Method::Ddim { pred: Prediction::Data }),
            ["pndm"] | ["plms"] => Some(Method::Plms),
            ["dpmpp", "3s"] => Some(Method::DpmSolverPp3S),
            ["dpmpp", om] if om.ends_with('m') => {
                let order: usize = om.trim_end_matches('m').parse().ok()?;
                (1..=3).contains(&order).then_some(Method::DpmSolverPp { order })
            }
            // Canonical "dpm-solver-2s" and the short "dpm-2s" spelling.
            ["dpm", "solver", os] | ["dpm", os] if os.ends_with('s') => {
                let order: usize = os.trim_end_matches('s').parse().ok()?;
                (2..=3).contains(&order).then_some(Method::DpmSolverSingle { order })
            }
            ["deis", o] => {
                let order: usize = o.parse().ok()?;
                // tAB-DEIS is defined for small extrapolation windows; an
                // unbounded order would demand unbounded history (and
                // "deis-0" would be a zero-term quadrature).
                (1..=4).contains(&order).then_some(Method::Deis { order })
            }
            ["unip", rest @ ..] | ["unipc", rest @ ..] => {
                let order: usize = rest.first()?.parse().ok()?;
                if !(1..=6).contains(&order) {
                    return None;
                }
                let mut variant = CoeffVariant::Bh(BFunction::Bh2);
                let mut pred = Prediction::Noise;
                let mut schedule = None;
                for tok in &rest[1..] {
                    match *tok {
                        "bh1" => variant = CoeffVariant::Bh(BFunction::Bh1),
                        "bh2" => variant = CoeffVariant::Bh(BFunction::Bh2),
                        "vary" => variant = CoeffVariant::Varying,
                        "noise" => pred = Prediction::Noise,
                        "data" => pred = Prediction::Data,
                        // The cache-key form spells the Table-4 schedule out
                        // ("sched[1,2,3]"); the bare "-sched" id suffix is
                        // display-only and cannot be reconstructed.
                        t if t.starts_with("sched[") && t.ends_with(']') => {
                            let inner = &t["sched[".len()..t.len() - 1];
                            let parsed: Option<Vec<usize>> = if inner.is_empty() {
                                Some(Vec::new())
                            } else {
                                inner.split(',').map(|o| o.parse().ok()).collect()
                            };
                            schedule = Some(parsed?);
                        }
                        _ => return None,
                    }
                }
                Some(Method::UniP { order, variant, pred, schedule })
            }
            _ => None,
        }
    }

    /// The full parseable solver zoo: every method family at **every order
    /// `Method::parse` accepts** — both DDIM parametrizations, DPM-Solver
    /// singlestep 2S/3S, DPM-Solver++ 1M/2M/3M/3S, PNDM, DEIS 1–4, the
    /// full UniP order-1..3 × coefficient-variant × parametrization grid,
    /// and one instance of each UniP order 4–6 (Bh and Varying). The
    /// conformance suite sweeps exactly this list, so anything the parser
    /// admits into the coordinator is covered by planned-vs-reference
    /// bit-identity and id/cache-key round-trip tests.
    pub fn zoo() -> Vec<Method> {
        let mut v = vec![
            Method::Ddim { pred: Prediction::Noise },
            Method::Ddim { pred: Prediction::Data },
            Method::Plms,
            Method::DpmSolverSingle { order: 2 },
            Method::DpmSolverSingle { order: 3 },
            Method::DpmSolverPp { order: 1 },
            Method::DpmSolverPp { order: 2 },
            Method::DpmSolverPp { order: 3 },
            Method::DpmSolverPp3S,
            Method::Deis { order: 1 },
            Method::Deis { order: 2 },
            Method::Deis { order: 3 },
            Method::Deis { order: 4 },
        ];
        for order in [1usize, 2, 3] {
            for variant in [
                CoeffVariant::Bh(BFunction::Bh1),
                CoeffVariant::Bh(BFunction::Bh2),
                CoeffVariant::Varying,
            ] {
                for pred in [Prediction::Noise, Prediction::Data] {
                    v.push(Method::UniP { order, variant, pred, schedule: None });
                }
            }
        }
        // The high-order tail the parser admits (orders 4–6): one Bh and
        // one Varying instance per order keeps the sweep bounded while
        // covering the deep-history code paths (order_sweep's regime).
        for order in [4usize, 5, 6] {
            v.push(Method::UniP {
                order,
                variant: CoeffVariant::Bh(BFunction::Bh2),
                pred: Prediction::Noise,
                schedule: None,
            });
            v.push(Method::UniP {
                order,
                variant: CoeffVariant::Varying,
                pred: Prediction::Data,
                schedule: None,
            });
        }
        v
    }
}

/// Split an NFE budget into singlestep group orders, following the official
/// DPM-Solver `get_orders_and_timesteps_for_singlestep_solver`.
pub fn singlestep_orders(max_order: usize, nfe: usize) -> Vec<usize> {
    assert!(nfe >= 1);
    match max_order {
        3 => match nfe % 3 {
            0 => {
                let mut v = vec![3; nfe / 3 - 1];
                v.extend([2, 1]);
                v
            }
            1 => {
                let mut v = vec![3; nfe / 3];
                v.push(1);
                v
            }
            _ => {
                let mut v = vec![3; nfe / 3];
                v.push(2);
                v
            }
        },
        2 => {
            if nfe % 2 == 0 {
                vec![2; nfe / 2]
            } else {
                let mut v = vec![2; nfe / 2];
                v.push(1);
                v
            }
        }
        1 => vec![1; nfe],
        _ => panic!("singlestep orders supported up to 3"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn id_parse_roundtrip() {
        // Every zoo entry round-trips through both string forms.
        for m in Method::zoo() {
            let parsed = Method::parse(&m.id()).unwrap_or_else(|| panic!("parse {}", m.id()));
            assert_eq!(parsed, m, "{}", m.id());
            let parsed = Method::parse(&m.cache_key())
                .unwrap_or_else(|| panic!("parse {}", m.cache_key()));
            assert_eq!(parsed, m, "{}", m.cache_key());
        }
    }

    #[test]
    fn scheduled_unip_roundtrips_via_cache_key() {
        let m = Method::UniP {
            order: 3,
            variant: CoeffVariant::Bh(BFunction::Bh2),
            pred: Prediction::Data,
            schedule: Some(vec![1, 2, 3, 3, 2, 1]),
        };
        assert_eq!(m.cache_key(), "unip-3-bh2-data-sched[1,2,3,3,2,1]");
        assert_eq!(Method::parse(&m.cache_key()), Some(m.clone()));
        // The display id stays lossy by design: no schedule to reconstruct.
        assert_eq!(m.id(), "unip-3-bh2-data-sched");
        assert_eq!(Method::parse(&m.id()), None);
    }

    #[test]
    fn parse_rejects_out_of_range_orders() {
        assert_eq!(Method::parse("deis-0"), None);
        assert_eq!(Method::parse("deis-9"), None);
        assert_eq!(Method::parse("unip-0"), None);
        assert_eq!(Method::parse("unipc-7"), None);
        assert_eq!(Method::parse("dpmpp-0m"), None);
        assert_eq!(Method::parse("dpmpp-4m"), None);
        assert_eq!(Method::parse("dpm-solver-1s"), None);
        assert_eq!(Method::parse("dpm-solver-4s"), None);
    }

    #[test]
    fn cache_key_distinguishes_schedules() {
        let mk = |schedule: Option<Vec<usize>>| Method::UniP {
            order: 3,
            variant: CoeffVariant::Bh(BFunction::Bh2),
            pred: Prediction::Noise,
            schedule,
        };
        let a = mk(Some(vec![1, 2, 3]));
        let b = mk(Some(vec![1, 2, 2]));
        let c = mk(None);
        assert_eq!(a.id(), b.id(), "id() alone cannot tell schedules apart");
        assert_ne!(a.cache_key(), b.cache_key());
        assert_ne!(a.cache_key(), c.cache_key());
        assert_eq!(c.cache_key(), c.id());
    }

    #[test]
    fn aliases_parse() {
        assert_eq!(Method::parse("ddim").unwrap(), Method::Ddim { pred: Prediction::Noise });
        assert_eq!(
            Method::parse("unipc-3").unwrap(),
            Method::unip(3, BFunction::Bh2, Prediction::Noise)
        );
        // Short DPM-Solver singlestep spelling.
        assert_eq!(
            Method::parse("dpm-2s").unwrap(),
            Method::DpmSolverSingle { order: 2 }
        );
        assert_eq!(
            Method::parse("dpm-3s").unwrap(),
            Method::DpmSolverSingle { order: 3 }
        );
        assert!(Method::parse("nope").is_none());
    }

    #[test]
    fn singlestep_orders_sum_to_nfe() {
        for nfe in 1..=30 {
            for order in 1..=3 {
                let v = singlestep_orders(order, nfe);
                assert_eq!(v.iter().sum::<usize>(), nfe, "order {order} nfe {nfe}: {v:?}");
                assert!(v.iter().all(|&k| k >= 1 && k <= order));
            }
        }
    }

    #[test]
    fn predictions_match_paper_conventions() {
        assert_eq!(Method::DpmSolverSingle { order: 2 }.prediction(), Prediction::Noise);
        assert_eq!(Method::DpmSolverPp { order: 3 }.prediction(), Prediction::Data);
        assert_eq!(Method::Plms.prediction(), Prediction::Noise);
    }
}
