//! The multistep buffer Q of Algorithms 5–8.
//!
//! Stores the last `cap` model outputs with their timesteps and half
//! log-SNRs, oldest first. Multistep methods read `back(m)` to reach the
//! output at t_{i−m−1}.

use crate::tensor::Tensor;
use std::collections::VecDeque;

/// One buffered model evaluation.
#[derive(Clone, Debug)]
pub struct HistoryEntry {
    pub t: f64,
    pub lambda: f64,
    /// Model output (in the evaluator's parametrization) at `t`.
    pub m: Tensor,
}

/// Ring buffer of the most recent model outputs.
#[derive(Clone, Debug)]
pub struct History {
    entries: VecDeque<HistoryEntry>,
    cap: usize,
}

impl History {
    /// A buffer retaining the `cap` most recent entries (cap ≥ max order).
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1);
        History { entries: VecDeque::with_capacity(cap + 1), cap }
    }

    pub fn push(&mut self, t: f64, lambda: f64, m: Tensor) {
        if let Some(last) = self.entries.back() {
            debug_assert!(t < last.t, "history timesteps must strictly decrease");
        }
        self.entries.push_back(HistoryEntry { t, lambda, m });
        while self.entries.len() > self.cap {
            self.entries.pop_front();
        }
    }

    /// Number of buffered entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The most recent entry (at t_{i−1} when stepping to t_i).
    pub fn last(&self) -> &HistoryEntry {
        self.entries.back().expect("empty history")
    }

    /// Entry `m` steps back from the most recent: `back(0) == last()`,
    /// `back(1)` is at t_{i−2}, etc.
    pub fn back(&self, m: usize) -> &HistoryEntry {
        let n = self.entries.len();
        assert!(m < n, "history back({m}) with only {n} entries");
        &self.entries[n - 1 - m]
    }

    /// Model output of the most recent entry (the m₀ of Algorithms 5–8).
    pub fn last_m(&self) -> &Tensor {
        &self.last().m
    }

    /// Model output `m` steps back (`m_back(0) == last_m()`). Plan-executed
    /// steps read only the buffered outputs — timesteps and λ's live in the
    /// precomputed [`super::plan::SamplePlan`].
    pub fn m_back(&self, m: usize) -> &Tensor {
        &self.back(m).m
    }

    /// Replace the most recent entry's model output (oracle corrector:
    /// re-evaluated at the corrected point).
    pub fn replace_last(&mut self, m: Tensor) {
        let last = self.entries.back_mut().expect("empty history");
        last.m = m;
    }

    /// Clear all entries (engine reuse between requests).
    pub fn clear(&mut self) {
        self.entries.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t1(v: f64) -> Tensor {
        Tensor::from_slice(&[v])
    }

    #[test]
    fn push_and_back_indexing() {
        let mut h = History::new(3);
        h.push(0.9, -1.0, t1(1.0));
        h.push(0.8, -0.5, t1(2.0));
        h.push(0.7, 0.0, t1(3.0));
        assert_eq!(h.len(), 3);
        assert_eq!(h.last().m.data(), &[3.0]);
        assert_eq!(h.back(0).m.data(), &[3.0]);
        assert_eq!(h.back(2).m.data(), &[1.0]);
        assert_eq!(h.back(2).t, 0.9);
    }

    #[test]
    fn capacity_evicts_oldest() {
        let mut h = History::new(2);
        h.push(0.9, 0.0, t1(1.0));
        h.push(0.8, 0.1, t1(2.0));
        h.push(0.7, 0.2, t1(3.0));
        assert_eq!(h.len(), 2);
        assert_eq!(h.back(1).m.data(), &[2.0]);
    }

    #[test]
    fn replace_last_swaps_output() {
        let mut h = History::new(2);
        h.push(0.9, 0.0, t1(1.0));
        h.replace_last(t1(5.0));
        assert_eq!(h.last().m.data(), &[5.0]);
        assert_eq!(h.last().t, 0.9);
    }

    #[test]
    #[should_panic(expected = "back(1)")]
    fn back_out_of_range_panics() {
        let mut h = History::new(2);
        h.push(0.9, 0.0, t1(1.0));
        let _ = h.back(1);
    }
}
