//! Diffusion-ODE solvers: UniPC (the paper's contribution) and every
//! baseline its evaluation compares against.
//!
//! Layout:
//! * [`Model`] / [`Evaluator`] — the ε_θ/x_θ abstraction. A model natively
//!   predicts noise or data; the evaluator converts to the parametrization a
//!   solver wants, applies optional dynamic thresholding (Saharia et al.),
//!   and counts NFE.
//! * [`history`] — the multistep buffer Q of Algorithms 5–8.
//! * [`unipc`] — UniP-p / UniC-p / UniPC-p of arbitrary order (Eq. 3, 8, 9)
//!   plus the varying-coefficient variant UniPC_v (Appendix C).
//! * [`ddim`], [`dpm_solver`], [`dpm_solverpp`], [`pndm`], [`deis`] —
//!   baselines (Tables 2, 5, 6–9).
//! * [`thresholding`] — dynamic thresholding for data-prediction guided
//!   sampling (§3.4).
//! * [`runner`] — drives any method over a timestep grid, optionally
//!   wrapping it with UniC ("+UniC" rows of Table 2/3), with NFE accounting
//!   and trajectory capture.
//! * [`plan`] — the method-agnostic plan compiler: one [`SamplePlan`] per
//!   `(schedule, options)` resolves every per-step scalar and coefficient
//!   up front for **every method in the registry** (per-family
//!   [`plan::CompileStep`] compilers lower each step to a
//!   [`plan::StepOp`]), and [`sample_with_plan`] executes it with zero
//!   solver-side heap allocations in steady state, bit-identical to the
//!   per-method reference loops (`sample_unplanned` is the conformance
//!   oracle). The coordinator caches plans by [`plan_key`] across requests,
//!   and [`sample_batch_with_plan`] executes many same-plan requests in
//!   lockstep on one stacked batch (one model evaluation per step for the
//!   whole batch), with a pooled [`BatchWorkspace`] reused across runs.

pub mod ddim;
pub mod deis;
pub mod dpm_solver;
pub mod dpm_solverpp;
pub mod history;
pub mod method;
pub mod plan;
pub mod pndm;
pub mod runner;
pub mod thresholding;
pub mod unipc;

pub use history::History;
pub use method::{Method, UniPcCoeffs};
pub use plan::{
    plan_key, sample_batch_with_plan, sample_batch_with_plan_observed, sample_with_plan,
    sample_with_plan_observed, BatchWorkspace, CompileStep, PlannedStep, SamplePlan, StepCx,
    StepHealth, StepObserver, StepOp, StepWorkspace,
};
pub use runner::{sample, sample_batch, sample_unplanned, SampleOptions, SampleResult};
pub use thresholding::DynamicThresholding;

use crate::sched::NoiseSchedule;
use crate::tensor::Tensor;
use std::cell::Cell;

/// What a denoising network predicts.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Prediction {
    /// ε_θ(x_t, t): the added noise (ScoreSDE-style models).
    Noise,
    /// x_θ(x_t, t) = (x_t − σ_t ε_θ)/α_t: the clean data (DPM-Solver++-style).
    Data,
}

impl Prediction {
    pub fn name(self) -> &'static str {
        match self {
            Prediction::Noise => "noise",
            Prediction::Data => "data",
        }
    }
}

/// A (possibly learned, possibly analytic) denoising model. Implementations:
/// [`crate::analytic::GmmModel`] (closed-form score), the PJRT-backed
/// [`crate::runtime::PjrtModel`], guidance wrappers, and test closures.
///
/// `eval` is batched: `x` is `[n, d]` and all rows share the timestep `t`
/// (per-request semantics; the serving layer batches *across* requests with
/// a per-sample t vector below this interface).
pub trait Model {
    /// Native parametrization of the network output.
    fn prediction(&self) -> Prediction;
    /// Evaluate the network on a batch at time `t`.
    fn eval(&self, x: &Tensor, t: f64) -> Tensor;
    /// Flattened data dimensionality.
    fn dim(&self) -> usize;
}

impl<F> Model for (Prediction, usize, F)
where
    F: Fn(&Tensor, f64) -> Tensor,
{
    fn prediction(&self) -> Prediction {
        self.0
    }
    fn eval(&self, x: &Tensor, t: f64) -> Tensor {
        (self.2)(x, t)
    }
    fn dim(&self) -> usize {
        self.1
    }
}

/// Converts model outputs to the solver's parametrization, applies dynamic
/// thresholding, and counts function evaluations (the paper's NFE metric).
pub struct Evaluator<'a> {
    model: &'a dyn Model,
    sched: &'a dyn NoiseSchedule,
    want: Prediction,
    thresholding: Option<DynamicThresholding>,
    nfe: Cell<usize>,
}

impl<'a> Evaluator<'a> {
    pub fn new(
        model: &'a dyn Model,
        sched: &'a dyn NoiseSchedule,
        want: Prediction,
        thresholding: Option<DynamicThresholding>,
    ) -> Self {
        Evaluator { model, sched, want, thresholding, nfe: Cell::new(0) }
    }

    /// The parametrization this evaluator returns.
    pub fn prediction(&self) -> Prediction {
        self.want
    }

    /// Number of model evaluations so far.
    pub fn nfe(&self) -> usize {
        self.nfe.get()
    }

    /// Evaluate the model at `(x, t)` in the solver's parametrization.
    pub fn eval(&self, x: &Tensor, t: f64) -> Tensor {
        self.nfe.set(self.nfe.get() + 1);
        let raw = self.model.eval(x, t);
        let mut out = match (self.model.prediction(), self.want) {
            (Prediction::Noise, Prediction::Noise) | (Prediction::Data, Prediction::Data) => raw,
            (Prediction::Noise, Prediction::Data) => {
                // x0 = (x − σ ε) / α
                let (a, s) = (self.sched.alpha(t), self.sched.sigma(t));
                Tensor::lincomb(1.0 / a, x, -s / a, &raw)
            }
            (Prediction::Data, Prediction::Noise) => {
                // ε = (x − α x0) / σ
                let (a, s) = (self.sched.alpha(t), self.sched.sigma(t));
                Tensor::lincomb(1.0 / s, x, -a / s, &raw)
            }
        };
        if self.want == Prediction::Data {
            if let Some(th) = &self.thresholding {
                th.apply(&mut out);
            }
        }
        out
    }

    /// Convert the final state to an x₀ estimate (used at the end of
    /// sampling when t_end > 0, matching the DPM-Solver convention of
    /// returning x_{t_end} directly; exposed for metrics that want x̂₀).
    pub fn to_data(&self, x: &Tensor, t: f64) -> Tensor {
        let raw = self.model.eval(x, t);
        self.nfe.set(self.nfe.get() + 1);
        match self.model.prediction() {
            Prediction::Data => raw,
            Prediction::Noise => {
                let (a, s) = (self.sched.alpha(t), self.sched.sigma(t));
                Tensor::lincomb(1.0 / a, x, -s / a, &raw)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::VpLinear;

    fn toy_model(pred: Prediction) -> impl Model {
        // ε(x, t) = 0.5 x (or the data-space equivalent of returning 0.5x).
        (pred, 2, |x: &Tensor, _t: f64| x.scaled(0.5))
    }

    #[test]
    fn nfe_counts_evaluations() {
        let sched = VpLinear::default();
        let m = toy_model(Prediction::Noise);
        let ev = Evaluator::new(&m, &sched, Prediction::Noise, None);
        let x = Tensor::from_slice(&[1.0, 2.0]).reshaped(&[1, 2]);
        let _ = ev.eval(&x, 0.5);
        let _ = ev.eval(&x, 0.4);
        assert_eq!(ev.nfe(), 2);
    }

    #[test]
    fn noise_to_data_conversion_roundtrip() {
        let sched = VpLinear::default();
        let m = toy_model(Prediction::Noise);
        let t = 0.5;
        let x = Tensor::from_slice(&[1.0, -2.0]).reshaped(&[1, 2]);

        let ev_noise = Evaluator::new(&m, &sched, Prediction::Noise, None);
        let ev_data = Evaluator::new(&m, &sched, Prediction::Data, None);
        let eps = ev_noise.eval(&x, t);
        let x0 = ev_data.eval(&x, t);
        // Check x = α x0 + σ ε.
        let (a, s) = (sched.alpha(t), sched.sigma(t));
        let recon = Tensor::lincomb(a, &x0, s, &eps);
        for (r, xv) in recon.data().iter().zip(x.data()) {
            assert!((r - xv).abs() < 1e-12);
        }
    }

    #[test]
    fn identity_when_parametrizations_match() {
        let sched = VpLinear::default();
        let m = toy_model(Prediction::Data);
        let ev = Evaluator::new(&m, &sched, Prediction::Data, None);
        let x = Tensor::from_slice(&[2.0, 4.0]).reshaped(&[1, 2]);
        let out = ev.eval(&x, 0.3);
        assert_eq!(out.data(), &[1.0, 2.0]);
    }
}
