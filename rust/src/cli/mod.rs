//! Hand-rolled argument parser (no clap in the offline registry).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! and subcommands; generates usage text from declared options.

use std::collections::BTreeMap;

/// Declared option for usage text.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

/// Parsed arguments: flags, key-value options, and positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse a raw argv slice (without the program/subcommand names).
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` terminates option parsing.
                    out.positional.extend(argv[i + 1..].iter().cloned());
                    break;
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.opts.insert(rest.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn from_env() -> (String, Args) {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        let sub = argv.first().cloned().unwrap_or_default();
        let rest = if argv.is_empty() { &[][..] } else { &argv[1..] };
        (sub, Args::parse(rest).unwrap_or_default())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: expected integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: expected number, got '{v}'")),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Keys of unknown options given a spec list (for strict commands).
    pub fn unknown_keys(&self, specs: &[OptSpec]) -> Vec<String> {
        self.opts
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !specs.iter().any(|s| s.name == k.as_str()))
            .cloned()
            .collect()
    }
}

/// Render usage text for a subcommand.
pub fn usage(cmd: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut s = format!("{cmd} — {about}\n\noptions:\n");
    for o in specs {
        let def = o.default.map(|d| format!(" (default: {d})")).unwrap_or_default();
        s.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, def));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn key_value_forms() {
        let a = Args::parse(&argv(&["--steps", "10", "--method=unipc-3", "--verbose"])).unwrap();
        assert_eq!(a.get("steps"), Some("10"));
        assert_eq!(a.get("method"), Some("unipc-3"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_getters() {
        let a = Args::parse(&argv(&["--n", "5", "--x", "2.5"])).unwrap();
        assert_eq!(a.get_usize("n", 1).unwrap(), 5);
        assert_eq!(a.get_usize("m", 7).unwrap(), 7);
        assert!((a.get_f64("x", 0.0).unwrap() - 2.5).abs() < 1e-12);
        assert!(a.get_usize("x", 0).is_err());
    }

    #[test]
    fn positionals_and_separator() {
        let a = Args::parse(&argv(&["file1", "--k", "v", "--", "--not-an-opt"])).unwrap();
        assert_eq!(a.positional(), &["file1", "--not-an-opt"]);
    }

    #[test]
    fn negative_number_as_value() {
        // "--x -5" would read -5 as a flag start; use --x=-5 form.
        let a = Args::parse(&argv(&["--x=-5"])).unwrap();
        assert_eq!(a.get_f64("x", 0.0).unwrap(), -5.0);
    }

    #[test]
    fn unknown_key_detection() {
        let specs = [OptSpec { name: "steps", help: "", default: None }];
        let a = Args::parse(&argv(&["--steps", "3", "--bogus", "1"])).unwrap();
        assert_eq!(a.unknown_keys(&specs), vec!["bogus".to_string()]);
    }

    #[test]
    fn usage_renders() {
        let u = usage("serve", "run the server", &[OptSpec {
            name: "port",
            help: "TCP port",
            default: Some("7878"),
        }]);
        assert!(u.contains("--port"));
        assert!(u.contains("default: 7878"));
    }
}
