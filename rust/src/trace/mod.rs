//! End-to-end request tracing: span events, bounded per-shard rings, and
//! span-tree / Chrome `trace_event` exporters.
//!
//! Every request admitted by the coordinator is assigned a `trace_id` and
//! leaves a trail of [`SpanEvent`]s as it moves through the serving
//! lifecycle. Events are tiny `Copy` records written into preallocated
//! bounded ring buffers ([`TraceRing`], one per coordinator shard), so
//! steady-state recording allocates nothing and is cheap enough to leave on
//! in production (`rust/tests/plan_alloc.rs` proves the zero-allocation
//! claim; bench row `L3-h` in `BENCH_hot_path.json` bounds the overhead).
//!
//! # Span taxonomy
//!
//! | stage | emitted | `a` | `b` |
//! |---|---|---|---|
//! | [`Stage::Admit`] | request accepted into a shard queue | rows (`n`) | steps |
//! | [`Stage::Route`] | worker pops the job | owner shard | `0` = home pop, else stealer shard + 1 |
//! | [`Stage::Queue`] | worker pops the job (dur = queue wait) | — | — |
//! | [`Stage::Assemble`] | cohort gathered (dur = linger wait) | members | slabs (distinct conditionings) |
//! | [`Stage::CohortLink`] | per member of a multi-request cohort | member index | member rows |
//! | [`Stage::ModelEval`] | per solver step (trace level `steps`) | step index | batch rows |
//! | [`Stage::SolverStep`] | per solver step (trace level `steps`) | step index | batch rows |
//! | [`Stage::Quarantine`] | member failed inside a surviving cohort | member index | failure code |
//! | [`Stage::Retry`] | cohort re-run solo after a mid-batch panic | members re-run | — |
//! | [`Stage::Respond`] | terminal (dur = e2e) | `0` = ok, else failure code + 1 | NFE |
//!
//! `ModelEval`/`SolverStep` pairs split each planned step into model-eval
//! time vs. solver-kernel time — the paper's NFE-level efficiency claim
//! (UniC raises order with no extra model evaluations) made measurable
//! per request.
//!
//! # Cohort linkage
//!
//! A batched run mints a *cohort* id: the leader's `trace_id` for a
//! batch of one, a fresh id otherwise. Assemble and per-step events carry
//! the cohort id; each member emits a [`Stage::CohortLink`] event whose
//! `parent` is the cohort id, so one trace shows a single model evaluation
//! fanning across N requests.
//!
//! # Building span trees
//!
//! ```
//! use unipc::trace::{span_trees_json, SpanEvent, Stage};
//!
//! // A solo request: admit -> route/queue -> assemble -> respond, with one
//! // traced solver step. All events share trace_id 7 (cohort of one).
//! let events = vec![
//!     SpanEvent { trace_id: 7, stage: Stage::Admit, start_us: 0, dur_us: 2, a: 4, b: 8, ..Default::default() },
//!     SpanEvent { trace_id: 7, stage: Stage::Route, start_us: 40, a: 1, shard: 1, ..Default::default() },
//!     SpanEvent { trace_id: 7, stage: Stage::Queue, start_us: 0, dur_us: 40, shard: 1, ..Default::default() },
//!     SpanEvent { trace_id: 7, stage: Stage::Assemble, start_us: 40, dur_us: 5, a: 1, b: 1, shard: 1, ..Default::default() },
//!     SpanEvent { trace_id: 7, stage: Stage::ModelEval, start_us: 45, dur_us: 90, a: 0, b: 4, shard: 1, ..Default::default() },
//!     SpanEvent { trace_id: 7, stage: Stage::SolverStep, start_us: 135, dur_us: 10, a: 0, b: 4, shard: 1, ..Default::default() },
//!     SpanEvent { trace_id: 7, stage: Stage::Respond, start_us: 0, dur_us: 150, a: 0, b: 8, shard: 1, ..Default::default() },
//! ];
//! let trees = span_trees_json(&events, 16);
//! let traces = trees.get("traces").unwrap().as_arr().unwrap();
//! assert_eq!(traces.len(), 1);
//! let spans = traces[0].get("spans").unwrap().as_arr().unwrap();
//! assert_eq!(spans[0].get("stage").unwrap().as_str(), Some("admit"));
//! assert_eq!(spans.last().unwrap().get("stage").unwrap().as_str(), Some("respond"));
//! ```

use crate::json::Value;
use crate::solver::{Model, Prediction, StepObserver};
use crate::tensor::Tensor;
use std::cell::Cell;
use std::time::Instant;

/// How much the serving stack records per request.
///
/// The split digests (`model_eval_us` / `solver_us`) and response timing
/// fields are always maintained; the level only gates span *events*.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum TraceLevel {
    /// No span events recorded.
    Off,
    /// Lifecycle events only: admit, route, queue, assemble, cohort links,
    /// quarantine, retry, respond.
    #[default]
    Lifecycle,
    /// Lifecycle plus a `model_eval`/`solver_step` pair per planned step.
    Steps,
}

impl TraceLevel {
    /// Parse the wire/CLI spelling (`off` | `lifecycle` | `steps`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "off" => Some(Self::Off),
            "lifecycle" => Some(Self::Lifecycle),
            "steps" => Some(Self::Steps),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Self::Off => "off",
            Self::Lifecycle => "lifecycle",
            Self::Steps => "steps",
        }
    }

    /// Lifecycle events are recorded at this level.
    pub fn lifecycle(self) -> bool {
        self >= Self::Lifecycle
    }

    /// Per-step events are recorded at this level.
    pub fn steps(self) -> bool {
        self >= Self::Steps
    }
}

/// Lifecycle stage of a [`SpanEvent`]. See the module docs for the
/// per-stage meaning of the `a`/`b` detail fields.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Stage {
    #[default]
    Admit,
    Route,
    Queue,
    Assemble,
    /// Links a member request (`trace_id`) to its cohort (`parent`).
    CohortLink,
    ModelEval,
    SolverStep,
    Quarantine,
    Retry,
    Respond,
}

impl Stage {
    pub fn as_str(self) -> &'static str {
        match self {
            Self::Admit => "admit",
            Self::Route => "route",
            Self::Queue => "queue",
            Self::Assemble => "assemble",
            Self::CohortLink => "cohort",
            Self::ModelEval => "model_eval",
            Self::SolverStep => "solver_step",
            Self::Quarantine => "quarantine",
            Self::Retry => "retry",
            Self::Respond => "respond",
        }
    }
}

/// One recorded span. `Copy` and fixed-size so rings and scratch buffers
/// never allocate per event. Timestamps are microseconds relative to the
/// owning service's epoch (a monotonic `Instant` captured at startup).
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct SpanEvent {
    /// Request (or cohort) this event belongs to.
    pub trace_id: u64,
    /// Enclosing id (0 = none). Used by [`Stage::CohortLink`] to point a
    /// member request at its cohort, and by cohort-scoped events
    /// (assemble / per-step) to point back at the cohort id.
    pub parent: u64,
    pub stage: Stage,
    /// Shard the event was recorded on.
    pub shard: u32,
    /// Microseconds since the service epoch.
    pub start_us: u64,
    /// Span duration in microseconds (0 for instant events).
    pub dur_us: u64,
    /// Stage-specific detail (see module docs).
    pub a: u64,
    /// Stage-specific detail (see module docs).
    pub b: u64,
}

/// Fixed-capacity overwrite-oldest ring of [`SpanEvent`]s.
///
/// The backing store is allocated once at construction
/// (`vec![SpanEvent::default(); cap]`); [`TraceRing::record`] is a slot
/// write + cursor bump and never allocates or grows.
#[derive(Debug)]
pub struct TraceRing {
    slots: Vec<SpanEvent>,
    /// Next write position.
    head: usize,
    /// Total events ever recorded (>= slots.len() once the ring wraps).
    recorded: u64,
}

impl TraceRing {
    pub fn new(cap: usize) -> Self {
        Self { slots: vec![SpanEvent::default(); cap.max(1)], head: 0, recorded: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total events ever recorded (including overwritten ones).
    pub fn recorded(&self) -> u64 {
        self.recorded
    }

    /// Events lost to overwrite so far.
    pub fn dropped(&self) -> u64 {
        self.recorded.saturating_sub(self.slots.len() as u64)
    }

    /// Record one event, overwriting the oldest when full. Never allocates.
    pub fn record(&mut self, ev: SpanEvent) {
        self.slots[self.head] = ev;
        self.head = (self.head + 1) % self.slots.len();
        self.recorded += 1;
    }

    /// Copy every event from `scratch` into the ring (one call per batch
    /// run keeps lock hold times short). Never allocates.
    pub fn record_all(&mut self, scratch: &[SpanEvent]) {
        for &ev in scratch {
            self.record(ev);
        }
    }

    /// Retained events, oldest first. Allocates (snapshot path only).
    pub fn snapshot(&self) -> Vec<SpanEvent> {
        let n = self.recorded.min(self.slots.len() as u64) as usize;
        let mut out = Vec::with_capacity(n);
        // Oldest retained event sits at `head` once wrapped, at 0 before.
        let start = if self.recorded as usize > self.slots.len() { self.head } else { 0 };
        for i in 0..n {
            out.push(self.slots[(start + i) % self.slots.len()]);
        }
        out
    }
}

/// [`Model`] wrapper that accumulates wall-clock time spent inside
/// `eval` into a [`Cell`], attributing model-eval time separately from
/// solver-kernel time. Interposed by the coordinator on every run (two
/// `Instant` reads per evaluation — far below per-step solver work), it
/// feeds the `model_eval_us`/`solver_us` digests and, through
/// [`StepSpans`], the per-step span events.
pub struct TimedModel<'a> {
    inner: &'a dyn Model,
    eval_ns: Cell<u64>,
    evals: Cell<u64>,
}

impl<'a> TimedModel<'a> {
    pub fn new(inner: &'a dyn Model) -> Self {
        Self { inner, eval_ns: Cell::new(0), evals: Cell::new(0) }
    }

    /// Total wall-clock nanoseconds spent inside `eval` so far.
    pub fn eval_ns(&self) -> u64 {
        self.eval_ns.get()
    }

    /// Number of `eval` calls so far.
    pub fn evals(&self) -> u64 {
        self.evals.get()
    }
}

impl Model for TimedModel<'_> {
    fn prediction(&self) -> Prediction {
        self.inner.prediction()
    }

    fn eval(&self, x: &Tensor, t: f64) -> Tensor {
        let t0 = Instant::now();
        let out = self.inner.eval(x, t);
        self.eval_ns.set(self.eval_ns.get() + t0.elapsed().as_nanos() as u64);
        self.evals.set(self.evals.get() + 1);
        out
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }
}

/// Per-step span recorder: a [`StepObserver`] that, combined with a
/// [`TimedModel`], splits each planned step into a `model_eval` span and a
/// `solver_step` span pushed into a caller-owned scratch buffer.
///
/// The caller must reserve the scratch buffer up front
/// (`2 * plan_steps + slack`) — `on_step` only pushes, so steady-state
/// recording stays allocation-free.
pub struct StepSpans<'a> {
    out: &'a mut Vec<SpanEvent>,
    model_ns: &'a Cell<u64>,
    epoch: Instant,
    trace_id: u64,
    parent: u64,
    shard: u32,
    rows: u64,
    /// Wall-clock mark at the start of the current step segment.
    mark: Instant,
    /// `model_ns` reading at `mark`.
    mark_model_ns: u64,
}

impl<'a> StepSpans<'a> {
    /// Start observing. `trace_id` is the cohort id the step spans belong
    /// to, `parent` its enclosing id (0 for none), `rows` the stacked batch
    /// row count.
    pub fn new(
        out: &'a mut Vec<SpanEvent>,
        timed: &'a TimedModel<'_>,
        epoch: Instant,
        trace_id: u64,
        parent: u64,
        shard: u32,
        rows: u64,
    ) -> Self {
        let mark_model_ns = timed.eval_ns.get();
        Self {
            out,
            model_ns: &timed.eval_ns,
            epoch,
            trace_id,
            parent,
            shard,
            rows,
            mark: Instant::now(),
            mark_model_ns,
        }
    }
}

impl StepObserver for StepSpans<'_> {
    // `wants_health` stays at the default `false`: StepSpans is purely a
    // timing observer. The serving layer's `HealthSpans` wrapper
    // (`crate::telemetry`) opts in and forwards here.
    fn on_step(&mut self, k: usize, _health: &crate::solver::StepHealth) {
        let now = Instant::now();
        let seg_us = now.duration_since(self.mark).as_micros() as u64;
        let model_ns_now = self.model_ns.get();
        let model_us = (model_ns_now - self.mark_model_ns) / 1_000;
        let model_us = model_us.min(seg_us);
        let start_us =
            self.mark.checked_duration_since(self.epoch).map_or(0, |d| d.as_micros() as u64);
        self.out.push(SpanEvent {
            trace_id: self.trace_id,
            parent: self.parent,
            stage: Stage::ModelEval,
            shard: self.shard,
            start_us,
            dur_us: model_us,
            a: k as u64,
            b: self.rows,
        });
        self.out.push(SpanEvent {
            trace_id: self.trace_id,
            parent: self.parent,
            stage: Stage::SolverStep,
            shard: self.shard,
            start_us: start_us + model_us,
            dur_us: seg_us - model_us,
            a: k as u64,
            b: self.rows,
        });
        self.mark = now;
        self.mark_model_ns = model_ns_now;
    }
}

/// One span event as a JSON object with per-stage field naming (the same
/// shape `span_trees_json` embeds; also reused by the telemetry push
/// channel's NDJSON frames).
pub fn event_json(ev: &SpanEvent) -> Value {
    let mut pairs = vec![
        ("stage", Value::from(ev.stage.as_str())),
        ("start_us", Value::from(ev.start_us as f64)),
        ("dur_us", Value::from(ev.dur_us as f64)),
        ("shard", Value::from(ev.shard as f64)),
    ];
    if ev.parent != 0 {
        pairs.push(("parent", Value::from(ev.parent as f64)));
    }
    match ev.stage {
        Stage::Admit => {
            pairs.push(("rows", Value::from(ev.a as f64)));
            pairs.push(("steps", Value::from(ev.b as f64)));
        }
        Stage::Route => {
            pairs.push(("owner_shard", Value::from(ev.a as f64)));
            pairs.push((
                "stolen_by",
                if ev.b == 0 { Value::Null } else { Value::from((ev.b - 1) as f64) },
            ));
        }
        Stage::Queue => {}
        Stage::Assemble => {
            pairs.push(("members", Value::from(ev.a as f64)));
            pairs.push(("slabs", Value::from(ev.b as f64)));
        }
        Stage::CohortLink => {
            pairs.push(("member", Value::from(ev.a as f64)));
            pairs.push(("rows", Value::from(ev.b as f64)));
        }
        Stage::ModelEval | Stage::SolverStep => {
            pairs.push(("step", Value::from(ev.a as f64)));
            pairs.push(("rows", Value::from(ev.b as f64)));
        }
        Stage::Quarantine => {
            pairs.push(("member", Value::from(ev.a as f64)));
            pairs.push(("kind_code", Value::from(ev.b as f64)));
        }
        Stage::Retry => {
            pairs.push(("members", Value::from(ev.a as f64)));
        }
        Stage::Respond => {
            pairs.push(("ok", Value::Bool(ev.a == 0)));
            if ev.a != 0 {
                pairs.push(("kind_code", Value::from((ev.a - 1) as f64)));
            }
            pairs.push(("nfe", Value::from(ev.b as f64)));
        }
    }
    Value::obj(pairs)
}

/// Assemble flat span events into per-request span trees.
///
/// Roots are trace ids that carry an [`Stage::Admit`] event; the most
/// recent `limit` roots (by admit time) are returned, oldest first. Each
/// tree lists the request's own spans sorted by `start_us` and, when the
/// request rode a multi-member cohort, a `cohort` object embedding the
/// cohort-scoped spans (assemble, per-step pairs, retry) plus the member
/// trace ids.
pub fn span_trees_json(events: &[SpanEvent], limit: usize) -> Value {
    // Roots, in admit order.
    let mut roots: Vec<(u64, u64)> = events
        .iter()
        .filter(|e| e.stage == Stage::Admit)
        .map(|e| (e.start_us, e.trace_id))
        .collect();
    roots.sort_unstable();
    let skip = roots.len().saturating_sub(limit);
    let roots = &roots[skip..];

    let trees: Vec<Value> = roots
        .iter()
        .map(|&(_, id)| {
            let mut own: Vec<&SpanEvent> = events.iter().filter(|e| e.trace_id == id).collect();
            // Time order, except the terminal respond sorts by its *end*
            // (it starts back at enqueue time, covering the whole e2e
            // window) so trees always read admit-first, respond-last.
            own.sort_by_key(|e| {
                let at = if e.stage == Stage::Respond { e.start_us + e.dur_us } else { e.start_us };
                (at, e.stage as usize)
            });
            // A CohortLink event points at the enclosing multi-member cohort.
            let cohort_id = own
                .iter()
                .find(|e| e.stage == Stage::CohortLink)
                .map(|e| e.parent)
                .filter(|&c| c != id && c != 0);
            let mut pairs = vec![
                ("trace_id", Value::from(id as f64)),
                ("spans", Value::Arr(own.iter().map(|e| event_json(e)).collect())),
            ];
            if let Some(cid) = cohort_id {
                let mut cohort_spans: Vec<&SpanEvent> =
                    events.iter().filter(|e| e.trace_id == cid).collect();
                cohort_spans.sort_by_key(|e| (e.start_us, e.stage as usize));
                let mut members: Vec<f64> = events
                    .iter()
                    .filter(|e| e.stage == Stage::CohortLink && e.parent == cid)
                    .map(|e| e.trace_id as f64)
                    .collect();
                members.sort_by(f64::total_cmp);
                members.dedup();
                pairs.push((
                    "cohort",
                    Value::obj(vec![
                        ("cohort_id", Value::from(cid as f64)),
                        ("members", Value::Arr(members.into_iter().map(Value::Num).collect())),
                        ("spans", Value::Arr(cohort_spans.iter().map(|e| event_json(e)).collect())),
                    ]),
                ));
            }
            Value::obj(pairs)
        })
        .collect();
    Value::obj(vec![("traces", Value::Arr(trees))])
}

/// Export flat span events in Chrome `trace_event` format (the JSON Array
/// Format with metadata), loadable at `chrome://tracing` or
/// <https://ui.perfetto.dev>. Complete events (`"ph":"X"`) with `ts`/`dur`
/// in microseconds; `pid` is the shard, `tid` the trace (or cohort) id.
pub fn chrome_trace_json(events: &[SpanEvent]) -> Value {
    let rows: Vec<Value> = events
        .iter()
        .map(|e| {
            let args = event_json(e);
            Value::obj(vec![
                ("name", Value::from(e.stage.as_str())),
                ("cat", Value::from("serving")),
                ("ph", Value::from("X")),
                ("ts", Value::from(e.start_us as f64)),
                ("dur", Value::from(e.dur_us.max(1) as f64)),
                ("pid", Value::from(e.shard as f64)),
                ("tid", Value::from(e.trace_id as f64)),
                ("args", args),
            ])
        })
        .collect();
    Value::obj(vec![
        ("traceEvents", Value::Arr(rows)),
        ("displayTimeUnit", Value::from("ms")),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(trace_id: u64, stage: Stage, start_us: u64) -> SpanEvent {
        SpanEvent { trace_id, stage, start_us, ..Default::default() }
    }

    #[test]
    fn trace_level_parse_roundtrip_and_gating() {
        for lvl in [TraceLevel::Off, TraceLevel::Lifecycle, TraceLevel::Steps] {
            assert_eq!(TraceLevel::parse(lvl.as_str()), Some(lvl));
        }
        assert_eq!(TraceLevel::parse("verbose"), None);
        assert!(!TraceLevel::Off.lifecycle());
        assert!(TraceLevel::Lifecycle.lifecycle());
        assert!(!TraceLevel::Lifecycle.steps());
        assert!(TraceLevel::Steps.steps());
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let mut ring = TraceRing::new(4);
        assert_eq!(ring.capacity(), 4);
        for i in 0..6u64 {
            ring.record(ev(i, Stage::Admit, i));
        }
        assert_eq!(ring.recorded(), 6);
        assert_eq!(ring.dropped(), 2);
        let snap = ring.snapshot();
        let ids: Vec<u64> = snap.iter().map(|e| e.trace_id).collect();
        assert_eq!(ids, vec![2, 3, 4, 5], "oldest two overwritten, order preserved");
    }

    #[test]
    fn ring_snapshot_before_wrap_is_prefix() {
        let mut ring = TraceRing::new(8);
        ring.record_all(&[ev(1, Stage::Admit, 0), ev(1, Stage::Respond, 9)]);
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].stage, Stage::Admit);
        assert_eq!(ring.dropped(), 0);
    }

    #[test]
    fn span_trees_group_by_root_and_respect_limit() {
        let events = vec![
            ev(1, Stage::Admit, 0),
            ev(1, Stage::Respond, 50),
            ev(2, Stage::Admit, 10),
            ev(2, Stage::Respond, 60),
            // Orphan (no admit retained): must not become a root.
            ev(9, Stage::Respond, 70),
        ];
        let all = span_trees_json(&events, 16);
        let traces = all.get("traces").unwrap().as_arr().unwrap();
        assert_eq!(traces.len(), 2);
        assert_eq!(traces[0].get("trace_id").unwrap().as_f64(), Some(1.0));
        let last_only = span_trees_json(&events, 1);
        let traces = last_only.get("traces").unwrap().as_arr().unwrap();
        assert_eq!(traces.len(), 1);
        assert_eq!(traces[0].get("trace_id").unwrap().as_f64(), Some(2.0), "limit keeps newest");
    }

    #[test]
    fn cohort_subtree_embeds_shared_spans_and_members() {
        let cohort = 100u64;
        let mut events = Vec::new();
        for id in [1u64, 2] {
            events.push(ev(id, Stage::Admit, id));
            events.push(SpanEvent {
                trace_id: id,
                parent: cohort,
                stage: Stage::CohortLink,
                a: id - 1,
                b: 4,
                start_us: 20,
                ..Default::default()
            });
            events.push(ev(id, Stage::Respond, 90));
        }
        events.push(SpanEvent {
            trace_id: cohort,
            stage: Stage::Assemble,
            start_us: 15,
            dur_us: 5,
            a: 2,
            b: 1,
            ..Default::default()
        });
        events.push(SpanEvent {
            trace_id: cohort,
            stage: Stage::ModelEval,
            start_us: 20,
            dur_us: 30,
            b: 8,
            ..Default::default()
        });
        let trees = span_trees_json(&events, 16);
        let traces = trees.get("traces").unwrap().as_arr().unwrap();
        assert_eq!(traces.len(), 2, "cohort id itself is not a root");
        for t in traces {
            let c = t.get("cohort").expect("member must embed its cohort");
            assert_eq!(c.get("cohort_id").unwrap().as_f64(), Some(100.0));
            let members = c.get("members").unwrap().as_arr().unwrap();
            assert_eq!(members.len(), 2);
            let spans = c.get("spans").unwrap().as_arr().unwrap();
            assert_eq!(spans[0].get("stage").unwrap().as_str(), Some("assemble"));
            assert_eq!(spans[1].get("stage").unwrap().as_str(), Some("model_eval"));
        }
    }

    #[test]
    fn chrome_export_is_valid_trace_event_json() {
        let events =
            vec![ev(1, Stage::Admit, 0), ev(1, Stage::Respond, 50), ev(2, Stage::Queue, 5)];
        let v = chrome_trace_json(&events);
        let s = v.to_string();
        let parsed = crate::json::parse(&s).unwrap();
        let rows = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 3);
        for r in rows {
            assert_eq!(r.get("ph").unwrap().as_str(), Some("X"));
            assert!(r.get("ts").unwrap().as_f64().is_some());
            assert!(r.get("dur").unwrap().as_f64().unwrap() >= 1.0);
            assert!(r.get("args").is_some());
        }
    }

    /// Identity "model" that burns a little wall time per eval.
    fn toy_model() -> impl Model {
        (Prediction::Noise, 2usize, |x: &Tensor, _t: f64| {
            std::thread::sleep(std::time::Duration::from_micros(200));
            x.clone()
        })
    }

    #[test]
    fn timed_model_accumulates_eval_time() {
        let model = toy_model();
        let timed = TimedModel::new(&model);
        let x = Tensor::zeros(&[1, timed.dim()]);
        assert_eq!(timed.evals(), 0);
        let _ = timed.eval(&x, 0.5);
        let _ = timed.eval(&x, 0.4);
        assert_eq!(timed.evals(), 2);
        assert!(timed.eval_ns() > 0, "two evals must accumulate nonzero wall time");
        assert_eq!(timed.prediction(), model.prediction());
    }

    #[test]
    fn step_spans_emit_a_pair_per_step_with_exclusive_solver_time() {
        let model = toy_model();
        let timed = TimedModel::new(&model);
        let epoch = Instant::now();
        let mut out = Vec::with_capacity(8);
        let x = Tensor::zeros(&[1, timed.dim()]);
        let mut spans = StepSpans::new(&mut out, &timed, epoch, 42, 0, 3, 1);
        let health = crate::solver::StepHealth::default();
        let _ = timed.eval(&x, 0.9);
        spans.on_step(0, &health);
        let _ = timed.eval(&x, 0.5);
        spans.on_step(1, &health);
        assert_eq!(out.len(), 4);
        for (i, pair) in out.chunks(2).enumerate() {
            assert_eq!(pair[0].stage, Stage::ModelEval);
            assert_eq!(pair[1].stage, Stage::SolverStep);
            assert_eq!(pair[0].a, i as u64);
            assert_eq!(pair[0].trace_id, 42);
            assert_eq!(pair[0].shard, 3);
            // The pair tiles the step segment: solver starts where model ends.
            assert_eq!(pair[1].start_us, pair[0].start_us + pair[0].dur_us);
        }
        // Steps are contiguous segments: step 1 starts at or after step 0's end.
        assert!(out[2].start_us >= out[0].start_us + out[0].dur_us + out[1].dur_us);
    }
}
