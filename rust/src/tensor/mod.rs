//! Minimal host tensor substrate.
//!
//! The offline registry carries no ndarray-style crate, so the solver hot
//! path runs on this small, contiguous, row-major `f64` tensor. Double
//! precision matters here: the paper's order-of-accuracy experiments measure
//! local errors down to `O(h^5)`, which is below the `f32` noise floor.
//! Conversion to/from `f32` happens only at the PJRT boundary
//! ([`crate::runtime`]).
//!
//! Two families of kernels serve the solver hot path:
//!
//! * **In-place step kernels** ([`Tensor::assign_lincomb`],
//!   [`Tensor::assign_sub_scaled`], [`weighted_sum_into`], …) — the
//!   zero-allocation arithmetic behind plan-executed UniPC steps. Each is
//!   bit-identical to its allocating counterpart.
//! * **Batch-axis kernels** ([`Tensor::resize_to`],
//!   [`Tensor::copy_rows_from`]) — assembly and workspace pooling for the
//!   serving layer's lockstep request batching: member states stack into one
//!   batch-major tensor, and pooled buffers change batch size without
//!   reallocating. Every elementwise kernel is row-independent, which is
//!   what makes batched execution bit-identical to per-request execution.

use std::fmt;

/// A contiguous, row-major, `f64` tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f64>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 8 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

impl Tensor {
    /// A tensor of zeros with the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// A tensor filled with `v`.
    pub fn full(shape: &[usize], v: f64) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    /// Build from raw data; `data.len()` must equal the shape product.
    pub fn from_vec(shape: &[usize], data: Vec<f64>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} incompatible with data length {}",
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    /// 1-D tensor from a slice.
    pub fn from_slice(data: &[f64]) -> Self {
        Tensor { shape: vec![data.len()], data: data.to_vec() }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Leading dimension (batch size for `[n, d]` tensors).
    pub fn batch(&self) -> usize {
        *self.shape.first().unwrap_or(&0)
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshaped(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// A zero tensor with this tensor's shape.
    pub fn zeros_like(&self) -> Self {
        Tensor::zeros(&self.shape)
    }

    /// Row `i` of a 2-D `[n, d]` tensor.
    pub fn row(&self, i: usize) -> &[f64] {
        assert_eq!(self.shape.len(), 2);
        let d = self.shape[1];
        &self.data[i * d..(i + 1) * d]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        assert_eq!(self.shape.len(), 2);
        let d = self.shape[1];
        &mut self.data[i * d..(i + 1) * d]
    }

    /// Whether rows `start..start + len` of a 2-D `[n, d]` tensor contain
    /// only finite values. This is the serving layer's per-member output
    /// check on a stacked batch tensor: each request's row range is
    /// validated independently, so one member's NaN/Inf cannot fail its
    /// batch cohort.
    pub fn rows_finite(&self, start: usize, len: usize) -> bool {
        assert_eq!(self.shape.len(), 2, "rows_finite needs a 2-D tensor");
        assert!(start + len <= self.shape[0], "row range out of bounds");
        let d = self.shape[1];
        self.data[start * d..(start + len) * d].iter().all(|v| v.is_finite())
    }

    /// `self <- a * self`.
    pub fn scale(&mut self, a: f64) {
        for v in &mut self.data {
            *v *= a;
        }
    }

    /// `self <- self + a * other` (shapes must match).
    pub fn axpy(&mut self, a: f64, other: &Tensor) {
        assert_eq!(self.shape, other.shape, "axpy shape mismatch");
        for (v, o) in self.data.iter_mut().zip(&other.data) {
            *v += a * o;
        }
    }

    /// `a * x + b * y` as a new tensor.
    pub fn lincomb(a: f64, x: &Tensor, b: f64, y: &Tensor) -> Tensor {
        assert_eq!(x.shape, y.shape, "lincomb shape mismatch");
        let data = x
            .data
            .iter()
            .zip(&y.data)
            .map(|(xv, yv)| a * xv + b * yv)
            .collect();
        Tensor { shape: x.shape.clone(), data }
    }

    /// `self <- a * x + b * y`, reusing this tensor's buffer (the
    /// zero-allocation mirror of [`Tensor::lincomb`]; same accumulation
    /// order, so results are bit-identical).
    pub fn assign_lincomb(&mut self, a: f64, x: &Tensor, b: f64, y: &Tensor) {
        assert_eq!(x.shape, y.shape, "lincomb shape mismatch");
        assert_eq!(self.shape, x.shape, "assign_lincomb output shape mismatch");
        for ((o, xv), yv) in self.data.iter_mut().zip(&x.data).zip(&y.data) {
            *o = a * xv + b * yv;
        }
    }

    /// Fused `(x − y) * s` as a new tensor: one traversal instead of the
    /// sub-then-scale pair (bit-identical to it, since `1·a + (−1)·b` and
    /// `a − b` round the same way).
    pub fn sub_scaled(x: &Tensor, y: &Tensor, s: f64) -> Tensor {
        assert_eq!(x.shape, y.shape, "sub_scaled shape mismatch");
        let data = x
            .data
            .iter()
            .zip(&y.data)
            .map(|(xv, yv)| (xv - yv) * s)
            .collect();
        Tensor { shape: x.shape.clone(), data }
    }

    /// `self <- (x − y) * s`, reusing this tensor's buffer (workspace form
    /// of [`Tensor::sub_scaled`]; the solver's D_m/r_m rows).
    pub fn assign_sub_scaled(&mut self, x: &Tensor, y: &Tensor, s: f64) {
        assert_eq!(x.shape, y.shape, "sub_scaled shape mismatch");
        assert_eq!(self.shape, x.shape, "assign_sub_scaled output shape mismatch");
        for ((o, xv), yv) in self.data.iter_mut().zip(&x.data).zip(&y.data) {
            *o = (xv - yv) * s;
        }
    }

    /// `self <- x − y`, reusing this tensor's buffer.
    pub fn assign_sub(&mut self, x: &Tensor, y: &Tensor) {
        assert_eq!(x.shape, y.shape, "sub shape mismatch");
        assert_eq!(self.shape, x.shape, "assign_sub output shape mismatch");
        for ((o, xv), yv) in self.data.iter_mut().zip(&x.data).zip(&y.data) {
            *o = xv - yv;
        }
    }

    /// `self <- x * a`, reusing this tensor's buffer (workspace form of
    /// [`Tensor::scaled`]; same multiplication order, so results are
    /// bit-identical).
    pub fn assign_scaled(&mut self, x: &Tensor, a: f64) {
        assert_eq!(self.shape, x.shape, "assign_scaled shape mismatch");
        for (o, xv) in self.data.iter_mut().zip(&x.data) {
            *o = xv * a;
        }
    }

    /// `self <- x` without allocating (shapes must match).
    pub fn copy_from(&mut self, x: &Tensor) {
        assert_eq!(self.shape, x.shape, "copy_from shape mismatch");
        self.data.copy_from_slice(&x.data);
    }

    /// Reshape in place, reusing the existing allocation whenever the new
    /// element count fits the buffer's capacity. This is the
    /// workspace-pooling primitive behind the batched serving path: one
    /// buffer serves runs of varying batch size without returning to the
    /// allocator. Surviving elements keep their values, newly exposed
    /// elements are zero. Returns `true` when no reallocation was needed.
    pub fn resize_to(&mut self, shape: &[usize]) -> bool {
        let n: usize = shape.iter().product();
        let reused = n <= self.data.capacity();
        self.data.resize(n, 0.0);
        self.shape.clear();
        self.shape.extend_from_slice(shape);
        reused
    }

    /// Copy every row of 2-D `src` into rows `[at, at + src_rows)` of this
    /// 2-D tensor — the in-place, batch-axis counterpart of
    /// [`Tensor::concat_rows`]. Batched runs assemble member states into one
    /// batch-major tensor with repeated calls, allocation-free.
    pub fn copy_rows_from(&mut self, at: usize, src: &Tensor) {
        assert_eq!(self.shape.len(), 2, "copy_rows_from expects [n, d] destination");
        assert_eq!(src.shape.len(), 2, "copy_rows_from expects [n, d] source");
        assert_eq!(self.shape[1], src.shape[1], "copy_rows_from width mismatch");
        let (d, rows) = (self.shape[1], src.shape[0]);
        assert!(
            at + rows <= self.shape[0],
            "copy_rows_from rows {}..{} out of range for {} rows",
            at,
            at + rows,
            self.shape[0]
        );
        self.data[at * d..(at + rows) * d].copy_from_slice(&src.data);
    }

    /// Elementwise difference `self - other` as a new tensor.
    pub fn sub(&self, other: &Tensor) -> Tensor {
        Tensor::lincomb(1.0, self, -1.0, other)
    }

    /// Elementwise sum `self + other` as a new tensor.
    pub fn add(&self, other: &Tensor) -> Tensor {
        Tensor::lincomb(1.0, self, 1.0, other)
    }

    /// Scaled copy `a * self`.
    pub fn scaled(&self, a: f64) -> Tensor {
        let mut t = self.clone();
        t.scale(a);
        t
    }

    /// l2 norm of the flattened tensor.
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Root-mean-square of the flattened tensor (`‖x‖₂ / √D`).
    pub fn rms(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.norm() / (self.data.len() as f64).sqrt()
    }

    /// Max |x_i|.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, v| m.max(v.abs()))
    }

    /// Mean of all elements.
    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f64>() / self.data.len() as f64
    }

    /// Clamp every element into `[lo, hi]`.
    pub fn clamp(&mut self, lo: f64, hi: f64) {
        for v in &mut self.data {
            *v = v.clamp(lo, hi);
        }
    }

    /// Concatenate 2-D tensors along the batch (first) axis.
    pub fn concat_rows(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let d = parts[0].shape[1];
        let mut data = Vec::new();
        let mut n = 0;
        for p in parts {
            assert_eq!(p.shape.len(), 2);
            assert_eq!(p.shape[1], d, "concat_rows feature-dim mismatch");
            n += p.shape[0];
            data.extend_from_slice(&p.data);
        }
        Tensor { shape: vec![n, d], data }
    }

    /// Extract rows `[start, start+len)` of a 2-D tensor as a new tensor.
    pub fn slice_rows(&self, start: usize, len: usize) -> Tensor {
        assert_eq!(self.shape.len(), 2);
        let d = self.shape[1];
        let data = self.data[start * d..(start + len) * d].to_vec();
        Tensor { shape: vec![len, d], data }
    }

    /// Lossy conversion to `f32` (PJRT boundary).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }

    /// Build from `f32` data (PJRT boundary).
    pub fn from_f32(shape: &[usize], data: &[f32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data: data.iter().map(|&v| v as f64).collect() }
    }
}

/// `Σ_m c_m * ts[m]` — the UniPC residual combination `Σ a_m D_m / r_m`
/// evaluated in a single fused pass: one read per input element, one write,
/// with small arities (the common p ≤ 4) fully unrolled so the compiler
/// vectorizes a single loop instead of re-traversing the output per
/// coefficient. This is the L3 mirror of the Pallas `unipc_update` kernel;
/// the before/after is recorded in EXPERIMENTS.md §Perf-L3.
pub fn weighted_sum(coeffs: &[f64], ts: &[&Tensor]) -> Tensor {
    assert_eq!(coeffs.len(), ts.len());
    assert!(!ts.is_empty(), "weighted_sum of zero tensors");
    let shape = ts[0].shape().to_vec();
    let n = ts[0].len();
    for t in ts {
        assert_eq!(t.shape(), &shape[..], "weighted_sum shape mismatch");
    }
    let mut out = Vec::with_capacity(n);
    match ts.len() {
        1 => {
            let (c0, a) = (coeffs[0], ts[0].data());
            out.extend(a.iter().map(|&x| c0 * x));
        }
        2 => {
            let (c0, c1) = (coeffs[0], coeffs[1]);
            let (a, b) = (ts[0].data(), ts[1].data());
            out.extend((0..n).map(|i| c0 * a[i] + c1 * b[i]));
        }
        3 => {
            let (c0, c1, c2) = (coeffs[0], coeffs[1], coeffs[2]);
            let (a, b, c) = (ts[0].data(), ts[1].data(), ts[2].data());
            out.extend((0..n).map(|i| c0 * a[i] + c1 * b[i] + c2 * c[i]));
        }
        4 => {
            let (c0, c1, c2, c3) = (coeffs[0], coeffs[1], coeffs[2], coeffs[3]);
            let (a, b, c, d) = (ts[0].data(), ts[1].data(), ts[2].data(), ts[3].data());
            out.extend((0..n).map(|i| c0 * a[i] + c1 * b[i] + c2 * c[i] + c3 * d[i]));
        }
        5 => {
            let (c0, c1, c2, c3, c4) =
                (coeffs[0], coeffs[1], coeffs[2], coeffs[3], coeffs[4]);
            let (a, b, c, d, e) =
                (ts[0].data(), ts[1].data(), ts[2].data(), ts[3].data(), ts[4].data());
            out.extend(
                (0..n).map(|i| c0 * a[i] + c1 * b[i] + c2 * c[i] + c3 * d[i] + c4 * e[i]),
            );
        }
        6 => {
            let (c0, c1, c2, c3, c4, c5) =
                (coeffs[0], coeffs[1], coeffs[2], coeffs[3], coeffs[4], coeffs[5]);
            let (a, b, c, d, e, f) = (
                ts[0].data(),
                ts[1].data(),
                ts[2].data(),
                ts[3].data(),
                ts[4].data(),
                ts[5].data(),
            );
            out.extend((0..n).map(|i| {
                c0 * a[i] + c1 * b[i] + c2 * c[i] + c3 * d[i] + c4 * e[i] + c5 * f[i]
            }));
        }
        _ => {
            out.resize(n, 0.0);
            for (&cm, t) in coeffs.iter().zip(ts) {
                if cm == 0.0 {
                    continue;
                }
                let src = t.data();
                for i in 0..n {
                    out[i] += cm * src[i];
                }
            }
        }
    }
    Tensor { shape, data: out }
}

impl AsRef<Tensor> for Tensor {
    fn as_ref(&self) -> &Tensor {
        self
    }
}

/// In-place variant of [`weighted_sum`]: writes `Σ_m c_m * ts[m]` into
/// `out`'s existing buffer — zero allocations, for the plan-executed step
/// path where `ts` are workspace rows. The unrolled fast paths use the same
/// accumulation order as [`weighted_sum`], so results are bit-identical.
///
/// Generic over `&[Tensor]` (workspace rows) and `&[&Tensor]` (borrowed
/// history outputs) so plan-executed steps can combine either without
/// collecting an intermediate `Vec`.
pub fn weighted_sum_into<T: AsRef<Tensor>>(out: &mut Tensor, coeffs: &[f64], ts: &[T]) {
    assert_eq!(coeffs.len(), ts.len());
    assert!(!ts.is_empty(), "weighted_sum_into of zero tensors");
    let first = ts[0].as_ref();
    let n = first.len();
    assert_eq!(out.shape(), first.shape(), "weighted_sum_into output shape mismatch");
    for t in ts {
        assert_eq!(t.as_ref().shape(), first.shape(), "weighted_sum_into shape mismatch");
    }
    let o = out.data_mut();
    match ts.len() {
        1 => {
            let (c0, a) = (coeffs[0], ts[0].as_ref().data());
            for i in 0..n {
                o[i] = c0 * a[i];
            }
        }
        2 => {
            let (c0, c1) = (coeffs[0], coeffs[1]);
            let (a, b) = (ts[0].as_ref().data(), ts[1].as_ref().data());
            for i in 0..n {
                o[i] = c0 * a[i] + c1 * b[i];
            }
        }
        3 => {
            let (c0, c1, c2) = (coeffs[0], coeffs[1], coeffs[2]);
            let (a, b, c) =
                (ts[0].as_ref().data(), ts[1].as_ref().data(), ts[2].as_ref().data());
            for i in 0..n {
                o[i] = c0 * a[i] + c1 * b[i] + c2 * c[i];
            }
        }
        4 => {
            let (c0, c1, c2, c3) = (coeffs[0], coeffs[1], coeffs[2], coeffs[3]);
            let (a, b, c, d) = (
                ts[0].as_ref().data(),
                ts[1].as_ref().data(),
                ts[2].as_ref().data(),
                ts[3].as_ref().data(),
            );
            for i in 0..n {
                o[i] = c0 * a[i] + c1 * b[i] + c2 * c[i] + c3 * d[i];
            }
        }
        5 => {
            let (c0, c1, c2, c3, c4) =
                (coeffs[0], coeffs[1], coeffs[2], coeffs[3], coeffs[4]);
            let (a, b, c, d, e) = (
                ts[0].as_ref().data(),
                ts[1].as_ref().data(),
                ts[2].as_ref().data(),
                ts[3].as_ref().data(),
                ts[4].as_ref().data(),
            );
            for i in 0..n {
                o[i] = c0 * a[i] + c1 * b[i] + c2 * c[i] + c3 * d[i] + c4 * e[i];
            }
        }
        6 => {
            let (c0, c1, c2, c3, c4, c5) =
                (coeffs[0], coeffs[1], coeffs[2], coeffs[3], coeffs[4], coeffs[5]);
            let (a, b, c, d, e, f) = (
                ts[0].as_ref().data(),
                ts[1].as_ref().data(),
                ts[2].as_ref().data(),
                ts[3].as_ref().data(),
                ts[4].as_ref().data(),
                ts[5].as_ref().data(),
            );
            for i in 0..n {
                o[i] = c0 * a[i] + c1 * b[i] + c2 * c[i] + c3 * d[i] + c4 * e[i] + c5 * f[i];
            }
        }
        _ => {
            for v in o.iter_mut() {
                *v = 0.0;
            }
            for (&cm, t) in coeffs.iter().zip(ts) {
                if cm == 0.0 {
                    continue;
                }
                let src = t.as_ref().data();
                for i in 0..n {
                    o[i] += cm * src[i];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_full_from_vec() {
        let z = Tensor::zeros(&[2, 3]);
        assert_eq!(z.len(), 6);
        assert_eq!(z.shape(), &[2, 3]);
        let f = Tensor::full(&[2], 1.5);
        assert_eq!(f.data(), &[1.5, 1.5]);
        let v = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(v.row(1), &[3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "incompatible")]
    fn from_vec_shape_mismatch_panics() {
        let _ = Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn rows_finite_checks_only_the_requested_range() {
        let mut t = Tensor::zeros(&[4, 3]);
        t.row_mut(2)[1] = f64::NAN;
        assert!(!t.rows_finite(0, 4));
        assert!(t.rows_finite(0, 2), "rows before the NaN are finite");
        assert!(!t.rows_finite(2, 1), "the NaN row is flagged");
        assert!(t.rows_finite(3, 1), "rows after the NaN are finite");
        t.row_mut(2)[1] = f64::INFINITY;
        assert!(!t.rows_finite(1, 2), "Inf is non-finite too");
        assert!(t.rows_finite(4, 0), "empty range at the end is fine");
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn rows_finite_rejects_out_of_range() {
        let t = Tensor::zeros(&[2, 2]);
        let _ = t.rows_finite(1, 2);
    }

    #[test]
    fn axpy_lincomb_sub() {
        let mut x = Tensor::from_slice(&[1.0, 2.0]);
        let y = Tensor::from_slice(&[10.0, 20.0]);
        x.axpy(0.5, &y);
        assert_eq!(x.data(), &[6.0, 12.0]);
        let l = Tensor::lincomb(2.0, &x, -1.0, &y);
        assert_eq!(l.data(), &[2.0, 4.0]);
        let s = y.sub(&y);
        assert_eq!(s.data(), &[0.0, 0.0]);
    }

    #[test]
    fn norms() {
        let x = Tensor::from_slice(&[3.0, 4.0]);
        assert!((x.norm() - 5.0).abs() < 1e-12);
        assert!((x.rms() - 5.0 / 2f64.sqrt()).abs() < 1e-12);
        assert_eq!(x.max_abs(), 4.0);
        assert!((x.mean() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn concat_and_slice_rows() {
        let a = Tensor::from_vec(&[1, 2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[2, 2], vec![3.0, 4.0, 5.0, 6.0]);
        let c = Tensor::concat_rows(&[&a, &b]);
        assert_eq!(c.shape(), &[3, 2]);
        assert_eq!(c.row(2), &[5.0, 6.0]);
        let s = c.slice_rows(1, 2);
        assert_eq!(s.data(), &[3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn weighted_sum_matches_manual() {
        let a = Tensor::from_slice(&[1.0, 0.0]);
        let b = Tensor::from_slice(&[0.0, 1.0]);
        let w = weighted_sum(&[2.0, -3.0], &[&a, &b]);
        assert_eq!(w.data(), &[2.0, -3.0]);
    }

    #[test]
    fn weighted_sum_all_arities_match_generic() {
        // The unrolled fast paths (1..=6) and the generic loop must agree;
        // `weighted_sum_into` must be bit-identical to `weighted_sum`.
        let ts: Vec<Tensor> = (0..7)
            .map(|k| {
                Tensor::from_slice(
                    &(0..5).map(|i| ((k * 5 + i) as f64).sin()).collect::<Vec<_>>(),
                )
            })
            .collect();
        let coeffs = [0.4, -0.2, 0.1, 0.05, -0.03, 0.02, 0.7];
        for q in 1..=7usize {
            let refs: Vec<&Tensor> = ts[..q].iter().collect();
            let fused = weighted_sum(&coeffs[..q], &refs);
            // Generic reference: per-coefficient accumulation passes.
            let mut acc = ts[0].scaled(coeffs[0]);
            for m in 1..q {
                acc.axpy(coeffs[m], &ts[m]);
            }
            for (f, g) in fused.data().iter().zip(acc.data()) {
                assert!((f - g).abs() < 1e-14, "arity {q}: {f} vs {g}");
            }
            let mut out = Tensor::zeros(&[5]);
            weighted_sum_into(&mut out, &coeffs[..q], &ts[..q]);
            for (a, b) in out.data().iter().zip(fused.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "arity {q} into mismatch");
            }
        }
    }

    #[test]
    fn assign_kernels_match_allocating_forms() {
        let x = Tensor::from_slice(&[1.0, -2.0, 3.5]);
        let y = Tensor::from_slice(&[0.5, 4.0, -1.25]);
        let mut out = Tensor::zeros(&[3]);

        out.assign_lincomb(2.0, &x, -0.5, &y);
        let expect = Tensor::lincomb(2.0, &x, -0.5, &y);
        assert_eq!(out, expect);

        out.assign_sub(&x, &y);
        assert_eq!(out, x.sub(&y));

        out.assign_sub_scaled(&x, &y, 0.25);
        let mut ref_d = x.sub(&y);
        ref_d.scale(0.25);
        for (a, b) in out.data().iter().zip(ref_d.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(out, Tensor::sub_scaled(&x, &y, 0.25));

        out.assign_scaled(&x, -1.5);
        let scaled = x.scaled(-1.5);
        for (a, b) in out.data().iter().zip(scaled.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }

        out.copy_from(&y);
        assert_eq!(out, y);
    }

    #[test]
    fn weighted_sum_into_accepts_owned_and_borrowed_slices() {
        // The plan executor combines workspace rows (`&[Tensor]`) and
        // borrowed history outputs (`&[&Tensor]`); both must produce the
        // same bits as the allocating `weighted_sum`.
        let ts: Vec<Tensor> = (0..4)
            .map(|k| Tensor::from_slice(&[(k as f64) + 0.5, -(k as f64) * 0.3, 1.0 / (k as f64 + 1.0)]))
            .collect();
        let coeffs = [0.7, -0.4, 0.2, 1.1];
        let refs: Vec<&Tensor> = ts.iter().collect();
        let expect = weighted_sum(&coeffs, &refs);

        let mut out_owned = Tensor::zeros(&[3]);
        weighted_sum_into(&mut out_owned, &coeffs, &ts[..]);
        let mut out_borrowed = Tensor::zeros(&[3]);
        weighted_sum_into(&mut out_borrowed, &coeffs, &refs[..]);
        for ((a, b), e) in out_owned.data().iter().zip(out_borrowed.data()).zip(expect.data()) {
            assert_eq!(a.to_bits(), e.to_bits());
            assert_eq!(b.to_bits(), e.to_bits());
        }
    }

    #[test]
    fn resize_to_reuses_capacity() {
        let mut t = Tensor::from_vec(&[4, 3], (0..12).map(|v| v as f64).collect());
        assert!(t.resize_to(&[2, 3]), "shrink must reuse the allocation");
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.data(), &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!(t.resize_to(&[4, 3]), "regrow within capacity must reuse");
        assert_eq!(t.len(), 12);
        // Surviving elements keep values, re-exposed ones are zeroed.
        assert_eq!(t.data()[..6], [0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(t.data()[6..], [0.0; 6]);
        assert!(!t.resize_to(&[8, 3]), "growth past capacity reallocates");
        assert_eq!(t.shape(), &[8, 3]);
        assert_eq!(t.len(), 24);
    }

    #[test]
    fn copy_rows_from_matches_concat_rows() {
        let a = Tensor::from_vec(&[1, 2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[2, 2], vec![3.0, 4.0, 5.0, 6.0]);
        let mut stacked = Tensor::zeros(&[3, 2]);
        stacked.copy_rows_from(0, &a);
        stacked.copy_rows_from(1, &b);
        assert_eq!(stacked.data(), Tensor::concat_rows(&[&a, &b]).data());
        // Round-trip through slice_rows recovers the members.
        assert_eq!(stacked.slice_rows(0, 1).data(), a.data());
        assert_eq!(stacked.slice_rows(1, 2).data(), b.data());
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn copy_rows_from_width_mismatch_panics() {
        let mut dst = Tensor::zeros(&[2, 3]);
        let src = Tensor::zeros(&[1, 2]);
        dst.copy_rows_from(0, &src);
    }

    #[test]
    fn f32_roundtrip() {
        let x = Tensor::from_slice(&[1.5, -2.25]);
        let f = x.to_f32();
        let y = Tensor::from_f32(&[2], &f);
        assert_eq!(x, y);
    }

    #[test]
    fn clamp_works() {
        let mut x = Tensor::from_slice(&[-2.0, 0.5, 3.0]);
        x.clamp(-1.0, 1.0);
        assert_eq!(x.data(), &[-1.0, 0.5, 1.0]);
    }
}
