//! Timestep selection for the sampling loop.
//!
//! The paper (following DPM-Solver) places the M+1 grid points uniformly in
//! half log-SNR λ by default; uniform-in-t and quadratic spacings are kept
//! for the DDIM/PNDM baselines that traditionally use them.

use super::NoiseSchedule;

/// How to space the sampling grid t_0 = t_start > … > t_M = t_end.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TimeSpacing {
    /// Uniform in λ (logSNR) — the DPM-Solver/UniPC default.
    LogSnr,
    /// Uniform in t.
    Uniform,
    /// Quadratic in t (denser near t_end).
    Quadratic,
}

impl TimeSpacing {
    pub fn name(self) -> &'static str {
        match self {
            TimeSpacing::LogSnr => "logsnr",
            TimeSpacing::Uniform => "time_uniform",
            TimeSpacing::Quadratic => "time_quadratic",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "logsnr" => Some(TimeSpacing::LogSnr),
            "time_uniform" => Some(TimeSpacing::Uniform),
            "time_quadratic" => Some(TimeSpacing::Quadratic),
            _ => None,
        }
    }
}

/// Decreasing grid of M+1 timesteps from `t_start` down to `t_end`.
///
/// `steps` = M is the number of solver steps, so the returned vector has
/// `steps + 1` entries with `ts[0] = t_start` and `ts[steps] = t_end`.
pub fn timesteps(
    sched: &dyn NoiseSchedule,
    spacing: TimeSpacing,
    t_start: f64,
    t_end: f64,
    steps: usize,
) -> Vec<f64> {
    assert!(steps >= 1, "need at least one step");
    assert!(t_start > t_end && t_end > 0.0, "need t_start > t_end > 0");
    let m = steps;
    let mut ts: Vec<f64> = match spacing {
        TimeSpacing::LogSnr => {
            let l0 = sched.lambda(t_start);
            let l1 = sched.lambda(t_end);
            (0..=m)
                .map(|i| {
                    let lam = l0 + (l1 - l0) * i as f64 / m as f64;
                    if i == 0 {
                        t_start
                    } else if i == m {
                        t_end
                    } else {
                        sched.t_of_lambda(lam)
                    }
                })
                .collect()
        }
        TimeSpacing::Uniform => (0..=m)
            .map(|i| t_start + (t_end - t_start) * i as f64 / m as f64)
            .collect(),
        TimeSpacing::Quadratic => {
            let (a, b) = (t_start.sqrt(), t_end.sqrt());
            (0..=m)
                .map(|i| {
                    let s = a + (b - a) * i as f64 / m as f64;
                    s * s
                })
                .collect()
        }
    };
    // Pin the endpoints bit-exactly (sqrt/exp round-trips drift by ~1 ulp,
    // and callers key reference solutions on exact t_start/t_end).
    ts[0] = t_start;
    ts[m] = t_end;
    ts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::VpLinear;

    #[test]
    fn endpoints_and_monotonicity() {
        let s = VpLinear::default();
        for spacing in [TimeSpacing::LogSnr, TimeSpacing::Uniform, TimeSpacing::Quadratic] {
            let ts = timesteps(&s, spacing, 1.0, 1e-3, 10);
            assert_eq!(ts.len(), 11);
            assert_eq!(ts[0], 1.0);
            assert!((ts[10] - 1e-3).abs() < 1e-12);
            for w in ts.windows(2) {
                assert!(w[1] < w[0], "{spacing:?} not decreasing: {ts:?}");
            }
        }
    }

    #[test]
    fn logsnr_spacing_is_uniform_in_lambda() {
        let s = VpLinear::default();
        let ts = timesteps(&s, TimeSpacing::LogSnr, 1.0, 1e-3, 8);
        let lams: Vec<f64> = ts.iter().map(|&t| s.lambda(t)).collect();
        let h0 = lams[1] - lams[0];
        for w in lams.windows(2) {
            assert!(((w[1] - w[0]) - h0).abs() < 1e-6, "{lams:?}");
        }
    }

    #[test]
    fn single_step_grid() {
        let s = VpLinear::default();
        let ts = timesteps(&s, TimeSpacing::LogSnr, 1.0, 1e-3, 1);
        assert_eq!(ts.len(), 2);
    }

    #[test]
    #[should_panic(expected = "t_start > t_end")]
    fn rejects_bad_range() {
        let s = VpLinear::default();
        let _ = timesteps(&s, TimeSpacing::Uniform, 0.5, 0.9, 4);
    }
}
