//! Noise schedules and timestep selection.
//!
//! A diffusion forward process q(x_t|x_0) = N(α_t x_0, σ_t² I) is described
//! by a [`NoiseSchedule`]: the log-mean coefficient log α_t, the noise level
//! σ_t, the half log-SNR λ_t = log(α_t/σ_t), and the inverse map t_λ(λ)
//! used by singlestep solvers to place intermediate nodes (paper §3.1).
//!
//! Implementations mirror the schedules of the paper's pre-trained models:
//! the VP linear-β schedule (ScoreSDE / DDPM / guided-diffusion /
//! stable-diffusion) and the VP cosine schedule (improved DDPM). The python
//! mirror (`python/compile/sde.py`) is held to golden-value parity with
//! this module by `python/tests/test_sde_parity.py`.

pub mod timesteps;

pub use timesteps::{timesteps, TimeSpacing};

/// Continuous-time noise schedule for a VP diffusion.
pub trait NoiseSchedule: Send + Sync {
    /// log α_t (the log-mean coefficient of q(x_t | x_0)), t ∈ [0, 1].
    fn log_alpha(&self, t: f64) -> f64;

    /// α_t.
    fn alpha(&self, t: f64) -> f64 {
        self.log_alpha(t).exp()
    }

    /// σ_t = sqrt(1 − α_t²).
    fn sigma(&self, t: f64) -> f64 {
        // Compute in log space to stay accurate as α_t → 1 (t → 0).
        let la = self.log_alpha(t);
        (-((2.0 * la).exp_m1())).max(0.0).sqrt()
    }

    /// Half log-SNR λ_t = log α_t − log σ_t. Strictly decreasing in t.
    fn lambda(&self, t: f64) -> f64 {
        let la = self.log_alpha(t);
        let log_sigma = 0.5 * (-((2.0 * la).exp_m1())).max(f64::MIN_POSITIVE).ln();
        la - log_sigma
    }

    /// Inverse of [`NoiseSchedule::lambda`]: the t with λ_t = λ.
    fn t_of_lambda(&self, lam: f64) -> f64;

    /// Human-readable name (manifests, logs).
    fn name(&self) -> &'static str;

    /// Cache discriminator for schedule-derived caches (the solver's plan
    /// cache): the name plus every parameter that changes the λ/α/σ maps.
    /// Required (no default) so a new schedule cannot silently collide in
    /// the plan cache: same-name schedules with different parameters must
    /// never share cached plans.
    fn cache_key(&self) -> String;
}

/// VP SDE with linear β(t) = β₀ + t(β₁ − β₀):
/// log α_t = −t²(β₁−β₀)/4 − tβ₀/2 (ScoreSDE continuous-time convention).
#[derive(Clone, Debug)]
pub struct VpLinear {
    pub beta_0: f64,
    pub beta_1: f64,
}

impl Default for VpLinear {
    fn default() -> Self {
        // The DDPM/ScoreSDE defaults used by every checkpoint in the paper.
        VpLinear { beta_0: 0.1, beta_1: 20.0 }
    }
}

impl NoiseSchedule for VpLinear {
    fn log_alpha(&self, t: f64) -> f64 {
        -t * t * (self.beta_1 - self.beta_0) / 4.0 - t * self.beta_0 / 2.0
    }

    fn t_of_lambda(&self, lam: f64) -> f64 {
        // Closed form (DPM-Solver Appendix): with L = logaddexp(−2λ, 0),
        //   t = 2L / (sqrt(β₀² + 2(β₁−β₀)L) + β₀).
        let l = log1p_exp(-2.0 * lam);
        let tmp = 2.0 * (self.beta_1 - self.beta_0) * l;
        let delta = self.beta_0 * self.beta_0 + tmp;
        tmp / ((delta.sqrt() + self.beta_0) * (self.beta_1 - self.beta_0))
    }

    fn name(&self) -> &'static str {
        "vp_linear"
    }

    fn cache_key(&self) -> String {
        format!(
            "vp_linear:{:x}:{:x}",
            self.beta_0.to_bits(),
            self.beta_1.to_bits()
        )
    }
}

/// VP cosine schedule (Nichol & Dhariwal 2021):
/// log α_t = log cos(π/2 · (t+s)/(1+s)) − log cos(π/2 · s/(1+s)).
#[derive(Clone, Debug)]
pub struct VpCosine {
    pub s: f64,
    /// Clip t to [0, t_max] so λ stays finite (cos → 0 at t → 1).
    pub t_max: f64,
}

impl Default for VpCosine {
    fn default() -> Self {
        VpCosine { s: 0.008, t_max: 0.9946 }
    }
}

impl NoiseSchedule for VpCosine {
    fn log_alpha(&self, t: f64) -> f64 {
        let t = t.min(self.t_max);
        let f = |u: f64| (std::f64::consts::FRAC_PI_2 * (u + self.s) / (1.0 + self.s)).cos().ln();
        f(t) - f(0.0)
    }

    fn t_of_lambda(&self, lam: f64) -> f64 {
        // λ = log α − log σ with α = cos(...) / cos(f0). Invert:
        // log α_t(λ) = −½ log1p(e^{−2λ}) + log cos(f0·π/2-normalized)…
        // Following the DPM-Solver reference implementation:
        let log_alpha = -0.5 * log1p_exp(-2.0 * lam);
        let f0 = (std::f64::consts::FRAC_PI_2 * self.s / (1.0 + self.s)).cos().ln();
        let inner = (log_alpha + f0).exp().clamp(-1.0, 1.0);
        let t = 2.0 * (1.0 + self.s) / std::f64::consts::PI * inner.acos() - self.s;
        t.clamp(0.0, self.t_max)
    }

    fn name(&self) -> &'static str {
        "vp_cosine"
    }

    fn cache_key(&self) -> String {
        format!("vp_cosine:{:x}:{:x}", self.s.to_bits(), self.t_max.to_bits())
    }
}

/// log(1 + e^x), overflow-safe.
fn log1p_exp(x: f64) -> f64 {
    if x > 0.0 {
        x + (-x).exp().ln_1p()
    } else {
        x.exp().ln_1p()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() <= tol, "{a} vs {b}");
    }

    #[test]
    fn vp_linear_boundaries() {
        let s = VpLinear::default();
        close(s.alpha(0.0), 1.0, 1e-12);
        close(s.sigma(0.0), 0.0, 1e-9);
        // At t=1 the marginal is ~N(0, I): α ≈ 0, σ ≈ 1.
        assert!(s.alpha(1.0) < 0.01);
        assert!(s.sigma(1.0) > 0.999);
    }

    #[test]
    fn lambda_strictly_decreasing() {
        for sched in [&VpLinear::default() as &dyn NoiseSchedule, &VpCosine::default()] {
            let mut prev = f64::INFINITY;
            let mut t = 1e-3;
            while t <= 0.99 {
                let l = sched.lambda(t);
                assert!(l < prev, "{} λ not decreasing at t={t}", sched.name());
                prev = l;
                t += 0.01;
            }
        }
    }

    #[test]
    fn vp_linear_lambda_roundtrip() {
        let s = VpLinear::default();
        for &t in &[1e-3, 0.05, 0.2, 0.5, 0.8, 1.0] {
            let lam = s.lambda(t);
            let t2 = s.t_of_lambda(lam);
            close(t2, t, 1e-9);
        }
    }

    #[test]
    fn vp_cosine_lambda_roundtrip() {
        let s = VpCosine::default();
        for &t in &[1e-3, 0.05, 0.2, 0.5, 0.8, 0.97] {
            let lam = s.lambda(t);
            let t2 = s.t_of_lambda(lam);
            close(t2, t, 1e-6);
        }
    }

    #[test]
    fn alpha_sq_plus_sigma_sq_is_one() {
        let s = VpLinear::default();
        for &t in &[0.01, 0.3, 0.7, 1.0] {
            let a = s.alpha(t);
            let g = s.sigma(t);
            close(a * a + g * g, 1.0, 1e-12);
        }
    }

    #[test]
    fn cache_key_folds_in_parameters() {
        // Same-name schedules with different parameters must not share
        // plan-cache entries (solver::plan_key relies on this).
        let a = VpLinear::default();
        let b = VpLinear { beta_0: 0.2, beta_1: 25.0 };
        assert_eq!(a.name(), b.name());
        assert_ne!(a.cache_key(), b.cache_key());
        assert_eq!(a.cache_key(), VpLinear::default().cache_key());
        let c = VpCosine::default();
        let d = VpCosine { s: 0.01, t_max: 0.9946 };
        assert_ne!(c.cache_key(), d.cache_key());
    }

    #[test]
    fn golden_values_vp_linear() {
        // Golden values shared with python/tests/test_sde_parity.py — keep in
        // sync with python/compile/sde.py.
        let s = VpLinear::default();
        close(s.log_alpha(0.5), -0.5 * 0.5 * 19.9 / 4.0 - 0.5 * 0.05, 1e-15);
        close(s.lambda(1e-3), 4.557714932729898, 1e-9);
        close(s.lambda(1.0), -5.024978406659204, 1e-9);
        close(s.lambda(0.5), -1.2275677344107871, 1e-9);
    }
}
