//! Gaussian mixtures with closed-form noise prediction, plus guided
//! (classifier-free-style) variants.

use crate::rng::Rng;
use crate::sched::NoiseSchedule;
use crate::solver::{Model, Prediction};
use crate::tensor::Tensor;

/// An isotropic Gaussian mixture q₀ = Σ_k w_k N(μ_k, s_k² I).
#[derive(Clone, Debug)]
pub struct GaussianMixture {
    pub dim: usize,
    /// Mixture weights (normalized on construction).
    pub weights: Vec<f64>,
    /// Component means, each of length `dim`.
    pub means: Vec<Vec<f64>>,
    /// Component standard deviations (isotropic).
    pub stds: Vec<f64>,
}

impl GaussianMixture {
    pub fn new(means: Vec<Vec<f64>>, stds: Vec<f64>, weights: Vec<f64>) -> Self {
        assert!(!means.is_empty());
        assert_eq!(means.len(), stds.len());
        assert_eq!(means.len(), weights.len());
        let dim = means[0].len();
        for m in &means {
            assert_eq!(m.len(), dim);
        }
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0);
        let weights = weights.iter().map(|w| w / total).collect();
        GaussianMixture { dim, weights, means, stds }
    }

    pub fn n_components(&self) -> usize {
        self.means.len()
    }

    /// Draw `n` samples from q₀ as an `[n, dim]` tensor.
    pub fn sample(&self, rng: &mut Rng, n: usize) -> Tensor {
        let mut data = Vec::with_capacity(n * self.dim);
        for _ in 0..n {
            let k = rng.categorical(&self.weights);
            for j in 0..self.dim {
                data.push(self.means[k][j] + self.stds[k] * rng.normal());
            }
        }
        Tensor::from_vec(&[n, self.dim], data)
    }

    /// Mixture mean E[x].
    pub fn mean(&self) -> Vec<f64> {
        let mut mu = vec![0.0; self.dim];
        for (k, m) in self.means.iter().enumerate() {
            for j in 0..self.dim {
                mu[j] += self.weights[k] * m[j];
            }
        }
        mu
    }

    /// Mixture covariance (row-major dim×dim):
    /// Σ_k w_k (s_k² I + μ_k μ_kᵀ) − μ μᵀ.
    pub fn covariance(&self) -> Vec<f64> {
        let d = self.dim;
        let mu = self.mean();
        let mut c = vec![0.0; d * d];
        for (k, m) in self.means.iter().enumerate() {
            let w = self.weights[k];
            for i in 0..d {
                c[i * d + i] += w * self.stds[k] * self.stds[k];
                for j in 0..d {
                    c[i * d + j] += w * m[i] * m[j];
                }
            }
        }
        for i in 0..d {
            for j in 0..d {
                c[i * d + j] -= mu[i] * mu[j];
            }
        }
        c
    }

    /// ε*(x, t) for one flattened row, writing into `out`. The schedule
    /// scalars `a`/`sg`, the component subset `ks`, the per-component
    /// marginal variances `vks`, and the row-independent log-posterior
    /// constants `logc` (log w_k − d/2·log v_k) are precomputed once per
    /// call by [`GaussianMixture::eps_star`]; `logp`/`gammas` are
    /// caller-provided scratch of length `ks.len()` shared across rows.
    #[allow(clippy::too_many_arguments)]
    fn eps_row(
        &self,
        a: f64,
        sg: f64,
        x: &[f64],
        ks: &[usize],
        vks: &[f64],
        logc: &[f64],
        logp: &mut [f64],
        gammas: &mut [f64],
        out: &mut [f64],
    ) {
        let d = self.dim;
        // log γ_k ∝ log w_k − d/2 log v_k − ‖x − α μ_k‖²/(2 v_k), with the
        // row-independent head precomputed in `logc` (same association as
        // the inline form, so results are bit-identical).
        for (i, &k) in ks.iter().enumerate() {
            let v = vks[i];
            let mut sq = 0.0;
            for j in 0..d {
                let r = x[j] - a * self.means[k][j];
                sq += r * r;
            }
            logp[i] = logc[i] - sq / (2.0 * v);
        }
        let mx = logp.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut total = 0.0;
        for i in 0..logp.len() {
            let g = (logp[i] - mx).exp();
            total += g;
            gammas[i] = g;
        }

        // ε* = σ Σ_k γ_k (x − α μ_k) / v_k
        out.iter_mut().for_each(|o| *o = 0.0);
        for (i, &k) in ks.iter().enumerate() {
            let g = gammas[i] / total;
            let v = vks[i];
            for j in 0..d {
                out[j] += g * (x[j] - a * self.means[k][j]) / v;
            }
        }
        for o in out.iter_mut() {
            *o *= sg;
        }
    }

    /// Batched ε*(x, t). Subset restricts to the given components
    /// (class-conditional score); `None` uses all components.
    ///
    /// Rows are evaluated independently, so a stacked batch of requests
    /// yields bit-identical rows to evaluating each request alone — the
    /// property the serving layer's lockstep request batching relies on.
    /// Per-call work (component subset, marginal variances, posterior
    /// scratch) is hoisted out of the row loop, so batched calls also
    /// amortize it across rows.
    pub fn eps_star(
        &self,
        sched: &dyn NoiseSchedule,
        x: &Tensor,
        t: f64,
        subset: Option<&[usize]>,
    ) -> Tensor {
        let mut out = Tensor::zeros(x.shape());
        self.eps_star_rows(sched, x, t, subset, 0, x.shape()[0], &mut out);
        out
    }

    /// ε*(x, t) for the row range `[start, start + rows)` of `x`, written
    /// into the same rows of `out` — the slab form of
    /// [`GaussianMixture::eps_star`] used by the serving layer's
    /// row-conditioned model view, where contiguous same-conditioning row
    /// ranges of a mixed cohort evaluate under their own component subsets.
    ///
    /// The per-call hoisted work (component subset expansion, marginal
    /// variances, log-posterior constants) depends only on `(t, subset)`,
    /// and the per-row kernel is the same one `eps_star` uses, so a slab
    /// evaluation is bit-identical to evaluating those rows alone.
    #[allow(clippy::too_many_arguments)]
    pub fn eps_star_rows(
        &self,
        sched: &dyn NoiseSchedule,
        x: &Tensor,
        t: f64,
        subset: Option<&[usize]>,
        start: usize,
        rows: usize,
        out: &mut Tensor,
    ) {
        assert_eq!(x.shape().len(), 2);
        assert_eq!(x.shape()[1], self.dim);
        assert_eq!(out.shape(), x.shape());
        assert!(start + rows <= x.shape()[0]);
        let a = sched.alpha(t);
        let sg = sched.sigma(t);
        let all;
        let ks: &[usize] = match subset {
            Some(s) => s,
            None => {
                all = (0..self.n_components()).collect::<Vec<usize>>();
                &all
            }
        };
        let d = self.dim;
        let mut vks = Vec::with_capacity(ks.len());
        let mut logc = Vec::with_capacity(ks.len());
        for &k in ks {
            let v = a * a * self.stds[k] * self.stds[k] + sg * sg;
            vks.push(v);
            logc.push(self.weights[k].ln() - 0.5 * d as f64 * v.ln());
        }
        let mut logp = vec![0.0; ks.len()];
        let mut gammas = vec![0.0; ks.len()];
        for i in start..start + rows {
            self.eps_row(
                a,
                sg,
                x.row(i),
                ks,
                &vks,
                &logc,
                &mut logp,
                &mut gammas,
                out.row_mut(i),
            );
        }
    }

    /// Classifier-free-guided ε̃ = (1+s)·ε_cond − s·ε_uncond for the row
    /// range `[start, start + rows)` of `x`, written into the same rows of
    /// `out` — the slab form of [`GuidedGmmModel`]. The per-row combine
    /// uses exactly the `a·x + b·y` expression [`Tensor::lincomb`]
    /// evaluates, so a guided slab is bit-identical to running
    /// `GuidedGmmModel` on those rows alone.
    #[allow(clippy::too_many_arguments)]
    pub fn eps_star_guided_rows(
        &self,
        sched: &dyn NoiseSchedule,
        x: &Tensor,
        t: f64,
        class_components: &[usize],
        scale: f64,
        start: usize,
        rows: usize,
        out: &mut Tensor,
    ) {
        if scale == 0.0 {
            self.eps_star_rows(sched, x, t, Some(class_components), start, rows, out);
            return;
        }
        assert_eq!(x.shape().len(), 2);
        assert_eq!(x.shape()[1], self.dim);
        assert_eq!(out.shape(), x.shape());
        assert!(start + rows <= x.shape()[0]);
        let a = sched.alpha(t);
        let sg = sched.sigma(t);
        let d = self.dim;
        let all: Vec<usize> = (0..self.n_components()).collect();
        // Hoist both model views' row-independent heads once per call,
        // exactly as two separate `eps_star` calls would.
        let hoist = |ks: &[usize]| {
            let mut vks = Vec::with_capacity(ks.len());
            let mut logc = Vec::with_capacity(ks.len());
            for &k in ks {
                let v = a * a * self.stds[k] * self.stds[k] + sg * sg;
                vks.push(v);
                logc.push(self.weights[k].ln() - 0.5 * d as f64 * v.ln());
            }
            (vks, logc)
        };
        let (vks_c, logc_c) = hoist(class_components);
        let (vks_u, logc_u) = hoist(&all);
        let mut logp_c = vec![0.0; class_components.len()];
        let mut gammas_c = vec![0.0; class_components.len()];
        let mut logp_u = vec![0.0; all.len()];
        let mut gammas_u = vec![0.0; all.len()];
        let mut cbuf = vec![0.0; d];
        let mut ubuf = vec![0.0; d];
        for i in start..start + rows {
            self.eps_row(
                a,
                sg,
                x.row(i),
                class_components,
                &vks_c,
                &logc_c,
                &mut logp_c,
                &mut gammas_c,
                &mut cbuf,
            );
            self.eps_row(
                a,
                sg,
                x.row(i),
                &all,
                &vks_u,
                &logc_u,
                &mut logp_u,
                &mut gammas_u,
                &mut ubuf,
            );
            let o = out.row_mut(i);
            for j in 0..d {
                o[j] = (1.0 + scale) * cbuf[j] + (-scale) * ubuf[j];
            }
        }
    }

    /// A standard benchmark mixture: `k` components on a circle of radius
    /// `r` embedded in `dim` dimensions, std `s`.
    pub fn ring(dim: usize, k: usize, r: f64, s: f64) -> Self {
        assert!(dim >= 2);
        let means = (0..k)
            .map(|i| {
                let th = 2.0 * std::f64::consts::PI * i as f64 / k as f64;
                let mut m = vec![0.0; dim];
                m[0] = r * th.cos();
                m[1] = r * th.sin();
                m
            })
            .collect();
        GaussianMixture::new(means, vec![s; k], vec![1.0; k])
    }
}

/// The unconditional analytic model: ε_θ := ε* (noise prediction).
pub struct GmmModel<'a> {
    pub gm: &'a GaussianMixture,
    pub sched: &'a dyn NoiseSchedule,
}

impl Model for GmmModel<'_> {
    fn prediction(&self) -> Prediction {
        Prediction::Noise
    }
    fn eval(&self, x: &Tensor, t: f64) -> Tensor {
        self.gm.eps_star(self.sched, x, t, None)
    }
    fn dim(&self) -> usize {
        self.gm.dim
    }
}

/// Guided analytic model: classifier-free guidance over class-conditional
/// component subsets, ε̃ = (1+s)·ε_cond − s·ε_uncond (paper §4.1 setting).
pub struct GuidedGmmModel<'a> {
    pub gm: &'a GaussianMixture,
    pub sched: &'a dyn NoiseSchedule,
    /// Components belonging to the conditioned class.
    pub class_components: Vec<usize>,
    /// Guidance scale s (s = 0 recovers the conditional model).
    pub scale: f64,
}

impl Model for GuidedGmmModel<'_> {
    fn prediction(&self) -> Prediction {
        Prediction::Noise
    }
    fn eval(&self, x: &Tensor, t: f64) -> Tensor {
        let cond = self.gm.eps_star(self.sched, x, t, Some(&self.class_components));
        if self.scale == 0.0 {
            return cond;
        }
        let uncond = self.gm.eps_star(self.sched, x, t, None);
        Tensor::lincomb(1.0 + self.scale, &cond, -self.scale, &uncond)
    }
    fn dim(&self) -> usize {
        self.gm.dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::VpLinear;

    fn single(dim: usize, s: f64) -> GaussianMixture {
        GaussianMixture::new(vec![vec![0.0; dim]], vec![s], vec![1.0])
    }

    #[test]
    fn weights_normalized() {
        let g = GaussianMixture::new(
            vec![vec![0.0], vec![1.0]],
            vec![1.0, 1.0],
            vec![2.0, 6.0],
        );
        assert!((g.weights[0] - 0.25).abs() < 1e-12);
        assert!((g.weights[1] - 0.75).abs() < 1e-12);
    }

    #[test]
    fn single_gaussian_eps_is_linear() {
        // ε*(x,t) = σ x / (α²s² + σ²) for a centered Gaussian.
        let sched = VpLinear::default();
        let g = single(3, 2.0);
        let t = 0.6;
        let x = Tensor::from_vec(&[2, 3], vec![1.0, -2.0, 0.5, 0.0, 3.0, -1.0]);
        let eps = g.eps_star(&sched, &x, t, None);
        let (a, s) = (sched.alpha(t), sched.sigma(t));
        let v = a * a * 4.0 + s * s;
        for (e, xv) in eps.data().iter().zip(x.data()) {
            assert!((e - s * xv / v).abs() < 1e-12, "{e} vs {}", s * xv / v);
        }
    }

    #[test]
    fn eps_matches_finite_difference_score() {
        // ε* = −σ ∇ log q_t: check against a numerical gradient of the
        // mixture log-density.
        let sched = VpLinear::default();
        let g = GaussianMixture::ring(2, 3, 2.0, 0.5);
        let t = 0.4;
        let (a, sg) = (sched.alpha(t), sched.sigma(t));
        let logq = |x: &[f64]| -> f64 {
            let mut terms = Vec::new();
            for k in 0..g.n_components() {
                let v = a * a * g.stds[k] * g.stds[k] + sg * sg;
                let mut sq = 0.0;
                for j in 0..2 {
                    let r = x[j] - a * g.means[k][j];
                    sq += r * r;
                }
                terms.push(g.weights[k].ln() - (v * 2.0 * std::f64::consts::PI).ln() - sq / (2.0 * v));
            }
            let mx = terms.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            mx + terms.iter().map(|t| (t - mx).exp()).sum::<f64>().ln()
        };
        let x = [0.7, -1.1];
        let h = 1e-5;
        let mut grad = [0.0; 2];
        for j in 0..2 {
            let mut xp = x;
            let mut xm = x;
            xp[j] += h;
            xm[j] -= h;
            grad[j] = (logq(&xp) - logq(&xm)) / (2.0 * h);
        }
        let xt = Tensor::from_vec(&[1, 2], x.to_vec());
        let eps = g.eps_star(&sched, &xt, t, None);
        for j in 0..2 {
            let expect = -sg * grad[j];
            assert!(
                (eps.data()[j] - expect).abs() < 1e-6,
                "{} vs {expect}",
                eps.data()[j]
            );
        }
    }

    #[test]
    fn sampling_moments_match() {
        let g = GaussianMixture::ring(2, 4, 3.0, 0.3);
        let mut rng = Rng::seed_from(5);
        let xs = g.sample(&mut rng, 50_000);
        let mu = g.mean();
        let mut emp = vec![0.0; 2];
        for i in 0..xs.shape()[0] {
            for j in 0..2 {
                emp[j] += xs.row(i)[j];
            }
        }
        for j in 0..2 {
            emp[j] /= xs.shape()[0] as f64;
            assert!((emp[j] - mu[j]).abs() < 0.05, "dim {j}: {} vs {}", emp[j], mu[j]);
        }
    }

    #[test]
    fn covariance_of_symmetric_ring_is_isotropic_in_plane() {
        let g = GaussianMixture::ring(2, 8, 2.0, 0.5);
        let c = g.covariance();
        // Symmetry: c[0][0] == c[1][1], off-diagonals ~0.
        assert!((c[0] - c[3]).abs() < 1e-10);
        assert!(c[1].abs() < 1e-10);
        // Variance = r²/2 + s².
        assert!((c[0] - (2.0 * 2.0 / 2.0 + 0.25)).abs() < 1e-10, "{}", c[0]);
    }

    #[test]
    fn guidance_zero_scale_equals_conditional() {
        let sched = VpLinear::default();
        let g = GaussianMixture::ring(2, 4, 2.0, 0.4);
        let guided = GuidedGmmModel {
            gm: &g,
            sched: &sched,
            class_components: vec![0, 1],
            scale: 0.0,
        };
        let x = Tensor::from_vec(&[1, 2], vec![0.3, 0.4]);
        let a = guided.eval(&x, 0.5);
        let b = g.eps_star(&sched, &x, 0.5, Some(&[0, 1]));
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn slab_eval_is_bit_identical_to_whole_tensor_eval() {
        // The row-conditioned serving path evaluates contiguous row ranges
        // (slabs) of a stacked batch separately; each slab must reproduce
        // the exact bits the whole-tensor call produces for those rows.
        let sched = VpLinear::default();
        let g = GaussianMixture::ring(3, 5, 2.0, 0.4);
        let mut rng = Rng::seed_from(11);
        let x = rng.normal_tensor(&[7, 3]);
        for subset in [None, Some(&[1usize, 3][..])] {
            let whole = g.eps_star(&sched, &x, 0.5, subset);
            let mut out = Tensor::zeros(x.shape());
            g.eps_star_rows(&sched, &x, 0.5, subset, 0, 2, &mut out);
            g.eps_star_rows(&sched, &x, 0.5, subset, 2, 4, &mut out);
            g.eps_star_rows(&sched, &x, 0.5, subset, 6, 1, &mut out);
            assert_eq!(whole.data(), out.data());
        }
    }

    #[test]
    fn guided_slab_is_bit_identical_to_guided_model_rows() {
        let sched = VpLinear::default();
        let g = GaussianMixture::ring(3, 5, 2.0, 0.4);
        let mut rng = Rng::seed_from(12);
        let x = rng.normal_tensor(&[4, 3]);
        for scale in [0.0, 0.5, 4.0] {
            let guided = GuidedGmmModel {
                gm: &g,
                sched: &sched,
                class_components: vec![0, 2],
                scale,
            };
            let whole = guided.eval(&x, 0.37);
            let mut out = Tensor::zeros(x.shape());
            g.eps_star_guided_rows(&sched, &x, 0.37, &[0, 2], scale, 0, 3, &mut out);
            g.eps_star_guided_rows(&sched, &x, 0.37, &[0, 2], scale, 3, 1, &mut out);
            assert_eq!(whole.data(), out.data());
        }
    }

    #[test]
    fn guidance_pushes_toward_class() {
        // With a large scale the guided field should differ from uncond.
        let sched = VpLinear::default();
        let g = GaussianMixture::ring(2, 4, 2.0, 0.4);
        let guided = GuidedGmmModel {
            gm: &g,
            sched: &sched,
            class_components: vec![0],
            scale: 4.0,
        };
        let x = Tensor::from_vec(&[1, 2], vec![0.1, 0.1]);
        let eg = guided.eval(&x, 0.5);
        let eu = g.eps_star(&sched, &x, 0.5, None);
        assert!(eg.sub(&eu).norm() > 1e-3);
    }
}
