//! High-accuracy reference solutions of the diffusion ODE.
//!
//! In the half log-SNR domain the VP probability-flow ODE becomes
//!   dx/dλ = σ_λ² x − σ_λ ε̂(x, λ)
//! (using α² + σ² = 1 ⇒ d log α/dλ = σ², and α e^{−λ} = σ). A classic RK4
//! over a fine λ grid gives global error O(h⁴·N) ≈ 1e-12 at N = 10⁴ steps —
//! far below anything the 5–10 NFE solvers reach, so it serves as ground
//! truth for convergence-order measurements and the paper's l₂ metric
//! (Fig. 4c uses 999-step DDIM as truth; we offer that too via the runner).

use crate::sched::NoiseSchedule;
use crate::solver::{Model, Prediction};
use crate::tensor::Tensor;

/// Solve the diffusion ODE from `t_start` to `t_end` with `n` RK4 steps in λ.
/// Works with any noise-prediction model (analytic or learned).
pub fn reference_solution(
    model: &dyn Model,
    sched: &dyn NoiseSchedule,
    x_init: &Tensor,
    t_start: f64,
    t_end: f64,
    n: usize,
) -> Tensor {
    assert_eq!(model.prediction(), Prediction::Noise, "reference integrates the ε form");
    let l0 = sched.lambda(t_start);
    let l1 = sched.lambda(t_end);
    let h = (l1 - l0) / n as f64;

    // σ as a function of λ under VP: σ(λ) = 1/sqrt(1 + e^{2λ}).
    let sig = |lam: f64| 1.0 / (1.0 + (2.0 * lam).exp()).sqrt();
    let f = |lam: f64, x: &Tensor| -> Tensor {
        let s = sig(lam);
        let t = sched.t_of_lambda(lam);
        let eps = model.eval(x, t);
        let mut dx = x.scaled(s * s);
        dx.axpy(-s, &eps);
        dx
    };

    let mut x = x_init.clone();
    let mut lam = l0;
    for _ in 0..n {
        let k1 = f(lam, &x);
        let mut x2 = x.clone();
        x2.axpy(h / 2.0, &k1);
        let k2 = f(lam + h / 2.0, &x2);
        let mut x3 = x.clone();
        x3.axpy(h / 2.0, &k2);
        let k3 = f(lam + h / 2.0, &x3);
        let mut x4 = x.clone();
        x4.axpy(h, &k3);
        let k4 = f(lam + h, &x4);
        x.axpy(h / 6.0, &k1);
        x.axpy(h / 3.0, &k2);
        x.axpy(h / 3.0, &k3);
        x.axpy(h / 6.0, &k4);
        lam += h;
    }
    x
}

/// Exact flow map for a single centered Gaussian q₀ = N(0, s² I):
/// x_t = sqrt(v_t / v_s) · x_s with v_t = α_t² s² + σ_t². Used to validate
/// [`reference_solution`] against a true closed form.
pub fn single_gaussian_flow(
    sched: &dyn NoiseSchedule,
    x_init: &Tensor,
    t_start: f64,
    t_end: f64,
    data_std: f64,
) -> Tensor {
    let v = |t: f64| {
        let a = sched.alpha(t);
        let s = sched.sigma(t);
        a * a * data_std * data_std + s * s
    };
    x_init.scaled((v(t_end) / v(t_start)).sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::gmm::{GaussianMixture, GmmModel};
    use crate::sched::VpLinear;

    #[test]
    fn rk4_matches_closed_form_single_gaussian() {
        let sched = VpLinear::default();
        let gm = GaussianMixture::new(vec![vec![0.0, 0.0]], vec![1.5], vec![1.0]);
        let model = GmmModel { gm: &gm, sched: &sched };
        let x = Tensor::from_vec(&[1, 2], vec![1.2, -0.7]);
        let (t0, t1) = (1.0, 1e-3);
        let rk = reference_solution(&model, &sched, &x, t0, t1, 2000);
        let exact = single_gaussian_flow(&sched, &x, t0, t1, 1.5);
        let err = rk.sub(&exact).max_abs();
        assert!(err < 1e-9, "err {err}");
    }

    #[test]
    fn rk4_converges_with_step_count() {
        let sched = VpLinear::default();
        let gm = GaussianMixture::ring(2, 3, 2.0, 0.5);
        let model = GmmModel { gm: &gm, sched: &sched };
        let x = Tensor::from_vec(&[1, 2], vec![0.5, 0.8]);
        let fine = reference_solution(&model, &sched, &x, 1.0, 1e-3, 4000);
        let coarse = reference_solution(&model, &sched, &x, 1.0, 1e-3, 500);
        let coarser = reference_solution(&model, &sched, &x, 1.0, 1e-3, 250);
        let e1 = coarse.sub(&fine).norm();
        let e2 = coarser.sub(&fine).norm();
        // RK4: halving steps multiplies the error by ~16.
        assert!(e2 / e1 > 8.0, "ratio {}", e2 / e1);
    }

    #[test]
    fn sigma_lambda_identity() {
        // σ(λ(t)) must equal σ(t) under VP.
        let sched = VpLinear::default();
        for &t in &[0.1, 0.5, 0.9] {
            let lam = sched.lambda(t);
            let s = 1.0 / (1.0 + (2.0 * lam).exp()).sqrt();
            assert!((s - sched.sigma(t)).abs() < 1e-10);
        }
    }
}
