//! Analytic-score diffusion substrate.
//!
//! The paper evaluates on pre-trained networks we cannot ship; its claims,
//! however, are properties of the *solver* given a smooth ε_θ. A Gaussian
//! mixture data distribution gives a diffusion model whose exact noise
//! prediction ε*(x, t) is available in closed form, so:
//!
//! * the true ODE solution is computable to ~1e-12 ([`reference_solution`]),
//!   making order-of-accuracy/convergence claims (Thm 3.1, Cor 3.2,
//!   Prop D.5/D.6) directly measurable;
//! * sample-quality tables become exact distribution distances
//!   ([`crate::stats`]) instead of Inception-feature FID.
//!
//! For q₀ = Σ_k w_k N(μ_k, s_k² I), the time-t marginal is
//! q_t = Σ_k w_k N(α_t μ_k, v_k I) with v_k = α_t² s_k² + σ_t², and
//!   ε*(x, t) = −σ_t ∇ log q_t(x) = σ_t Σ_k γ_k(x) (x − α_t μ_k)/v_k,
//! with responsibilities γ_k computed in log space.

pub mod datasets;
pub mod gmm;
pub mod reference;

pub use datasets::{dataset, DatasetSpec};
pub use gmm::{GaussianMixture, GmmModel, GuidedGmmModel};
pub use reference::{reference_solution, single_gaussian_flow};
