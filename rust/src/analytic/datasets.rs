//! Named analytic "datasets" standing in for the paper's benchmarks.
//!
//! Each spec mirrors one of the paper's evaluation settings: same role
//! (pixel- vs latent-space, unconditional vs class-conditional), scaled to a
//! dimensionality where exact reference solutions are cheap. The mapping is
//! recorded in DESIGN.md §2 (substitutions).

use super::gmm::GaussianMixture;
use crate::rng::Rng;

/// A named analytic benchmark distribution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetSpec {
    /// Stands in for CIFAR10 (pixel-space DPM): 16-d, 10 spread-out modes
    /// (one per "class"), moderate within-mode spread.
    Cifar10Like,
    /// Stands in for LSUN Bedroom (latent-space DPM): 8-d, 4 broad modes.
    BedroomLike,
    /// Stands in for FFHQ (latent-space DPM): 12-d, 6 modes, tighter spread.
    FfhqLike,
    /// Stands in for class-conditional ImageNet-256 (guided sampling):
    /// 16-d, 10 classes × 2 modes each.
    ImagenetLike,
}

impl DatasetSpec {
    pub fn name(self) -> &'static str {
        match self {
            DatasetSpec::Cifar10Like => "cifar10-like",
            DatasetSpec::BedroomLike => "bedroom-like",
            DatasetSpec::FfhqLike => "ffhq-like",
            DatasetSpec::ImagenetLike => "imagenet-like",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "cifar10-like" | "cifar10" => Some(DatasetSpec::Cifar10Like),
            "bedroom-like" | "bedroom" | "lsun" => Some(DatasetSpec::BedroomLike),
            "ffhq-like" | "ffhq" => Some(DatasetSpec::FfhqLike),
            "imagenet-like" | "imagenet" => Some(DatasetSpec::ImagenetLike),
            _ => None,
        }
    }

    /// Number of classes for the conditional datasets (components per class
    /// are contiguous blocks).
    pub fn n_classes(self) -> usize {
        match self {
            DatasetSpec::ImagenetLike => 10,
            DatasetSpec::Cifar10Like => 10,
            _ => 1,
        }
    }

    /// Component indices belonging to `class`.
    pub fn class_components(self, class: usize) -> Vec<usize> {
        match self {
            DatasetSpec::ImagenetLike => vec![2 * class, 2 * class + 1],
            DatasetSpec::Cifar10Like => vec![class],
            _ => (0..dataset(self).n_components()).collect(),
        }
    }
}

/// Build the mixture for a spec (deterministic: component layout is seeded).
pub fn dataset(spec: DatasetSpec) -> GaussianMixture {
    match spec {
        DatasetSpec::Cifar10Like => random_mixture(16, 10, 3.0, 0.6, 101),
        DatasetSpec::BedroomLike => random_mixture(8, 4, 2.5, 0.9, 202),
        DatasetSpec::FfhqLike => random_mixture(12, 6, 2.8, 0.5, 303),
        DatasetSpec::ImagenetLike => random_mixture(16, 20, 3.5, 0.55, 404),
    }
}

/// Deterministic mixture with means drawn on a sphere of radius `r` and
/// jittered, stds jittered around `s`.
fn random_mixture(dim: usize, k: usize, r: f64, s: f64, seed: u64) -> GaussianMixture {
    let mut rng = Rng::seed_from(seed);
    let means = (0..k)
        .map(|_| {
            let mut m = rng.normal_vec(dim);
            let n = m.iter().map(|v| v * v).sum::<f64>().sqrt();
            for v in &mut m {
                *v *= r / n;
            }
            m
        })
        .collect();
    let stds = (0..k).map(|_| s * (0.8 + 0.4 * rng.uniform())).collect();
    let weights = (0..k).map(|_| 0.5 + rng.uniform()).collect();
    GaussianMixture::new(means, stds, weights)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_are_deterministic() {
        let a = dataset(DatasetSpec::Cifar10Like);
        let b = dataset(DatasetSpec::Cifar10Like);
        assert_eq!(a.means, b.means);
        assert_eq!(a.weights, b.weights);
    }

    #[test]
    fn dims_and_components() {
        assert_eq!(dataset(DatasetSpec::Cifar10Like).dim, 16);
        assert_eq!(dataset(DatasetSpec::BedroomLike).n_components(), 4);
        assert_eq!(dataset(DatasetSpec::ImagenetLike).n_components(), 20);
    }

    #[test]
    fn class_components_partition_imagenet() {
        let spec = DatasetSpec::ImagenetLike;
        let mut seen = std::collections::HashSet::new();
        for c in 0..spec.n_classes() {
            for k in spec.class_components(c) {
                assert!(seen.insert(k), "component {k} in two classes");
            }
        }
        assert_eq!(seen.len(), 20);
    }

    #[test]
    fn parse_names() {
        for spec in [
            DatasetSpec::Cifar10Like,
            DatasetSpec::BedroomLike,
            DatasetSpec::FfhqLike,
            DatasetSpec::ImagenetLike,
        ] {
            assert_eq!(DatasetSpec::parse(spec.name()), Some(spec));
        }
        assert_eq!(DatasetSpec::parse("zzz"), None);
    }
}
