//! Distribution distances used as FID stand-ins on analytic benchmarks
//! (DESIGN.md §2): sliced 2-Wasserstein, Gaussian Fréchet distance (the
//! literal FID formula in data space), and RBF MMD.

use super::linalg::{matmul, sym_sqrt, trace};
use crate::rng::Rng;
use crate::tensor::Tensor;

/// Sliced 2-Wasserstein distance between two `[n, d]` sample sets:
/// average over random unit projections of the 1-d W₂ (quantile matching).
pub fn sliced_wasserstein2(a: &Tensor, b: &Tensor, n_proj: usize, rng: &mut Rng) -> f64 {
    assert_eq!(a.shape().len(), 2);
    assert_eq!(b.shape().len(), 2);
    assert_eq!(a.shape()[1], b.shape()[1]);
    let d = a.shape()[1];
    let (na, nb) = (a.shape()[0], b.shape()[0]);
    let q = 256.min(na.min(nb)); // quantile grid

    let mut total = 0.0;
    let mut pa = vec![0.0; na];
    let mut pb = vec![0.0; nb];
    for _ in 0..n_proj {
        // Random unit direction.
        let mut dir = rng.normal_vec(d);
        let norm = dir.iter().map(|v| v * v).sum::<f64>().sqrt();
        dir.iter_mut().for_each(|v| *v /= norm);

        for i in 0..na {
            pa[i] = a.row(i).iter().zip(&dir).map(|(x, w)| x * w).sum();
        }
        for i in 0..nb {
            pb[i] = b.row(i).iter().zip(&dir).map(|(x, w)| x * w).sum();
        }
        pa.sort_by(|x, y| x.partial_cmp(y).unwrap());
        pb.sort_by(|x, y| x.partial_cmp(y).unwrap());

        // W₂² over a shared quantile grid.
        let mut w2 = 0.0;
        for k in 0..q {
            let frac = (k as f64 + 0.5) / q as f64;
            let qa = quantile_sorted(&pa, frac);
            let qb = quantile_sorted(&pb, frac);
            w2 += (qa - qb) * (qa - qb);
        }
        total += w2 / q as f64;
    }
    (total / n_proj as f64).sqrt()
}

fn quantile_sorted(xs: &[f64], q: f64) -> f64 {
    let pos = q * (xs.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        xs[lo]
    } else {
        xs[lo] * (hi as f64 - pos) + xs[hi] * (pos - lo as f64)
    }
}

/// Fit (mean, covariance) of an `[n, d]` sample set; covariance row-major.
pub fn gaussian_fit(x: &Tensor) -> (Vec<f64>, Vec<f64>) {
    let n = x.shape()[0];
    let d = x.shape()[1];
    assert!(n >= 2);
    let mut mu = vec![0.0; d];
    for i in 0..n {
        for (j, v) in x.row(i).iter().enumerate() {
            mu[j] += v;
        }
    }
    mu.iter_mut().for_each(|v| *v /= n as f64);
    let mut cov = vec![0.0; d * d];
    for i in 0..n {
        let row = x.row(i);
        for a in 0..d {
            let da = row[a] - mu[a];
            for b in a..d {
                cov[a * d + b] += da * (row[b] - mu[b]);
            }
        }
    }
    for a in 0..d {
        for b in a..d {
            let v = cov[a * d + b] / (n as f64 - 1.0);
            cov[a * d + b] = v;
            cov[b * d + a] = v;
        }
    }
    (mu, cov)
}

/// Fréchet distance between two Gaussians — the FID formula evaluated in
/// data space: ‖μ₁−μ₂‖² + tr(C₁ + C₂ − 2(C₁^{1/2} C₂ C₁^{1/2})^{1/2}).
pub fn frechet_distance(mu1: &[f64], c1: &[f64], mu2: &[f64], c2: &[f64]) -> f64 {
    let d = mu1.len();
    assert_eq!(mu2.len(), d);
    let dm: f64 = mu1.iter().zip(mu2).map(|(a, b)| (a - b) * (a - b)).sum();
    let s1 = sym_sqrt(c1, d);
    let inner = matmul(&matmul(&s1, c2, d), &s1, d);
    // Symmetrize against rounding before the second sqrt.
    let mut sym = inner.clone();
    for i in 0..d {
        for j in 0..d {
            sym[i * d + j] = 0.5 * (inner[i * d + j] + inner[j * d + i]);
        }
    }
    let cross = sym_sqrt(&sym, d);
    (dm + trace(c1, d) + trace(c2, d) - 2.0 * trace(&cross, d)).max(0.0)
}

/// RBF-kernel MMD² (biased estimator) with bandwidth by the median
/// heuristic over a subsample.
pub fn mmd_rbf(a: &Tensor, b: &Tensor) -> f64 {
    let (na, nb) = (a.shape()[0], b.shape()[0]);
    let d = a.shape()[1];
    assert_eq!(b.shape()[1], d);

    let sq = |x: &[f64], y: &[f64]| -> f64 {
        x.iter().zip(y).map(|(p, q)| (p - q) * (p - q)).sum()
    };

    // Median heuristic over cross pairs (capped subsample).
    let cap = 200.min(na).min(nb);
    let mut d2s = Vec::with_capacity(cap * cap);
    for i in 0..cap {
        for j in 0..cap {
            d2s.push(sq(a.row(i * na / cap), b.row(j * nb / cap)));
        }
    }
    d2s.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let med = d2s[d2s.len() / 2].max(1e-12);
    let gamma = 1.0 / med;

    let mut kaa = 0.0;
    for i in 0..na {
        for j in 0..na {
            kaa += (-gamma * sq(a.row(i), a.row(j))).exp();
        }
    }
    let mut kbb = 0.0;
    for i in 0..nb {
        for j in 0..nb {
            kbb += (-gamma * sq(b.row(i), b.row(j))).exp();
        }
    }
    let mut kab = 0.0;
    for i in 0..na {
        for j in 0..nb {
            kab += (-gamma * sq(a.row(i), b.row(j))).exp();
        }
    }
    (kaa / (na * na) as f64 + kbb / (nb * nb) as f64 - 2.0 * kab / (na * nb) as f64).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gaussian_samples(rng: &mut Rng, n: usize, d: usize, mu: f64, s: f64) -> Tensor {
        let data = (0..n * d).map(|_| mu + s * rng.normal()).collect();
        Tensor::from_vec(&[n, d], data)
    }

    #[test]
    fn sw2_zero_for_identical_samples() {
        let mut rng = Rng::seed_from(1);
        let a = gaussian_samples(&mut rng, 500, 3, 0.0, 1.0);
        let mut rng2 = Rng::seed_from(99);
        let d = sliced_wasserstein2(&a, &a.clone(), 16, &mut rng2);
        assert!(d < 1e-12, "{d}");
    }

    #[test]
    fn sw2_detects_mean_shift() {
        // SW2 between shifted Gaussians must dwarf the same-distribution
        // estimator noise, and sit near sqrt(‖shift‖²/d) = 2 up to
        // finite-sample/tail-quantile bias.
        let mut rng = Rng::seed_from(2);
        let a = gaussian_samples(&mut rng, 2000, 3, 0.0, 1.0);
        let a2 = gaussian_samples(&mut rng, 2000, 3, 0.0, 1.0);
        let b = gaussian_samples(&mut rng, 2000, 3, 2.0, 1.0);
        let mut prng = Rng::seed_from(3);
        let d_same = sliced_wasserstein2(&a, &a2, 64, &mut prng);
        let mut prng = Rng::seed_from(3);
        let d_shift = sliced_wasserstein2(&a, &b, 64, &mut prng);
        assert!(d_shift > 10.0 * d_same, "shift {d_shift} vs same {d_same}");
        assert!((1.4..=2.8).contains(&d_shift), "{d_shift}");
    }

    #[test]
    fn frechet_zero_for_same_gaussian() {
        let mu = vec![1.0, -1.0];
        let c = vec![2.0, 0.3, 0.3, 1.0];
        let f = frechet_distance(&mu, &c, &mu, &c);
        assert!(f < 1e-9, "{f}");
    }

    #[test]
    fn frechet_matches_univariate_formula() {
        // d=1: F = (μ1−μ2)² + (σ1−σ2)².
        let f = frechet_distance(&[0.0], &[4.0], &[3.0], &[1.0]);
        assert!((f - (9.0 + 1.0)).abs() < 1e-9, "{f}");
    }

    #[test]
    fn gaussian_fit_recovers_moments() {
        let mut rng = Rng::seed_from(7);
        let x = gaussian_samples(&mut rng, 30_000, 2, 0.5, 2.0);
        let (mu, cov) = gaussian_fit(&x);
        assert!((mu[0] - 0.5).abs() < 0.05);
        assert!((cov[0] - 4.0).abs() < 0.15);
        assert!(cov[1].abs() < 0.1);
    }

    #[test]
    fn mmd_orders_distributions() {
        let mut rng = Rng::seed_from(11);
        let a = gaussian_samples(&mut rng, 300, 2, 0.0, 1.0);
        let near = gaussian_samples(&mut rng, 300, 2, 0.2, 1.0);
        let far = gaussian_samples(&mut rng, 300, 2, 3.0, 1.0);
        let d_near = mmd_rbf(&a, &near);
        let d_far = mmd_rbf(&a, &far);
        assert!(d_near < d_far, "{d_near} vs {d_far}");
    }
}
