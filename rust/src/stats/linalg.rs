//! Symmetric eigendecomposition (cyclic Jacobi) for the small (dim ≤ 64)
//! covariance matrices used by the Fréchet metric. No LAPACK offline, so we
//! roll the classic O(d³ · sweeps) rotation scheme; Jacobi is backward
//! stable and precise for symmetric matrices of this size.

/// Eigendecomposition of a symmetric matrix (row-major d×d).
/// Returns (eigenvalues, eigenvectors-as-columns row-major).
pub fn sym_eigen(a: &[f64], d: usize) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(a.len(), d * d);
    let mut m = a.to_vec();
    // v starts as identity; accumulates rotations.
    let mut v = vec![0.0; d * d];
    for i in 0..d {
        v[i * d + i] = 1.0;
    }

    for _sweep in 0..100 {
        // Off-diagonal Frobenius mass.
        let mut off = 0.0;
        for i in 0..d {
            for j in (i + 1)..d {
                off += m[i * d + j] * m[i * d + j];
            }
        }
        if off < 1e-24 {
            break;
        }
        for p in 0..d {
            for q in (p + 1)..d {
                let apq = m[p * d + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[p * d + p];
                let aqq = m[q * d + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;

                // Rows/cols p and q of m.
                for k in 0..d {
                    let mkp = m[k * d + p];
                    let mkq = m[k * d + q];
                    m[k * d + p] = c * mkp - s * mkq;
                    m[k * d + q] = s * mkp + c * mkq;
                }
                for k in 0..d {
                    let mpk = m[p * d + k];
                    let mqk = m[q * d + k];
                    m[p * d + k] = c * mpk - s * mqk;
                    m[q * d + k] = s * mpk + c * mqk;
                }
                for k in 0..d {
                    let vkp = v[k * d + p];
                    let vkq = v[k * d + q];
                    v[k * d + p] = c * vkp - s * vkq;
                    v[k * d + q] = s * vkp + c * vkq;
                }
            }
        }
    }
    let evals = (0..d).map(|i| m[i * d + i]).collect();
    (evals, v)
}

/// Symmetric PSD matrix square root via eigendecomposition (negative
/// eigenvalues from rounding are clamped to zero).
pub fn sym_sqrt(a: &[f64], d: usize) -> Vec<f64> {
    let (evals, v) = sym_eigen(a, d);
    let roots: Vec<f64> = evals.iter().map(|&e| e.max(0.0).sqrt()).collect();
    // V diag(sqrt) Vᵀ
    let mut out = vec![0.0; d * d];
    for i in 0..d {
        for j in 0..d {
            let mut s = 0.0;
            for k in 0..d {
                s += v[i * d + k] * roots[k] * v[j * d + k];
            }
            out[i * d + j] = s;
        }
    }
    out
}

/// C = A·B for row-major d×d matrices.
pub fn matmul(a: &[f64], b: &[f64], d: usize) -> Vec<f64> {
    let mut c = vec![0.0; d * d];
    for i in 0..d {
        for k in 0..d {
            let aik = a[i * d + k];
            if aik == 0.0 {
                continue;
            }
            for j in 0..d {
                c[i * d + j] += aik * b[k * d + j];
            }
        }
    }
    c
}

/// Trace.
pub fn trace(a: &[f64], d: usize) -> f64 {
    (0..d).map(|i| a[i * d + i]).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eigen_of_diagonal() {
        let a = [3.0, 0.0, 0.0, 7.0];
        let (mut e, _) = sym_eigen(&a, 2);
        e.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert!((e[0] - 3.0).abs() < 1e-12);
        assert!((e[1] - 7.0).abs() < 1e-12);
    }

    #[test]
    fn eigen_reconstructs_matrix() {
        let a = [2.0, 1.0, 0.5, 1.0, 3.0, -0.2, 0.5, -0.2, 1.5];
        let d = 3;
        let (e, v) = sym_eigen(&a, d);
        // A = V diag(e) Vᵀ
        for i in 0..d {
            for j in 0..d {
                let mut s = 0.0;
                for k in 0..d {
                    s += v[i * d + k] * e[k] * v[j * d + k];
                }
                assert!((s - a[i * d + j]).abs() < 1e-10, "({i},{j})");
            }
        }
    }

    #[test]
    fn sqrt_squares_back() {
        let a = [4.0, 2.0, 2.0, 5.0];
        let r = sym_sqrt(&a, 2);
        let sq = matmul(&r, &r, 2);
        for (x, y) in sq.iter().zip(a.iter()) {
            assert!((x - y).abs() < 1e-10);
        }
    }

    #[test]
    fn trace_and_matmul() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [0.0, 1.0, 1.0, 0.0];
        let c = matmul(&a, &b, 2);
        assert_eq!(c, vec![2.0, 1.0, 4.0, 3.0]);
        assert_eq!(trace(&a, 2), 5.0);
    }
}
