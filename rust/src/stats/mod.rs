//! Metrics substrates: distribution distances (the FID stand-ins of
//! DESIGN.md §2) and serving-side latency statistics.

pub mod distances;
pub mod latency;
pub mod linalg;

pub use distances::{frechet_distance, gaussian_fit, mmd_rbf, sliced_wasserstein2};
pub use latency::LatencyDigest;
pub use linalg::sym_eigen;
