//! Latency statistics for the serving benches: a simple sorted-sample digest
//! with exact percentiles (request volumes here are small enough that an
//! approximate sketch would be over-engineering).

use std::time::Duration;

/// Collects latency samples and reports count/mean/percentiles.
#[derive(Clone, Debug, Default)]
pub struct LatencyDigest {
    samples_us: Vec<u64>,
    sorted: bool,
}

impl LatencyDigest {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
        self.sorted = false;
    }

    pub fn record_us(&mut self, us: u64) {
        self.samples_us.push(us);
        self.sorted = false;
    }

    pub fn merge(&mut self, other: &LatencyDigest) {
        self.samples_us.extend_from_slice(&other.samples_us);
        self.sorted = false;
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    pub fn mean_us(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<u64>() as f64 / self.samples_us.len() as f64
    }

    /// Exact percentile (nearest-rank), `p` in [0, 100].
    pub fn percentile_us(&mut self, p: f64) -> u64 {
        assert!((0.0..=100.0).contains(&p));
        if self.samples_us.is_empty() {
            return 0;
        }
        if !self.sorted {
            self.samples_us.sort_unstable();
            self.sorted = true;
        }
        let rank = ((p / 100.0) * (self.samples_us.len() - 1) as f64).round() as usize;
        self.samples_us[rank]
    }

    /// The raw samples in canonical (sorted) order — the merge property
    /// tests fingerprint digests with this, and the Prometheus summary
    /// exposition derives its exact `_sum` from it.
    pub fn samples_sorted(&mut self) -> &[u64] {
        if !self.sorted {
            self.samples_us.sort_unstable();
            self.sorted = true;
        }
        &self.samples_us
    }

    /// "p50/p95/p99 (mean) over n" one-liner for logs.
    pub fn summary(&mut self) -> String {
        let n = self.count();
        if n == 0 {
            return "no samples".into();
        }
        let (p50, p95, p99) = (
            self.percentile_us(50.0),
            self.percentile_us(95.0),
            self.percentile_us(99.0),
        );
        format!(
            "p50={:.2}ms p95={:.2}ms p99={:.2}ms mean={:.2}ms n={}",
            p50 as f64 / 1e3,
            p95 as f64 / 1e3,
            p99 as f64 / 1e3,
            self.mean_us() / 1e3,
            n
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_of_known_sequence() {
        let mut d = LatencyDigest::new();
        for v in 1..=100u64 {
            d.record_us(v * 1000);
        }
        assert_eq!(d.count(), 100);
        assert_eq!(d.percentile_us(0.0), 1000);
        assert_eq!(d.percentile_us(100.0), 100_000);
        let p50 = d.percentile_us(50.0);
        assert!((49_000..=51_000).contains(&p50), "{p50}");
    }

    #[test]
    fn merge_combines_samples() {
        let mut a = LatencyDigest::new();
        a.record_us(10);
        let mut b = LatencyDigest::new();
        b.record_us(20);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.mean_us() - 15.0).abs() < 1e-9);
    }

    #[test]
    fn empty_digest_is_safe() {
        let mut d = LatencyDigest::new();
        assert_eq!(d.percentile_us(99.0), 0);
        assert_eq!(d.summary(), "no samples");
    }
}
