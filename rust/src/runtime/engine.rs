//! The PJRT executor thread + dynamic batcher.
//!
//! One OS thread owns the (non-`Send`) PJRT client, the compiled
//! executables, and the weight literals. Everyone else talks to it through
//! a cloneable [`PjrtHandle`]. The executor drains its queue with a short
//! batching window: compatible evaluation jobs (same entry-point kind and
//! guidance scale) are coalesced into one padded call against the smallest
//! compiled batch size that fits — the serving paper's dynamic batching,
//! applied per diffusion step. Per-row timestep/label vectors mean
//! requests at *different* solver steps still share a call.

use super::manifest::Manifest;
use crate::log;
use crate::solver::{Model, Prediction};
use crate::tensor::Tensor;
use crate::weights::WeightsFile;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Inert stand-in for the `xla` PJRT bindings, which are absent from the
/// offline registry. The executor code below is written against the real
/// crate's API; this stub satisfies the type-checker while making every
/// entry point fail fast: `PjRtClient::cpu()` returns an error, so
/// `PjrtHandle::spawn` reports "pjrt unavailable" cleanly and every
/// caller (the serve command, benches, tests) falls back to the analytic
/// backend. Swapping in the real bindings means deleting this module and
/// adding the dependency — no executor code changes.
mod xla {
    use std::path::Path;

    /// The one error every stubbed entry point returns.
    pub struct Unavailable;

    impl std::fmt::Debug for Unavailable {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.write_str("pjrt unavailable: xla bindings not present in this build")
        }
    }

    pub struct PjRtClient;

    impl PjRtClient {
        pub fn cpu() -> Result<PjRtClient, Unavailable> {
            Err(Unavailable)
        }

        pub fn compile(
            &self,
            _comp: &XlaComputation,
        ) -> Result<PjRtLoadedExecutable, Unavailable> {
            Err(Unavailable)
        }
    }

    pub struct Literal;

    impl Literal {
        pub fn vec1<T: Copy>(_v: &[T]) -> Literal {
            Literal
        }

        pub fn scalar(_v: f32) -> Literal {
            Literal
        }

        pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Unavailable> {
            Err(Unavailable)
        }

        pub fn to_tuple1(&self) -> Result<Literal, Unavailable> {
            Err(Unavailable)
        }

        pub fn to_tuple2(&self) -> Result<(Literal, Literal), Unavailable> {
            Err(Unavailable)
        }

        pub fn to_vec<T>(&self) -> Result<Vec<T>, Unavailable> {
            Err(Unavailable)
        }
    }

    pub struct HloModuleProto;

    impl HloModuleProto {
        pub fn from_text_file<P: AsRef<Path>>(
            _path: P,
        ) -> Result<HloModuleProto, Unavailable> {
            Err(Unavailable)
        }
    }

    pub struct XlaComputation;

    impl XlaComputation {
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }

    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Unavailable> {
            Err(Unavailable)
        }
    }

    pub struct PjRtBuffer;

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal, Unavailable> {
            Err(Unavailable)
        }
    }
}

/// Executor tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct EngineOptions {
    /// Maximum rows coalesced into one PJRT call.
    pub max_batch: usize,
    /// How long to wait for more compatible jobs once one is pending.
    pub batch_wait: Duration,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions { max_batch: 64, batch_wait: Duration::from_micros(200) }
    }
}

/// Executor-side statistics (batching effectiveness, §Perf-L3).
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub calls: u64,
    pub rows: u64,
    pub coalesced_jobs: u64,
    pub padded_rows: u64,
    /// Histogram over executed batch sizes (index = compiled batch).
    pub batch_hist: Vec<(usize, u64)>,
}

impl EngineStats {
    pub fn mean_rows_per_call(&self) -> f64 {
        if self.calls == 0 {
            0.0
        } else {
            self.rows as f64 / self.calls as f64
        }
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum EvalKind {
    Eps,
    /// Guidance scale carried as bits so it can be a hash/eq key.
    EpsCfg { scale_bits: u32 },
}

struct EvalJob {
    kind: EvalKind,
    rows: usize,
    x: Vec<f32>,
    t: Vec<f32>,
    y: Vec<i32>,
    reply: mpsc::Sender<Result<Vec<f32>>>,
}

#[allow(clippy::large_enum_variant)]
enum Job {
    Eval(EvalJob),
    Correct {
        rows: usize,
        x_pred: Vec<f32>,
        t: Vec<f32>,
        y: Vec<i32>,
        x_prev: Vec<f32>,
        m0: Vec<f32>,
        d1s: Vec<f32>,
        coeffs: Vec<f32>,
        reply: mpsc::Sender<Result<(Vec<f32>, Vec<f32>)>>,
    },
    Stats(mpsc::Sender<EngineStats>),
    Shutdown,
}

/// Cloneable, `Send` handle to the executor thread.
#[derive(Clone)]
pub struct PjrtHandle {
    tx: mpsc::Sender<Job>,
    pub dim: usize,
    pub n_classes: usize,
    pub fused_p: usize,
}

impl PjrtHandle {
    /// Start the executor: loads the manifest + weights, creates the PJRT
    /// CPU client on a dedicated thread, and compiles entry points lazily.
    pub fn spawn(artifacts_dir: &Path, weights: Option<&Path>, opts: EngineOptions) -> Result<PjrtHandle> {
        let manifest = Manifest::load(artifacts_dir)?;
        let weights_path =
            weights.map(PathBuf::from).unwrap_or_else(|| artifacts_dir.join(&manifest.weights_file));
        let weights = WeightsFile::load(&weights_path)?;
        // Validate against the manifest before starting the thread.
        for name in &manifest.param_names {
            let t = weights
                .get(name)
                .ok_or_else(|| anyhow!("weights missing parameter '{name}'"))?;
            let want = &manifest.param_shapes[name];
            if &t.dims != want {
                bail!("param '{name}': weights shape {:?} != manifest {:?}", t.dims, want);
            }
        }

        let (tx, rx) = mpsc::channel::<Job>();
        let (init_tx, init_rx) = mpsc::channel::<Result<()>>();
        let dim = manifest.model.dim;
        let n_classes = manifest.model.n_classes;
        let fused_p = manifest.fused_p;
        std::thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || executor_main(manifest, weights, opts, rx, init_tx))
            .context("spawn pjrt executor")?;
        init_rx
            .recv()
            .context("pjrt executor died during init")??;
        Ok(PjrtHandle { tx, dim, n_classes, fused_p })
    }

    /// Unconditional/conditional ε evaluation (rows share nothing; per-row t, y).
    pub fn eps(&self, x: Vec<f32>, t: Vec<f32>, y: Vec<i32>) -> Result<Vec<f32>> {
        self.eval(EvalKind::Eps, x, t, y)
    }

    /// Classifier-free-guided ε.
    pub fn eps_cfg(&self, x: Vec<f32>, t: Vec<f32>, y: Vec<i32>, scale: f32) -> Result<Vec<f32>> {
        self.eval(EvalKind::EpsCfg { scale_bits: scale.to_bits() }, x, t, y)
    }

    fn eval(&self, kind: EvalKind, x: Vec<f32>, t: Vec<f32>, y: Vec<i32>) -> Result<Vec<f32>> {
        let rows = t.len();
        if rows == 0 || x.len() != rows * self.dim || y.len() != rows {
            bail!("eval: inconsistent input lengths");
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Job::Eval(EvalJob { kind, rows, x, t, y, reply: reply_tx }))
            .map_err(|_| anyhow!("pjrt executor is gone"))?;
        reply_rx.recv().map_err(|_| anyhow!("pjrt executor dropped reply"))?
    }

    /// Fused model-eval + UniC correction (one PJRT call; §Perf).
    /// `d1s` is `[fused_p, rows, dim]` flattened; `coeffs` is
    /// `[c_1..c_P, c_{P+1}, a, b, s]` (see aot.py `lower_correct`).
    #[allow(clippy::too_many_arguments)]
    pub fn fused_correct(
        &self,
        x_pred: Vec<f32>,
        t: Vec<f32>,
        y: Vec<i32>,
        x_prev: Vec<f32>,
        m0: Vec<f32>,
        d1s: Vec<f32>,
        coeffs: Vec<f32>,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let rows = t.len();
        if coeffs.len() != self.fused_p + 4 || d1s.len() != self.fused_p * rows * self.dim {
            bail!("fused_correct: inconsistent coeff/buffer lengths");
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Job::Correct {
                rows,
                x_pred,
                t,
                y,
                x_prev,
                m0,
                d1s,
                coeffs,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("pjrt executor is gone"))?;
        reply_rx.recv().map_err(|_| anyhow!("pjrt executor dropped reply"))?
    }

    pub fn stats(&self) -> Result<EngineStats> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Job::Stats(tx)).map_err(|_| anyhow!("pjrt executor is gone"))?;
        rx.recv().map_err(|_| anyhow!("pjrt executor dropped reply"))
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Job::Shutdown);
    }
}

// ---------------------------------------------------------------------------
// Executor thread
// ---------------------------------------------------------------------------

struct Executor {
    manifest: Manifest,
    client: xla::PjRtClient,
    params: Vec<xla::Literal>,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    stats: EngineStats,
    hist: HashMap<usize, u64>,
}

fn executor_main(
    manifest: Manifest,
    weights: WeightsFile,
    opts: EngineOptions,
    rx: mpsc::Receiver<Job>,
    init_tx: mpsc::Sender<Result<()>>,
) {
    let mut exec = match Executor::new(manifest, weights) {
        Ok(e) => {
            let _ = init_tx.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = init_tx.send(Err(e));
            return;
        }
    };

    let mut backlog: Vec<Job> = Vec::new();
    loop {
        let job = if let Some(j) = pop_front(&mut backlog) {
            j
        } else {
            match rx.recv() {
                Ok(j) => j,
                Err(_) => break,
            }
        };
        match job {
            Job::Shutdown => break,
            Job::Stats(reply) => {
                let mut s = exec.stats.clone();
                let mut hist: Vec<(usize, u64)> = exec.hist.iter().map(|(&k, &v)| (k, v)).collect();
                hist.sort_unstable();
                s.batch_hist = hist;
                let _ = reply.send(s);
            }
            Job::Correct { rows, x_pred, t, y, x_prev, m0, d1s, coeffs, reply } => {
                let r = exec.run_correct(rows, &x_pred, &t, &y, &x_prev, &m0, &d1s, &coeffs);
                let _ = reply.send(r);
            }
            Job::Eval(first) => {
                // Batching window: gather compatible eval jobs.
                let mut group = vec![first];
                let mut rows: usize = group[0].rows;
                let kind = group[0].kind;
                let deadline = Instant::now() + opts.batch_wait;
                // Drain backlog first (older jobs), then the live queue.
                let mut i = 0;
                while i < backlog.len() {
                    if rows >= opts.max_batch {
                        break;
                    }
                    let compatible = matches!(&backlog[i], Job::Eval(j)
                        if j.kind == kind && rows + j.rows <= opts.max_batch);
                    if compatible {
                        if let Job::Eval(j) = backlog.remove(i) {
                            rows += j.rows;
                            group.push(j);
                        }
                    } else {
                        i += 1;
                    }
                }
                while rows < opts.max_batch {
                    let left = deadline.saturating_duration_since(Instant::now());
                    if left.is_zero() {
                        break;
                    }
                    match rx.recv_timeout(left) {
                        Ok(Job::Eval(j))
                            if j.kind == kind && rows + j.rows <= opts.max_batch =>
                        {
                            rows += j.rows;
                            group.push(j);
                        }
                        Ok(other) => backlog.push(other),
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }
                exec.run_eval_group(kind, group, rows);
            }
        }
    }
}

fn pop_front(v: &mut Vec<Job>) -> Option<Job> {
    if v.is_empty() {
        None
    } else {
        Some(v.remove(0))
    }
}

impl Executor {
    fn new(manifest: Manifest, weights: WeightsFile) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let ordered = weights.ordered(&manifest.param_names)?;
        let params = ordered
            .iter()
            .map(|t| {
                let lit = xla::Literal::vec1(&t.data);
                if t.dims.len() == 1 {
                    Ok(lit)
                } else {
                    let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
                    lit.reshape(&dims).map_err(|e| anyhow!("reshape {}: {e:?}", t.name))
                }
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Executor {
            manifest,
            client,
            params,
            exes: HashMap::new(),
            stats: EngineStats::default(),
            hist: HashMap::new(),
        })
    }

    /// Compile (once) and cache the executable for (kind, batch); returns
    /// its cache key so callers can re-borrow immutably alongside params.
    fn ensure_exe(&mut self, kind: &str, batch: usize) -> Result<String> {
        let key = format!("{kind}_b{batch}");
        if !self.exes.contains_key(&key) {
            let info = self.manifest.artifact(kind, batch)?;
            let path = self.manifest.dir.join(&info.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("load {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {key}: {e:?}"))?;
            log::info!("compiled artifact {key}");
            self.exes.insert(key.clone(), exe);
        }
        Ok(key)
    }

    /// Execute one coalesced eval group, scattering per-job replies.
    fn run_eval_group(&mut self, kind: EvalKind, group: Vec<EvalJob>, rows: usize) {
        let dim = self.manifest.model.dim;
        let mut x = Vec::with_capacity(rows * dim);
        let mut t = Vec::with_capacity(rows);
        let mut y = Vec::with_capacity(rows);
        for j in &group {
            x.extend_from_slice(&j.x);
            t.extend_from_slice(&j.t);
            y.extend_from_slice(&j.y);
        }
        let result = self.run_eval(kind, rows, &x, &t, &y);
        match result {
            Ok(out) => {
                let mut off = 0;
                for j in &group {
                    let slice = out[off * dim..(off + j.rows) * dim].to_vec();
                    off += j.rows;
                    let _ = j.reply.send(Ok(slice));
                }
                self.stats.coalesced_jobs += group.len() as u64;
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for j in &group {
                    let _ = j.reply.send(Err(anyhow!("{msg}")));
                }
            }
        }
    }

    fn run_eval(&mut self, kind: EvalKind, rows: usize, x: &[f32], t: &[f32], y: &[i32]) -> Result<Vec<f32>> {
        let dim = self.manifest.model.dim;
        let max_compiled = *self.manifest.batches.last().unwrap();
        let mut out = Vec::with_capacity(rows * dim);
        let mut start = 0;
        while start < rows {
            let chunk = (rows - start).min(max_compiled);
            let part = self.run_eval_chunk(
                kind,
                chunk,
                &x[start * dim..(start + chunk) * dim],
                &t[start..start + chunk],
                &y[start..start + chunk],
            )?;
            out.extend_from_slice(&part);
            start += chunk;
        }
        Ok(out)
    }

    fn run_eval_chunk(&mut self, kind: EvalKind, rows: usize, x: &[f32], t: &[f32], y: &[i32]) -> Result<Vec<f32>> {
        let dim = self.manifest.model.dim;
        let batch = self.manifest.batch_for(rows)?;
        let (kind_str, scale) = match kind {
            EvalKind::Eps => ("eps", None),
            EvalKind::EpsCfg { scale_bits } => ("eps_cfg", Some(f32::from_bits(scale_bits))),
        };

        // Pad to the compiled batch by repeating the last row.
        let (xp, tp, yp) = pad_inputs(x, t, y, rows, batch, dim);
        let x_lit = xla::Literal::vec1(&xp)
            .reshape(&[batch as i64, dim as i64])
            .map_err(|e| anyhow!("reshape x: {e:?}"))?;
        let t_lit = xla::Literal::vec1(&tp);
        let y_lit = xla::Literal::vec1(&yp);

        let key = self.ensure_exe(kind_str, batch)?;
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.push(&x_lit);
        inputs.push(&t_lit);
        inputs.push(&y_lit);
        let scale_lit;
        if let Some(s) = scale {
            scale_lit = xla::Literal::scalar(s);
            inputs.push(&scale_lit);
        }

        let exe = &self.exes[&key];
        let result = exe
            .execute::<&xla::Literal>(&inputs)
            .map_err(|e| anyhow!("execute {kind_str}_b{batch}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let tup = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let mut data = tup.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        data.truncate(rows * dim);

        self.stats.calls += 1;
        self.stats.rows += rows as u64;
        self.stats.padded_rows += (batch - rows) as u64;
        *self.hist.entry(batch).or_default() += 1;
        Ok(data)
    }

    #[allow(clippy::too_many_arguments)]
    fn run_correct(
        &mut self,
        rows: usize,
        x_pred: &[f32],
        t: &[f32],
        y: &[i32],
        x_prev: &[f32],
        m0: &[f32],
        d1s: &[f32],
        coeffs: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let dim = self.manifest.model.dim;
        let p = self.manifest.fused_p;
        let batch = self.manifest.batch_for(rows)?;

        let (xp, tp, yp) = pad_inputs(x_pred, t, y, rows, batch, dim);
        let (xv, _, _) = pad_inputs(x_prev, t, y, rows, batch, dim);
        let (m0p, _, _) = pad_inputs(m0, t, y, rows, batch, dim);
        // Pad the buffer per plane.
        let mut d1sp = Vec::with_capacity(p * batch * dim);
        for plane in 0..p {
            let src = &d1s[plane * rows * dim..(plane + 1) * rows * dim];
            let (pp, _, _) = pad_inputs(src, t, y, rows, batch, dim);
            d1sp.extend_from_slice(&pp);
        }

        let mk = |v: &[f32], dims: &[i64]| -> Result<xla::Literal> {
            xla::Literal::vec1(v)
                .reshape(dims)
                .map_err(|e| anyhow!("reshape: {e:?}"))
        };
        let x_lit = mk(&xp, &[batch as i64, dim as i64])?;
        let t_lit = xla::Literal::vec1(&tp);
        let y_lit = xla::Literal::vec1(&yp);
        let xprev_lit = mk(&xv, &[batch as i64, dim as i64])?;
        let m0_lit = mk(&m0p, &[batch as i64, dim as i64])?;
        let d1s_lit = mk(&d1sp, &[p as i64, batch as i64, dim as i64])?;
        let coef_lit = xla::Literal::vec1(coeffs);

        let key = self.ensure_exe("correct", batch)?;
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.extend([&x_lit, &t_lit, &y_lit, &xprev_lit, &m0_lit, &d1s_lit, &coef_lit]);

        let exe = &self.exes[&key];
        let result = exe
            .execute::<&xla::Literal>(&inputs)
            .map_err(|e| anyhow!("execute correct_b{batch}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let (xc, mt) = lit.to_tuple2().map_err(|e| anyhow!("untuple2: {e:?}"))?;
        let mut xc = xc.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        let mut mt = mt.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        xc.truncate(rows * dim);
        mt.truncate(rows * dim);

        self.stats.calls += 1;
        self.stats.rows += rows as u64;
        self.stats.padded_rows += (batch - rows) as u64;
        *self.hist.entry(batch).or_default() += 1;
        Ok((xc, mt))
    }
}

fn pad_inputs(
    x: &[f32],
    t: &[f32],
    y: &[i32],
    rows: usize,
    batch: usize,
    dim: usize,
) -> (Vec<f32>, Vec<f32>, Vec<i32>) {
    let mut xp = x.to_vec();
    let mut tp = t.to_vec();
    let mut yp = y.to_vec();
    for _ in rows..batch {
        let last = (rows - 1) * dim;
        xp.extend_from_within(last..last + dim);
        tp.push(t[rows - 1]);
        yp.push(y[rows - 1]);
    }
    (xp, tp, yp)
}

// ---------------------------------------------------------------------------
// Model adapter
// ---------------------------------------------------------------------------

/// Adapts a [`PjrtHandle`] to the [`Model`] trait so all solvers run
/// against the learned network. Each uniform cohort — or each conditioning
/// slab of a mixed cohort (`coordinator::CohortModel` holds one adapter per
/// slab) — evaluates through its own adapter with its class/guidance
/// configuration; concurrent adapter calls batch together inside the
/// executor, so per-slab calls still coalesce into padded device batches.
pub struct PjrtModel {
    pub handle: PjrtHandle,
    /// Class label; `None` = unconditional (the null class).
    pub class: Option<usize>,
    /// Classifier-free guidance scale; `None` or 0.0 = plain conditional.
    pub guidance: Option<f64>,
}

impl PjrtModel {
    pub fn new(handle: PjrtHandle) -> Self {
        PjrtModel { handle, class: None, guidance: None }
    }

    pub fn with_class(mut self, class: usize, guidance: Option<f64>) -> Self {
        self.class = Some(class);
        self.guidance = guidance;
        self
    }
}

impl Model for PjrtModel {
    fn prediction(&self) -> Prediction {
        Prediction::Noise
    }

    fn eval(&self, x: &Tensor, t: f64) -> Tensor {
        let rows = x.batch();
        let xf = x.to_f32();
        let tf = vec![t as f32; rows];
        let label = self.class.unwrap_or(self.handle.n_classes) as i32;
        let yf = vec![label; rows];
        let out = match self.guidance {
            Some(s) if s != 0.0 => self.handle.eps_cfg(xf, tf, yf, s as f32),
            _ => self.handle.eps(xf, tf, yf),
        }
        .expect("pjrt eval failed");
        Tensor::from_f32(x.shape(), &out)
    }

    fn dim(&self) -> usize {
        self.handle.dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_repeats_last_row() {
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let t = [0.5f32, 0.6];
        let y = [1i32, 2];
        let (xp, tp, yp) = pad_inputs(&x, &t, &y, 2, 4, 2);
        assert_eq!(xp, vec![1.0, 2.0, 3.0, 4.0, 3.0, 4.0, 3.0, 4.0]);
        assert_eq!(tp, vec![0.5, 0.6, 0.6, 0.6]);
        assert_eq!(yp, vec![1, 2, 2, 2]);
    }

    #[test]
    fn eval_kind_compat_keys() {
        let a = EvalKind::EpsCfg { scale_bits: 1.5f32.to_bits() };
        let b = EvalKind::EpsCfg { scale_bits: 1.5f32.to_bits() };
        let c = EvalKind::EpsCfg { scale_bits: 2.0f32.to_bits() };
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, EvalKind::Eps);
    }
}
