//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the Rust runtime.

use crate::json::{self, Value};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Model hyper-parameters (mirrors python `ModelConfig`).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelCfg {
    pub dim: usize,
    pub width: usize,
    pub depth: usize,
    pub tokens: usize,
    pub n_classes: usize,
    pub temb_dim: usize,
}

/// One lowered entry point.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactInfo {
    pub file: String,
    pub kind: String,
    pub batch: usize,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelCfg,
    pub param_names: Vec<String>,
    pub param_shapes: BTreeMap<String, Vec<usize>>,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
    pub batches: Vec<usize>,
    pub fused_p: usize,
    pub beta_0: f64,
    pub beta_1: f64,
    pub weights_file: String,
    pub mixture_file: String,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let v = json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        Self::from_json(dir, &v)
    }

    pub fn from_json(dir: &Path, v: &Value) -> Result<Self> {
        let model = v.get("model").ok_or_else(|| anyhow!("manifest missing 'model'"))?;
        let g = |k: &str| -> Result<usize> {
            model
                .get(k)
                .and_then(Value::as_usize)
                .ok_or_else(|| anyhow!("model.{k} missing/invalid"))
        };
        let model = ModelCfg {
            dim: g("dim")?,
            width: g("width")?,
            depth: g("depth")?,
            tokens: g("tokens")?,
            n_classes: g("n_classes")?,
            temb_dim: g("temb_dim")?,
        };

        let param_names: Vec<String> = v
            .get("param_names")
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'param_names'"))?
            .iter()
            .map(|n| n.as_str().map(str::to_string).ok_or_else(|| anyhow!("bad param name")))
            .collect::<Result<_>>()?;

        let mut param_shapes = BTreeMap::new();
        if let Some(Value::Obj(m)) = v.get("param_shapes") {
            for (k, s) in m {
                let dims = s
                    .as_arr()
                    .ok_or_else(|| anyhow!("param_shapes.{k} not an array"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<Vec<_>>>()?;
                param_shapes.insert(k.clone(), dims);
            }
        } else {
            bail!("manifest missing 'param_shapes'");
        }
        for n in &param_names {
            if !param_shapes.contains_key(n) {
                bail!("param '{n}' has no shape entry");
            }
        }

        let mut artifacts = BTreeMap::new();
        if let Some(Value::Obj(m)) = v.get("artifacts") {
            for (k, a) in m {
                artifacts.insert(
                    k.clone(),
                    ArtifactInfo {
                        file: a
                            .get("file")
                            .and_then(Value::as_str)
                            .ok_or_else(|| anyhow!("artifact {k} missing file"))?
                            .to_string(),
                        kind: a
                            .get("kind")
                            .and_then(Value::as_str)
                            .unwrap_or_default()
                            .to_string(),
                        batch: a
                            .get("batch")
                            .and_then(Value::as_usize)
                            .ok_or_else(|| anyhow!("artifact {k} missing batch"))?,
                    },
                );
            }
        } else {
            bail!("manifest missing 'artifacts'");
        }

        let mut batches: Vec<usize> = v
            .get("batches")
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'batches'"))?
            .iter()
            .map(|b| b.as_usize().ok_or_else(|| anyhow!("bad batch")))
            .collect::<Result<_>>()?;
        batches.sort_unstable();

        let sched = v.get("schedule").ok_or_else(|| anyhow!("manifest missing 'schedule'"))?;
        let beta_0 = sched.get("beta_0").and_then(Value::as_f64).unwrap_or(0.1);
        let beta_1 = sched.get("beta_1").and_then(Value::as_f64).unwrap_or(20.0);

        Ok(Manifest {
            dir: dir.to_path_buf(),
            model,
            param_names,
            param_shapes,
            artifacts,
            batches,
            fused_p: v.get("fused_p").and_then(Value::as_usize).unwrap_or(3),
            beta_0,
            beta_1,
            weights_file: v
                .get("weights")
                .and_then(Value::as_str)
                .unwrap_or("model.upw")
                .to_string(),
            mixture_file: v
                .get("mixture")
                .and_then(Value::as_str)
                .unwrap_or("mixture.json")
                .to_string(),
        })
    }

    /// Smallest compiled batch size that fits `rows`.
    pub fn batch_for(&self, rows: usize) -> Result<usize> {
        self.batches
            .iter()
            .copied()
            .find(|&b| b >= rows)
            .ok_or_else(|| anyhow!("no artifact batch fits {rows} rows (max {:?})", self.batches.last()))
    }

    /// Artifact name for (kind, batch).
    pub fn artifact(&self, kind: &str, batch: usize) -> Result<&ArtifactInfo> {
        let key = format!("{kind}_b{batch}");
        self.artifacts
            .get(&key)
            .ok_or_else(|| anyhow!("manifest has no artifact '{key}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> Value {
        json::parse(
            r#"{
          "model": {"dim": 4, "width": 16, "depth": 1, "tokens": 2, "n_classes": 3, "temb_dim": 8},
          "param_names": ["a", "b"],
          "param_shapes": {"a": [4, 16], "b": [16]},
          "schedule": {"kind": "vp_linear", "beta_0": 0.1, "beta_1": 20},
          "fused_p": 3,
          "batches": [4, 1, 16],
          "artifacts": {
            "eps_b1": {"file": "eps_b1.hlo.txt", "kind": "eps", "batch": 1},
            "eps_b4": {"file": "eps_b4.hlo.txt", "kind": "eps", "batch": 4}
          },
          "weights": "model.upw",
          "mixture": "mixture.json"
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_and_sorts_batches() {
        let m = Manifest::from_json(Path::new("/tmp"), &sample_json()).unwrap();
        assert_eq!(m.batches, vec![1, 4, 16]);
        assert_eq!(m.model.dim, 4);
        assert_eq!(m.param_names, vec!["a", "b"]);
    }

    #[test]
    fn batch_selection() {
        let m = Manifest::from_json(Path::new("/tmp"), &sample_json()).unwrap();
        assert_eq!(m.batch_for(1).unwrap(), 1);
        assert_eq!(m.batch_for(3).unwrap(), 4);
        assert_eq!(m.batch_for(16).unwrap(), 16);
        assert!(m.batch_for(17).is_err());
    }

    #[test]
    fn artifact_lookup() {
        let m = Manifest::from_json(Path::new("/tmp"), &sample_json()).unwrap();
        assert_eq!(m.artifact("eps", 4).unwrap().file, "eps_b4.hlo.txt");
        assert!(m.artifact("eps", 2).is_err());
    }

    #[test]
    fn missing_fields_rejected() {
        let v = json::parse(r#"{"model": {}}"#).unwrap();
        assert!(Manifest::from_json(Path::new("/tmp"), &v).is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        // When `make artifacts` has run, validate the real file end-to-end.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.param_names.is_empty());
            assert!(m.artifacts.contains_key("eps_b1"));
        }
    }
}
