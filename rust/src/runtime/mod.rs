//! PJRT runtime: executes the AOT-compiled JAX/Pallas artifacts from Rust.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so all PJRT
//! state lives on one dedicated **executor thread** ([`engine`]). That
//! thread is also the serving stack's *dynamic batcher*: concurrent model
//! evaluations from all in-flight sampling requests funnel into its queue
//! and are coalesced into one padded PJRT call (the artifacts take a
//! per-row timestep vector, so requests at different diffusion steps share
//! a batch — continuous batching for diffusion).
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (parameter order,
//!   artifact registry, schedule constants).
//! * [`engine`] — executor thread + cloneable [`engine::PjrtHandle`];
//!   [`engine::PjrtModel`] adapts a handle to the [`crate::solver::Model`]
//!   trait so every solver in this crate can run against the learned model.

pub mod engine;
pub mod manifest;

pub use engine::{EngineOptions, PjrtHandle, PjrtModel};
pub use manifest::Manifest;
