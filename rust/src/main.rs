//! `unipc` — the serving launcher + utility CLI.
//!
//! Subcommands:
//!   serve        start the sampling server (PJRT backend when artifacts
//!                exist, analytic backend otherwise)
//!   sample       one-shot sampling to stdout/JSON
//!   client       fire a request at a running server
//!   trace-demo   headless serve + load + Chrome trace artifact
//!   slo-demo     headless SLO burn-rate breach demo (chaos + subscription)
//!   order-sweep  empirical order-of-convergence study (analytic model)
//!   info         print manifest/weights/artifact info

use std::path::Path;
use std::sync::Arc;

use unipc::analytic::datasets::{dataset, DatasetSpec};
use unipc::cli::{usage, Args, OptSpec};
use unipc::config::ServerConfig;
use unipc::coordinator::{ModelBackend, SampleRequest, Service};
use unipc::log;
use unipc::runtime::{EngineOptions, PjrtHandle};
use unipc::server::{Client, Server};

fn main() {
    let (sub, args) = Args::from_env();
    let code = match sub.as_str() {
        "serve" => cmd_serve(&args),
        "sample" => cmd_sample(&args),
        "client" => cmd_client(&args),
        "trace-demo" => cmd_trace_demo(&args),
        "slo-demo" => cmd_slo_demo(&args),
        "order-sweep" => cmd_order_sweep(&args),
        "info" => cmd_info(&args),
        "" | "help" | "--help" => {
            print!("{}", top_usage());
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n{}", top_usage());
            std::process::exit(2);
        }
    }
    .map(|_| 0)
    .unwrap_or_else(|e: anyhow::Error| {
        eprintln!("error: {e:#}");
        1
    });
    std::process::exit(code);
}

fn top_usage() -> String {
    "unipc — UniPC diffusion sampling server\n\n\
     subcommands:\n\
    \x20 serve        start the TCP sampling server\n\
    \x20 sample       one-shot sampling (no server)\n\
    \x20 client       send a request to a running server\n\
    \x20 trace-demo   headless serve + load + Chrome trace artifact\n\
    \x20 slo-demo     headless SLO burn-rate breach demo\n\
    \x20 order-sweep  empirical convergence orders on the analytic model\n\
    \x20 info         inspect artifacts + weights\n"
        .to_string()
}

/// Build the backend: PJRT over artifacts when present, analytic otherwise.
fn backend_from(cfg: &ServerConfig, force_analytic: bool) -> anyhow::Result<ModelBackend> {
    let have_artifacts = cfg.artifacts_dir.join("manifest.json").exists()
        && cfg
            .weights
            .clone()
            .unwrap_or_else(|| cfg.artifacts_dir.join("model.upw"))
            .exists();
    if have_artifacts && !force_analytic {
        let handle = PjrtHandle::spawn(
            &cfg.artifacts_dir,
            cfg.weights.as_deref(),
            EngineOptions {
                max_batch: cfg.max_batch,
                batch_wait: std::time::Duration::from_micros(cfg.batch_wait_us),
            },
        )?;
        eprintln!("backend: pjrt (dim {}, {} classes)", handle.dim, handle.n_classes);
        Ok(ModelBackend::Pjrt(handle))
    } else {
        let spec = DatasetSpec::Cifar10Like;
        let gm = Arc::new(dataset(spec));
        let classes = (0..spec.n_classes()).map(|c| spec.class_components(c)).collect();
        eprintln!("backend: analytic ({})", spec.name());
        Ok(ModelBackend::Analytic { gm, class_components: Arc::new(classes) })
    }
}

fn load_config(args: &Args) -> anyhow::Result<ServerConfig> {
    let base = match args.get("config") {
        Some(path) => ServerConfig::from_file(Path::new(path))?,
        None => ServerConfig::default(),
    };
    base.apply_args(args)
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    if args.flag("help") {
        print!(
            "{}",
            usage(
                "serve",
                "start the sampling server",
                &[
                    OptSpec { name: "config", help: "JSON config file", default: None },
                    OptSpec { name: "addr", help: "bind address", default: Some("127.0.0.1:7878") },
                    OptSpec { name: "artifacts", help: "AOT artifacts dir", default: Some("artifacts") },
                    OptSpec { name: "weights", help: ".upw weights path", default: None },
                    OptSpec { name: "workers", help: "sampler threads", default: Some("4") },
                    OptSpec { name: "shards", help: "coordinator shards (0 = workers.min(4))", default: Some("0") },
                    OptSpec { name: "max-batch", help: "max rows per model call", default: Some("64") },
                    OptSpec { name: "deadline-ms", help: "default request deadline (0 = none)", default: Some("30000") },
                    OptSpec { name: "drain-deadline-ms", help: "shutdown drain bound", default: Some("2000") },
                    OptSpec { name: "trace", help: "span level: off|lifecycle|steps", default: Some("lifecycle") },
                    OptSpec { name: "trace-buf", help: "span-ring capacity per shard", default: Some("4096") },
                    OptSpec { name: "trace-out", help: "Chrome trace_event JSON, rewritten each minute", default: None },
                    OptSpec { name: "metrics-out", help: "Prometheus text file, rewritten each minute", default: None },
                    OptSpec { name: "slo", help: "comma-separated SLOs, e.g. deadline_exceeded<0.1%/5m", default: None },
                    OptSpec { name: "sub-buf", help: "per-subscriber event queue capacity", default: Some("1024") },
                    OptSpec { name: "analytic", help: "force the analytic backend", default: None },
                ],
            )
        );
        return Ok(());
    }
    let cfg = load_config(args)?;
    let backend = backend_from(&cfg, args.flag("analytic"))?;
    let service = Service::start(cfg.clone(), backend);
    let server = Server::spawn(service.clone(), &cfg.addr)?;
    println!(
        "listening on {} ({} workers across {} shards, trace={})",
        server.addr,
        cfg.workers,
        service.shards(),
        cfg.trace.as_str(),
    );
    let trace_out = args.get("trace-out").map(|s| s.to_string());
    let metrics_out = args.get("metrics-out").map(|s| s.to_string());
    // Serve until killed.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(60));
        log::info!("{}", service.metrics_json().to_string());
        if let Some(path) = &trace_out {
            if let Err(e) = std::fs::write(path, service.chrome_trace_json().to_string()) {
                log::warn!("failed to write trace to {path}: {e}");
            }
        }
        if let Some(path) = &metrics_out {
            // Periodic Prometheus text dump: a file-based scrape target
            // (node_exporter textfile-collector style) for setups without
            // a wire scraper.
            if let Err(e) = std::fs::write(path, service.prometheus_text()) {
                log::warn!("failed to write metrics to {path}: {e}");
            }
        }
    }
}

/// Headless observability demo: start an analytic-backend server, drive it
/// with the load generator, print the queue-vs-compute breakdown, and write
/// the retained spans as a Chrome `trace_event` JSON artifact.
fn cmd_trace_demo(args: &Args) -> anyhow::Result<()> {
    use unipc::server::{run_load, LoadConfig};
    if args.flag("help") {
        print!(
            "{}",
            usage(
                "trace-demo",
                "serve + load + Chrome trace artifact, headlessly",
                &[
                    OptSpec { name: "out", help: "Chrome trace output path", default: Some("TRACE_demo.json") },
                    OptSpec { name: "requests", help: "requests to fire", default: Some("64") },
                    OptSpec { name: "trace", help: "span level: off|lifecycle|steps", default: Some("steps") },
                ],
            )
        );
        return Ok(());
    }
    let out = args.get_or("out", "TRACE_demo.json").to_string();
    let total = args.get_usize("requests", 64).map_err(anyhow::Error::msg)?;
    let mut cfg = load_config(args)?;
    if args.get("trace").is_none() {
        // The demo exists to show span trees: default to per-step spans.
        cfg.trace = unipc::trace::TraceLevel::Steps;
    }
    let backend = backend_from(&cfg, true)?;
    let service = Service::start(cfg, backend);
    let server = Server::spawn(service.clone(), "127.0.0.1:0")?;
    let load = LoadConfig {
        rps: 400.0,
        total,
        connections: 4,
        template: SampleRequest { n: 2, steps: 8, return_samples: false, ..Default::default() },
        seed: 7,
        key_mix: 4,
        mix_guidance: Some(2.0),
        plan_mix: 2,
    };
    let mut report = run_load(&server.addr.to_string(), &load)?;
    println!("{}", report.summary());
    std::fs::write(&out, service.chrome_trace_json().to_string())?;
    println!(
        "wrote {} span events to {out} (load in chrome://tracing or Perfetto)",
        service.trace_events().len()
    );
    server.stop();
    service.shutdown();
    Ok(())
}

/// Headless SLO demo: configure a burn-rate objective, inject worker-panic
/// chaos that burns through its budget, subscribe to the push channel, and
/// prove the breach event fires (exactly once per evaluation window).
/// Exits nonzero when no breach is observed — `make slo-demo` uses this as
/// an end-to-end CI probe of the telemetry plane.
fn cmd_slo_demo(args: &Args) -> anyhow::Result<()> {
    use unipc::coordinator::{silence_injected_panics, ChaosConfig};
    use unipc::server::{run_load, LoadConfig};
    use unipc::telemetry::{SloSpec, TelemetryEvent};
    if args.flag("help") {
        print!(
            "{}",
            usage(
                "slo-demo",
                "provoke and observe an SLO burn-rate breach, headlessly",
                &[
                    OptSpec { name: "requests", help: "requests to fire", default: Some("64") },
                    OptSpec {
                        name: "slo",
                        help: "objective to breach",
                        default: Some("worker_panic<1%/1m"),
                    },
                    OptSpec { name: "panic-rate", help: "injected eval panic probability", default: Some("0.2") },
                ],
            )
        );
        return Ok(());
    }
    let total = args.get_usize("requests", 64).map_err(anyhow::Error::msg)?;
    let spec = SloSpec::parse(args.get_or("slo", "worker_panic<1%/1m"))
        .map_err(anyhow::Error::msg)?;
    let panic_rate = args.get_f64("panic-rate", 0.2).map_err(anyhow::Error::msg)?;

    let mut cfg = ServerConfig { workers: 2, ..Default::default() };
    cfg.slos = vec![spec];
    let backend = ModelBackend::chaos(
        backend_from(&cfg, true)?,
        ChaosConfig { seed: 11, panic_rate, ..Default::default() },
    );
    silence_injected_panics();
    let service = Service::start(cfg, backend);
    let server = Server::spawn(service.clone(), "127.0.0.1:0")?;
    println!("objective: {spec} — injecting eval panics at rate {panic_rate:.2}");

    // Subscribe before the load so every breach event is observable.
    let sub = service.subscribe(service.sub_buf());
    let load = LoadConfig {
        rps: 400.0,
        total,
        connections: 4,
        template: SampleRequest { n: 1, steps: 8, return_samples: false, ..Default::default() },
        seed: 3,
        key_mix: 1,
        mix_guidance: None,
        plan_mix: 2,
    };
    let mut report = run_load(&server.addr.to_string(), &load)?;
    println!("{}", report.summary());

    // Deterministic evaluation (the monitor thread ticks anyway).
    service.poke_slos();
    let mut events = Vec::new();
    sub.drain_into(&mut events);
    service.unsubscribe(&sub);
    let breaches: Vec<_> = events
        .iter()
        .filter_map(|ev| match ev {
            TelemetryEvent::SloBreach { kind, window_s, failed, total, .. } => {
                Some(format!(
                    "slo_breach: {kind} failed {failed}/{total} over trailing {window_s}s"
                ))
            }
            _ => None,
        })
        .collect();
    for b in &breaches {
        println!("{b}");
    }
    println!(
        "windowed 1m stats: {}",
        service.windowed_stats_json(60).to_string()
    );
    let total_breaches = service.slo_breaches();
    server.stop();
    service.shutdown();
    if breaches.is_empty() || total_breaches == 0 {
        anyhow::bail!(
            "no slo_breach observed (events={}, counter={total_breaches}) — \
             the telemetry plane failed end to end",
            events.len()
        );
    }
    println!("ok: {total_breaches} breach event(s) — telemetry plane verified end to end");
    Ok(())
}

fn cmd_sample(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let backend = backend_from(&cfg, args.flag("analytic"))?;
    let service = Service::start(cfg, backend);
    let req = request_from_args(args)?;
    let resp = service.sample_blocking(req);
    println!("{}", resp.to_json().to_string());
    service.shutdown();
    if resp.ok {
        Ok(())
    } else {
        anyhow::bail!("sampling failed: {:?}", resp.error)
    }
}

fn cmd_client(args: &Args) -> anyhow::Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7878");
    let mut client = Client::connect(addr)?;
    if args.flag("stats") {
        println!("{}", client.stats()?.to_string());
        return Ok(());
    }
    let req = request_from_args(args)?;
    let resp = client.sample(&req)?;
    println!("{}", resp.to_json().to_string());
    Ok(())
}

fn request_from_args(args: &Args) -> anyhow::Result<SampleRequest> {
    let mut req = SampleRequest {
        n: args.get_usize("n", 1).map_err(anyhow::Error::msg)?,
        steps: args.get_usize("steps", 10).map_err(anyhow::Error::msg)?,
        method: args.get_or("method", "unipc-3").to_string(),
        unic: !args.flag("no-unic"),
        seed: args.get_usize("seed", 0).map_err(anyhow::Error::msg)? as u64,
        return_samples: !args.flag("no-samples"),
        ..Default::default()
    };
    if let Some(c) = args.get("class") {
        req.class = Some(c.parse().map_err(|_| anyhow::anyhow!("bad --class"))?);
    }
    let g = args.get_f64("guidance", 0.0).map_err(anyhow::Error::msg)?;
    if g != 0.0 {
        req.guidance = Some(g);
    }
    Ok(req)
}

fn cmd_order_sweep(args: &Args) -> anyhow::Result<()> {
    use unipc::analytic::{reference_solution, GmmModel};
    use unipc::numerics::vandermonde::BFunction;
    use unipc::sched::VpLinear;
    use unipc::solver::{sample, Method, Prediction, SampleOptions};

    let spec = DatasetSpec::parse(args.get_or("dataset", "cifar10-like"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    let gm = dataset(spec);
    let sched = VpLinear::default();
    let model = GmmModel { gm: &gm, sched: &sched };
    let seed = args.get_usize("seed", 5).map_err(anyhow::Error::msg)? as u64;
    let mut rng = unipc::rng::Rng::seed_from(seed);
    let x_t = rng.normal_tensor(&[4, gm.dim]);
    let truth = reference_solution(&model, &sched, &x_t, 1.0, 1e-3, 6000);

    println!("# empirical global error vs steps ({})", spec.name());
    println!(
        "{:>8} {:>12} {:>12} {:>12} {:>12}",
        "steps", "UniP-2", "UniP-3", "UniPC-2", "UniPC-3"
    );
    for steps in [20usize, 40, 80, 160, 320] {
        let mut row = format!("{steps:>8}");
        for (order, corrector) in [(2, false), (3, false), (2, true), (3, true)] {
            let mut opts = if corrector {
                SampleOptions::unipc(order, BFunction::Bh2, Prediction::Noise, steps)
            } else {
                SampleOptions::new(Method::unip(order, BFunction::Bh2, Prediction::Noise), steps)
            };
            opts.exact_warmup = true;
            let err = sample(&model, &sched, &x_t, &opts).x.sub(&truth).norm();
            row.push_str(&format!(" {err:>12.3e}"));
        }
        println!("{row}");
    }
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let dir = Path::new(args.get_or("artifacts", "artifacts"));
    let manifest = unipc::runtime::Manifest::load(dir)?;
    println!(
        "model: dim={} width={} depth={} classes={}",
        manifest.model.dim, manifest.model.width, manifest.model.depth, manifest.model.n_classes
    );
    println!("params: {} tensors", manifest.param_names.len());
    println!("batches: {:?}", manifest.batches);
    println!("artifacts:");
    for (k, a) in &manifest.artifacts {
        println!("  {k:<16} {}", a.file);
    }
    let wpath = dir.join(&manifest.weights_file);
    if wpath.exists() {
        let w = unipc::weights::WeightsFile::load(&wpath)?;
        println!("weights: {} tensors, {} params", w.len(), w.total_params());
    } else {
        println!("weights: (missing — run `make artifacts`)");
    }
    Ok(())
}
