//! # UniPC — unified predictor-corrector sampling for diffusion models, served from Rust
//!
//! This crate reproduces *UniPC: A Unified Predictor-Corrector Framework for
//! Fast Sampling of Diffusion Models* (Zhao et al., NeurIPS 2023) as a
//! production-shaped serving system:
//!
//! * [`solver`] — the paper's contribution: UniP-p / UniC-p / UniPC-p of
//!   arbitrary order (noise- and data-prediction), the varying-coefficient
//!   variant UniPC_v, and every baseline the paper evaluates against
//!   (DDIM, DPM-Solver, DPM-Solver++, PNDM, DEIS).
//! * [`sched`] — noise schedules (α_t, σ_t, λ_t and the inverse t_λ) and
//!   timestep selectors.
//! * [`numerics`] — exponential-integrator φ/ψ functions and small
//!   Vandermonde systems (Theorem 3.1's R_p and Appendix C's C_p).
//! * [`analytic`] — an analytic-score diffusion-model substrate (Gaussian
//!   mixtures with closed-form ε*(x,t)) used to measure true discretization
//!   error in the paper's experiments.
//! * [`runtime`] — PJRT execution of AOT-compiled JAX/Pallas artifacts
//!   (HLO text) for the learned ε_θ models; python never runs at serve time.
//! * [`coordinator`] + [`server`] — the serving layer: admission and
//!   backpressure, a shared sampling-plan cache, lockstep request batching
//!   (same-plan requests stack into one batch-major run with one model
//!   evaluation per step), per-request solver state, metrics, and a
//!   TCP/JSON front end.
//! * [`trace`] — end-to-end request tracing: span events for every
//!   lifecycle stage (admit → route/queue → assemble → per-step
//!   model-eval/solver split → respond), bounded per-shard rings, span-tree
//!   and Chrome `trace_event` exporters.
//! * [`telemetry`] — the continuous telemetry plane: windowed time-series
//!   metrics (60×1s + 60×1m rings), Prometheus text exposition, push-based
//!   event subscription with bounded per-subscriber queues, SLO burn-rate
//!   monitors, and solver numerical-health accumulation (predictor→
//!   corrector delta norms, non-finite provenance).
//! * substrates built from scratch for the offline environment:
//!   [`tensor`], [`rng`], [`stats`], [`json`], [`cli`], [`config`],
//!   [`testing`].
//!
//! See the repository `README.md` for the architecture overview (the
//! build→cache→execute plan pipeline, the batched serving path, and the
//! paper-reproduction bench index), and `ROADMAP.md` for per-PR
//! architecture notes.

pub mod analytic;
pub mod cli;
pub mod config;
pub mod evalharness;
pub mod coordinator;
pub mod json;
pub mod log;
pub mod numerics;
pub mod rng;
pub mod runtime;
pub mod sched;
pub mod server;
pub mod solver;
pub mod stats;
pub mod telemetry;
pub mod tensor;
pub mod testing;
pub mod trace;
pub mod weights;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
