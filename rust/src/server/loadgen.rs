//! Open-loop Poisson load generator for serving benchmarks.
//!
//! Spawns client threads that fire requests at exponentially distributed
//! inter-arrival times (open-loop: arrivals don't wait for completions, so
//! queueing behaviour under overload is observable — the honest way to
//! measure a serving system).

use super::client::Client;
use crate::coordinator::SampleRequest;
use crate::rng::Rng;
use crate::stats::LatencyDigest;
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Workload shape.
#[derive(Clone, Debug)]
pub struct LoadConfig {
    /// Target offered load, requests/second (across all connections).
    pub rps: f64,
    /// Total requests to send.
    pub total: usize,
    /// Client connections (each runs its own arrival process at rps/conns).
    pub connections: usize,
    /// Request template; seed is varied per request.
    pub template: SampleRequest,
    pub seed: u64,
    /// Distinct conditionings to fan the workload across, driven by cycling
    /// the request class label (`class = k % key_mix` for the k-th request
    /// overall). Conditioning is *not* part of the batch key anymore, so
    /// this knob no longer routes: mixed-class traffic stacks into one
    /// lockstep cohort per plan key (use [`LoadConfig::plan_mix`] to fan
    /// across shards). 1 = every request keeps the template's own class.
    /// Must not exceed the backend's class count.
    pub key_mix: usize,
    /// When `key_mix > 1`, also attach this guidance scale to every other
    /// classed request (`k % 2 == 0`), so the conditioning mix exercises
    /// guided and unguided rows in the same cohort. Ignored when `key_mix`
    /// is 1 (guidance requires a class label).
    pub mix_guidance: Option<f64>,
    /// Distinct *plan keys* to fan the workload across, driven by cycling
    /// the step count (`steps = template.steps + k % plan_mix`). The plan
    /// key is the batch key, which routes the request — so `plan_mix`
    /// controls how many coordinator shards the workload can occupy
    /// (1 = every request shares the template's plan).
    pub plan_mix: usize,
}

/// Server-reported stage latencies, accumulated from the timing stamps on
/// each ok response: queue wait vs. compute, with compute further split
/// into model-eval and solver-kernel time.
#[derive(Clone, Debug, Default)]
pub struct StageDigests {
    pub queue: LatencyDigest,
    pub compute: LatencyDigest,
    pub model_eval: LatencyDigest,
    pub solver: LatencyDigest,
}

impl StageDigests {
    fn record(&mut self, queue_us: u64, compute_us: u64, model_eval_us: u64, solver_us: u64) {
        self.queue.record_us(queue_us);
        self.compute.record_us(compute_us);
        self.model_eval.record_us(model_eval_us);
        self.solver.record_us(solver_us);
    }
}

/// Aggregate results.
#[derive(Debug)]
pub struct LoadReport {
    pub sent: usize,
    pub ok: usize,
    pub rejected: usize,
    pub wall: Duration,
    pub latency: LatencyDigest,
    /// Achieved throughput in samples (images)/second.
    pub samples_per_sec: f64,
    /// Non-ok responses broken down by failure kind (wire name); empty
    /// under a fault-free run.
    pub failures: BTreeMap<String, u64>,
    /// Where server-side time went, from per-response timing stamps.
    pub stages: StageDigests,
    /// Server-side telemetry-loss accounting, snapshotted from a final
    /// `stats` call: span events recorded into trace rings, span events
    /// overwritten by ring wrap, and events dropped on full subscriber
    /// queues. Zero when the final stats fetch failed.
    pub trace_recorded: u64,
    pub trace_dropped: u64,
    pub sub_dropped: u64,
}

impl LoadReport {
    pub fn summary(&mut self) -> String {
        let mut s = format!(
            "sent={} ok={} rejected={} wall={:.2}s thpt={:.1} samples/s lat[{}]",
            self.sent,
            self.ok,
            self.rejected,
            self.wall.as_secs_f64(),
            self.samples_per_sec,
            self.latency.summary()
        );
        if !self.failures.is_empty() {
            s.push_str(&format!(" fails={:?}", self.failures));
        }
        s.push_str(&format!(
            " trace[recorded={} dropped={} sub_dropped={}]",
            self.trace_recorded, self.trace_dropped, self.sub_dropped
        ));
        if self.stages.queue.count() > 0 {
            // Queue-vs-compute attribution: how much of the server-side
            // latency was waiting rather than working, and how the working
            // half splits between the model and the solver kernels.
            let qm = self.stages.queue.mean_us();
            let cm = self.stages.compute.mean_us();
            let share = 100.0 * qm / (qm + cm).max(1.0);
            s.push_str(&format!(
                "\n  breakdown: queue[{}] compute[{}] — {share:.0}% of server time queued",
                self.stages.queue.summary(),
                self.stages.compute.summary(),
            ));
            s.push_str(&format!(
                "\n  compute split: model_eval[{}] solver[{}]",
                self.stages.model_eval.summary(),
                self.stages.solver.summary(),
            ));
        }
        s
    }
}

/// Run the workload against `addr`.
pub fn run_load(addr: &str, cfg: &LoadConfig) -> Result<LoadReport> {
    let started = Instant::now();
    let ok = Arc::new(AtomicU64::new(0));
    let rejected = Arc::new(AtomicU64::new(0));
    let samples = Arc::new(AtomicU64::new(0));
    let latency = Arc::new(Mutex::new(LatencyDigest::new()));
    let stages = Arc::new(Mutex::new(StageDigests::default()));
    let failures: Arc<Mutex<BTreeMap<String, u64>>> = Arc::new(Mutex::new(BTreeMap::new()));

    let per_conn = cfg.total / cfg.connections;
    let conn_rps = cfg.rps / cfg.connections as f64;
    let mut handles = Vec::new();
    for c in 0..cfg.connections {
        let addr = addr.to_string();
        let template = cfg.template.clone();
        let ok = Arc::clone(&ok);
        let rejected = Arc::clone(&rejected);
        let samples = Arc::clone(&samples);
        let latency = Arc::clone(&latency);
        let stages = Arc::clone(&stages);
        let failures = Arc::clone(&failures);
        let seed = cfg.seed;
        let key_mix = cfg.key_mix;
        let mix_guidance = cfg.mix_guidance;
        let plan_mix = cfg.plan_mix;
        handles.push(std::thread::spawn(move || -> Result<()> {
            let mut client = Client::connect(&addr)?;
            let mut rng = Rng::seed_from(seed).split(c as u64 + 1);
            let t0 = Instant::now();
            let mut next_at = Duration::ZERO;
            for i in 0..per_conn {
                // Open-loop pacing.
                next_at += Duration::from_secs_f64(rng.exponential(conn_rps));
                let now = t0.elapsed();
                if next_at > now {
                    std::thread::sleep(next_at - now);
                }
                let mut req = template.clone();
                req.seed = seed ^ ((c as u64) << 32) ^ i as u64;
                // Deterministic per-request mix assignment, spread evenly
                // across connections.
                let k = c * per_conn + i;
                if plan_mix > 1 {
                    req.steps = template.steps + k % plan_mix;
                }
                if key_mix > 1 {
                    req.class = Some(k % key_mix);
                    if let Some(g) = mix_guidance {
                        if k % 2 == 0 {
                            req.guidance = Some(g);
                        }
                    }
                }
                let sent = Instant::now();
                match client.sample(&req) {
                    Ok(resp) if resp.ok => {
                        ok.fetch_add(1, Ordering::Relaxed);
                        samples.fetch_add(req.n as u64, Ordering::Relaxed);
                        latency.lock().unwrap().record(sent.elapsed());
                        stages.lock().unwrap().record(
                            resp.queue_us,
                            resp.compute_us,
                            resp.model_eval_us,
                            resp.solver_us,
                        );
                    }
                    Ok(resp) => {
                        rejected.fetch_add(1, Ordering::Relaxed);
                        let kind = resp
                            .kind
                            .map(|k| k.as_str().to_string())
                            .unwrap_or_else(|| "unknown".into());
                        *failures.lock().unwrap().entry(kind).or_insert(0) += 1;
                    }
                    Err(e) => return Err(e),
                }
            }
            Ok(())
        }));
    }
    for h in handles {
        h.join().expect("load thread panicked")?;
    }
    let wall = started.elapsed();
    let latency = Arc::try_unwrap(latency)
        .map(|m| m.into_inner().unwrap())
        .unwrap_or_else(|arc| arc.lock().unwrap().clone());
    let stages = Arc::try_unwrap(stages)
        .map(|m| m.into_inner().unwrap())
        .unwrap_or_else(|arc| arc.lock().unwrap().clone());
    let failures = Arc::try_unwrap(failures)
        .map(|m| m.into_inner().unwrap())
        .unwrap_or_else(|arc| arc.lock().unwrap().clone());
    // Close the loop on telemetry loss: one stats call after the run pulls
    // the server's trace-ring and subscription accounting into the report.
    let get = |v: &crate::json::Value, key: &str| {
        v.get(key).and_then(crate::json::Value::as_f64).unwrap_or(0.0) as u64
    };
    let (trace_recorded, trace_dropped, sub_dropped) = Client::connect(addr)
        .and_then(|mut c| c.stats())
        .map(|v| (get(&v, "trace_recorded"), get(&v, "trace_dropped"), get(&v, "sub_dropped")))
        .unwrap_or((0, 0, 0));
    Ok(LoadReport {
        sent: per_conn * cfg.connections,
        ok: ok.load(Ordering::Relaxed) as usize,
        rejected: rejected.load(Ordering::Relaxed) as usize,
        wall,
        samples_per_sec: samples.load(Ordering::Relaxed) as f64 / wall.as_secs_f64(),
        latency,
        failures,
        stages,
        trace_recorded,
        trace_dropped,
        sub_dropped,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::datasets::{dataset, DatasetSpec};
    use crate::config::ServerConfig;
    use crate::coordinator::{ModelBackend, Service};
    use crate::server::Server;

    #[test]
    fn load_generator_end_to_end() {
        let gm = Arc::new(dataset(DatasetSpec::BedroomLike));
        let svc = Service::start(
            ServerConfig { workers: 2, ..Default::default() },
            ModelBackend::Analytic {
                gm,
                class_components: Arc::new(vec![(0..4).collect()]),
            },
        );
        let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
        let cfg = LoadConfig {
            rps: 200.0,
            total: 24,
            connections: 2,
            template: SampleRequest {
                n: 1,
                steps: 5,
                return_samples: false,
                ..Default::default()
            },
            seed: 1,
            key_mix: 1,
            mix_guidance: None,
            plan_mix: 1,
        };
        let mut report = run_load(&server.addr.to_string(), &cfg).unwrap();
        assert_eq!(report.sent, 24);
        assert_eq!(report.ok, 24);
        assert!(report.samples_per_sec > 0.0);
        assert!(report.failures.is_empty(), "clean run must have no failures");
        // Stage attribution covers every ok response, and the split fields
        // are internally consistent (model + solver = compute per sample,
        // so it holds for the means too).
        assert_eq!(report.stages.queue.count(), 24);
        assert_eq!(report.stages.compute.count(), 24);
        let me = report.stages.model_eval.mean_us();
        let so = report.stages.solver.mean_us();
        let cm = report.stages.compute.mean_us();
        assert!((me + so - cm).abs() <= 24.0, "model({me}) + solver({so}) ≈ compute({cm})");
        let s = report.summary();
        assert!(s.contains("breakdown:"), "summary must print the stage breakdown: {s}");
        assert!(s.contains("model_eval["), "summary must print the compute split: {s}");
        // Telemetry-loss accounting rides on the report: every request
        // records spans under the default lifecycle level, and a ring
        // sized far above the span volume drops nothing.
        assert!(
            report.trace_recorded >= report.sent as u64,
            "expected ≥1 span per request, got {}",
            report.trace_recorded
        );
        assert_eq!(report.trace_dropped, 0);
        assert_eq!(report.sub_dropped, 0);
        assert!(s.contains("sub_dropped=0"), "summary must print telemetry loss: {s}");
        server.stop();
        svc.shutdown();
    }
}
