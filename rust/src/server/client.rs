//! Blocking client for the newline-JSON protocol.

use crate::coordinator::{SampleRequest, SampleResponse};
use crate::json::{self, Value};
use anyhow::{anyhow, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

/// One connection to a unipc server.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    /// Send one raw line, get one parsed reply.
    pub fn raw(&mut self, line: &str) -> Result<Value> {
        self.stream.write_all(line.as_bytes())?;
        self.stream.write_all(b"\n")?;
        let mut reply = String::new();
        if self.reader.read_line(&mut reply)? == 0 {
            return Err(anyhow!("server closed connection"));
        }
        json::parse(reply.trim()).map_err(|e| anyhow!("bad reply: {e}"))
    }

    pub fn ping(&mut self) -> Result<bool> {
        let v = self.raw(r#"{"op":"ping"}"#)?;
        Ok(v.get("ok").and_then(Value::as_bool).unwrap_or(false))
    }

    pub fn stats(&mut self) -> Result<Value> {
        self.raw(r#"{"op":"stats"}"#)
    }

    /// Fetch the span trees of the most recent `limit` requests (the
    /// `trace` op); returns the `traces` array from the reply.
    pub fn trace(&mut self, limit: usize) -> Result<Value> {
        let v = self.raw(&format!(r#"{{"op":"trace","limit":{limit}}}"#))?;
        v.get("traces")
            .cloned()
            .ok_or_else(|| anyhow!("trace reply missing traces: {v:?}"))
    }

    pub fn sample(&mut self, req: &SampleRequest) -> Result<SampleResponse> {
        let v = self.raw(&req.to_json().to_string())?;
        SampleResponse::from_json(&v)
    }
}
