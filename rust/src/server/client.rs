//! Blocking client for the newline-JSON protocol.

use crate::coordinator::{SampleRequest, SampleResponse};
use crate::json::{self, Value};
use anyhow::{anyhow, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

/// One connection to a unipc server.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    /// Send one raw line, get one parsed reply.
    pub fn raw(&mut self, line: &str) -> Result<Value> {
        self.stream.write_all(line.as_bytes())?;
        self.stream.write_all(b"\n")?;
        let mut reply = String::new();
        if self.reader.read_line(&mut reply)? == 0 {
            return Err(anyhow!("server closed connection"));
        }
        json::parse(reply.trim()).map_err(|e| anyhow!("bad reply: {e}"))
    }

    pub fn ping(&mut self) -> Result<bool> {
        let v = self.raw(r#"{"op":"ping"}"#)?;
        Ok(v.get("ok").and_then(Value::as_bool).unwrap_or(false))
    }

    pub fn stats(&mut self) -> Result<Value> {
        self.raw(r#"{"op":"stats"}"#)
    }

    /// Windowed rates over a trailing span, e.g. `"30s"`, `"1m"`, `"1h"`.
    pub fn stats_window(&mut self, window: &str) -> Result<Value> {
        self.raw(&format!(r#"{{"op":"stats","window":"{window}"}}"#))
    }

    /// The Prometheus text exposition (the `metrics` op's `text` field).
    pub fn metrics_text(&mut self) -> Result<String> {
        let v = self.raw(r#"{"op":"metrics"}"#)?;
        v.get("text")
            .and_then(Value::as_str)
            .map(str::to_string)
            .ok_or_else(|| anyhow!("metrics reply missing text: {v:?}"))
    }

    /// Switch this connection into event-streaming mode (`subscribe` op).
    /// Returns the ack object; after it, every line read from this client
    /// via [`Client::read_event`] is one telemetry event (NDJSON).
    pub fn subscribe(&mut self) -> Result<Value> {
        let ack = self.raw(r#"{"op":"subscribe"}"#)?;
        if ack.get("subscribed").and_then(Value::as_bool) != Some(true) {
            return Err(anyhow!("subscribe refused: {ack:?}"));
        }
        Ok(ack)
    }

    /// Read one streamed event line (blocks; use a read timeout on the
    /// underlying socket to bound it). `Ok(None)` = server closed.
    pub fn read_event(&mut self) -> Result<Option<Value>> {
        let mut line = String::new();
        loop {
            match self.reader.read_line(&mut line) {
                Ok(0) => return Ok(None),
                Ok(_) => {
                    let trimmed = line.trim();
                    if trimmed.is_empty() {
                        line.clear();
                        continue;
                    }
                    return json::parse(trimmed)
                        .map(Some)
                        .map_err(|e| anyhow!("bad event line: {e}"));
                }
                Err(e) => return Err(e.into()),
            }
        }
    }

    /// Bound every read on this connection (event streams use this so a
    /// quiet server can't pin the test).
    pub fn set_read_timeout(&mut self, d: std::time::Duration) -> Result<()> {
        self.reader.get_ref().set_read_timeout(Some(d))?;
        Ok(())
    }

    /// Fetch the span trees of the most recent `limit` requests (the
    /// `trace` op); returns the `traces` array from the reply.
    pub fn trace(&mut self, limit: usize) -> Result<Value> {
        let v = self.raw(&format!(r#"{{"op":"trace","limit":{limit}}}"#))?;
        v.get("traces")
            .cloned()
            .ok_or_else(|| anyhow!("trace reply missing traces: {v:?}"))
    }

    pub fn sample(&mut self, req: &SampleRequest) -> Result<SampleResponse> {
        let v = self.raw(&req.to_json().to_string())?;
        SampleResponse::from_json(&v)
    }
}
