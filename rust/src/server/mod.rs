//! TCP front end: newline-delimited JSON over a thread-per-connection
//! listener, a blocking client, and an open-loop Poisson load generator for
//! the serving benches.
//!
//! Protocol (one JSON object per line):
//!   → {"op":"sample", "n":4, "steps":10, "method":"unipc-3", ...}
//!   ← {"ok":true, "nfe":10, "samples":[...], "trace_id":…, ...}
//!   → {"op":"stats"}   ← metrics snapshot + front-end gauges
//!   → {"op":"stats", "window":"1m"}  ← windowed rates (see [`crate::telemetry`])
//!   → {"op":"ping"}    ← {"ok":true}
//!   → {"op":"trace", "limit":8}  ← recent span trees (see [`crate::trace`])
//!   → {"op":"metrics"} ← {"ok":true, "text": <Prometheus exposition>}
//!   → {"op":"subscribe"}  ← ack, then the connection becomes a push
//!     channel: span events and `slo_breach` events stream back as NDJSON
//!     until the client disconnects (bounded per-subscriber queue;
//!     overflow is counted in `sub_dropped`, never blocking workers).
//!
//! Present-but-invalid parameters (`limit`, `window`) get a typed
//! `invalid_request` error reply instead of a silent default.
//!
//! The listener accounts for its connections: a `connections_open` gauge
//! and per-op counters ride on every `stats` reply, and [`Server::stop`]
//! waits (bounded) for per-connection threads to drain instead of leaving
//! them unaccounted.

pub mod client;
pub mod loadgen;

pub use client::Client;
pub use loadgen::{run_load, LoadConfig, LoadReport};

use crate::coordinator::{SampleRequest, Service};
use crate::json::{self, Value};
use crate::log;
use crate::telemetry::{event_line, parse_window, PromWriter, Subscription};
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Default span-tree count for `{"op":"trace"}` when no `limit` is given.
const DEFAULT_TRACE_LIMIT: usize = 8;

/// Front-end accounting, shared by the accept loop and every connection
/// thread. All plain atomics: the hot path pays one relaxed increment per
/// request.
#[derive(Debug, Default)]
pub struct FrontendStats {
    /// Connections ever accepted.
    pub connections_total: AtomicU64,
    /// Connections currently open (gauge; maintained by a drop guard, so a
    /// panicking connection thread still decrements it).
    pub connections_open: AtomicU64,
    /// Per-op request counters.
    pub op_sample: AtomicU64,
    pub op_stats: AtomicU64,
    pub op_ping: AtomicU64,
    pub op_trace: AtomicU64,
    pub op_metrics: AtomicU64,
    pub op_subscribe: AtomicU64,
    /// Unknown ops and unparsable lines.
    pub op_other: AtomicU64,
}

impl FrontendStats {
    /// The gauge/counter block merged into every `stats` reply.
    fn fields(&self) -> Vec<(&'static str, Value)> {
        let g = |a: &AtomicU64| Value::from(a.load(Ordering::Relaxed) as f64);
        vec![
            ("connections_total", g(&self.connections_total)),
            ("connections_open", g(&self.connections_open)),
            ("op_sample", g(&self.op_sample)),
            ("op_stats", g(&self.op_stats)),
            ("op_ping", g(&self.op_ping)),
            ("op_trace", g(&self.op_trace)),
            ("op_metrics", g(&self.op_metrics)),
            ("op_subscribe", g(&self.op_subscribe)),
            ("op_other", g(&self.op_other)),
        ]
    }

    /// The same block as Prometheus lines, appended to `{"op":"metrics"}`
    /// replies so scrapes see the front end too.
    fn prometheus(&self) -> String {
        let g = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64;
        let mut w = PromWriter::new();
        w.counter(
            "unipc_connections_total",
            "Connections ever accepted.",
            g(&self.connections_total),
        );
        w.gauge(
            "unipc_connections_open",
            "Connections currently open.",
            g(&self.connections_open),
        );
        w.counter_vec(
            "unipc_requests_total",
            "Front-end requests by op.",
            "op",
            &[
                ("sample", g(&self.op_sample)),
                ("stats", g(&self.op_stats)),
                ("ping", g(&self.op_ping)),
                ("trace", g(&self.op_trace)),
                ("metrics", g(&self.op_metrics)),
                ("subscribe", g(&self.op_subscribe)),
                ("other", g(&self.op_other)),
            ],
        );
        w.finish()
    }
}

/// Decrements `connections_open` when a connection thread exits — normally
/// or by panic — so the gauge cannot drift.
struct ConnGuard(Arc<FrontendStats>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.connections_open.fetch_sub(1, Ordering::Relaxed);
    }
}

/// A running server (owns the listener thread).
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    stats: Arc<FrontendStats>,
}

impl Server {
    /// Bind and serve in background threads. `addr` may use port 0 to pick
    /// a free port (the chosen address is in `self.addr`).
    pub fn spawn(service: Service, addr: &str) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let stats = Arc::new(FrontendStats::default());
        let stats2 = Arc::clone(&stats);
        std::thread::Builder::new()
            .name("unipc-server".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let svc = service.clone();
                            let st = Arc::clone(&stats2);
                            let sp = Arc::clone(&stop2);
                            st.connections_total.fetch_add(1, Ordering::Relaxed);
                            st.connections_open.fetch_add(1, Ordering::Relaxed);
                            std::thread::spawn(move || {
                                let _guard = ConnGuard(Arc::clone(&st));
                                let _ = handle_conn(stream, svc, st, sp);
                            });
                        }
                        Err(e) => log::warn!("accept error: {e}"),
                    }
                }
            })
            .context("spawn server thread")?;
        log::info!("serving on {local}");
        Ok(Server { addr: local, stop, stats })
    }

    /// Front-end accounting (connection gauge + per-op counters).
    pub fn frontend_stats(&self) -> &FrontendStats {
        &self.stats
    }

    /// Ask the accept loop to stop, then wait — bounded — for open
    /// connection threads to finish their in-flight request and exit
    /// (each connection re-checks the stop flag between requests).
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // Nudge the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        let deadline = Instant::now() + Duration::from_secs(2);
        while self.stats.connections_open.load(Ordering::Relaxed) > 0
            && Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    service: Service,
    stats: Arc<FrontendStats>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // Bound idle reads so a quiet connection notices the stop flag instead
    // of pinning its thread on a blocking read forever.
    stream.set_read_timeout(Some(Duration::from_millis(200))).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(()); // server stopping: finish between requests
        }
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Idle poll: re-check the stop flag. `line` keeps any
                // partial prefix already read, so a slow writer straddling
                // the timeout loses nothing.
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        let trimmed = line.trim();
        if !trimmed.is_empty() {
            match dispatch(trimmed, &service, &stats) {
                Dispatch::Reply(reply) => {
                    stream.write_all(reply.to_string().as_bytes())?;
                    stream.write_all(b"\n")?;
                }
                Dispatch::Subscribe => {
                    // The connection becomes a push channel: ack, then
                    // stream events until the client goes away.
                    let sub = service.subscribe(service.sub_buf());
                    let ack = Value::obj(vec![
                        ("ok", Value::from(true)),
                        ("subscribed", Value::from(true)),
                        ("cap", Value::from(service.sub_buf())),
                    ]);
                    let r = stream
                        .write_all(ack.to_string().as_bytes())
                        .and_then(|()| stream.write_all(b"\n"))
                        .map_err(anyhow::Error::from)
                        .and_then(|()| {
                            stream_events(&mut reader, &mut stream, &sub, &stop)
                        });
                    service.unsubscribe(&sub);
                    return r;
                }
            }
        }
        line.clear();
    }
}

/// Streams queued telemetry events to a subscribed connection as NDJSON.
/// Returns when the client closes, writes fail, or the server stops.
fn stream_events(
    reader: &mut BufReader<TcpStream>,
    stream: &mut TcpStream,
    sub: &Arc<Subscription>,
    stop: &AtomicBool,
) -> Result<()> {
    // Short read timeout: each lap polls for client close (Ok(0)) without
    // stalling event delivery.
    reader.get_ref().set_read_timeout(Some(Duration::from_millis(1))).ok();
    let mut junk = String::new();
    let mut events = Vec::with_capacity(64);
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        match reader.read_line(&mut junk) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => junk.clear(),  // input on a streaming conn is ignored
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) => return Err(e.into()),
        }
        if sub.wait_drain_into(&mut events, Duration::from_millis(50)) {
            for ev in events.drain(..) {
                stream.write_all(event_line(&ev).to_string().as_bytes())?;
                stream.write_all(b"\n")?;
            }
            stream.flush()?;
        }
    }
}

/// What a request line turns into: an immediate reply, or a switch of the
/// connection into event-streaming mode.
enum Dispatch {
    Reply(Value),
    Subscribe,
}

fn error_reply(msg: String) -> Value {
    Value::obj(vec![
        ("ok", Value::from(false)),
        ("kind", Value::from("invalid_request")),
        ("error", Value::from(msg)),
    ])
}

fn dispatch(line: &str, service: &Service, stats: &FrontendStats) -> Dispatch {
    let parsed = match json::parse(line) {
        Ok(v) => v,
        Err(e) => {
            stats.op_other.fetch_add(1, Ordering::Relaxed);
            return Dispatch::Reply(error_reply(format!("bad json: {e}")));
        }
    };
    Dispatch::Reply(match parsed.get("op").and_then(Value::as_str) {
        Some("ping") => {
            stats.op_ping.fetch_add(1, Ordering::Relaxed);
            Value::obj(vec![("ok", Value::from(true))])
        }
        Some("stats") => {
            stats.op_stats.fetch_add(1, Ordering::Relaxed);
            // A present `window` selects windowed rates; present-but-bad
            // values are a typed error, not a silent fallback.
            match parsed.get("window") {
                Some(w) => {
                    let spec = w.as_str().map(str::to_string).or_else(|| {
                        // Bare numbers are accepted too: {"window": 30}.
                        w.as_usize().map(|n| n.to_string())
                    });
                    match spec.as_deref().and_then(parse_window) {
                        Some(window_s) => {
                            let mut v = service.windowed_stats_json(window_s);
                            if let Value::Obj(m) = &mut v {
                                m.insert("ok".to_string(), Value::from(true));
                            }
                            v
                        }
                        None => error_reply(format!(
                            "bad 'window' {w:?}: want seconds or a 1s..=1h \
                             suffixed span like \"90s\", \"5m\", \"1h\""
                        )),
                    }
                }
                None => {
                    let mut v = service.metrics_json();
                    if let Value::Obj(m) = &mut v {
                        for (k, val) in stats.fields() {
                            m.insert(k.to_string(), val);
                        }
                    }
                    v
                }
            }
        }
        Some("trace") => {
            stats.op_trace.fetch_add(1, Ordering::Relaxed);
            let limit = match parsed.get("limit") {
                None => DEFAULT_TRACE_LIMIT,
                // Present but non-numeric / negative / fractional: typed
                // error instead of the silent default.
                Some(l) => match l.as_usize() {
                    Some(n) => n,
                    None => {
                        return Dispatch::Reply(error_reply(format!(
                            "bad 'limit' {l:?}: want a non-negative integer"
                        )))
                    }
                },
            };
            // `trace_json` already returns `{"traces": [...]}`; stamp the
            // protocol's `ok` onto it rather than nesting another object.
            let mut v = service.trace_json(limit);
            if let Value::Obj(m) = &mut v {
                m.insert("ok".to_string(), Value::from(true));
            }
            v
        }
        Some("metrics") => {
            stats.op_metrics.fetch_add(1, Ordering::Relaxed);
            let mut text = service.prometheus_text();
            text.push_str(&stats.prometheus());
            Value::obj(vec![("ok", Value::from(true)), ("text", Value::from(text))])
        }
        Some("subscribe") => {
            stats.op_subscribe.fetch_add(1, Ordering::Relaxed);
            return Dispatch::Subscribe;
        }
        Some("sample") => {
            stats.op_sample.fetch_add(1, Ordering::Relaxed);
            match SampleRequest::from_json(&parsed) {
                Ok(req) => service.sample_blocking(req).to_json(),
                Err(e) => error_reply(format!("{e:#}")),
            }
        }
        other => {
            stats.op_other.fetch_add(1, Ordering::Relaxed);
            error_reply(format!("unknown op {other:?}"))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::datasets::{dataset, DatasetSpec};
    use crate::config::ServerConfig;
    use crate::coordinator::ModelBackend;

    fn test_server() -> (Server, Service) {
        let spec = DatasetSpec::BedroomLike;
        let gm = Arc::new(dataset(spec));
        let svc = Service::start(
            ServerConfig { workers: 2, ..Default::default() },
            ModelBackend::Analytic {
                gm,
                class_components: Arc::new(vec![(0..4).collect()]),
            },
        );
        let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
        (server, svc)
    }

    #[test]
    fn ping_stats_sample_over_tcp() {
        let (server, svc) = test_server();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        assert!(client.ping().unwrap());

        let resp = client
            .sample(&SampleRequest { n: 2, steps: 5, seed: 3, ..Default::default() })
            .unwrap();
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.nfe, 5);
        assert_eq!(resp.samples.unwrap().len(), 2 * svc.dim());

        let stats = client.stats().unwrap();
        assert_eq!(stats.get("completed").unwrap().as_f64(), Some(1.0));
        server.stop();
        svc.shutdown();
    }

    #[test]
    fn malformed_lines_get_error_replies() {
        let (server, svc) = test_server();
        let mut c = Client::connect(&server.addr.to_string()).unwrap();
        let v = c.raw("{not json").unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        let v = c.raw(r#"{"op":"wat"}"#).unwrap();
        assert!(v.get("error").unwrap().as_str().unwrap().contains("unknown op"));
        // The connection stays usable.
        assert!(c.ping().unwrap());
        server.stop();
        svc.shutdown();
    }

    #[test]
    fn frontend_counters_and_trace_op() {
        let (server, svc) = test_server();
        let mut c = Client::connect(&server.addr.to_string()).unwrap();
        assert!(c.ping().unwrap());
        let resp = c
            .sample(&SampleRequest { n: 1, steps: 5, seed: 1, trace_id: Some(99), ..Default::default() })
            .unwrap();
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.trace_id, 99, "trace id must round-trip the wire");

        // The trace op returns that request's span tree.
        let traces = c.trace(8).unwrap();
        let arr = traces.as_arr().expect("traces is an array");
        assert!(
            arr.iter().any(|t| t.get("trace_id").and_then(Value::as_f64) == Some(99.0)),
            "span tree for trace 99 missing: {traces:?}"
        );

        // Stats carry the front-end gauge/counter block.
        let stats = c.stats().unwrap();
        assert_eq!(stats.get("connections_open").unwrap().as_f64(), Some(1.0));
        assert_eq!(stats.get("connections_total").unwrap().as_f64(), Some(1.0));
        assert_eq!(stats.get("op_ping").unwrap().as_f64(), Some(1.0));
        assert_eq!(stats.get("op_sample").unwrap().as_f64(), Some(1.0));
        assert_eq!(stats.get("op_trace").unwrap().as_f64(), Some(1.0));
        assert_eq!(stats.get("op_stats").unwrap().as_f64(), Some(1.0));

        // stop() drains the connection thread: the gauge returns to 0.
        server.stop();
        assert_eq!(
            server.frontend_stats().connections_open.load(Ordering::Relaxed),
            0,
            "stop must wait for connection threads to exit"
        );
        svc.shutdown();
    }

    #[test]
    fn metrics_op_returns_valid_exposition() {
        let (server, svc) = test_server();
        let mut c = Client::connect(&server.addr.to_string()).unwrap();
        let r = c
            .sample(&SampleRequest { n: 1, steps: 5, seed: 2, return_samples: false, ..Default::default() })
            .unwrap();
        assert!(r.ok, "{:?}", r.error);

        let text = c.metrics_text().unwrap();
        let parsed = crate::telemetry::parse_exposition(&text).unwrap();
        assert_eq!(parsed.value("unipc_completed_total", &[]), Some(1.0));
        // Front-end lines ride along.
        assert_eq!(parsed.value("unipc_requests_total", &[("op", "sample")]), Some(1.0));
        assert_eq!(parsed.value("unipc_connections_open", &[]), Some(1.0));
        server.stop();
        svc.shutdown();
    }

    #[test]
    fn windowed_stats_and_typed_param_errors() {
        let (server, svc) = test_server();
        let mut c = Client::connect(&server.addr.to_string()).unwrap();
        let r = c
            .sample(&SampleRequest { n: 3, steps: 5, seed: 4, return_samples: false, ..Default::default() })
            .unwrap();
        assert!(r.ok, "{:?}", r.error);

        // The completion lands in the 60-second window.
        let w = c.stats_window("1m").unwrap();
        assert_eq!(w.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(w.get("window_s").unwrap().as_f64(), Some(60.0));
        assert_eq!(w.get("completed").unwrap().as_f64(), Some(1.0));
        assert_eq!(w.get("samples_out").unwrap().as_f64(), Some(3.0));
        // Bare-number windows are accepted.
        let w = c.raw(r#"{"op":"stats","window":30}"#).unwrap();
        assert_eq!(w.get("window_s").unwrap().as_f64(), Some(30.0));

        // Present-but-invalid params are typed errors, not silent defaults.
        for bad in [
            r#"{"op":"stats","window":"eternity"}"#,
            r#"{"op":"stats","window":-5}"#,
            r#"{"op":"stats","window":"0s"}"#,
            r#"{"op":"trace","limit":"many"}"#,
            r#"{"op":"trace","limit":-1}"#,
            r#"{"op":"trace","limit":1.5}"#,
        ] {
            let v = c.raw(bad).unwrap();
            assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{bad}");
            assert_eq!(v.get("kind").unwrap().as_str(), Some("invalid_request"), "{bad}");
        }
        // The connection survives the error replies.
        assert!(c.ping().unwrap());
        server.stop();
        svc.shutdown();
    }

    #[test]
    fn subscribe_streams_span_events() {
        let (server, svc) = test_server();
        let mut sub = Client::connect(&server.addr.to_string()).unwrap();
        sub.set_read_timeout(Duration::from_secs(5)).unwrap();
        let ack = sub.subscribe().unwrap();
        assert_eq!(ack.get("ok").unwrap().as_bool(), Some(true));

        let mut c = Client::connect(&server.addr.to_string()).unwrap();
        let r = c
            .sample(&SampleRequest {
                n: 1,
                steps: 5,
                seed: 9,
                trace_id: Some(4242),
                return_samples: false,
                ..Default::default()
            })
            .unwrap();
        assert!(r.ok, "{:?}", r.error);

        // The request's span events stream back as NDJSON; collect until
        // the respond-stage span for our trace id shows up.
        let mut saw_respond = false;
        for _ in 0..64 {
            let ev = sub.read_event().unwrap().expect("stream open");
            assert_eq!(ev.get("event").and_then(Value::as_str), Some("span"));
            if ev.get("trace_id").and_then(Value::as_f64) == Some(4242.0)
                && ev.get("stage").and_then(Value::as_str) == Some("respond")
            {
                saw_respond = true;
                break;
            }
        }
        assert!(saw_respond, "respond span for trace 4242 never streamed");
        drop(sub);
        server.stop();
        svc.shutdown();
    }

    // Satellite: `connections_open` must return to zero however the
    // connection dies — clean close, garbage then close, close mid-line,
    // or a subscriber hangup.
    #[test]
    fn connection_gauge_survives_failing_connection_churn() {
        let (server, svc) = test_server();
        let addr = server.addr.to_string();
        for i in 0..12 {
            let mut s = std::net::TcpStream::connect(&addr).unwrap();
            match i % 4 {
                0 => {} // connect and immediately close
                1 => {
                    // Garbage line (error reply), then close without reading.
                    s.write_all(b"{not json\n").unwrap();
                }
                2 => {
                    // Half a line, no newline: the read loop must not hang.
                    s.write_all(b"{\"op\":\"pi").unwrap();
                }
                _ => {
                    // Subscribe, then vanish mid-stream.
                    s.write_all(b"{\"op\":\"subscribe\"}\n").unwrap();
                    let mut one = [0u8; 1];
                    use std::io::Read;
                    let _ = s.read(&mut one); // wait for the ack to start
                }
            }
            drop(s);
        }
        let st = server.frontend_stats();
        let deadline = Instant::now() + Duration::from_secs(5);
        while (st.connections_total.load(Ordering::Relaxed) < 12
            || st.connections_open.load(Ordering::Relaxed) > 0)
            && Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(st.connections_total.load(Ordering::Relaxed), 12);
        assert_eq!(
            st.connections_open.load(Ordering::Relaxed),
            0,
            "every exit path must decrement the gauge"
        );
        server.stop();
        svc.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let (server, svc) = test_server();
        let addr = server.addr.to_string();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut c = Client::connect(&addr).unwrap();
                    let r = c
                        .sample(&SampleRequest {
                            n: 1,
                            steps: 5,
                            seed: i,
                            return_samples: false,
                            ..Default::default()
                        })
                        .unwrap();
                    assert!(r.ok);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        server.stop();
        svc.shutdown();
    }
}
