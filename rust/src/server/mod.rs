//! TCP front end: newline-delimited JSON over a thread-per-connection
//! listener, a blocking client, and an open-loop Poisson load generator for
//! the serving benches.
//!
//! Protocol (one JSON object per line):
//!   → {"op":"sample", "n":4, "steps":10, "method":"unipc-3", ...}
//!   ← {"ok":true, "nfe":10, "samples":[...], "trace_id":…, ...}
//!   → {"op":"stats"}   ← metrics snapshot + front-end gauges
//!   → {"op":"ping"}    ← {"ok":true}
//!   → {"op":"trace", "limit":8}  ← recent span trees (see [`crate::trace`])
//!
//! The listener accounts for its connections: a `connections_open` gauge
//! and per-op counters ride on every `stats` reply, and [`Server::stop`]
//! waits (bounded) for per-connection threads to drain instead of leaving
//! them unaccounted.

pub mod client;
pub mod loadgen;

pub use client::Client;
pub use loadgen::{run_load, LoadConfig, LoadReport};

use crate::coordinator::{SampleRequest, Service};
use crate::json::{self, Value};
use crate::log;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Default span-tree count for `{"op":"trace"}` when no `limit` is given.
const DEFAULT_TRACE_LIMIT: usize = 8;

/// Front-end accounting, shared by the accept loop and every connection
/// thread. All plain atomics: the hot path pays one relaxed increment per
/// request.
#[derive(Debug, Default)]
pub struct FrontendStats {
    /// Connections ever accepted.
    pub connections_total: AtomicU64,
    /// Connections currently open (gauge; maintained by a drop guard, so a
    /// panicking connection thread still decrements it).
    pub connections_open: AtomicU64,
    /// Per-op request counters.
    pub op_sample: AtomicU64,
    pub op_stats: AtomicU64,
    pub op_ping: AtomicU64,
    pub op_trace: AtomicU64,
    /// Unknown ops and unparsable lines.
    pub op_other: AtomicU64,
}

impl FrontendStats {
    /// The gauge/counter block merged into every `stats` reply.
    fn fields(&self) -> Vec<(&'static str, Value)> {
        let g = |a: &AtomicU64| Value::from(a.load(Ordering::Relaxed) as f64);
        vec![
            ("connections_total", g(&self.connections_total)),
            ("connections_open", g(&self.connections_open)),
            ("op_sample", g(&self.op_sample)),
            ("op_stats", g(&self.op_stats)),
            ("op_ping", g(&self.op_ping)),
            ("op_trace", g(&self.op_trace)),
            ("op_other", g(&self.op_other)),
        ]
    }
}

/// Decrements `connections_open` when a connection thread exits — normally
/// or by panic — so the gauge cannot drift.
struct ConnGuard(Arc<FrontendStats>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.connections_open.fetch_sub(1, Ordering::Relaxed);
    }
}

/// A running server (owns the listener thread).
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    stats: Arc<FrontendStats>,
}

impl Server {
    /// Bind and serve in background threads. `addr` may use port 0 to pick
    /// a free port (the chosen address is in `self.addr`).
    pub fn spawn(service: Service, addr: &str) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let stats = Arc::new(FrontendStats::default());
        let stats2 = Arc::clone(&stats);
        std::thread::Builder::new()
            .name("unipc-server".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let svc = service.clone();
                            let st = Arc::clone(&stats2);
                            let sp = Arc::clone(&stop2);
                            st.connections_total.fetch_add(1, Ordering::Relaxed);
                            st.connections_open.fetch_add(1, Ordering::Relaxed);
                            std::thread::spawn(move || {
                                let _guard = ConnGuard(Arc::clone(&st));
                                let _ = handle_conn(stream, svc, st, sp);
                            });
                        }
                        Err(e) => log::warn!("accept error: {e}"),
                    }
                }
            })
            .context("spawn server thread")?;
        log::info!("serving on {local}");
        Ok(Server { addr: local, stop, stats })
    }

    /// Front-end accounting (connection gauge + per-op counters).
    pub fn frontend_stats(&self) -> &FrontendStats {
        &self.stats
    }

    /// Ask the accept loop to stop, then wait — bounded — for open
    /// connection threads to finish their in-flight request and exit
    /// (each connection re-checks the stop flag between requests).
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // Nudge the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        let deadline = Instant::now() + Duration::from_secs(2);
        while self.stats.connections_open.load(Ordering::Relaxed) > 0
            && Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    service: Service,
    stats: Arc<FrontendStats>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // Bound idle reads so a quiet connection notices the stop flag instead
    // of pinning its thread on a blocking read forever.
    stream.set_read_timeout(Some(Duration::from_millis(200))).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(()); // server stopping: finish between requests
        }
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Idle poll: re-check the stop flag. `line` keeps any
                // partial prefix already read, so a slow writer straddling
                // the timeout loses nothing.
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        let trimmed = line.trim();
        if !trimmed.is_empty() {
            let reply = dispatch(trimmed, &service, &stats);
            stream.write_all(reply.to_string().as_bytes())?;
            stream.write_all(b"\n")?;
        }
        line.clear();
    }
}

fn dispatch(line: &str, service: &Service, stats: &FrontendStats) -> Value {
    let parsed = match json::parse(line) {
        Ok(v) => v,
        Err(e) => {
            stats.op_other.fetch_add(1, Ordering::Relaxed);
            return Value::obj(vec![
                ("ok", Value::from(false)),
                ("kind", Value::from("invalid_request")),
                ("error", Value::from(format!("bad json: {e}"))),
            ])
        }
    };
    match parsed.get("op").and_then(Value::as_str) {
        Some("ping") => {
            stats.op_ping.fetch_add(1, Ordering::Relaxed);
            Value::obj(vec![("ok", Value::from(true))])
        }
        Some("stats") => {
            stats.op_stats.fetch_add(1, Ordering::Relaxed);
            let mut v = service.metrics_json();
            if let Value::Obj(m) = &mut v {
                for (k, val) in stats.fields() {
                    m.insert(k.to_string(), val);
                }
            }
            v
        }
        Some("trace") => {
            stats.op_trace.fetch_add(1, Ordering::Relaxed);
            let limit = parsed
                .get("limit")
                .and_then(Value::as_usize)
                .unwrap_or(DEFAULT_TRACE_LIMIT);
            // `trace_json` already returns `{"traces": [...]}`; stamp the
            // protocol's `ok` onto it rather than nesting another object.
            let mut v = service.trace_json(limit);
            if let Value::Obj(m) = &mut v {
                m.insert("ok".to_string(), Value::from(true));
            }
            v
        }
        Some("sample") => {
            stats.op_sample.fetch_add(1, Ordering::Relaxed);
            match SampleRequest::from_json(&parsed) {
                Ok(req) => service.sample_blocking(req).to_json(),
                Err(e) => Value::obj(vec![
                    ("ok", Value::from(false)),
                    ("kind", Value::from("invalid_request")),
                    ("error", Value::from(format!("{e:#}"))),
                ]),
            }
        }
        other => {
            stats.op_other.fetch_add(1, Ordering::Relaxed);
            Value::obj(vec![
                ("ok", Value::from(false)),
                ("kind", Value::from("invalid_request")),
                ("error", Value::from(format!("unknown op {other:?}"))),
            ])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::datasets::{dataset, DatasetSpec};
    use crate::config::ServerConfig;
    use crate::coordinator::ModelBackend;

    fn test_server() -> (Server, Service) {
        let spec = DatasetSpec::BedroomLike;
        let gm = Arc::new(dataset(spec));
        let svc = Service::start(
            ServerConfig { workers: 2, ..Default::default() },
            ModelBackend::Analytic {
                gm,
                class_components: Arc::new(vec![(0..4).collect()]),
            },
        );
        let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
        (server, svc)
    }

    #[test]
    fn ping_stats_sample_over_tcp() {
        let (server, svc) = test_server();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        assert!(client.ping().unwrap());

        let resp = client
            .sample(&SampleRequest { n: 2, steps: 5, seed: 3, ..Default::default() })
            .unwrap();
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.nfe, 5);
        assert_eq!(resp.samples.unwrap().len(), 2 * svc.dim());

        let stats = client.stats().unwrap();
        assert_eq!(stats.get("completed").unwrap().as_f64(), Some(1.0));
        server.stop();
        svc.shutdown();
    }

    #[test]
    fn malformed_lines_get_error_replies() {
        let (server, svc) = test_server();
        let mut c = Client::connect(&server.addr.to_string()).unwrap();
        let v = c.raw("{not json").unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        let v = c.raw(r#"{"op":"wat"}"#).unwrap();
        assert!(v.get("error").unwrap().as_str().unwrap().contains("unknown op"));
        // The connection stays usable.
        assert!(c.ping().unwrap());
        server.stop();
        svc.shutdown();
    }

    #[test]
    fn frontend_counters_and_trace_op() {
        let (server, svc) = test_server();
        let mut c = Client::connect(&server.addr.to_string()).unwrap();
        assert!(c.ping().unwrap());
        let resp = c
            .sample(&SampleRequest { n: 1, steps: 5, seed: 1, trace_id: Some(99), ..Default::default() })
            .unwrap();
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.trace_id, 99, "trace id must round-trip the wire");

        // The trace op returns that request's span tree.
        let traces = c.trace(8).unwrap();
        let arr = traces.as_arr().expect("traces is an array");
        assert!(
            arr.iter().any(|t| t.get("trace_id").and_then(Value::as_f64) == Some(99.0)),
            "span tree for trace 99 missing: {traces:?}"
        );

        // Stats carry the front-end gauge/counter block.
        let stats = c.stats().unwrap();
        assert_eq!(stats.get("connections_open").unwrap().as_f64(), Some(1.0));
        assert_eq!(stats.get("connections_total").unwrap().as_f64(), Some(1.0));
        assert_eq!(stats.get("op_ping").unwrap().as_f64(), Some(1.0));
        assert_eq!(stats.get("op_sample").unwrap().as_f64(), Some(1.0));
        assert_eq!(stats.get("op_trace").unwrap().as_f64(), Some(1.0));
        assert_eq!(stats.get("op_stats").unwrap().as_f64(), Some(1.0));

        // stop() drains the connection thread: the gauge returns to 0.
        server.stop();
        assert_eq!(
            server.frontend_stats().connections_open.load(Ordering::Relaxed),
            0,
            "stop must wait for connection threads to exit"
        );
        svc.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let (server, svc) = test_server();
        let addr = server.addr.to_string();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut c = Client::connect(&addr).unwrap();
                    let r = c
                        .sample(&SampleRequest {
                            n: 1,
                            steps: 5,
                            seed: i,
                            return_samples: false,
                            ..Default::default()
                        })
                        .unwrap();
                    assert!(r.ok);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        server.stop();
        svc.shutdown();
    }
}
