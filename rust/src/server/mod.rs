//! TCP front end: newline-delimited JSON over a thread-per-connection
//! listener, a blocking client, and an open-loop Poisson load generator for
//! the serving benches.
//!
//! Protocol (one JSON object per line):
//!   → {"op":"sample", "n":4, "steps":10, "method":"unipc-3", ...}
//!   ← {"ok":true, "nfe":10, "samples":[...], ...}
//!   → {"op":"stats"}   ← metrics snapshot
//!   → {"op":"ping"}    ← {"ok":true}

pub mod client;
pub mod loadgen;

pub use client::Client;
pub use loadgen::{run_load, LoadConfig, LoadReport};

use crate::coordinator::{SampleRequest, Service};
use crate::json::{self, Value};
use crate::log;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// A running server (owns the listener thread).
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
}

impl Server {
    /// Bind and serve in background threads. `addr` may use port 0 to pick
    /// a free port (the chosen address is in `self.addr`).
    pub fn spawn(service: Service, addr: &str) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        std::thread::Builder::new()
            .name("unipc-server".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let svc = service.clone();
                            std::thread::spawn(move || {
                                let _ = handle_conn(stream, svc);
                            });
                        }
                        Err(e) => log::warn!("accept error: {e}"),
                    }
                }
            })
            .context("spawn server thread")?;
        log::info!("serving on {local}");
        Ok(Server { addr: local, stop })
    }

    /// Ask the accept loop to stop (takes effect on the next connection).
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // Nudge the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
    }
}

fn handle_conn(stream: TcpStream, service: Service) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let reply = dispatch(trimmed, &service);
        stream.write_all(reply.to_string().as_bytes())?;
        stream.write_all(b"\n")?;
    }
}

fn dispatch(line: &str, service: &Service) -> Value {
    let parsed = match json::parse(line) {
        Ok(v) => v,
        Err(e) => {
            return Value::obj(vec![
                ("ok", Value::from(false)),
                ("kind", Value::from("invalid_request")),
                ("error", Value::from(format!("bad json: {e}"))),
            ])
        }
    };
    match parsed.get("op").and_then(Value::as_str) {
        Some("ping") => Value::obj(vec![("ok", Value::from(true))]),
        Some("stats") => service.metrics_json(),
        Some("sample") => match SampleRequest::from_json(&parsed) {
            Ok(req) => service.sample_blocking(req).to_json(),
            Err(e) => Value::obj(vec![
                ("ok", Value::from(false)),
                ("kind", Value::from("invalid_request")),
                ("error", Value::from(format!("{e:#}"))),
            ]),
        },
        other => Value::obj(vec![
            ("ok", Value::from(false)),
            ("kind", Value::from("invalid_request")),
            ("error", Value::from(format!("unknown op {other:?}"))),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::datasets::{dataset, DatasetSpec};
    use crate::config::ServerConfig;
    use crate::coordinator::ModelBackend;

    fn test_server() -> (Server, Service) {
        let spec = DatasetSpec::BedroomLike;
        let gm = Arc::new(dataset(spec));
        let svc = Service::start(
            ServerConfig { workers: 2, ..Default::default() },
            ModelBackend::Analytic {
                gm,
                class_components: Arc::new(vec![(0..4).collect()]),
            },
        );
        let server = Server::spawn(svc.clone(), "127.0.0.1:0").unwrap();
        (server, svc)
    }

    #[test]
    fn ping_stats_sample_over_tcp() {
        let (server, svc) = test_server();
        let mut client = Client::connect(&server.addr.to_string()).unwrap();
        assert!(client.ping().unwrap());

        let resp = client
            .sample(&SampleRequest { n: 2, steps: 5, seed: 3, ..Default::default() })
            .unwrap();
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.nfe, 5);
        assert_eq!(resp.samples.unwrap().len(), 2 * svc.dim());

        let stats = client.stats().unwrap();
        assert_eq!(stats.get("completed").unwrap().as_f64(), Some(1.0));
        server.stop();
        svc.shutdown();
    }

    #[test]
    fn malformed_lines_get_error_replies() {
        let (server, svc) = test_server();
        let mut c = Client::connect(&server.addr.to_string()).unwrap();
        let v = c.raw("{not json").unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        let v = c.raw(r#"{"op":"wat"}"#).unwrap();
        assert!(v.get("error").unwrap().as_str().unwrap().contains("unknown op"));
        // The connection stays usable.
        assert!(c.ping().unwrap());
        server.stop();
        svc.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let (server, svc) = test_server();
        let addr = server.addr.to_string();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut c = Client::connect(&addr).unwrap();
                    let r = c
                        .sample(&SampleRequest {
                            n: 1,
                            steps: 5,
                            seed: i,
                            return_samples: false,
                            ..Default::default()
                        })
                        .unwrap();
                    assert!(r.ok);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        server.stop();
        svc.shutdown();
    }
}
