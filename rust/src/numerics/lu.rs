//! Small dense linear solve with partial pivoting.
//!
//! Solves the p×p systems of Theorem 3.1 (p is the solver order, ≤ ~8 in
//! practice), so an O(p³) LU with partial pivoting is exactly right — no
//! external linear-algebra crate needed.

/// Solve `A x = b` for square `A` (row-major, n×n). Returns `None` if the
/// matrix is numerically singular.
pub fn solve(a: &[f64], b: &[f64], n: usize) -> Option<Vec<f64>> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    let mut m = a.to_vec();
    let mut x = b.to_vec();

    for col in 0..n {
        // Partial pivot.
        let mut piv = col;
        let mut best = m[col * n + col].abs();
        for row in (col + 1)..n {
            let v = m[row * n + col].abs();
            if v > best {
                best = v;
                piv = row;
            }
        }
        if best < 1e-300 {
            return None;
        }
        if piv != col {
            for k in 0..n {
                m.swap(col * n + k, piv * n + k);
            }
            x.swap(col, piv);
        }
        // Eliminate below.
        let d = m[col * n + col];
        for row in (col + 1)..n {
            let f = m[row * n + col] / d;
            if f == 0.0 {
                continue;
            }
            for k in col..n {
                m[row * n + k] -= f * m[col * n + k];
            }
            x[row] -= f * x[col];
        }
    }
    // Back substitution.
    for col in (0..n).rev() {
        let mut v = x[col];
        for k in (col + 1)..n {
            v -= m[col * n + k] * x[k];
        }
        x[col] = v / m[col * n + col];
    }
    Some(x)
}

/// Invert a square matrix (used for the UniPC_v coefficient matrix
/// A_p = C_p⁻¹ of Appendix C). Returns row-major inverse, or `None` if
/// singular.
pub fn invert(a: &[f64], n: usize) -> Option<Vec<f64>> {
    let mut inv = vec![0.0; n * n];
    let mut e = vec![0.0; n];
    for j in 0..n {
        e.iter_mut().for_each(|v| *v = 0.0);
        e[j] = 1.0;
        let col = solve(a, &e, n)?;
        for i in 0..n {
            inv[i * n + j] = col[i];
        }
    }
    Some(inv)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solve_identity() {
        let a = [1.0, 0.0, 0.0, 1.0];
        let b = [3.0, -4.0];
        let x = solve(&a, &b, 2).unwrap();
        assert_eq!(x, vec![3.0, -4.0]);
    }

    #[test]
    fn solve_requires_pivoting() {
        // Zero on the diagonal forces a row swap.
        let a = [0.0, 1.0, 1.0, 0.0];
        let b = [2.0, 5.0];
        let x = solve(&a, &b, 2).unwrap();
        assert!((x[0] - 5.0).abs() < 1e-14);
        assert!((x[1] - 2.0).abs() < 1e-14);
    }

    #[test]
    fn solve_3x3() {
        let a = [2.0, 1.0, -1.0, -3.0, -1.0, 2.0, -2.0, 1.0, 2.0];
        let b = [8.0, -11.0, -3.0];
        let x = solve(&a, &b, 3).unwrap();
        let expect = [2.0, 3.0, -1.0];
        for (xi, ei) in x.iter().zip(expect) {
            assert!((xi - ei).abs() < 1e-12, "{x:?}");
        }
    }

    #[test]
    fn singular_returns_none() {
        let a = [1.0, 2.0, 2.0, 4.0];
        assert!(solve(&a, &[1.0, 2.0], 2).is_none());
    }

    #[test]
    fn invert_roundtrip() {
        let a = [4.0, 7.0, 2.0, 6.0];
        let inv = invert(&a, 2).unwrap();
        // a * inv = I
        for i in 0..2 {
            for j in 0..2 {
                let mut v = 0.0;
                for k in 0..2 {
                    v += a[i * 2 + k] * inv[k * 2 + j];
                }
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((v - expect).abs() < 1e-12);
            }
        }
    }
}
