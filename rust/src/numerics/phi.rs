//! φ_k / ψ_k exponential-integrator functions.
//!
//! Definitions (paper Appendix E.1):
//!   φ_0(h) = e^h,           φ_{k+1}(h) = (φ_k(h) − 1/k!) / h,
//!   φ_k(h) = ∫₀¹ e^{(1−r)h} r^{k−1}/(k−1)! dr  (k ≥ 1),
//! with closed forms φ₁ = (e^h−1)/h, φ₂ = (e^h−h−1)/h², … and Taylor series
//!   φ_k(h) = Σ_{j≥0} h^j / (j+k)!.
//!
//! The data-prediction functions of Appendix E.4 satisfy ψ_k(h) = φ_k(−h)
//! (ψ₀ = e^{−h}, ψ_{k+1} = (1/k! − ψ_k)/h), so a single implementation
//! serves both (tested below).
//!
//! Numerical care: the forward recurrence loses ~k digits of precision per
//! level when |h| is small (subtracting nearly equal quantities). We switch
//! to the Taylor series for |h| below a level-dependent threshold; the two
//! branches agree to ~1e-13 at the crossover (see tests).

/// Factorial as f64 (exact for n ≤ 20).
pub fn factorial(n: usize) -> f64 {
    (1..=n).fold(1.0f64, |acc, i| acc * i as f64)
}

/// Series evaluation φ_k(h) = Σ_{j≥0} h^j / (j+k)!.
fn phi_series(k: usize, h: f64) -> f64 {
    // Terms decay like h^j / (j+k)!; 30 terms is far beyond f64 precision
    // for the |h| < 0.5 range where this branch is used.
    let mut term = 1.0 / factorial(k);
    let mut sum = term;
    for j in 1..30 {
        term *= h / (j + k) as f64;
        sum += term;
        if term.abs() < 1e-18 * sum.abs() {
            break;
        }
    }
    sum
}

/// φ_k(h), stable for all h.
pub fn phi(k: usize, h: f64) -> f64 {
    if k == 0 {
        return h.exp();
    }
    // The forward recurrence divides cancellation error by h at each level;
    // use the series whenever |h| is small enough that the recurrence would
    // lose more than ~3 digits at level k.
    if h.abs() < 0.5 {
        return phi_series(k, h);
    }
    let mut v = h.exp();
    for j in 0..k {
        v = (v - 1.0 / factorial(j)) / h;
    }
    v
}

/// ψ_k(h) = φ_k(−h) — the data-prediction mirror (Appendix E.4).
pub fn psi(k: usize, h: f64) -> f64 {
    phi(k, -h)
}

/// The vector (φ₁(h), …, φ_p(h)).
pub fn phi_vec(p: usize, h: f64) -> Vec<f64> {
    (1..=p).map(|k| phi(k, h)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())), "{a} vs {b}");
    }

    #[test]
    fn closed_forms_k123() {
        // Appendix E.1 closed forms.
        for &h in &[-2.0, -0.7, 0.9, 2.5] {
            close(phi(1, h), (h.exp() - 1.0) / h, 1e-14);
            close(phi(2, h), (h.exp() - h - 1.0) / (h * h), 1e-13);
            close(phi(3, h), (h.exp() - h * h / 2.0 - h - 1.0) / (h * h * h), 1e-12);
        }
    }

    #[test]
    fn series_matches_recurrence_at_crossover() {
        for k in 1..=6 {
            for &h in &[0.5, 0.6, -0.5, -0.6, 1.0, -1.0] {
                let rec = {
                    let mut v = (h as f64).exp();
                    for j in 0..k {
                        v = (v - 1.0 / factorial(j)) / h;
                    }
                    v
                };
                close(phi(k, h), rec, 1e-10);
            }
        }
    }

    #[test]
    fn phi_at_zero_is_inverse_factorial() {
        for k in 0..8 {
            close(phi(k, 1e-18), 1.0 / factorial(k), 1e-12);
        }
    }

    #[test]
    fn psi_closed_forms() {
        // Appendix E.4: ψ₁ = (1−e^{−h})/h, ψ₂ = (h−1+e^{−h})/h², ψ₃ = (h²/2−h+1−e^{−h})/h³.
        for &h in &[0.8, 2.0, -1.3] {
            close(psi(1, h), (1.0 - (-h).exp()) / h, 1e-14);
            close(psi(2, h), (h - 1.0 + (-h).exp()) / (h * h), 1e-13);
            close(
                psi(3, h),
                (h * h / 2.0 - h + 1.0 - (-h).exp()) / (h * h * h),
                1e-12,
            );
        }
    }

    #[test]
    fn psi_recurrence_identity() {
        // ψ_{k+1}(h) = (1/k! − ψ_k(h))/h — the paper's recursion (Eq. 10).
        for &h in &[0.3, 1.7] {
            for k in 0..5 {
                close(psi(k + 1, h), (1.0 / factorial(k) - psi(k, h)) / h, 1e-11);
            }
        }
    }

    #[test]
    fn phi_recurrence_identity_large_h() {
        for &h in &[1.0, 3.0, -2.0] {
            for k in 0..5 {
                close(phi(k + 1, h), (phi(k, h) - 1.0 / factorial(k)) / h, 1e-10);
            }
        }
    }

    #[test]
    fn small_h_stability() {
        // Naive recurrence at h=1e-8 would be pure noise by k=2; series must
        // return 1/k! + h/(k+1)! to high relative accuracy.
        let h = 1e-8;
        for k in 1..6 {
            let expect = 1.0 / factorial(k) + h / factorial(k + 1);
            close(phi(k, h), expect, 1e-12);
        }
    }

    #[test]
    fn phi_vec_contents() {
        let v = phi_vec(3, 0.9);
        assert_eq!(v.len(), 3);
        close(v[0], phi(1, 0.9), 0.0);
        close(v[2], phi(3, 0.9), 0.0);
    }
}
