//! Theorem 3.1 coefficient systems.
//!
//! UniPC chooses its combination weights a_p by solving
//!     R_p(h) a_p B(h) = φ_p(h)                      (Eq. 5)
//! where R_p(h) is the Vandermonde matrix with entries (r_m h)^{k−1} and
//! φ_p(h) stacks φ_n(h) = hⁿ n! φ_{n+1}(h). Dividing row k by h^{k−1}
//! removes h from the matrix:
//!     Σ_m r_m^{k−1} a_m = h · k! · φ_{k+1}(h) / B(h)   for k = 1..p,
//! which is the form solved here (it matches the official implementation).
//! The data-prediction system (Proposition A.1, Eq. 11) is identical after
//! the substitution h → −h (because ψ_k(h) = φ_k(−h)); callers pass the
//! *signed* step `hh` (+h for noise prediction, −h for data prediction).

use super::lu;
use super::phi::{factorial, phi};

/// The paper's two instantiations of B(h) (§3.1; Table 1 ablates them).
/// Any non-degenerate B(h) = O(h) is admissible; these are the ones the
/// paper evaluates. Applied to the signed step `hh`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BFunction {
    /// B₁(h) = h.
    Bh1,
    /// B₂(h) = e^h − 1.
    Bh2,
}

impl BFunction {
    /// Evaluate B at the signed step.
    pub fn eval(self, hh: f64) -> f64 {
        match self {
            BFunction::Bh1 => hh,
            BFunction::Bh2 => hh.exp_m1(),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BFunction::Bh1 => "bh1",
            BFunction::Bh2 => "bh2",
        }
    }
}

/// Row-major q×q Vandermonde matrix V[k][m] = r_m^k (k = 0..q-1).
pub fn vandermonde_matrix(rks: &[f64]) -> Vec<f64> {
    let q = rks.len();
    let mut v = vec![0.0; q * q];
    for (m, &r) in rks.iter().enumerate() {
        let mut p = 1.0;
        for k in 0..q {
            v[k * q + m] = p;
            p *= r;
        }
    }
    v
}

/// Right-hand side b_k = hh · k! · φ_{k+1}(hh) / B(hh) for k = 1..q.
pub fn unipc_b_vector(q: usize, hh: f64, b: BFunction) -> Vec<f64> {
    let bh = b.eval(hh);
    (1..=q)
        .map(|k| hh * factorial(k) * phi(k + 1, hh) / bh)
        .collect()
}

/// Solve for the UniPC combination coefficients a (length q) given the
/// normalized node positions r_1..r_q and the signed step hh.
///
/// For the corrector of order p: q = p with r_q = 1.
/// For the predictor of order p: q = p − 1 (the D_p term is dropped,
/// Corollary 3.2).
///
/// Panics on duplicate r values (the paper requires strict monotonicity,
/// which guarantees invertibility of the Vandermonde matrix).
pub fn unipc_coeffs(rks: &[f64], hh: f64, b: BFunction) -> Vec<f64> {
    let q = rks.len();
    assert!(q > 0, "unipc_coeffs needs at least one node");
    if q == 1 {
        // Degenerate case (UniP-2 / UniC-1): the paper shows a₁ = 1/2
        // satisfies the order condition for both B₁ and B₂ independent of h
        // (Appendix F), and the reference implementation hardcodes it. This
        // is also *why* B(h) is a real knob: with a₁ fixed, the update term
        // a₁·B(h)·D differs between B₁ and B₂ at O(h²), whereas an exact
        // 1×1 solve would cancel B entirely.
        return vec![0.5];
    }
    let v = vandermonde_matrix(rks);
    let rhs = unipc_b_vector(q, hh, b);
    lu::solve(&v, &rhs, q)
        .unwrap_or_else(|| panic!("singular Vandermonde system for r = {rks:?}"))
}

/// Appendix C (UniPC_v): the varying-coefficient matrix A_p = C_p⁻¹ with
/// C_p[k][m] = r_m^k / (k+1)! for k = 0..q−1 (1-indexed: r^{k−1}/k!),
/// returned row-major. A_p depends only on the node ratios {r_m} — not on
/// the step size — which is why [`crate::solver::plan::SamplePlan`] can
/// precompute the (otherwise per-step) LU inversion once per run.
///
/// Panics on duplicate r values (C_p is a scaled Vandermonde matrix, so
/// distinct nodes guarantee invertibility).
pub fn varying_coeff_matrix(rks: &[f64]) -> Vec<f64> {
    let q = rks.len();
    assert!(q > 0, "varying_coeff_matrix needs at least one node");
    let mut c = vec![0.0; q * q];
    let mut fact = 1.0;
    for k in 0..q {
        fact *= (k + 1) as f64;
        for (m, &r) in rks.iter().enumerate() {
            c[k * q + m] = r.powi(k as i32) / fact;
        }
    }
    lu::invert(&c, q).expect("C_p is invertible for distinct r")
}

/// Residual of the order condition |R_p(h) a B(h) − φ_p(h)| (l1 norm over
/// rows, in the *unscaled* form of Eq. 5). Used by tests to verify the
/// O(h^{p+1}) bound of Theorem 3.1 empirically.
pub fn order_condition_residual(rks: &[f64], a: &[f64], hh: f64, b: BFunction) -> f64 {
    let q = rks.len();
    let bh = b.eval(hh);
    let mut res = 0.0;
    for k in 1..=q {
        // Row k of Eq. 5: Σ_m (r_m hh)^{k−1} a_m B − hh^k k! φ_{k+1}(hh).
        let mut lhs = 0.0;
        for (m, &r) in rks.iter().enumerate() {
            lhs += (r * hh).powi(k as i32 - 1) * a[m];
        }
        lhs *= bh;
        let rhs = hh.powi(k as i32) * factorial(k) * phi(k + 1, hh);
        res += (lhs - rhs).abs();
    }
    res
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vandermonde_shape_and_values() {
        let v = vandermonde_matrix(&[-2.0, -1.0, 1.0]);
        // Row 0: ones. Row 1: r. Row 2: r².
        assert_eq!(&v[0..3], &[1.0, 1.0, 1.0]);
        assert_eq!(&v[3..6], &[-2.0, -1.0, 1.0]);
        assert_eq!(&v[6..9], &[4.0, 1.0, 1.0]);
    }

    #[test]
    fn degenerate_a1_is_exactly_half() {
        // Appendix F: UniP-2 / UniC-1 degenerate to a₁ = 1/2 for both B's,
        // independent of h (the reference-implementation convention).
        for b in [BFunction::Bh1, BFunction::Bh2] {
            for &h in &[1e-4, -1e-4, 0.7] {
                let a = unipc_coeffs(&[1.0], h, b);
                assert_eq!(a, vec![0.5], "{b:?} h={h}");
            }
        }
    }

    #[test]
    fn b_function_matters_beyond_degenerate_order() {
        // With a₁ fixed at 1/2, the effective residual coefficient
        // a₁·B(h) differs between B₁ and B₂ — the Table 1 ablation knob.
        let h = 0.5;
        assert_ne!(BFunction::Bh1.eval(h), BFunction::Bh2.eval(h));
        // For q ≥ 2 the exact solve makes B(h)·a_m independent of B.
        let a1 = unipc_coeffs(&[-1.0, 1.0], h, BFunction::Bh1);
        let a2 = unipc_coeffs(&[-1.0, 1.0], h, BFunction::Bh2);
        let c1: Vec<f64> = a1.iter().map(|a| a * BFunction::Bh1.eval(h)).collect();
        let c2: Vec<f64> = a2.iter().map(|a| a * BFunction::Bh2.eval(h)).collect();
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-12, "{c1:?} vs {c2:?}");
        }
    }

    #[test]
    fn exact_solution_satisfies_rows() {
        let rks = [-1.5, -0.5, 1.0];
        let hh = 0.4;
        for b in [BFunction::Bh1, BFunction::Bh2] {
            let a = unipc_coeffs(&rks, hh, b);
            let v = vandermonde_matrix(&rks);
            let rhs = unipc_b_vector(3, hh, b);
            for k in 0..3 {
                let lhs: f64 = (0..3).map(|m| v[k * 3 + m] * a[m]).sum();
                assert!((lhs - rhs[k]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn order_condition_residual_is_zero_for_exact_solve() {
        // We solve Eq. 5 exactly (not just to O(h^{p+1})), so the residual
        // must vanish to rounding.
        let rks = [-2.0, -1.0, 1.0];
        for &hh in &[0.3, -0.25] {
            for b in [BFunction::Bh1, BFunction::Bh2] {
                let a = unipc_coeffs(&rks, hh, b);
                let res = order_condition_residual(&rks, &a, hh, b);
                assert!(res < 1e-12, "residual {res}");
            }
        }
    }

    #[test]
    fn taylor_coefficients_recovered_as_h_to_zero() {
        // As h→0 the system becomes Σ r^{k−1} a_m = k! φ_{k+1}(0) = 1/(k+1)
        // × k!·1/(k+1)!… i.e. b_k → k!/(k+1)! = 1/(k+1) for B₁.
        let rks = [-1.0, 1.0];
        let a = unipc_coeffs(&rks, 1e-9, BFunction::Bh1);
        // Solve by hand: a1+a2 = 1/2, -a1+a2 = 1/3 → a2 = 5/12, a1 = 1/12.
        assert!((a[0] - 1.0 / 12.0).abs() < 1e-6, "{a:?}");
        assert!((a[1] - 5.0 / 12.0).abs() < 1e-6, "{a:?}");
    }

    #[test]
    #[should_panic(expected = "singular")]
    fn duplicate_nodes_panic() {
        let _ = unipc_coeffs(&[1.0, 1.0], 0.1, BFunction::Bh1);
    }

    #[test]
    fn varying_coeff_matrix_inverts_cp() {
        // A_p · C_p = I for asymmetric nodes (q = 3).
        let rks = [-2.0, -0.5, 1.0];
        let q = rks.len();
        let a = varying_coeff_matrix(&rks);
        let mut c = vec![0.0; q * q];
        let mut fact = 1.0;
        for k in 0..q {
            fact *= (k + 1) as f64;
            for (m, &r) in rks.iter().enumerate() {
                c[k * q + m] = r.powi(k as i32) / fact;
            }
        }
        for i in 0..q {
            for j in 0..q {
                let mut v = 0.0;
                for k in 0..q {
                    v += a[i * q + k] * c[k * q + j];
                }
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((v - expect).abs() < 1e-10, "A·C [{i},{j}] = {v}");
            }
        }
    }
}
