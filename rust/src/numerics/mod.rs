//! Exponential-integrator numerics (paper Appendix E.1/E.4 + Theorem 3.1).
//!
//! * [`phi`] — the φ_k(h) functions of Hochbruck & Ostermann and their
//!   data-prediction mirror ψ_k(h) = φ_k(−h), evaluated stably (forward
//!   recurrence for moderate |h|, Taylor series near 0 where the recurrence
//!   catastrophically cancels).
//! * [`vandermonde`] — the R_p(h)/C_p systems of Theorem 3.1 / Appendix C,
//!   plus a small partial-pivot LU used to solve them.

pub mod lu;
pub mod phi;
pub mod vandermonde;

pub use lu::solve as lu_solve;
pub use phi::{phi, phi_vec, psi};
pub use vandermonde::{unipc_b_vector, unipc_coeffs, vandermonde_matrix, BFunction};
