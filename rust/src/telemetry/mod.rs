//! The continuous telemetry plane: always-on observability for the
//! sharded coordinator, complementing the per-request forensics in
//! [`crate::trace`].
//!
//! Four pillars:
//!
//! * **Windowed time-series metrics** — [`WindowStore`]: a fixed-slot ring
//!   of per-second buckets (60×1s) plus a per-minute rollup ring (60×1m)
//!   over completions, failures-by-kind, batch sizes, queue depth, steals,
//!   and a fixed-bucket e2e latency histogram. Slots are keyed by the
//!   *absolute* second (or minute) they cover, so cross-shard
//!   [`WindowStore::merge`] is lossless: two slots at the same ring index
//!   either cover the same instant (counters sum exactly) or differ by a
//!   full ring span — and the older one is outside every window the store
//!   can answer, so dropping it loses nothing a query could see. Exposed
//!   on the wire as `{"op":"stats","window":"1m"}`.
//! * **Prometheus text exposition** — [`PromWriter`] renders every
//!   counter/gauge/histogram in the standard text format (`# HELP` /
//!   `# TYPE` lines) for the `{"op":"metrics"}` op and `serve
//!   --metrics-out`; [`parse_exposition`] is the round-trip validator the
//!   format test drives.
//! * **Push-based event subscription** — [`EventHub`]: bounded
//!   per-subscriber queues of [`TelemetryEvent`]s published at span-flush
//!   time. Publishing never blocks workers and never allocates: when no
//!   subscriber is registered it is a single relaxed atomic load, and a
//!   full queue counts the miss in `sub_dropped` instead of growing. Every
//!   span recorded while a subscription is live is therefore delivered
//!   exactly once or counted dropped — closing the ring-wrap blind spot of
//!   the pull-only `{"op":"trace"}` op.
//! * **SLO burn-rate monitors + solver numerical health** —
//!   [`BurnRateMonitor`] evaluates config-declared per-failure-kind error
//!   budgets (e.g. `deadline_exceeded<0.1%/5m`) against the windowed
//!   counters and emits at most one `slo_breach` event per evaluation
//!   window on the push channel. [`HealthAccum`] + [`HealthSpans`] feed on
//!   the executor's [`StepHealth`] payload — the predictor→corrector
//!   relative delta is a zero-extra-NFE local error estimate because UniC
//!   reuses the current model evaluation (§3.2 of the paper) — recording
//!   per-run delta norms and non-finite provenance (first bad step).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::coordinator::FailureKind;
use crate::json::Value;
use crate::solver::{StepHealth, StepObserver};
use crate::trace::{event_json, SpanEvent, StepSpans};

/// Slots per ring: the seconds ring covers the trailing 60 s, the minutes
/// ring the trailing 60 min.
pub const WINDOW_SLOTS: usize = 60;

/// Upper `le` bounds (µs) of the windowed e2e latency histogram; the
/// eighth bucket is `+Inf`. Powers of four from 1 ms.
pub const E2E_LE_US: [u64; 7] =
    [1_000, 4_000, 16_000, 64_000, 256_000, 1_024_000, 4_096_000];

fn e2e_bucket(us: u64) -> usize {
    E2E_LE_US.iter().position(|&le| us <= le).unwrap_or(E2E_LE_US.len())
}

/// One fixed time bucket of windowed counters, keyed by the absolute
/// second (seconds ring) or minute (minutes ring) it covers. An all-zero
/// slot is indistinguishable from "no activity at epoch 0", which is
/// exactly what it means — so empty needs no sentinel.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WindowSlot {
    /// Absolute slot index on the service clock, in this ring's resolution.
    pub epoch: u64,
    pub completed: u64,
    pub failed: u64,
    pub failures_by_kind: [u64; 6],
    pub samples_out: u64,
    pub nfe_total: u64,
    pub batched_runs: u64,
    pub batch_members: u64,
    pub steals: u64,
    pub depth_sum: u64,
    pub depth_obs: u64,
    pub e2e_sum_us: u64,
    pub e2e_max_us: u64,
    pub e2e_hist: [u64; 8],
}

impl WindowSlot {
    fn accumulate(&mut self, other: &WindowSlot) {
        self.completed += other.completed;
        self.failed += other.failed;
        for (a, b) in self.failures_by_kind.iter_mut().zip(&other.failures_by_kind) {
            *a += b;
        }
        self.samples_out += other.samples_out;
        self.nfe_total += other.nfe_total;
        self.batched_runs += other.batched_runs;
        self.batch_members += other.batch_members;
        self.steals += other.steals;
        self.depth_sum += other.depth_sum;
        self.depth_obs += other.depth_obs;
        self.e2e_sum_us += other.e2e_sum_us;
        self.e2e_max_us = self.e2e_max_us.max(other.e2e_max_us);
        for (a, b) in self.e2e_hist.iter_mut().zip(&other.e2e_hist) {
            *a += b;
        }
    }
}

/// The windowed time-series store: 60 one-second slots plus a 60-slot
/// per-minute rollup, all fixed-size arrays — recording and querying never
/// allocate (the counting-allocator proof in `tests/plan_alloc.rs` pins
/// this). Timestamps are explicit (`now_s` = whole seconds on the service
/// clock) so deterministic replays drive synthetic time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WindowStore {
    pub secs: [WindowSlot; WINDOW_SLOTS],
    pub mins: [WindowSlot; WINDOW_SLOTS],
}

impl Default for WindowStore {
    fn default() -> Self {
        WindowStore {
            secs: [WindowSlot::default(); WINDOW_SLOTS],
            mins: [WindowSlot::default(); WINDOW_SLOTS],
        }
    }
}

fn ring_slot(ring: &mut [WindowSlot; WINDOW_SLOTS], epoch: u64) -> &mut WindowSlot {
    let s = &mut ring[(epoch % WINDOW_SLOTS as u64) as usize];
    if s.epoch != epoch {
        // The slot last covered an instant a full ring span ago (or is
        // fresh): recycle it for the current epoch.
        *s = WindowSlot { epoch, ..WindowSlot::default() };
    }
    s
}

impl WindowStore {
    fn both(&mut self, now_s: u64, f: impl Fn(&mut WindowSlot)) {
        f(ring_slot(&mut self.secs, now_s));
        f(ring_slot(&mut self.mins, now_s / 60));
    }

    pub fn record_completion(&mut self, now_s: u64, n_samples: usize, nfe: usize, e2e_us: u64) {
        self.both(now_s, |s| {
            s.completed += 1;
            s.samples_out += n_samples as u64;
            s.nfe_total += nfe as u64;
            s.e2e_sum_us += e2e_us;
            s.e2e_max_us = s.e2e_max_us.max(e2e_us);
            s.e2e_hist[e2e_bucket(e2e_us)] += 1;
        });
    }

    pub fn record_failure(&mut self, now_s: u64, kind: FailureKind) {
        self.both(now_s, |s| {
            s.failed += 1;
            s.failures_by_kind[kind.index()] += 1;
        });
    }

    pub fn record_batch(&mut self, now_s: u64, members: usize) {
        self.both(now_s, |s| {
            s.batched_runs += 1;
            s.batch_members += members as u64;
        });
    }

    pub fn record_depth(&mut self, now_s: u64, depth: usize) {
        self.both(now_s, |s| {
            s.depth_sum += depth as u64;
            s.depth_obs += 1;
        });
    }

    pub fn record_steal(&mut self, now_s: u64) {
        self.both(now_s, |s| s.steals += 1);
    }

    /// Lossless cross-shard merge. Per ring index: equal epochs cover the
    /// same instant, so counters sum exactly; unequal epochs differ by ≥
    /// one full ring span, so the older slot is outside every answerable
    /// window and keeping the newer one drops nothing a query could see.
    /// Commutative and associative (sum on equal epochs, max-epoch-wins
    /// otherwise) — the merge property test exercises all three laws.
    pub fn merge(&mut self, other: &WindowStore) {
        for (mine, theirs) in self
            .secs
            .iter_mut()
            .chain(self.mins.iter_mut())
            .zip(other.secs.iter().chain(other.mins.iter()))
        {
            if theirs.epoch == mine.epoch {
                mine.accumulate(theirs);
            } else if theirs.epoch > mine.epoch {
                *mine = *theirs;
            }
        }
    }

    /// Sum every slot covering `(now_s − window_s, now_s]`. Windows of up
    /// to 60 s read the seconds ring at full resolution; longer windows
    /// (≤ 3600 s) read the minute rollup.
    pub fn totals(&self, now_s: u64, window_s: u64) -> WindowTotals {
        let mut t = WindowTotals { window_s, ..WindowTotals::default() };
        // The lower bound is signed: early in the service's life the window
        // extends past the epoch (lo < 0), and slot 0 — a real second of
        // traffic — must still be counted. Saturating at zero would make
        // the first second invisible whenever `now_s < window_s`.
        if window_s <= WINDOW_SLOTS as u64 {
            let lo = now_s as i64 - window_s as i64;
            for s in &self.secs {
                if s.epoch as i64 > lo && s.epoch <= now_s {
                    t.add(s);
                }
            }
        } else {
            let now_m = now_s / 60;
            let lo = now_m as i64 - window_s.div_ceil(60) as i64;
            for s in &self.mins {
                if s.epoch as i64 > lo && s.epoch <= now_m {
                    t.add(s);
                }
            }
        }
        t
    }
}

/// Aggregated counters over one query window (cross-shard totals sum with
/// [`WindowTotals::add_totals`]).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WindowTotals {
    pub window_s: u64,
    pub completed: u64,
    pub failed: u64,
    pub failures_by_kind: [u64; 6],
    pub samples_out: u64,
    pub nfe_total: u64,
    pub batched_runs: u64,
    pub batch_members: u64,
    pub steals: u64,
    pub depth_sum: u64,
    pub depth_obs: u64,
    pub e2e_sum_us: u64,
    pub e2e_max_us: u64,
    pub e2e_hist: [u64; 8],
}

impl WindowTotals {
    fn add(&mut self, slot: &WindowSlot) {
        self.completed += slot.completed;
        self.failed += slot.failed;
        for (a, b) in self.failures_by_kind.iter_mut().zip(&slot.failures_by_kind) {
            *a += b;
        }
        self.samples_out += slot.samples_out;
        self.nfe_total += slot.nfe_total;
        self.batched_runs += slot.batched_runs;
        self.batch_members += slot.batch_members;
        self.steals += slot.steals;
        self.depth_sum += slot.depth_sum;
        self.depth_obs += slot.depth_obs;
        self.e2e_sum_us += slot.e2e_sum_us;
        self.e2e_max_us = self.e2e_max_us.max(slot.e2e_max_us);
        for (a, b) in self.e2e_hist.iter_mut().zip(&slot.e2e_hist) {
            *a += b;
        }
    }

    /// Sum another shard's totals for the same window into this one.
    pub fn add_totals(&mut self, other: &WindowTotals) {
        debug_assert_eq!(self.window_s, other.window_s);
        let as_slot = WindowSlot {
            epoch: 0,
            completed: other.completed,
            failed: other.failed,
            failures_by_kind: other.failures_by_kind,
            samples_out: other.samples_out,
            nfe_total: other.nfe_total,
            batched_runs: other.batched_runs,
            batch_members: other.batch_members,
            steals: other.steals,
            depth_sum: other.depth_sum,
            depth_obs: other.depth_obs,
            e2e_sum_us: other.e2e_sum_us,
            e2e_max_us: other.e2e_max_us,
            e2e_hist: other.e2e_hist,
        };
        self.add(&as_slot);
    }

    /// The `{"op":"stats","window":…}` payload: raw windowed counters plus
    /// derived per-second rates and means.
    pub fn json(&self) -> Value {
        let w = self.window_s.max(1) as f64;
        let mut pairs = vec![
            ("window_s", Value::from(self.window_s as f64)),
            ("completed", Value::from(self.completed as f64)),
            ("failed", Value::from(self.failed as f64)),
            ("samples_out", Value::from(self.samples_out as f64)),
            ("nfe_total", Value::from(self.nfe_total as f64)),
            ("batched_runs", Value::from(self.batched_runs as f64)),
            ("batch_members", Value::from(self.batch_members as f64)),
            ("steals", Value::from(self.steals as f64)),
            ("completed_per_sec", Value::from(self.completed as f64 / w)),
            ("failed_per_sec", Value::from(self.failed as f64 / w)),
            ("samples_per_sec", Value::from(self.samples_out as f64 / w)),
            (
                "mean_batch",
                Value::from(if self.batched_runs > 0 {
                    self.batch_members as f64 / self.batched_runs as f64
                } else {
                    0.0
                }),
            ),
            (
                "mean_depth",
                Value::from(if self.depth_obs > 0 {
                    self.depth_sum as f64 / self.depth_obs as f64
                } else {
                    0.0
                }),
            ),
            (
                "e2e_mean_us",
                Value::from(if self.completed > 0 {
                    self.e2e_sum_us as f64 / self.completed as f64
                } else {
                    0.0
                }),
            ),
            ("e2e_max_us", Value::from(self.e2e_max_us as f64)),
            (
                "e2e_hist",
                Value::Arr(self.e2e_hist.iter().map(|&c| Value::from(c as f64)).collect()),
            ),
        ];
        for kind in FailureKind::ALL {
            pairs.push((
                kind.as_str(),
                Value::from(self.failures_by_kind[kind.index()] as f64),
            ));
        }
        Value::obj(pairs)
    }
}

/// Parse a window spec into whole seconds: a bare number is seconds, and
/// `s`/`m`/`h` suffixes scale. Rejects zero, non-numeric input, and
/// anything past the 1 h horizon the minute ring retains.
pub fn parse_window(s: &str) -> Option<u64> {
    let s = s.trim();
    let (digits, scale) = match s.as_bytes().last()? {
        b's' => (&s[..s.len() - 1], 1u64),
        b'm' => (&s[..s.len() - 1], 60u64),
        b'h' => (&s[..s.len() - 1], 3_600u64),
        _ => (s, 1u64),
    };
    let n: u64 = digits.parse().ok()?;
    let secs = n.checked_mul(scale)?;
    (secs >= 1 && secs <= 3_600).then_some(secs)
}

// ---------------------------------------------------------------------------
// Prometheus text exposition
// ---------------------------------------------------------------------------

/// Incremental writer for the Prometheus text exposition format. Every
/// family gets its `# HELP` / `# TYPE` preamble exactly once; histogram
/// emission takes *per-bucket* (non-cumulative) counts and writes the
/// cumulative `_bucket{le=…}` series, terminal `+Inf` bucket, and
/// `_count` the format requires.
#[derive(Default)]
pub struct PromWriter {
    buf: String,
}

fn fmt_value(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

impl PromWriter {
    pub fn new() -> Self {
        PromWriter::default()
    }

    fn head(&mut self, name: &str, typ: &str, help: &str) {
        self.buf.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {typ}\n"));
    }

    fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.buf.push_str(name);
        if !labels.is_empty() {
            self.buf.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.buf.push(',');
                }
                self.buf.push_str(&format!("{k}=\"{v}\""));
            }
            self.buf.push('}');
        }
        self.buf.push(' ');
        self.buf.push_str(&fmt_value(value));
        self.buf.push('\n');
    }

    pub fn counter(&mut self, name: &str, help: &str, value: f64) {
        self.head(name, "counter", help);
        self.sample(name, &[], value);
    }

    pub fn gauge(&mut self, name: &str, help: &str, value: f64) {
        self.head(name, "gauge", help);
        self.sample(name, &[], value);
    }

    /// A counter family with one label dimension (e.g. failures by kind).
    pub fn counter_vec(&mut self, name: &str, help: &str, label: &str, items: &[(&str, f64)]) {
        self.head(name, "counter", help);
        for (lv, v) in items {
            self.sample(name, &[(label, lv)], *v);
        }
    }

    /// Histogram from per-bucket counts: `les[i]` bounds bucket `i`, and a
    /// final overflow bucket (`counts.len() == les.len() + 1`) lands in
    /// `+Inf`. `sum` is emitted only when the caller tracks it exactly.
    pub fn histogram(&mut self, name: &str, help: &str, les: &[f64], counts: &[u64], sum: Option<f64>) {
        debug_assert_eq!(counts.len(), les.len() + 1);
        self.head(name, "histogram", help);
        let bucket = format!("{name}_bucket");
        let mut cum = 0u64;
        for (le, c) in les.iter().zip(counts) {
            cum += c;
            self.sample(&bucket, &[("le", &fmt_value(*le))], cum as f64);
        }
        cum += counts[les.len()];
        self.sample(&bucket, &[("le", "+Inf")], cum as f64);
        if let Some(s) = sum {
            self.sample(&format!("{name}_sum"), &[], s);
        }
        self.sample(&format!("{name}_count"), &[], cum as f64);
    }

    /// Summary with precomputed quantiles (the latency digests keep raw
    /// samples, so these are exact).
    pub fn summary(
        &mut self,
        name: &str,
        help: &str,
        quantiles: &[(f64, f64)],
        sum: f64,
        count: u64,
    ) {
        self.head(name, "summary", help);
        for (q, v) in quantiles {
            self.sample(name, &[("quantile", &fmt_value(*q))], *v);
        }
        self.sample(&format!("{name}_sum"), &[], sum);
        self.sample(&format!("{name}_count"), &[], count as f64);
    }

    pub fn finish(self) -> String {
        self.buf
    }
}

/// One parsed sample line of an exposition.
#[derive(Clone, Debug, PartialEq)]
pub struct PromSample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

/// A parsed exposition: family metadata plus every sample, in order.
#[derive(Clone, Debug, Default)]
pub struct PromParsed {
    pub types: std::collections::BTreeMap<String, String>,
    pub helps: std::collections::BTreeMap<String, String>,
    pub samples: Vec<PromSample>,
}

impl PromParsed {
    /// Value of the sample with this name and exact label set.
    pub fn value(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        self.samples
            .iter()
            .find(|s| {
                s.name == name
                    && s.labels.len() == labels.len()
                    && s.labels
                        .iter()
                        .zip(labels)
                        .all(|((k, v), (wk, wv))| k == wk && v == wv)
            })
            .map(|s| s.value)
    }
}

fn valid_metric_name(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_' || c == ':')
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// The family a sample belongs to: `_bucket`/`_sum`/`_count` suffixes fold
/// into their histogram or summary base metric when one is declared.
fn family_of<'a>(name: &'a str, types: &std::collections::BTreeMap<String, String>) -> &'a str {
    for (suffix, kinds) in [
        ("_bucket", &["histogram"][..]),
        ("_sum", &["histogram", "summary"][..]),
        ("_count", &["histogram", "summary"][..]),
    ] {
        if let Some(base) = name.strip_suffix(suffix) {
            if types.get(base).is_some_and(|t| kinds.contains(&t.as_str())) {
                return base;
            }
        }
    }
    name
}

/// Strict parser/validator for the Prometheus text format — the test-side
/// half of the exposition round-trip. Rejects malformed lines, samples
/// without a preceding `# TYPE`, unparseable values, duplicate label sets,
/// and histograms whose `_bucket` series is non-cumulative or missing the
/// terminal `+Inf` bucket.
pub fn parse_exposition(text: &str) -> Result<PromParsed, String> {
    let mut out = PromParsed::default();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            let (kw, rest) = rest.split_once(' ').ok_or(format!("line {ln}: bare comment keyword"))?;
            let (name, payload) = rest.split_once(' ').unwrap_or((rest, ""));
            if !valid_metric_name(name) {
                return Err(format!("line {ln}: invalid metric name {name:?}"));
            }
            match kw {
                "HELP" => {
                    out.helps.insert(name.to_string(), payload.to_string());
                }
                "TYPE" => {
                    if !["counter", "gauge", "histogram", "summary", "untyped"]
                        .contains(&payload)
                    {
                        return Err(format!("line {ln}: unknown type {payload:?}"));
                    }
                    if out.types.contains_key(name) {
                        return Err(format!("line {ln}: duplicate TYPE for {name}"));
                    }
                    out.types.insert(name.to_string(), payload.to_string());
                }
                other => return Err(format!("line {ln}: unknown comment keyword {other:?}")),
            }
            continue;
        }
        if line.starts_with('#') {
            continue; // plain comment
        }
        // Sample line: name[{labels}] value
        let (head, value) = line
            .rsplit_once(' ')
            .ok_or(format!("line {ln}: sample line without value"))?;
        let value: f64 = match value {
            "+Inf" => f64::INFINITY,
            "-Inf" => f64::NEG_INFINITY,
            v => v.parse().map_err(|_| format!("line {ln}: bad value {v:?}"))?,
        };
        let (name, labels) = match head.split_once('{') {
            None => (head, Vec::new()),
            Some((n, rest)) => {
                let body = rest
                    .strip_suffix('}')
                    .ok_or(format!("line {ln}: unterminated label set"))?;
                let mut labels = Vec::new();
                for pair in body.split(',').filter(|p| !p.is_empty()) {
                    let (k, v) =
                        pair.split_once('=').ok_or(format!("line {ln}: bad label {pair:?}"))?;
                    let v = v
                        .strip_prefix('"')
                        .and_then(|v| v.strip_suffix('"'))
                        .ok_or(format!("line {ln}: unquoted label value {v:?}"))?;
                    labels.push((k.to_string(), v.to_string()));
                }
                (n, labels)
            }
        };
        if !valid_metric_name(name) {
            return Err(format!("line {ln}: invalid sample name {name:?}"));
        }
        let family = family_of(name, &out.types);
        if !out.types.contains_key(family) {
            return Err(format!("line {ln}: sample {name} has no preceding # TYPE"));
        }
        if out.samples.iter().any(|s| s.name == name && s.labels == labels) {
            return Err(format!("line {ln}: duplicate sample {name} {labels:?}"));
        }
        out.samples.push(PromSample {
            name: name.to_string(),
            labels,
            value,
        });
    }
    // Histogram structural checks: cumulative buckets ending in +Inf that
    // agree with _count.
    let histos: Vec<String> = out
        .types
        .iter()
        .filter(|(_, t)| t.as_str() == "histogram")
        .map(|(n, _)| n.clone())
        .collect();
    for base in histos {
        let bucket = format!("{base}_bucket");
        let series: Vec<&PromSample> =
            out.samples.iter().filter(|s| s.name == bucket).collect();
        if series.is_empty() {
            return Err(format!("histogram {base} has no _bucket series"));
        }
        let mut prev = 0.0f64;
        let mut saw_inf = false;
        for s in &series {
            let le = s
                .labels
                .iter()
                .find(|(k, _)| k == "le")
                .map(|(_, v)| v.clone())
                .ok_or(format!("histogram {base} bucket without le label"))?;
            if s.value < prev {
                return Err(format!("histogram {base} buckets not cumulative at le={le}"));
            }
            prev = s.value;
            saw_inf |= le == "+Inf";
        }
        if !saw_inf {
            return Err(format!("histogram {base} missing +Inf bucket"));
        }
        if let Some(count) = out.value(&format!("{base}_count"), &[]) {
            if count != prev {
                return Err(format!("histogram {base}: _count {count} != +Inf bucket {prev}"));
            }
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Push-based event subscription
// ---------------------------------------------------------------------------

/// One event on the push channel. `Copy` so per-subscriber queues hold
/// events by value in preallocated storage — publishing never allocates.
#[derive(Clone, Copy, Debug)]
pub enum TelemetryEvent {
    /// A span event, published at the same moment it is recorded into a
    /// shard's trace ring.
    Span(SpanEvent),
    /// An SLO error-budget burn: `failed`/`total` of the trailing
    /// `window_s` seconds crossed `budget_ppm` during evaluation window
    /// `window_id` (= `now_s / window_s`; at most one event per id).
    SloBreach {
        kind: FailureKind,
        window_s: u64,
        window_id: u64,
        failed: u64,
        total: u64,
        budget_ppm: u64,
    },
}

/// The NDJSON frame for one pushed event.
pub fn event_line(ev: &TelemetryEvent) -> Value {
    match ev {
        TelemetryEvent::Span(sp) => {
            let mut v = event_json(sp);
            if let Value::Obj(m) = &mut v {
                m.insert("event".into(), Value::from("span"));
                m.insert("trace_id".into(), Value::from(sp.trace_id as f64));
            }
            v
        }
        TelemetryEvent::SloBreach { kind, window_s, window_id, failed, total, budget_ppm } => {
            Value::obj(vec![
                ("event", Value::from("slo_breach")),
                ("kind", Value::from(kind.as_str())),
                ("window_s", Value::from(*window_s as f64)),
                ("window_id", Value::from(*window_id as f64)),
                ("failed", Value::from(*failed as f64)),
                ("total", Value::from(*total as f64)),
                ("budget_ppm", Value::from(*budget_ppm as f64)),
            ])
        }
    }
}

/// One live subscription: a bounded queue of events drained by the
/// subscriber's connection thread.
pub struct Subscription {
    queue: Mutex<VecDeque<TelemetryEvent>>,
    cv: Condvar,
    cap: usize,
}

impl Subscription {
    /// Move every queued event into `out` without blocking.
    pub fn drain_into(&self, out: &mut Vec<TelemetryEvent>) {
        let mut q = self.queue.lock().unwrap();
        out.extend(q.drain(..));
    }

    /// Wait up to `timeout` for at least one event, then drain. Returns
    /// whether anything was drained.
    pub fn wait_drain_into(&self, out: &mut Vec<TelemetryEvent>, timeout: Duration) -> bool {
        let mut q = self.queue.lock().unwrap();
        if q.is_empty() {
            let (guard, _) = self.cv.wait_timeout(q, timeout).unwrap();
            q = guard;
        }
        let any = !q.is_empty();
        out.extend(q.drain(..));
        any
    }
}

/// The publish/subscribe hub. Workers publish at span-flush time; the only
/// cost with no subscriber registered is one relaxed atomic load. Full
/// queues drop (counted in [`EventHub::dropped`], the wire `sub_dropped`)
/// rather than block or grow, so a slow subscriber can never stall a
/// worker or break the steady-state allocation discipline.
#[derive(Default)]
pub struct EventHub {
    active: AtomicUsize,
    dropped: AtomicU64,
    subs: Mutex<Vec<Arc<Subscription>>>,
}

impl EventHub {
    pub fn new() -> Self {
        EventHub::default()
    }

    /// Register a subscriber with a queue bounded at `cap` events
    /// (preallocated here, on the subscriber's thread).
    pub fn subscribe(&self, cap: usize) -> Arc<Subscription> {
        let cap = cap.max(1);
        let sub = Arc::new(Subscription {
            queue: Mutex::new(VecDeque::with_capacity(cap)),
            cv: Condvar::new(),
            cap,
        });
        let mut subs = self.subs.lock().unwrap();
        subs.push(Arc::clone(&sub));
        self.active.store(subs.len(), Ordering::Release);
        sub
    }

    /// Deregister; pending undrained events are discarded (the subscriber
    /// chose to leave — they are not counted dropped).
    pub fn unsubscribe(&self, sub: &Arc<Subscription>) {
        let mut subs = self.subs.lock().unwrap();
        subs.retain(|s| !Arc::ptr_eq(s, sub));
        self.active.store(subs.len(), Ordering::Release);
    }

    /// Live subscriber count.
    pub fn active(&self) -> usize {
        self.active.load(Ordering::Acquire)
    }

    /// Missed deliveries: events a full subscriber queue could not accept,
    /// counted per (event, subscriber). `delivered + dropped` equals the
    /// events published while subscribed — nothing is ever lost silently.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Publish one event to every subscriber.
    pub fn publish(&self, ev: TelemetryEvent) {
        self.publish_batch(std::slice::from_ref(&ev), |e| *e);
    }

    /// Publish every span in `spans` (the flush-time batch form: one queue
    /// lock per subscriber for the whole batch).
    pub fn publish_spans(&self, spans: &[SpanEvent]) {
        self.publish_batch(spans, |s| TelemetryEvent::Span(*s));
    }

    fn publish_batch<T>(&self, items: &[T], wrap: impl Fn(&T) -> TelemetryEvent) {
        if items.is_empty() || self.active.load(Ordering::Acquire) == 0 {
            return;
        }
        let subs = self.subs.lock().unwrap();
        for sub in subs.iter() {
            let mut q = sub.queue.lock().unwrap();
            for item in items {
                if q.len() >= sub.cap {
                    self.dropped.fetch_add(1, Ordering::Relaxed);
                } else {
                    q.push_back(wrap(item));
                }
            }
            drop(q);
            sub.cv.notify_one();
        }
    }
}

// ---------------------------------------------------------------------------
// SLO burn-rate monitors
// ---------------------------------------------------------------------------

/// A declared service-level objective: `kind` failures must stay under
/// `budget_ppm` parts-per-million of windowed traffic over any trailing
/// `window_s` seconds. Declared in config as e.g. `deadline_exceeded<0.1%/5m`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SloSpec {
    pub kind: FailureKind,
    pub budget_ppm: u64,
    pub window_s: u64,
}

impl SloSpec {
    /// Parse `<kind><<percent>%/<window>`, e.g. `deadline_exceeded<0.1%/5m`.
    pub fn parse(s: &str) -> Result<SloSpec, String> {
        let (kind, rest) = s
            .split_once('<')
            .ok_or_else(|| format!("SLO {s:?}: expected <kind><<budget>%/<window>"))?;
        let kind = FailureKind::parse(kind.trim())
            .ok_or_else(|| format!("SLO {s:?}: unknown failure kind {kind:?}"))?;
        let (pct, window) = rest
            .split_once('/')
            .ok_or_else(|| format!("SLO {s:?}: missing /<window>"))?;
        let pct = pct
            .trim()
            .strip_suffix('%')
            .ok_or_else(|| format!("SLO {s:?}: budget must end in %"))?;
        let pct: f64 = pct
            .parse()
            .map_err(|_| format!("SLO {s:?}: bad budget percent {pct:?}"))?;
        if !(0.0..=100.0).contains(&pct) {
            return Err(format!("SLO {s:?}: budget must be within 0..=100%"));
        }
        let window_s = parse_window(window)
            .ok_or_else(|| format!("SLO {s:?}: bad window {window:?} (1s..=1h)"))?;
        Ok(SloSpec { kind, budget_ppm: (pct * 10_000.0).round() as u64, window_s })
    }
}

impl std::fmt::Display for SloSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}<{}%/{}s",
            self.kind.as_str(),
            self.budget_ppm as f64 / 10_000.0,
            self.window_s
        )
    }
}

/// Sliding error-budget evaluator over the windowed counters. Time is an
/// explicit parameter (`now_s` on the service clock), so tests drive it
/// deterministically; the serving layer ticks it from a monitor thread.
/// Emits **at most one breach per evaluation window** per SLO — window id
/// `now_s / window_s` — so a sustained burn alerts once per window instead
/// of once per tick.
pub struct BurnRateMonitor {
    specs: Vec<SloSpec>,
    last_window: Vec<Option<u64>>,
}

impl BurnRateMonitor {
    pub fn new(specs: Vec<SloSpec>) -> Self {
        let last_window = vec![None; specs.len()];
        BurnRateMonitor { specs, last_window }
    }

    pub fn specs(&self) -> &[SloSpec] {
        &self.specs
    }

    /// Evaluate every SLO at `now_s`; `totals` supplies the cross-shard
    /// windowed counters for a requested window. Breaches append to `out`.
    pub fn evaluate(
        &mut self,
        now_s: u64,
        mut totals: impl FnMut(u64) -> WindowTotals,
        out: &mut Vec<TelemetryEvent>,
    ) {
        for (i, spec) in self.specs.iter().enumerate() {
            let t = totals(spec.window_s);
            let total = t.completed + t.failed;
            let failed = t.failures_by_kind[spec.kind.index()];
            // Burn test: failed/total >= budget (ppm math keeps it exact in
            // integers). A zero budget means any failure breaches.
            if total == 0 || failed == 0 || failed * 1_000_000 < spec.budget_ppm * total {
                continue;
            }
            let window_id = now_s / spec.window_s;
            if self.last_window[i] == Some(window_id) {
                continue;
            }
            self.last_window[i] = Some(window_id);
            out.push(TelemetryEvent::SloBreach {
                kind: spec.kind,
                window_s: spec.window_s,
                window_id,
                failed,
                total,
                budget_ppm: spec.budget_ppm,
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Solver numerical health
// ---------------------------------------------------------------------------

/// Per-run accumulator of the executor's [`StepHealth`] stream: corrector
/// delta-norm statistics plus non-finite provenance (the first step index
/// whose state went bad). Plain `Copy` data, reset per run — a worker
/// holds one across its lifetime so the observed path never allocates.
#[derive(Clone, Copy, Debug, Default)]
pub struct HealthAccum {
    pub steps: u32,
    pub corrected_steps: u32,
    pub delta_sum: f64,
    pub delta_max: f64,
    pub first_nonfinite: Option<u32>,
}

impl HealthAccum {
    pub fn reset(&mut self) {
        *self = HealthAccum::default();
    }

    pub fn observe(&mut self, k: usize, h: &StepHealth) {
        self.steps += 1;
        if let Some(d) = h.corrector_delta {
            self.corrected_steps += 1;
            self.delta_sum += d;
            self.delta_max = self.delta_max.max(d);
        }
        if !h.finite && self.first_nonfinite.is_none() {
            self.first_nonfinite = Some(k as u32);
        }
    }

    /// Mean relative corrector delta across corrected steps, if any.
    pub fn mean_delta(&self) -> Option<f64> {
        (self.corrected_steps > 0).then(|| self.delta_sum / self.corrected_steps as f64)
    }
}

/// The serving-layer step observer: requests the health payload, feeds the
/// [`HealthAccum`], and forwards each step to an optional [`StepSpans`]
/// recorder so one executor pass serves both tracing and health.
pub struct HealthSpans<'a> {
    pub spans: Option<StepSpans<'a>>,
    pub accum: &'a mut HealthAccum,
}

impl StepObserver for HealthSpans<'_> {
    fn on_step(&mut self, k: usize, health: &StepHealth) {
        if let Some(s) = &mut self.spans {
            s.on_step(k, health);
        }
        self.accum.observe(k, health);
    }

    fn wants_health(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windowed_counters_land_in_the_right_slots() {
        let mut w = WindowStore::default();
        w.record_completion(10, 4, 8, 2_000);
        w.record_completion(11, 2, 8, 10_000);
        w.record_failure(11, FailureKind::DeadlineExceeded);
        w.record_batch(10, 3);
        w.record_depth(10, 5);
        w.record_steal(12);

        let t = w.totals(12, 3);
        assert_eq!(t.completed, 2);
        assert_eq!(t.samples_out, 6);
        assert_eq!(t.failed, 1);
        assert_eq!(t.failures_by_kind[FailureKind::DeadlineExceeded.index()], 1);
        assert_eq!(t.batched_runs, 1);
        assert_eq!(t.batch_members, 3);
        assert_eq!(t.steals, 1);
        assert_eq!(t.e2e_sum_us, 12_000);
        assert_eq!(t.e2e_max_us, 10_000);
        // A 2 s window at now=12 covers (10, 12]: second 11's completion
        // and second 12's steal stay, second 10 has slid past.
        let t = w.totals(12, 2);
        assert_eq!(t.completed, 1);
        assert_eq!(t.steals, 1);
    }

    #[test]
    fn second_slots_recycle_after_a_full_ring_span() {
        let mut w = WindowStore::default();
        w.record_completion(5, 1, 8, 1_000);
        // Same ring index, one span later: the old slot must be recycled.
        w.record_completion(65, 1, 8, 1_000);
        assert_eq!(w.totals(65, 60).completed, 1);
        // The minute rollup still sees both (minutes 0 and 1).
        assert_eq!(w.totals(65, 120).completed, 2);
    }

    #[test]
    fn window_merge_sums_equal_epochs_and_keeps_newer() {
        let mut a = WindowStore::default();
        let mut b = WindowStore::default();
        a.record_completion(100, 1, 8, 1_000);
        b.record_completion(100, 1, 8, 3_000);
        b.record_completion(160, 1, 8, 5_000); // same index as 100, newer
        a.merge(&b);
        // Index 40 keeps epoch 160 (the newer second); epoch 100 is a full
        // ring span stale and outside every answerable window.
        assert_eq!(a.totals(160, 60).completed, 1);
        assert_eq!(a.totals(160, 60).e2e_sum_us, 5_000);
        // The minute ring kept both: minutes 1 (epoch 100) and 2 (epoch 160).
        assert_eq!(a.totals(160, 120).completed, 3);
    }

    #[test]
    fn parse_window_accepts_suffixes_and_rejects_junk() {
        assert_eq!(parse_window("30"), Some(30));
        assert_eq!(parse_window("30s"), Some(30));
        assert_eq!(parse_window("5m"), Some(300));
        assert_eq!(parse_window("1h"), Some(3_600));
        assert_eq!(parse_window("0"), None);
        assert_eq!(parse_window("2h"), None);
        assert_eq!(parse_window("-5"), None);
        assert_eq!(parse_window("abc"), None);
        assert_eq!(parse_window("1.5m"), None);
    }

    #[test]
    fn prom_writer_output_round_trips_through_the_parser() {
        let mut w = PromWriter::new();
        w.counter("unipc_submitted_total", "Requests admitted.", 42.0);
        w.gauge("unipc_pending", "Queued jobs.", 3.0);
        w.counter_vec(
            "unipc_failures_total",
            "Failures by kind.",
            "kind",
            &[("deadline_exceeded", 2.0), ("queue_full", 1.0)],
        );
        w.histogram("unipc_batch_size", "Members per run.", &[1.0, 2.0, 4.0], &[5, 3, 1, 2], None);
        w.summary("unipc_e2e_seconds", "E2E latency.", &[(0.5, 0.01), (0.99, 0.09)], 1.5, 100);
        let text = w.finish();
        let parsed = parse_exposition(&text).expect("rendered exposition must parse");
        assert_eq!(parsed.value("unipc_submitted_total", &[]), Some(42.0));
        assert_eq!(parsed.value("unipc_pending", &[]), Some(3.0));
        assert_eq!(
            parsed.value("unipc_failures_total", &[("kind", "queue_full")]),
            Some(1.0)
        );
        assert_eq!(parsed.value("unipc_batch_size_bucket", &[("le", "2")]), Some(8.0));
        assert_eq!(parsed.value("unipc_batch_size_bucket", &[("le", "+Inf")]), Some(11.0));
        assert_eq!(parsed.value("unipc_batch_size_count", &[]), Some(11.0));
        assert_eq!(parsed.value("unipc_e2e_seconds", &[("quantile", "0.99")]), Some(0.09));
        assert_eq!(parsed.types.get("unipc_batch_size").map(String::as_str), Some("histogram"));
    }

    #[test]
    fn parser_rejects_structural_violations() {
        assert!(parse_exposition("no_type_metric 1\n").is_err(), "sample without TYPE");
        assert!(
            parse_exposition("# TYPE m counter\nm{x=\"1\" 2\n").is_err(),
            "unterminated labels"
        );
        assert!(parse_exposition("# TYPE m counter\nm pancake\n").is_err(), "bad value");
        assert!(
            parse_exposition("# TYPE m counter\nm 1\nm 2\n").is_err(),
            "duplicate sample"
        );
        let non_cumulative = "# TYPE h histogram\nh_bucket{le=\"1\"} 5\nh_bucket{le=\"+Inf\"} 3\n";
        assert!(parse_exposition(non_cumulative).is_err(), "non-cumulative buckets");
        let no_inf = "# TYPE h histogram\nh_bucket{le=\"1\"} 5\n";
        assert!(parse_exposition(no_inf).is_err(), "missing +Inf bucket");
    }

    #[test]
    fn hub_counts_overflow_and_delivers_the_rest() {
        let hub = EventHub::new();
        let sub = hub.subscribe(4);
        assert_eq!(hub.active(), 1);
        let spans: Vec<SpanEvent> = (0..6)
            .map(|i| SpanEvent { trace_id: i as u64 + 1, ..Default::default() })
            .collect();
        hub.publish_spans(&spans);
        let mut got = Vec::new();
        sub.drain_into(&mut got);
        assert_eq!(got.len(), 4, "queue bounded at cap");
        assert_eq!(hub.dropped(), 2, "overflow counted, not silently lost");
        // Drained capacity is reusable.
        hub.publish_spans(&spans[..2]);
        sub.drain_into(&mut got);
        assert_eq!(got.len(), 6);
        assert_eq!(hub.dropped(), 2);
        hub.unsubscribe(&sub);
        assert_eq!(hub.active(), 0);
        hub.publish_spans(&spans);
        assert_eq!(hub.dropped(), 2, "publishing with no subscriber is a no-op");
    }

    #[test]
    fn slo_spec_parses_and_displays() {
        let s = SloSpec::parse("deadline_exceeded<0.1%/5m").unwrap();
        assert_eq!(s.kind, FailureKind::DeadlineExceeded);
        assert_eq!(s.budget_ppm, 1_000);
        assert_eq!(s.window_s, 300);
        assert_eq!(s.to_string(), "deadline_exceeded<0.1%/300s");
        assert!(SloSpec::parse("bogus_kind<1%/5m").is_err());
        assert!(SloSpec::parse("queue_full<1/5m").is_err(), "missing %");
        assert!(SloSpec::parse("queue_full<1%").is_err(), "missing window");
        assert!(SloSpec::parse("queue_full<200%/5m").is_err(), "budget > 100%");
    }

    #[test]
    fn burn_monitor_emits_once_per_window() {
        let spec = SloSpec::parse("non_finite_output<1%/10s").unwrap();
        let mut mon = BurnRateMonitor::new(vec![spec]);
        let mut w = WindowStore::default();
        for s in 0..5 {
            w.record_completion(s, 1, 8, 1_000);
        }
        w.record_failure(3, FailureKind::NonFiniteOutput);

        let mut out = Vec::new();
        // Many ticks inside window id 0: exactly one breach. Start at
        // now=4 so the trailing 10 s window (−6, 4] holds all five
        // completions plus the failure when the first evaluation fires.
        for now in 4..10 {
            mon.evaluate(now, |ws| w.totals(now, ws), &mut out);
        }
        assert_eq!(out.len(), 1);
        match out[0] {
            TelemetryEvent::SloBreach { kind, window_id, failed, total, .. } => {
                assert_eq!(kind, FailureKind::NonFiniteOutput);
                assert_eq!(window_id, 0);
                assert_eq!(failed, 1);
                assert_eq!(total, 6);
            }
            _ => panic!("expected a breach"),
        }
        // The next evaluation window re-alerts while the burn persists…
        for now in 10..20 {
            mon.evaluate(now, |ws| w.totals(now, ws), &mut out);
        }
        assert_eq!(out.len(), 2);
        // …and stays quiet once the failures age out of the window.
        for now in 20..40 {
            mon.evaluate(now, |ws| w.totals(now, ws), &mut out);
        }
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn health_accum_tracks_deltas_and_first_bad_step() {
        let mut acc = HealthAccum::default();
        acc.observe(0, &StepHealth { corrector_delta: Some(0.5), finite: true });
        acc.observe(1, &StepHealth { corrector_delta: Some(0.1), finite: true });
        acc.observe(2, &StepHealth { corrector_delta: None, finite: false });
        acc.observe(3, &StepHealth { corrector_delta: None, finite: false });
        assert_eq!(acc.steps, 4);
        assert_eq!(acc.corrected_steps, 2);
        assert!((acc.mean_delta().unwrap() - 0.3).abs() < 1e-12);
        assert_eq!(acc.delta_max, 0.5);
        assert_eq!(acc.first_nonfinite, Some(2), "provenance pins the FIRST bad step");
        acc.reset();
        assert_eq!(acc.steps, 0);
        assert_eq!(acc.first_nonfinite, None);
    }

    #[test]
    fn event_lines_are_wire_shaped() {
        let sp = SpanEvent { trace_id: 7, ..Default::default() };
        let line = event_line(&TelemetryEvent::Span(sp));
        assert_eq!(line.get("event").and_then(Value::as_str), Some("span"));
        assert_eq!(line.get("trace_id").and_then(Value::as_f64), Some(7.0));
        let breach = TelemetryEvent::SloBreach {
            kind: FailureKind::QueueFull,
            window_s: 60,
            window_id: 2,
            failed: 5,
            total: 100,
            budget_ppm: 10_000,
        };
        let line = event_line(&breach);
        assert_eq!(line.get("event").and_then(Value::as_str), Some("slo_breach"));
        assert_eq!(line.get("kind").and_then(Value::as_str), Some("queue_full"));
        assert_eq!(line.get("window_id").and_then(Value::as_f64), Some(2.0));
    }
}
