//! Typed configuration for the serving stack, layered as
//! defaults ← JSON config file ← CLI overrides.

use crate::cli::Args;
use crate::json::{self, Value};
use crate::sched::TimeSpacing;
use crate::telemetry::SloSpec;
use crate::trace::TraceLevel;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Server + engine configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct ServerConfig {
    /// TCP bind address.
    pub addr: String,
    /// Directory holding AOT artifacts + manifest.json.
    pub artifacts_dir: PathBuf,
    /// Path to the `.upw` weights file (empty ⇒ use the analytic model).
    pub weights: Option<PathBuf>,
    /// Max batch rows per model call.
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch before dispatching.
    pub batch_wait_us: u64,
    /// How long a worker lingers for additional requests that share a
    /// sampling plan before executing a batched run. 0 (the default)
    /// batches opportunistically: only what is already queued coalesces,
    /// and an idle service adds no latency.
    pub batch_linger_us: u64,
    /// Ablation/compat switch: when true, the batch key re-appends the
    /// request conditioning (the legacy pre-row-conditioning behavior), so
    /// mixed class/guidance traffic splits into per-conditioning cohorts
    /// instead of stacking into one lockstep run over a row-conditioned
    /// model view. Benches and tests use it to quantify what the collapsed
    /// key buys; leave false in production.
    pub split_cond_batches: bool,
    /// Worker threads running sampling loops.
    pub workers: usize,
    /// Coordinator shards. Each shard owns its own queue, condvar, and
    /// worker sub-pool; requests route by `hash(batch_key) % shards`, so a
    /// batchable cohort always lands on one shard (batching/linger/deadline
    /// semantics are per shard and unchanged), with cross-shard work
    /// stealing when a shard runs dry. 0 (the default) auto-sizes to
    /// `workers.min(4)`; explicit values are clamped to `workers` so every
    /// shard has at least one home worker.
    pub shards: usize,
    /// Queue capacity **per shard**; requests beyond it are rejected
    /// (backpressure).
    pub queue_cap: usize,
    /// Default per-request deadline in milliseconds (admission to start of
    /// execution), for requests that don't set `deadline_ms` themselves.
    /// Jobs still queued past their deadline are shed with a typed
    /// `DeadlineExceeded` response instead of executing. 0 disables the
    /// default deadline.
    pub default_deadline_ms: u64,
    /// How long `Service::shutdown` waits for workers to drain the queue
    /// before shedding the remaining jobs with typed responses and joining
    /// the pool (bounded teardown).
    pub drain_deadline_ms: u64,
    /// Default solver settings for requests that don't override them.
    pub default_steps: usize,
    pub default_method: String,
    pub spacing: TimeSpacing,
    pub t_start: f64,
    pub t_end: f64,
    /// Span-event recording level (JSON/CLI values `off` | `lifecycle` |
    /// `steps`). `lifecycle` (the default) records admission-to-respond
    /// span events; `steps` adds a `model_eval`/`solver_step` pair per
    /// planned step. The per-request `model_eval_us`/`solver_us` digests
    /// and response fields are maintained at every level.
    pub trace: TraceLevel,
    /// Span-event ring capacity **per shard** (events, preallocated;
    /// oldest overwritten).
    pub trace_buf: usize,
    /// SLO burn-rate objectives, e.g. `deadline_exceeded<0.1%/5m` (JSON
    /// `"slos"`: array of spec strings; CLI `--slo a,b,c`). Each declares a
    /// failure-rate budget over a trailing window; the service evaluates
    /// them against the windowed metrics rings and emits at most one
    /// `slo_breach` event per objective per window.
    pub slos: Vec<SloSpec>,
    /// Per-subscriber event-queue capacity for `{"op":"subscribe"}`
    /// streams (events, preallocated; overflow is counted in
    /// `sub_dropped`, never blocking workers).
    pub sub_buf: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7878".into(),
            artifacts_dir: PathBuf::from("artifacts"),
            weights: None,
            max_batch: 64,
            batch_wait_us: 200,
            batch_linger_us: 0,
            split_cond_batches: false,
            workers: 4,
            shards: 0,
            queue_cap: 256,
            default_deadline_ms: 30_000,
            drain_deadline_ms: 2_000,
            default_steps: 10,
            default_method: "unipc-3".into(),
            spacing: TimeSpacing::LogSnr,
            t_start: 1.0,
            t_end: 1e-3,
            trace: TraceLevel::Lifecycle,
            trace_buf: 4096,
            slos: Vec::new(),
            sub_buf: 1024,
        }
    }
}

impl ServerConfig {
    /// Load from a JSON file; unknown keys are rejected (catch typos early).
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {}", path.display()))?;
        let v = json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let mut c = ServerConfig::default();
        let obj = match v {
            Value::Obj(m) => m,
            _ => bail!("config root must be an object"),
        };
        for (k, val) in obj {
            match k.as_str() {
                "addr" => c.addr = req_str(val, k)?,
                "artifacts_dir" => c.artifacts_dir = PathBuf::from(req_str(val, k)?),
                "weights" => {
                    c.weights = match val {
                        Value::Null => None,
                        _ => Some(PathBuf::from(req_str(val, k)?)),
                    }
                }
                "max_batch" => c.max_batch = req_usize(val, k)?,
                "batch_wait_us" => c.batch_wait_us = req_usize(val, k)? as u64,
                "batch_linger_us" => c.batch_linger_us = req_usize(val, k)? as u64,
                "split_cond_batches" => c.split_cond_batches = req_bool(val, k)?,
                "workers" => c.workers = req_usize(val, k)?,
                "shards" => c.shards = req_usize(val, k)?,
                "queue_cap" => c.queue_cap = req_usize(val, k)?,
                "default_deadline_ms" => c.default_deadline_ms = req_usize(val, k)? as u64,
                "drain_deadline_ms" => c.drain_deadline_ms = req_usize(val, k)? as u64,
                "default_steps" => c.default_steps = req_usize(val, k)?,
                "default_method" => c.default_method = req_str(val, k)?,
                "spacing" => {
                    let s = req_str(val, k)?;
                    c.spacing = TimeSpacing::parse(&s)
                        .ok_or_else(|| anyhow::anyhow!("unknown spacing '{s}'"))?;
                }
                "t_start" => c.t_start = req_f64(val, k)?,
                "t_end" => c.t_end = req_f64(val, k)?,
                "trace" => {
                    let s = req_str(val, k)?;
                    c.trace = TraceLevel::parse(&s)
                        .ok_or_else(|| anyhow::anyhow!("unknown trace level '{s}'"))?;
                }
                "trace_buf" => c.trace_buf = req_usize(val, k)?,
                "slos" => {
                    let arr = match val {
                        Value::Arr(a) => a,
                        _ => bail!("'slos' must be an array of spec strings"),
                    };
                    c.slos = arr
                        .iter()
                        .map(|s| {
                            let s = req_str(s, k)?;
                            SloSpec::parse(&s).map_err(anyhow::Error::msg)
                        })
                        .collect::<Result<Vec<_>>>()?;
                }
                "sub_buf" => c.sub_buf = req_usize(val, k)?,
                other => bail!("unknown config key '{other}'"),
            }
        }
        c.validate()?;
        Ok(c)
    }

    /// Apply CLI overrides on top.
    pub fn apply_args(mut self, args: &Args) -> Result<Self> {
        if let Some(a) = args.get("addr") {
            self.addr = a.to_string();
        }
        if let Some(a) = args.get("artifacts") {
            self.artifacts_dir = PathBuf::from(a);
        }
        if let Some(w) = args.get("weights") {
            self.weights = Some(PathBuf::from(w));
        }
        self.max_batch = args.get_usize("max-batch", self.max_batch).map_err(anyhow::Error::msg)?;
        self.workers = args.get_usize("workers", self.workers).map_err(anyhow::Error::msg)?;
        self.shards = args.get_usize("shards", self.shards).map_err(anyhow::Error::msg)?;
        self.queue_cap = args.get_usize("queue-cap", self.queue_cap).map_err(anyhow::Error::msg)?;
        self.batch_linger_us = args
            .get_usize("batch-linger-us", self.batch_linger_us as usize)
            .map_err(anyhow::Error::msg)? as u64;
        self.default_deadline_ms = args
            .get_usize("deadline-ms", self.default_deadline_ms as usize)
            .map_err(anyhow::Error::msg)? as u64;
        self.drain_deadline_ms = args
            .get_usize("drain-deadline-ms", self.drain_deadline_ms as usize)
            .map_err(anyhow::Error::msg)? as u64;
        self.default_steps =
            args.get_usize("steps", self.default_steps).map_err(anyhow::Error::msg)?;
        if let Some(m) = args.get("method") {
            self.default_method = m.to_string();
        }
        if let Some(t) = args.get("trace") {
            self.trace = TraceLevel::parse(t)
                .ok_or_else(|| anyhow::anyhow!("unknown trace level '{t}'"))?;
        }
        self.trace_buf =
            args.get_usize("trace-buf", self.trace_buf).map_err(anyhow::Error::msg)?;
        if let Some(specs) = args.get("slo") {
            self.slos = specs
                .split(',')
                .filter(|s| !s.trim().is_empty())
                .map(|s| SloSpec::parse(s.trim()).map_err(anyhow::Error::msg))
                .collect::<Result<Vec<_>>>()?;
        }
        self.sub_buf = args.get_usize("sub-buf", self.sub_buf).map_err(anyhow::Error::msg)?;
        self.validate()?;
        Ok(self)
    }

    /// The shard count the service actually runs: an explicit `shards`
    /// clamped to the worker count (every shard needs a home worker), or
    /// `workers.min(4)` when unset (0). Always ≥ 1.
    pub fn effective_shards(&self) -> usize {
        let n = if self.shards == 0 { self.workers.min(4) } else { self.shards };
        n.clamp(1, self.workers.max(1))
    }

    pub fn validate(&self) -> Result<()> {
        if self.max_batch == 0 {
            bail!("max_batch must be ≥ 1");
        }
        if self.workers == 0 {
            bail!("workers must be ≥ 1");
        }
        if !(self.t_start > self.t_end && self.t_end > 0.0) {
            bail!("need t_start > t_end > 0");
        }
        if crate::solver::Method::parse(&self.default_method).is_none() {
            bail!("unknown default_method '{}'", self.default_method);
        }
        if self.trace_buf == 0 {
            bail!("trace_buf must be ≥ 1");
        }
        if self.sub_buf == 0 {
            bail!("sub_buf must be ≥ 1");
        }
        Ok(())
    }
}

fn req_str(v: &Value, key: &str) -> Result<String> {
    v.as_str().map(|s| s.to_string()).ok_or_else(|| anyhow::anyhow!("'{key}' must be a string"))
}

fn req_usize(v: &Value, key: &str) -> Result<usize> {
    v.as_usize().ok_or_else(|| anyhow::anyhow!("'{key}' must be a non-negative integer"))
}

fn req_f64(v: &Value, key: &str) -> Result<f64> {
    v.as_f64().ok_or_else(|| anyhow::anyhow!("'{key}' must be a number"))
}

fn req_bool(v: &Value, key: &str) -> Result<bool> {
    v.as_bool().ok_or_else(|| anyhow::anyhow!("'{key}' must be a boolean"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ServerConfig::default().validate().unwrap();
    }

    #[test]
    fn shards_default_and_clamping() {
        // Unset (0) auto-sizes to workers.min(4).
        let mut c = ServerConfig::default();
        assert_eq!(c.shards, 0);
        assert_eq!(c.effective_shards(), 4, "4 workers ⇒ 4 auto shards");
        c.workers = 2;
        assert_eq!(c.effective_shards(), 2);
        c.workers = 16;
        assert_eq!(c.effective_shards(), 4, "auto caps at 4");
        // Explicit values are honored but clamped to the worker count.
        c.shards = 8;
        assert_eq!(c.effective_shards(), 8);
        c.workers = 3;
        assert_eq!(c.effective_shards(), 3, "no shard without a home worker");
        c.shards = 1;
        assert_eq!(c.effective_shards(), 1);
    }

    #[test]
    fn json_overrides_defaults() {
        let v = json::parse(
            r#"{"addr": "0.0.0.0:9000", "max_batch": 8, "default_method": "dpmpp-2m",
                "spacing": "time_uniform", "t_end": 0.01, "batch_linger_us": 500,
                "default_deadline_ms": 250, "drain_deadline_ms": 100, "shards": 2,
                "split_cond_batches": true}"#,
        )
        .unwrap();
        let c = ServerConfig::from_json(&v).unwrap();
        assert_eq!(c.addr, "0.0.0.0:9000");
        assert_eq!(c.max_batch, 8);
        assert_eq!(c.spacing, TimeSpacing::Uniform);
        assert_eq!(c.t_end, 0.01);
        assert_eq!(c.batch_linger_us, 500);
        assert_eq!(c.default_deadline_ms, 250);
        assert_eq!(c.drain_deadline_ms, 100);
        assert_eq!(c.shards, 2);
        assert!(c.split_cond_batches);
        assert!(!ServerConfig::default().split_cond_batches, "collapsed key is the default");
        // Untouched defaults survive.
        assert_eq!(c.workers, ServerConfig::default().workers);
    }

    #[test]
    fn trace_level_from_json_and_cli() {
        assert_eq!(ServerConfig::default().trace, TraceLevel::Lifecycle);
        let v = json::parse(r#"{"trace": "steps", "trace_buf": 128}"#).unwrap();
        let c = ServerConfig::from_json(&v).unwrap();
        assert_eq!(c.trace, TraceLevel::Steps);
        assert_eq!(c.trace_buf, 128);
        for bad in [r#"{"trace": "verbose"}"#, r#"{"trace_buf": 0}"#] {
            let v = json::parse(bad).unwrap();
            assert!(ServerConfig::from_json(&v).is_err(), "{bad}");
        }
        let args =
            crate::cli::Args::parse(&["--trace".to_string(), "off".to_string()]).unwrap();
        let c = ServerConfig::default().apply_args(&args).unwrap();
        assert_eq!(c.trace, TraceLevel::Off);
    }

    #[test]
    fn slos_and_sub_buf_from_json_and_cli() {
        let c = ServerConfig::default();
        assert!(c.slos.is_empty());
        assert_eq!(c.sub_buf, 1024);

        let v = json::parse(
            r#"{"slos": ["deadline_exceeded<0.1%/5m", "queue_full<1%/60s"], "sub_buf": 64}"#,
        )
        .unwrap();
        let c = ServerConfig::from_json(&v).unwrap();
        assert_eq!(c.slos.len(), 2);
        assert_eq!(c.slos[0].to_string(), "deadline_exceeded<0.1%/300s");
        assert_eq!(c.slos[1].window_s, 60);
        assert_eq!(c.sub_buf, 64);

        for bad in
            [r#"{"slos": ["wat<1%/5m"]}"#, r#"{"slos": "x"}"#, r#"{"sub_buf": 0}"#]
        {
            let v = json::parse(bad).unwrap();
            assert!(ServerConfig::from_json(&v).is_err(), "{bad}");
        }

        let args = crate::cli::Args::parse(&[
            "--slo".to_string(),
            "worker_panic<0.5%/1m, non_finite_output<2%/30s".to_string(),
            "--sub-buf".to_string(),
            "16".to_string(),
        ])
        .unwrap();
        let c = ServerConfig::default().apply_args(&args).unwrap();
        assert_eq!(c.slos.len(), 2);
        assert_eq!(c.slos[1].window_s, 30);
        assert_eq!(c.sub_buf, 16);
    }

    #[test]
    fn unknown_keys_rejected() {
        let v = json::parse(r#"{"max_batchh": 8}"#).unwrap();
        assert!(ServerConfig::from_json(&v).is_err());
    }

    #[test]
    fn invalid_values_rejected() {
        for bad in [
            r#"{"max_batch": 0}"#,
            r#"{"default_method": "wat"}"#,
            r#"{"t_end": 2.0}"#,
            r#"{"max_batch": "x"}"#,
            r#"{"split_cond_batches": 3}"#,
        ] {
            let v = json::parse(bad).unwrap();
            assert!(ServerConfig::from_json(&v).is_err(), "{bad}");
        }
    }

    #[test]
    fn cli_overrides_apply() {
        let args = crate::cli::Args::parse(&[
            "--max-batch".to_string(),
            "16".to_string(),
            "--method".to_string(),
            "ddim".to_string(),
            "--deadline-ms".to_string(),
            "500".to_string(),
            "--shards".to_string(),
            "2".to_string(),
        ])
        .unwrap();
        let c = ServerConfig::default().apply_args(&args).unwrap();
        assert_eq!(c.max_batch, 16);
        assert_eq!(c.default_method, "ddim");
        assert_eq!(c.default_deadline_ms, 500);
        assert_eq!(c.shards, 2);
    }
}
