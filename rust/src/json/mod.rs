//! Minimal JSON substrate (the offline registry has no serde facade).
//!
//! Implements the full JSON grammar (RFC 8259) minus some exotic corners we
//! don't need (\u surrogate pairs are supported; numbers parse via `f64`).
//! Used by the server protocol, the config system, and the bench harness's
//! machine-readable output.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap for deterministic serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            (n >= 0.0 && n.fract() == 0.0 && n <= usize::MAX as f64).then_some(n as usize)
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize compactly.
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        out.push_str(&format!("{}", *n as i64));
                    } else {
                        out.push_str(&format!("{n}"));
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte position.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Value::Null),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("invalid codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                _ => {
                    // Re-decode UTF-8 from the byte stream.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    if self.pos > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.b[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.pos + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "42", "-3.5", "1e3", "\"hi\""] {
            let v = parse(src).unwrap();
            let back = parse(&v.to_string()).unwrap();
            assert_eq!(v, back, "{src}");
        }
    }

    #[test]
    fn nested_document() {
        let src = r#"{"a": [1, 2, {"b": null}], "c": "x\ny", "d": -0.5e2}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("d").unwrap().as_f64(), Some(-50.0));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Value::Null));
        // Roundtrip.
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""Aé 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé 😀"));
        // Raw UTF-8 passthrough.
        let v = parse("\"héllo\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo"));
    }

    #[test]
    fn errors_reported() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"abc").is_err());
        let e = parse("[1, 2] junk").unwrap_err();
        assert!(e.msg.contains("trailing"));
    }

    #[test]
    fn accessors() {
        let v = Value::obj(vec![("n", 3.0.into()), ("s", "x".into()), ("b", true.into())]);
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("missing"), None);
        assert_eq!(Value::Num(1.5).as_usize(), None);
    }

    #[test]
    fn deterministic_serialization() {
        let v = parse(r#"{"z": 1, "a": 2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn string_escaping_roundtrip() {
        let v = Value::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }
}
