//! `.upw` — the flat weights container shared between `python/compile/train.py`
//! (writer) and the Rust runtime (reader).
//!
//! Layout (all integers little-endian):
//! ```text
//! magic  "UPW1"                      4 bytes
//! u32    n_tensors
//! repeat n_tensors times:
//!   u32  name_len,  name (utf-8)
//!   u32  ndim,      u32 × ndim dims
//!   u8   dtype (0 = f32)
//! payload: concatenated raw f32 LE in declaration order
//! ```
//! The AOT manifest lists parameter names in the positional order the lowered
//! HLO expects; [`WeightsFile::ordered`] resolves that order.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

/// One named tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct WeightTensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl WeightTensor {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

/// A parsed weights file.
#[derive(Clone, Debug, Default)]
pub struct WeightsFile {
    tensors: Vec<WeightTensor>,
    by_name: BTreeMap<String, usize>,
}

const MAGIC: &[u8; 4] = b"UPW1";

impl WeightsFile {
    pub fn new(tensors: Vec<WeightTensor>) -> Result<Self> {
        let mut by_name = BTreeMap::new();
        for (i, t) in tensors.iter().enumerate() {
            if by_name.insert(t.name.clone(), i).is_some() {
                bail!("duplicate tensor name '{}'", t.name);
            }
            if t.data.len() != t.numel() {
                bail!("tensor '{}' dims {:?} != data len {}", t.name, t.dims, t.data.len());
            }
        }
        Ok(WeightsFile { tensors, by_name })
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn get(&self, name: &str) -> Option<&WeightTensor> {
        self.by_name.get(name).map(|&i| &self.tensors[i])
    }

    pub fn tensors(&self) -> &[WeightTensor] {
        &self.tensors
    }

    /// Tensors resolved in the order of `names` (the manifest's positional
    /// parameter order); errors on any missing name.
    pub fn ordered(&self, names: &[String]) -> Result<Vec<&WeightTensor>> {
        names
            .iter()
            .map(|n| self.get(n).ok_or_else(|| anyhow!("weights file missing tensor '{n}'")))
            .collect()
    }

    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    /// Serialize to the `.upw` byte layout.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for t in &self.tensors {
            out.extend_from_slice(&(t.name.len() as u32).to_le_bytes());
            out.extend_from_slice(t.name.as_bytes());
            out.extend_from_slice(&(t.dims.len() as u32).to_le_bytes());
            for &d in &t.dims {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            out.push(0u8); // dtype f32
        }
        for t in &self.tensors {
            for &v in &t.data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    pub fn from_bytes(b: &[u8]) -> Result<Self> {
        let mut r = Reader { b, pos: 0 };
        let magic = r.take(4)?;
        if magic != MAGIC {
            bail!("not a UPW1 file (magic {magic:?})");
        }
        let n = r.u32()? as usize;
        let mut headers = Vec::with_capacity(n);
        for _ in 0..n {
            let name_len = r.u32()? as usize;
            let name = String::from_utf8(r.take(name_len)?.to_vec())
                .context("tensor name not utf-8")?;
            let ndim = r.u32()? as usize;
            if ndim > 8 {
                bail!("tensor '{name}': ndim {ndim} too large");
            }
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(r.u32()? as usize);
            }
            let dtype = r.take(1)?[0];
            if dtype != 0 {
                bail!("tensor '{name}': unsupported dtype {dtype}");
            }
            headers.push((name, dims));
        }
        let mut tensors = Vec::with_capacity(n);
        for (name, dims) in headers {
            let numel: usize = dims.iter().product();
            let raw = r.take(numel * 4)?;
            let data = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.push(WeightTensor { name, dims, data });
        }
        if r.pos != b.len() {
            bail!("trailing bytes in weights file");
        }
        WeightsFile::new(tensors)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        Self::from_bytes(&buf)
    }
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!("truncated weights file at byte {}", self.pos);
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> WeightsFile {
        WeightsFile::new(vec![
            WeightTensor { name: "w1".into(), dims: vec![2, 3], data: vec![1.0; 6] },
            WeightTensor { name: "b1".into(), dims: vec![3], data: vec![0.5, -0.5, 2.0] },
        ])
        .unwrap()
    }

    #[test]
    fn roundtrip_bytes() {
        let w = sample();
        let b = w.to_bytes();
        let w2 = WeightsFile::from_bytes(&b).unwrap();
        assert_eq!(w.tensors(), w2.tensors());
        assert_eq!(w2.total_params(), 9);
    }

    #[test]
    fn lookup_and_order() {
        let w = sample();
        assert_eq!(w.get("b1").unwrap().data[2], 2.0);
        let ord = w.ordered(&["b1".into(), "w1".into()]).unwrap();
        assert_eq!(ord[0].name, "b1");
        assert!(w.ordered(&["missing".into()]).is_err());
    }

    #[test]
    fn corrupted_files_rejected() {
        let w = sample();
        let mut b = w.to_bytes();
        assert!(WeightsFile::from_bytes(&b[..b.len() - 1]).is_err(), "truncated");
        b.push(0);
        assert!(WeightsFile::from_bytes(&b).is_err(), "trailing");
        let mut bad_magic = w.to_bytes();
        bad_magic[0] = b'X';
        assert!(WeightsFile::from_bytes(&bad_magic).is_err());
    }

    #[test]
    fn duplicate_names_rejected() {
        let r = WeightsFile::new(vec![
            WeightTensor { name: "a".into(), dims: vec![1], data: vec![0.0] },
            WeightTensor { name: "a".into(), dims: vec![1], data: vec![1.0] },
        ]);
        assert!(r.is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let r = WeightsFile::new(vec![WeightTensor {
            name: "a".into(),
            dims: vec![2, 2],
            data: vec![0.0; 3],
        }]);
        assert!(r.is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("unipc_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.upw");
        let w = sample();
        w.save(&path).unwrap();
        let w2 = WeightsFile::load(&path).unwrap();
        assert_eq!(w.tensors(), w2.tensors());
    }
}
