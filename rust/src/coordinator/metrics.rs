//! Serving metrics: counters + latency digests, snapshotted as JSON for the
//! `stats` op and the bench harness.
//!
//! The sharded coordinator keeps **one store per shard** (each behind that
//! shard's mutex, so recording never crosses shards) and aggregates on
//! demand with [`Metrics::merge`]. Merging is exact: counters and histogram
//! buckets add, and latency digests merge at the raw-sample level — the
//! aggregate never sums or re-bins already-snapshotted percentile fields,
//! which would be lossy.

use super::request::FailureKind;
use crate::json::Value;
use crate::stats::LatencyDigest;
use std::time::Duration;

/// How many slowest-e2e exemplars each store retains (and the merged
/// global snapshot surfaces).
pub const SLOWEST_K: usize = 8;

/// One slow-request exemplar: the per-stage timing split plus the trace id,
/// so a dashboard reader can jump from "p99 is bad" straight to
/// `{"op":"trace"}` for the offending request.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct Exemplar {
    pub trace_id: u64,
    pub e2e_us: u64,
    pub queue_us: u64,
    pub compute_us: u64,
    pub model_eval_us: u64,
    pub solver_us: u64,
}

impl Exemplar {
    /// Canonical ordering: slowest first, full-field tie-break so
    /// identical sample sets always snapshot identically regardless of
    /// arrival or merge order.
    fn sort_key(&self) -> (std::cmp::Reverse<u64>, u64, u64, u64, u64, u64) {
        (
            std::cmp::Reverse(self.e2e_us),
            self.trace_id,
            self.queue_us,
            self.compute_us,
            self.model_eval_us,
            self.solver_us,
        )
    }
}

/// Bounded slowest-K exemplar store.
///
/// Merging is exact: an exemplar in the global top-K of a union of stores
/// is necessarily in the top-K of the store that recorded it, so merging
/// per-shard stores (each already truncated to K) and re-truncating yields
/// exactly the global K slowest — never a per-shard concatenation artifact.
#[derive(Clone, Debug, Default)]
pub struct ExemplarStore {
    items: Vec<Exemplar>,
}

impl ExemplarStore {
    pub fn record(&mut self, ex: Exemplar) {
        self.items.push(ex);
        self.canonicalize();
    }

    /// Keep the union's K slowest (see the type docs for why this is
    /// exact).
    pub fn merge(&mut self, other: &ExemplarStore) {
        self.items.extend_from_slice(&other.items);
        self.canonicalize();
    }

    /// Retained exemplars, slowest first.
    pub fn items(&self) -> &[Exemplar] {
        &self.items
    }

    fn canonicalize(&mut self) {
        self.items.sort_by_key(Exemplar::sort_key);
        self.items.truncate(SLOWEST_K);
    }
}

/// Mutable metrics store (guarded by the owning shard's mutex).
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub samples_out: u64,
    pub nfe_total: u64,
    /// Sampling plans built (one per distinct solver config).
    pub plan_builds: u64,
    /// Requests served from a cached `Arc<SamplePlan>`.
    pub plan_hits: u64,
    /// Plan-executed runs that grouped ≥ 2 requests into one lockstep batch.
    pub batched_runs: u64,
    /// Histogram over batched-path run sizes: bucket `i` counts runs with
    /// `i + 1` member requests; the last bucket collects runs with ≥ 8.
    pub batch_size_hist: [u64; 8],
    /// Plan-executed runs whose members spanned ≥ 2 distinct model
    /// conditionings (class/guidance) — the cohorts the conditioning-free
    /// batch key admits that the legacy key would have split.
    pub mixed_cond_batches: u64,
    /// Histogram over distinct conditionings per batched-path run: bucket
    /// `i` counts runs with `i + 1` distinct (class, guidance) views (= the
    /// run's slab count); the last bucket collects runs with ≥ 8.
    pub cond_distinct_hist: [u64; 8],
    /// Runs served entirely from a worker's pooled `BatchWorkspace`
    /// (no solver-side allocation to start the run).
    pub workspace_reuses: u64,
    /// Per-kind failure counters, indexed by [`FailureKind::index`] and
    /// surfaced flat in the snapshot under each kind's wire name.
    pub failures_by_kind: [u64; 6],
    /// Workers respawned by the supervisor after a panic retired them
    /// (pool size is an invariant; this counts how often it was restored).
    pub worker_restarts: u64,
    /// Batch members failed individually for non-finite output while their
    /// cohort completed normally.
    pub quarantined_members: u64,
    /// Batch members re-run solo after a mid-batch panic poisoned their
    /// lockstep run.
    pub batch_retries: u64,
    /// Jobs this shard owned that an idle worker homed on a *different*
    /// shard popped (cross-shard work stealing). Attributed to the shard
    /// that owned the queue, so a per-shard snapshot describes that
    /// shard's traffic.
    pub steals: u64,
    /// Histogram of this shard's queue depth observed right after each
    /// enqueue, in power-of-two buckets: 1, 2, 3–4, 5–8, 9–16, 17–32,
    /// 33–64, >64. Element-wise summable across shards.
    pub shard_depth_hist: [u64; 8],
    pub queue: LatencyDigest,
    pub compute: LatencyDigest,
    pub e2e: LatencyDigest,
    /// Portion of each completion's compute spent inside model (network)
    /// evaluations — the paper's NFE cost made a first-class digest.
    pub model_eval: LatencyDigest,
    /// The rest of compute: solver kernels + batch plumbing
    /// (`compute − model_eval` per completion, so the two digests split
    /// `compute` exactly).
    pub solver: LatencyDigest,
    /// Slowest-K end-to-end exemplars with their stage splits and trace
    /// ids.
    pub slowest: ExemplarStore,
}

impl Metrics {
    pub fn record_completion(
        &mut self,
        n_samples: usize,
        nfe: usize,
        queue: Duration,
        compute: Duration,
        model_eval: Duration,
        trace_id: u64,
    ) {
        self.completed += 1;
        self.samples_out += n_samples as u64;
        self.nfe_total += nfe as u64;
        let model_eval = model_eval.min(compute);
        let solver = compute - model_eval;
        self.queue.record(queue);
        self.compute.record(compute);
        self.e2e.record(queue + compute);
        self.model_eval.record(model_eval);
        self.solver.record(solver);
        self.slowest.record(Exemplar {
            trace_id,
            e2e_us: (queue + compute).as_micros() as u64,
            queue_us: queue.as_micros() as u64,
            compute_us: compute.as_micros() as u64,
            model_eval_us: model_eval.as_micros() as u64,
            solver_us: solver.as_micros() as u64,
        });
    }

    /// Count one typed failure: the `failed` total plus the per-kind
    /// counter.
    pub fn record_failure(&mut self, kind: FailureKind) {
        self.failed += 1;
        self.failures_by_kind[kind.index()] += 1;
    }

    /// Record one plan-executed run that served `members` requests spanning
    /// `distinct_conds` distinct model conditionings (the run's slab
    /// count), `reuses` of whose workspace acquisitions came from pooled
    /// capacity (0 or 1 for a single run; passed as a delta so callers can
    /// batch).
    pub fn record_batch(&mut self, members: usize, distinct_conds: usize, reuses: u64) {
        debug_assert!(members >= 1);
        debug_assert!(distinct_conds >= 1 && distinct_conds <= members);
        self.batch_size_hist[members.min(8) - 1] += 1;
        self.cond_distinct_hist[distinct_conds.min(8) - 1] += 1;
        if members >= 2 {
            self.batched_runs += 1;
        }
        if distinct_conds >= 2 {
            self.mixed_cond_batches += 1;
        }
        self.workspace_reuses += reuses;
    }

    /// Record the queue depth observed right after an enqueue.
    pub fn record_depth(&mut self, depth: usize) {
        self.shard_depth_hist[Self::depth_bucket(depth)] += 1;
    }

    /// Bucket index used by [`Metrics::record_depth`] (public so tests and
    /// dashboards can compute expected bins).
    pub fn depth_bucket(depth: usize) -> usize {
        match depth {
            0 | 1 => 0,
            2 => 1,
            3..=4 => 2,
            5..=8 => 3,
            9..=16 => 4,
            17..=32 => 5,
            33..=64 => 6,
            _ => 7,
        }
    }

    /// Field-wise merge of another store into this one — the aggregation
    /// primitive behind the sharded service's global snapshot. Counters and
    /// histogram buckets add bucket-for-bucket (no re-binning), and the
    /// latency digests merge their **raw samples**, so percentiles of the
    /// merged store are exactly the percentiles of the union of samples.
    /// Summing two `snapshot_json` outputs instead would add percentile
    /// fields, which is meaningless — aggregate at this level, then
    /// snapshot.
    pub fn merge(&mut self, other: &Metrics) {
        self.submitted += other.submitted;
        self.rejected += other.rejected;
        self.completed += other.completed;
        self.failed += other.failed;
        self.samples_out += other.samples_out;
        self.nfe_total += other.nfe_total;
        self.plan_builds += other.plan_builds;
        self.plan_hits += other.plan_hits;
        self.batched_runs += other.batched_runs;
        self.mixed_cond_batches += other.mixed_cond_batches;
        self.workspace_reuses += other.workspace_reuses;
        self.worker_restarts += other.worker_restarts;
        self.quarantined_members += other.quarantined_members;
        self.batch_retries += other.batch_retries;
        self.steals += other.steals;
        for (a, b) in self.batch_size_hist.iter_mut().zip(&other.batch_size_hist) {
            *a += *b;
        }
        for (a, b) in self.cond_distinct_hist.iter_mut().zip(&other.cond_distinct_hist) {
            *a += *b;
        }
        for (a, b) in self.shard_depth_hist.iter_mut().zip(&other.shard_depth_hist) {
            *a += *b;
        }
        for (a, b) in self.failures_by_kind.iter_mut().zip(&other.failures_by_kind) {
            *a += *b;
        }
        self.queue.merge(&other.queue);
        self.compute.merge(&other.compute);
        self.e2e.merge(&other.e2e);
        self.model_eval.merge(&other.model_eval);
        self.solver.merge(&other.solver);
        self.slowest.merge(&other.slowest);
    }

    pub fn snapshot_json(&mut self) -> Value {
        let mut pairs = vec![
            ("submitted", Value::from(self.submitted as f64)),
            ("rejected", Value::from(self.rejected as f64)),
            ("completed", Value::from(self.completed as f64)),
            ("failed", Value::from(self.failed as f64)),
            ("samples_out", Value::from(self.samples_out as f64)),
            ("nfe_total", Value::from(self.nfe_total as f64)),
            ("plan_builds", Value::from(self.plan_builds as f64)),
            ("plan_hits", Value::from(self.plan_hits as f64)),
            ("batched_runs", Value::from(self.batched_runs as f64)),
            (
                "batch_size_hist",
                Value::Arr(
                    self.batch_size_hist.iter().map(|&c| Value::Num(c as f64)).collect(),
                ),
            ),
            ("mixed_cond_batches", Value::from(self.mixed_cond_batches as f64)),
            (
                "cond_distinct_hist",
                Value::Arr(
                    self.cond_distinct_hist.iter().map(|&c| Value::Num(c as f64)).collect(),
                ),
            ),
            ("workspace_reuses", Value::from(self.workspace_reuses as f64)),
            ("steals", Value::from(self.steals as f64)),
            (
                "shard_depth_hist",
                Value::Arr(
                    self.shard_depth_hist.iter().map(|&c| Value::Num(c as f64)).collect(),
                ),
            ),
        ];
        for k in FailureKind::ALL {
            pairs.push((k.as_str(), Value::from(self.failures_by_kind[k.index()] as f64)));
        }
        pairs.extend([
            ("worker_restarts", Value::from(self.worker_restarts as f64)),
            ("quarantined_members", Value::from(self.quarantined_members as f64)),
            ("batch_retries", Value::from(self.batch_retries as f64)),
            ("queue_p50_us", Value::from(self.queue.percentile_us(50.0) as f64)),
            ("queue_p99_us", Value::from(self.queue.percentile_us(99.0) as f64)),
            ("compute_p50_us", Value::from(self.compute.percentile_us(50.0) as f64)),
            ("compute_p99_us", Value::from(self.compute.percentile_us(99.0) as f64)),
            ("model_eval_p50_us", Value::from(self.model_eval.percentile_us(50.0) as f64)),
            ("model_eval_p99_us", Value::from(self.model_eval.percentile_us(99.0) as f64)),
            ("solver_p50_us", Value::from(self.solver.percentile_us(50.0) as f64)),
            ("solver_p99_us", Value::from(self.solver.percentile_us(99.0) as f64)),
            ("e2e_p50_us", Value::from(self.e2e.percentile_us(50.0) as f64)),
            ("e2e_p95_us", Value::from(self.e2e.percentile_us(95.0) as f64)),
            ("e2e_p99_us", Value::from(self.e2e.percentile_us(99.0) as f64)),
            ("e2e_mean_us", Value::from(self.e2e.mean_us())),
            (
                "slowest",
                Value::Arr(
                    self.slowest
                        .items()
                        .iter()
                        .map(|ex| {
                            Value::obj(vec![
                                ("trace_id", Value::from(ex.trace_id as f64)),
                                ("e2e_us", Value::from(ex.e2e_us as f64)),
                                ("queue_us", Value::from(ex.queue_us as f64)),
                                ("compute_us", Value::from(ex.compute_us as f64)),
                                ("model_eval_us", Value::from(ex.model_eval_us as f64)),
                                ("solver_us", Value::from(ex.solver_us as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        Value::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn completion_updates_everything() {
        let mut m = Metrics::default();
        m.record_completion(
            4,
            10,
            Duration::from_micros(50),
            Duration::from_micros(950),
            Duration::from_micros(600),
            7,
        );
        assert_eq!(m.completed, 1);
        assert_eq!(m.samples_out, 4);
        assert_eq!(m.nfe_total, 10);
        let snap = m.snapshot_json();
        assert_eq!(snap.get("completed").unwrap().as_f64(), Some(1.0));
        assert_eq!(snap.get("e2e_p50_us").unwrap().as_f64(), Some(1000.0));
        // The split digests tile compute exactly: model 600 + solver 350.
        assert_eq!(snap.get("model_eval_p50_us").unwrap().as_f64(), Some(600.0));
        assert_eq!(snap.get("solver_p50_us").unwrap().as_f64(), Some(350.0));
        let slowest = snap.get("slowest").unwrap().as_arr().unwrap();
        assert_eq!(slowest.len(), 1);
        assert_eq!(slowest[0].get("trace_id").unwrap().as_f64(), Some(7.0));
        assert_eq!(slowest[0].get("e2e_us").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn model_eval_is_clamped_to_compute() {
        let mut m = Metrics::default();
        // A model-eval reading slightly above compute (clock skew between
        // the two measurements) must clamp, keeping solver non-negative.
        m.record_completion(
            1,
            5,
            Duration::ZERO,
            Duration::from_micros(100),
            Duration::from_micros(130),
            1,
        );
        let snap = m.snapshot_json();
        assert_eq!(snap.get("model_eval_p50_us").unwrap().as_f64(), Some(100.0));
        assert_eq!(snap.get("solver_p50_us").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn record_batch_updates_hist_and_counters() {
        let mut m = Metrics::default();
        m.record_batch(1, 1, 1);
        m.record_batch(4, 3, 1);
        m.record_batch(12, 12, 0);
        assert_eq!(m.batched_runs, 2, "singletons are not batched runs");
        assert_eq!(m.batch_size_hist[0], 1);
        assert_eq!(m.batch_size_hist[3], 1);
        assert_eq!(m.batch_size_hist[7], 1, "oversize runs land in the last bucket");
        assert_eq!(m.workspace_reuses, 2);
        assert_eq!(m.mixed_cond_batches, 2, "uniform runs are not mixed");
        assert_eq!(m.cond_distinct_hist[0], 1);
        assert_eq!(m.cond_distinct_hist[2], 1);
        assert_eq!(m.cond_distinct_hist[7], 1, "≥8 distinct views hit the last bucket");
        let snap = m.snapshot_json();
        assert_eq!(snap.get("batched_runs").unwrap().as_f64(), Some(2.0));
        assert_eq!(snap.get("mixed_cond_batches").unwrap().as_f64(), Some(2.0));
        let hist = snap.get("batch_size_hist").unwrap().as_arr().unwrap();
        assert_eq!(hist.len(), 8);
        assert_eq!(hist[3].as_f64(), Some(1.0));
        let chist = snap.get("cond_distinct_hist").unwrap().as_arr().unwrap();
        assert_eq!(chist.len(), 8);
        assert_eq!(chist[2].as_f64(), Some(1.0));
    }

    #[test]
    fn snapshot_is_valid_json() {
        let mut m = Metrics::default();
        let s = m.snapshot_json().to_string();
        assert!(crate::json::parse(&s).is_ok());
    }

    /// The sharded aggregator must be lossless: merging two stores and
    /// snapshotting must equal recording everything into one store —
    /// counters and histograms bucket-for-bucket, percentiles from the
    /// union of raw samples (NOT the sum of per-store percentile fields,
    /// which is what a snapshot-level aggregator would lossily produce).
    #[test]
    fn merge_is_exact_no_lossy_rebinning() {
        let mut a = Metrics::default();
        let mut b = Metrics::default();
        let mut whole = Metrics::default();
        // Skewed latencies: percentiles of the union differ wildly from
        // any per-store percentile, so a lossy aggregator can't pass.
        for us in [10u64, 20, 30] {
            let (q, c, me) =
                (Duration::from_micros(us), Duration::from_micros(us), Duration::from_micros(us / 2));
            a.record_completion(2, 8, q, c, me, us);
            whole.record_completion(2, 8, q, c, me, us);
        }
        for us in [10_000u64, 20_000] {
            let (q, c, me) =
                (Duration::from_micros(us), Duration::from_micros(us), Duration::from_micros(us / 4));
            b.record_completion(1, 5, q, c, me, us);
            whole.record_completion(1, 5, q, c, me, us);
        }
        a.record_batch(3, 2, 1);
        whole.record_batch(3, 2, 1);
        b.record_batch(3, 1, 0);
        b.record_batch(12, 9, 1);
        whole.record_batch(3, 1, 0);
        whole.record_batch(12, 9, 1);
        a.record_depth(1);
        whole.record_depth(1);
        b.record_depth(40);
        whole.record_depth(40);
        a.record_failure(FailureKind::WorkerPanic);
        whole.record_failure(FailureKind::WorkerPanic);
        a.steals = 2;
        whole.steals = 2;

        let mut merged = Metrics::default();
        merged.merge(&a);
        merged.merge(&b);
        assert_eq!(merged.completed, whole.completed);
        assert_eq!(merged.samples_out, whole.samples_out);
        assert_eq!(merged.nfe_total, whole.nfe_total);
        assert_eq!(merged.failed, whole.failed);
        assert_eq!(merged.steals, whole.steals);
        assert_eq!(merged.batch_size_hist, whole.batch_size_hist);
        assert_eq!(merged.cond_distinct_hist, whole.cond_distinct_hist);
        assert_eq!(merged.mixed_cond_batches, whole.mixed_cond_batches);
        assert_eq!(merged.shard_depth_hist, whole.shard_depth_hist);
        assert_eq!(merged.failures_by_kind, whole.failures_by_kind);
        let (ms, mw) = (merged.snapshot_json(), whole.snapshot_json());
        // Exact percentiles prove the digests merged raw samples: the p50
        // of the union (30us) is not derivable from the two stores' own
        // p50s (20us and 10000+us).
        for key in [
            "e2e_p50_us",
            "e2e_p99_us",
            "queue_p50_us",
            "e2e_mean_us",
            "model_eval_p50_us",
            "model_eval_p99_us",
            "solver_p50_us",
            "solver_p99_us",
        ] {
            assert_eq!(ms.get(key), mw.get(key), "{key}");
        }
        assert_eq!(ms, mw, "merged snapshot must equal the single-store snapshot");
    }

    /// The merged exemplar store is the **global** K slowest — identical to
    /// a single store that saw every completion — not the concatenation of
    /// per-shard stores (which would over-represent whichever shard
    /// happened to merge first).
    #[test]
    fn slowest_k_merge_keeps_the_global_tail() {
        let mut a = Metrics::default();
        let mut b = Metrics::default();
        let mut whole = Metrics::default();
        // 12 completions split across two stores; e2e = queue + compute is
        // distinct per completion so the global top-8 is unambiguous.
        for i in 0..12u64 {
            let q = Duration::from_micros(100 * (i + 1));
            let c = Duration::from_micros(50);
            let me = Duration::from_micros(20);
            let store = if i % 2 == 0 { &mut a } else { &mut b };
            store.record_completion(1, 5, q, c, me, i);
            whole.record_completion(1, 5, q, c, me, i);
        }
        let mut merged = Metrics::default();
        merged.merge(&a);
        merged.merge(&b);
        let got: Vec<u64> = merged.slowest.items().iter().map(|e| e.trace_id).collect();
        let want: Vec<u64> = whole.slowest.items().iter().map(|e| e.trace_id).collect();
        assert_eq!(got, want, "merge must keep the global K slowest");
        assert_eq!(got.len(), SLOWEST_K);
        // Slowest first, and the global slowest (trace 11, e2e 1250us) leads.
        assert_eq!(got[0], 11);
        let items = merged.slowest.items();
        assert!(items.windows(2).all(|w| w[0].e2e_us >= w[1].e2e_us));
        // Every retained exemplar's split tiles its compute exactly.
        for ex in items {
            assert_eq!(ex.model_eval_us + ex.solver_us, ex.compute_us);
            assert_eq!(ex.queue_us + ex.compute_us, ex.e2e_us);
        }
        assert_eq!(merged.snapshot_json(), whole.snapshot_json());
    }

    #[test]
    fn depth_buckets_are_power_of_two() {
        for (depth, bucket) in
            [(0, 0), (1, 0), (2, 1), (3, 2), (4, 2), (5, 3), (8, 3), (9, 4), (16, 4), (17, 5), (33, 6), (64, 6), (65, 7), (10_000, 7)]
        {
            assert_eq!(Metrics::depth_bucket(depth), bucket, "depth {depth}");
        }
        let mut m = Metrics::default();
        m.record_depth(7);
        assert_eq!(m.shard_depth_hist[3], 1);
        let snap = m.snapshot_json();
        let hist = snap.get("shard_depth_hist").unwrap().as_arr().unwrap();
        assert_eq!(hist.len(), 8);
        assert_eq!(hist[3].as_f64(), Some(1.0));
        assert_eq!(snap.get("steals").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn record_failure_counts_per_kind() {
        let mut m = Metrics::default();
        m.record_failure(FailureKind::DeadlineExceeded);
        m.record_failure(FailureKind::DeadlineExceeded);
        m.record_failure(FailureKind::WorkerPanic);
        m.worker_restarts = 1;
        m.quarantined_members = 2;
        m.batch_retries = 3;
        assert_eq!(m.failed, 3);
        let snap = m.snapshot_json();
        assert_eq!(snap.get("failed").unwrap().as_f64(), Some(3.0));
        assert_eq!(snap.get("deadline_exceeded").unwrap().as_f64(), Some(2.0));
        assert_eq!(snap.get("worker_panic").unwrap().as_f64(), Some(1.0));
        assert_eq!(snap.get("non_finite_output").unwrap().as_f64(), Some(0.0));
        assert_eq!(snap.get("worker_restarts").unwrap().as_f64(), Some(1.0));
        assert_eq!(snap.get("quarantined_members").unwrap().as_f64(), Some(2.0));
        assert_eq!(snap.get("batch_retries").unwrap().as_f64(), Some(3.0));
    }
}
