//! Serving metrics: counters + latency digests, snapshotted as JSON for the
//! `stats` op and the bench harness.

use crate::json::Value;
use crate::stats::LatencyDigest;
use std::time::Duration;

/// Mutable metrics store (guarded by the service's mutex).
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub samples_out: u64,
    pub nfe_total: u64,
    /// Sampling plans built (one per distinct solver config).
    pub plan_builds: u64,
    /// Requests served from a cached `Arc<SamplePlan>`.
    pub plan_hits: u64,
    pub queue: LatencyDigest,
    pub compute: LatencyDigest,
    pub e2e: LatencyDigest,
}

impl Metrics {
    pub fn record_completion(
        &mut self,
        n_samples: usize,
        nfe: usize,
        queue: Duration,
        compute: Duration,
    ) {
        self.completed += 1;
        self.samples_out += n_samples as u64;
        self.nfe_total += nfe as u64;
        self.queue.record(queue);
        self.compute.record(compute);
        self.e2e.record(queue + compute);
    }

    pub fn snapshot_json(&mut self) -> Value {
        Value::obj(vec![
            ("submitted", Value::from(self.submitted as f64)),
            ("rejected", Value::from(self.rejected as f64)),
            ("completed", Value::from(self.completed as f64)),
            ("failed", Value::from(self.failed as f64)),
            ("samples_out", Value::from(self.samples_out as f64)),
            ("nfe_total", Value::from(self.nfe_total as f64)),
            ("plan_builds", Value::from(self.plan_builds as f64)),
            ("plan_hits", Value::from(self.plan_hits as f64)),
            ("queue_p50_us", Value::from(self.queue.percentile_us(50.0) as f64)),
            ("queue_p99_us", Value::from(self.queue.percentile_us(99.0) as f64)),
            ("compute_p50_us", Value::from(self.compute.percentile_us(50.0) as f64)),
            ("compute_p99_us", Value::from(self.compute.percentile_us(99.0) as f64)),
            ("e2e_p50_us", Value::from(self.e2e.percentile_us(50.0) as f64)),
            ("e2e_p95_us", Value::from(self.e2e.percentile_us(95.0) as f64)),
            ("e2e_p99_us", Value::from(self.e2e.percentile_us(99.0) as f64)),
            ("e2e_mean_us", Value::from(self.e2e.mean_us())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn completion_updates_everything() {
        let mut m = Metrics::default();
        m.record_completion(4, 10, Duration::from_micros(50), Duration::from_micros(950));
        assert_eq!(m.completed, 1);
        assert_eq!(m.samples_out, 4);
        assert_eq!(m.nfe_total, 10);
        let snap = m.snapshot_json();
        assert_eq!(snap.get("completed").unwrap().as_f64(), Some(1.0));
        assert_eq!(snap.get("e2e_p50_us").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn snapshot_is_valid_json() {
        let mut m = Metrics::default();
        let s = m.snapshot_json().to_string();
        assert!(crate::json::parse(&s).is_ok());
    }
}
