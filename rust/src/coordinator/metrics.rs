//! Serving metrics: counters + latency digests, snapshotted as JSON for the
//! `stats` op and the bench harness.
//!
//! The sharded coordinator keeps **one store per shard** (each behind that
//! shard's mutex, so recording never crosses shards) and aggregates on
//! demand with [`Metrics::merge`]. Merging is exact: counters and histogram
//! buckets add, and latency digests merge at the raw-sample level — the
//! aggregate never sums or re-bins already-snapshotted percentile fields,
//! which would be lossy.

use super::request::FailureKind;
use crate::json::Value;
use crate::stats::LatencyDigest;
use crate::telemetry::{PromWriter, WindowStore};
use std::time::Duration;

/// How many slowest-e2e exemplars each store retains (and the merged
/// global snapshot surfaces).
pub const SLOWEST_K: usize = 8;

/// One slow-request exemplar: the per-stage timing split plus the trace id,
/// so a dashboard reader can jump from "p99 is bad" straight to
/// `{"op":"trace"}` for the offending request.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct Exemplar {
    pub trace_id: u64,
    pub e2e_us: u64,
    pub queue_us: u64,
    pub compute_us: u64,
    pub model_eval_us: u64,
    pub solver_us: u64,
}

impl Exemplar {
    /// Canonical ordering: slowest first, full-field tie-break so
    /// identical sample sets always snapshot identically regardless of
    /// arrival or merge order.
    fn sort_key(&self) -> (std::cmp::Reverse<u64>, u64, u64, u64, u64, u64) {
        (
            std::cmp::Reverse(self.e2e_us),
            self.trace_id,
            self.queue_us,
            self.compute_us,
            self.model_eval_us,
            self.solver_us,
        )
    }
}

/// Bounded slowest-K exemplar store.
///
/// Merging is exact: an exemplar in the global top-K of a union of stores
/// is necessarily in the top-K of the store that recorded it, so merging
/// per-shard stores (each already truncated to K) and re-truncating yields
/// exactly the global K slowest — never a per-shard concatenation artifact.
#[derive(Clone, Debug, Default)]
pub struct ExemplarStore {
    items: Vec<Exemplar>,
}

impl ExemplarStore {
    pub fn record(&mut self, ex: Exemplar) {
        self.items.push(ex);
        self.canonicalize();
    }

    /// Keep the union's K slowest (see the type docs for why this is
    /// exact).
    pub fn merge(&mut self, other: &ExemplarStore) {
        self.items.extend_from_slice(&other.items);
        self.canonicalize();
    }

    /// Retained exemplars, slowest first.
    pub fn items(&self) -> &[Exemplar] {
        &self.items
    }

    fn canonicalize(&mut self) {
        self.items.sort_by_key(Exemplar::sort_key);
        self.items.truncate(SLOWEST_K);
    }
}

/// Mutable metrics store (guarded by the owning shard's mutex).
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub samples_out: u64,
    pub nfe_total: u64,
    /// Sampling plans built (one per distinct solver config).
    pub plan_builds: u64,
    /// Requests served from a cached `Arc<SamplePlan>`.
    pub plan_hits: u64,
    /// Plan-executed runs that grouped ≥ 2 requests into one lockstep batch.
    pub batched_runs: u64,
    /// Histogram over batched-path run sizes: bucket `i` counts runs with
    /// `i + 1` member requests; the last bucket collects runs with ≥ 8.
    pub batch_size_hist: [u64; 8],
    /// Plan-executed runs whose members spanned ≥ 2 distinct model
    /// conditionings (class/guidance) — the cohorts the conditioning-free
    /// batch key admits that the legacy key would have split.
    pub mixed_cond_batches: u64,
    /// Histogram over distinct conditionings per batched-path run: bucket
    /// `i` counts runs with `i + 1` distinct (class, guidance) views (= the
    /// run's slab count); the last bucket collects runs with ≥ 8.
    pub cond_distinct_hist: [u64; 8],
    /// Runs served entirely from a worker's pooled `BatchWorkspace`
    /// (no solver-side allocation to start the run).
    pub workspace_reuses: u64,
    /// Per-kind failure counters, indexed by [`FailureKind::index`] and
    /// surfaced flat in the snapshot under each kind's wire name.
    pub failures_by_kind: [u64; 6],
    /// Workers respawned by the supervisor after a panic retired them
    /// (pool size is an invariant; this counts how often it was restored).
    pub worker_restarts: u64,
    /// Batch members failed individually for non-finite output while their
    /// cohort completed normally.
    pub quarantined_members: u64,
    /// Batch members re-run solo after a mid-batch panic poisoned their
    /// lockstep run.
    pub batch_retries: u64,
    /// Jobs this shard owned that an idle worker homed on a *different*
    /// shard popped (cross-shard work stealing). Attributed to the shard
    /// that owned the queue, so a per-shard snapshot describes that
    /// shard's traffic.
    pub steals: u64,
    /// Histogram of this shard's queue depth observed right after each
    /// enqueue, in power-of-two buckets: 1, 2, 3–4, 5–8, 9–16, 17–32,
    /// 33–64, >64. Element-wise summable across shards.
    pub shard_depth_hist: [u64; 8],
    pub queue: LatencyDigest,
    pub compute: LatencyDigest,
    pub e2e: LatencyDigest,
    /// Portion of each completion's compute spent inside model (network)
    /// evaluations — the paper's NFE cost made a first-class digest.
    pub model_eval: LatencyDigest,
    /// The rest of compute: solver kernels + batch plumbing
    /// (`compute − model_eval` per completion, so the two digests split
    /// `compute` exactly).
    pub solver: LatencyDigest,
    /// Slowest-K end-to-end exemplars with their stage splits and trace
    /// ids.
    pub slowest: ExemplarStore,
    /// Windowed time-series rings (60×1s + 60×1m) fed by the same record
    /// calls that bump the cumulative counters above; `now_s` is whole
    /// seconds on the service clock, passed explicitly so deterministic
    /// replays drive synthetic time.
    pub windows: WindowStore,
    /// Runs that reported solver numerical health (trace=steps batches).
    pub health_runs: u64,
    /// Histogram of per-run **mean** predictor→corrector relative delta
    /// norms ‖x̃ᶜ−x̃ᵖ‖/‖x̃ᶜ‖, in power-of-ten buckets: ≤1e-6, ≤1e-5, …,
    /// ≤1e-1, ≤1, >1. A zero-extra-NFE local error signal (UniC reuses the
    /// step's model evaluation, §3.2).
    pub corrector_delta_hist: [u64; 8],
    /// Histogram over the FIRST step index whose state went non-finite
    /// (provenance, not just occurrence): buckets 0, 1, 2, 3–4, 5–8, 9–16,
    /// 17–32, >32.
    pub nonfinite_first_step_hist: [u64; 8],
}

impl Metrics {
    #[allow(clippy::too_many_arguments)]
    pub fn record_completion(
        &mut self,
        now_s: u64,
        n_samples: usize,
        nfe: usize,
        queue: Duration,
        compute: Duration,
        model_eval: Duration,
        trace_id: u64,
    ) {
        self.windows.record_completion(
            now_s,
            n_samples,
            nfe,
            (queue + compute).as_micros() as u64,
        );
        self.completed += 1;
        self.samples_out += n_samples as u64;
        self.nfe_total += nfe as u64;
        let model_eval = model_eval.min(compute);
        let solver = compute - model_eval;
        self.queue.record(queue);
        self.compute.record(compute);
        self.e2e.record(queue + compute);
        self.model_eval.record(model_eval);
        self.solver.record(solver);
        self.slowest.record(Exemplar {
            trace_id,
            e2e_us: (queue + compute).as_micros() as u64,
            queue_us: queue.as_micros() as u64,
            compute_us: compute.as_micros() as u64,
            model_eval_us: model_eval.as_micros() as u64,
            solver_us: solver.as_micros() as u64,
        });
    }

    /// Count one typed failure: the `failed` total plus the per-kind
    /// counter, in both the cumulative and windowed stores.
    pub fn record_failure(&mut self, now_s: u64, kind: FailureKind) {
        self.windows.record_failure(now_s, kind);
        self.failed += 1;
        self.failures_by_kind[kind.index()] += 1;
    }

    /// Count one cross-shard steal of a job this shard owned.
    pub fn record_steal(&mut self, now_s: u64) {
        self.windows.record_steal(now_s);
        self.steals += 1;
    }

    /// Record one run's solver numerical health (from the serving-layer
    /// health accumulator): the per-run mean corrector delta, and the first
    /// non-finite step index if the state went bad.
    pub fn record_health(&mut self, mean_delta: Option<f64>, first_nonfinite: Option<u32>) {
        self.health_runs += 1;
        if let Some(d) = mean_delta {
            self.corrector_delta_hist[Self::delta_bucket(d)] += 1;
        }
        if let Some(k) = first_nonfinite {
            self.nonfinite_first_step_hist[Self::first_step_bucket(k)] += 1;
        }
    }

    /// Bucket index for [`Metrics::corrector_delta_hist`] (power-of-ten
    /// upper bounds 1e-6 … 1e-1, 1, +Inf).
    pub fn delta_bucket(d: f64) -> usize {
        const LE: [f64; 7] = [1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0];
        LE.iter().position(|&le| d <= le).unwrap_or(LE.len())
    }

    /// Bucket index for [`Metrics::nonfinite_first_step_hist`].
    pub fn first_step_bucket(step: u32) -> usize {
        match step {
            0 => 0,
            1 => 1,
            2 => 2,
            3..=4 => 3,
            5..=8 => 4,
            9..=16 => 5,
            17..=32 => 6,
            _ => 7,
        }
    }

    /// Record one plan-executed run that served `members` requests spanning
    /// `distinct_conds` distinct model conditionings (the run's slab
    /// count), `reuses` of whose workspace acquisitions came from pooled
    /// capacity (0 or 1 for a single run; passed as a delta so callers can
    /// batch).
    pub fn record_batch(&mut self, now_s: u64, members: usize, distinct_conds: usize, reuses: u64) {
        debug_assert!(members >= 1);
        debug_assert!(distinct_conds >= 1 && distinct_conds <= members);
        self.windows.record_batch(now_s, members);
        self.batch_size_hist[members.min(8) - 1] += 1;
        self.cond_distinct_hist[distinct_conds.min(8) - 1] += 1;
        if members >= 2 {
            self.batched_runs += 1;
        }
        if distinct_conds >= 2 {
            self.mixed_cond_batches += 1;
        }
        self.workspace_reuses += reuses;
    }

    /// Record the queue depth observed right after an enqueue.
    pub fn record_depth(&mut self, now_s: u64, depth: usize) {
        self.windows.record_depth(now_s, depth);
        self.shard_depth_hist[Self::depth_bucket(depth)] += 1;
    }

    /// Bucket index used by [`Metrics::record_depth`] (public so tests and
    /// dashboards can compute expected bins).
    pub fn depth_bucket(depth: usize) -> usize {
        match depth {
            0 | 1 => 0,
            2 => 1,
            3..=4 => 2,
            5..=8 => 3,
            9..=16 => 4,
            17..=32 => 5,
            33..=64 => 6,
            _ => 7,
        }
    }

    /// Field-wise merge of another store into this one — the aggregation
    /// primitive behind the sharded service's global snapshot. Counters and
    /// histogram buckets add bucket-for-bucket (no re-binning), and the
    /// latency digests merge their **raw samples**, so percentiles of the
    /// merged store are exactly the percentiles of the union of samples.
    /// Summing two `snapshot_json` outputs instead would add percentile
    /// fields, which is meaningless — aggregate at this level, then
    /// snapshot.
    pub fn merge(&mut self, other: &Metrics) {
        self.submitted += other.submitted;
        self.rejected += other.rejected;
        self.completed += other.completed;
        self.failed += other.failed;
        self.samples_out += other.samples_out;
        self.nfe_total += other.nfe_total;
        self.plan_builds += other.plan_builds;
        self.plan_hits += other.plan_hits;
        self.batched_runs += other.batched_runs;
        self.mixed_cond_batches += other.mixed_cond_batches;
        self.workspace_reuses += other.workspace_reuses;
        self.worker_restarts += other.worker_restarts;
        self.quarantined_members += other.quarantined_members;
        self.batch_retries += other.batch_retries;
        self.steals += other.steals;
        for (a, b) in self.batch_size_hist.iter_mut().zip(&other.batch_size_hist) {
            *a += *b;
        }
        for (a, b) in self.cond_distinct_hist.iter_mut().zip(&other.cond_distinct_hist) {
            *a += *b;
        }
        for (a, b) in self.shard_depth_hist.iter_mut().zip(&other.shard_depth_hist) {
            *a += *b;
        }
        for (a, b) in self.failures_by_kind.iter_mut().zip(&other.failures_by_kind) {
            *a += *b;
        }
        self.health_runs += other.health_runs;
        for (a, b) in self.corrector_delta_hist.iter_mut().zip(&other.corrector_delta_hist) {
            *a += *b;
        }
        for (a, b) in
            self.nonfinite_first_step_hist.iter_mut().zip(&other.nonfinite_first_step_hist)
        {
            *a += *b;
        }
        self.queue.merge(&other.queue);
        self.compute.merge(&other.compute);
        self.e2e.merge(&other.e2e);
        self.model_eval.merge(&other.model_eval);
        self.solver.merge(&other.solver);
        self.slowest.merge(&other.slowest);
        self.windows.merge(&other.windows);
    }

    /// Canonical full-state dump for the merge property tests: every
    /// counter, histogram, windowed slot, exemplar, and raw digest sample
    /// in a representation independent of recording/merge order.
    #[doc(hidden)]
    pub fn fingerprint(&mut self) -> String {
        use std::fmt::Write as _;
        let mut out = self.snapshot_json().to_string();
        for (name, d) in [
            ("queue", &mut self.queue),
            ("compute", &mut self.compute),
            ("e2e", &mut self.e2e),
            ("model_eval", &mut self.model_eval),
            ("solver", &mut self.solver),
        ] {
            let _ = write!(out, "|{name}:{:?}", d.samples_sorted());
        }
        let _ = write!(out, "|windows:{:?}", self.windows);
        out
    }

    pub fn snapshot_json(&mut self) -> Value {
        let mut pairs = vec![
            ("submitted", Value::from(self.submitted as f64)),
            ("rejected", Value::from(self.rejected as f64)),
            ("completed", Value::from(self.completed as f64)),
            ("failed", Value::from(self.failed as f64)),
            ("samples_out", Value::from(self.samples_out as f64)),
            ("nfe_total", Value::from(self.nfe_total as f64)),
            ("plan_builds", Value::from(self.plan_builds as f64)),
            ("plan_hits", Value::from(self.plan_hits as f64)),
            ("batched_runs", Value::from(self.batched_runs as f64)),
            (
                "batch_size_hist",
                Value::Arr(
                    self.batch_size_hist.iter().map(|&c| Value::Num(c as f64)).collect(),
                ),
            ),
            ("mixed_cond_batches", Value::from(self.mixed_cond_batches as f64)),
            (
                "cond_distinct_hist",
                Value::Arr(
                    self.cond_distinct_hist.iter().map(|&c| Value::Num(c as f64)).collect(),
                ),
            ),
            ("workspace_reuses", Value::from(self.workspace_reuses as f64)),
            ("steals", Value::from(self.steals as f64)),
            (
                "shard_depth_hist",
                Value::Arr(
                    self.shard_depth_hist.iter().map(|&c| Value::Num(c as f64)).collect(),
                ),
            ),
        ];
        for k in FailureKind::ALL {
            pairs.push((k.as_str(), Value::from(self.failures_by_kind[k.index()] as f64)));
        }
        pairs.extend([
            ("worker_restarts", Value::from(self.worker_restarts as f64)),
            ("quarantined_members", Value::from(self.quarantined_members as f64)),
            ("batch_retries", Value::from(self.batch_retries as f64)),
            ("health_runs", Value::from(self.health_runs as f64)),
            (
                "corrector_delta_hist",
                Value::Arr(
                    self.corrector_delta_hist.iter().map(|&c| Value::Num(c as f64)).collect(),
                ),
            ),
            (
                "nonfinite_first_step_hist",
                Value::Arr(
                    self.nonfinite_first_step_hist
                        .iter()
                        .map(|&c| Value::Num(c as f64))
                        .collect(),
                ),
            ),
            ("queue_p50_us", Value::from(self.queue.percentile_us(50.0) as f64)),
            ("queue_p99_us", Value::from(self.queue.percentile_us(99.0) as f64)),
            ("compute_p50_us", Value::from(self.compute.percentile_us(50.0) as f64)),
            ("compute_p99_us", Value::from(self.compute.percentile_us(99.0) as f64)),
            ("model_eval_p50_us", Value::from(self.model_eval.percentile_us(50.0) as f64)),
            ("model_eval_p99_us", Value::from(self.model_eval.percentile_us(99.0) as f64)),
            ("solver_p50_us", Value::from(self.solver.percentile_us(50.0) as f64)),
            ("solver_p99_us", Value::from(self.solver.percentile_us(99.0) as f64)),
            ("e2e_p50_us", Value::from(self.e2e.percentile_us(50.0) as f64)),
            ("e2e_p95_us", Value::from(self.e2e.percentile_us(95.0) as f64)),
            ("e2e_p99_us", Value::from(self.e2e.percentile_us(99.0) as f64)),
            ("e2e_mean_us", Value::from(self.e2e.mean_us())),
            (
                "slowest",
                Value::Arr(
                    self.slowest
                        .items()
                        .iter()
                        .map(|ex| {
                            Value::obj(vec![
                                ("trace_id", Value::from(ex.trace_id as f64)),
                                ("e2e_us", Value::from(ex.e2e_us as f64)),
                                ("queue_us", Value::from(ex.queue_us as f64)),
                                ("compute_us", Value::from(ex.compute_us as f64)),
                                ("model_eval_us", Value::from(ex.model_eval_us as f64)),
                                ("solver_us", Value::from(ex.solver_us as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        Value::obj(pairs)
    }

    /// Render every counter, gauge, histogram, and latency digest in the
    /// Prometheus text exposition format (`unipc_`-prefixed families). The
    /// serving layer appends its own gauges (pending, subscribers, …) to
    /// the same writer.
    pub fn prometheus_into(&mut self, w: &mut PromWriter) {
        w.counter("unipc_submitted_total", "Requests admitted to a shard queue.", self.submitted as f64);
        w.counter("unipc_rejected_total", "Requests refused at admission.", self.rejected as f64);
        w.counter("unipc_completed_total", "Requests completed successfully.", self.completed as f64);
        w.counter("unipc_failed_total", "Requests failed (all kinds).", self.failed as f64);
        w.counter("unipc_samples_out_total", "Sample rows returned.", self.samples_out as f64);
        w.counter("unipc_nfe_total", "Model function evaluations (the paper's NFE).", self.nfe_total as f64);
        w.counter("unipc_plan_builds_total", "Sampling plans compiled.", self.plan_builds as f64);
        w.counter("unipc_plan_hits_total", "Requests served from a cached plan.", self.plan_hits as f64);
        w.counter("unipc_batched_runs_total", "Runs grouping >= 2 requests in lockstep.", self.batched_runs as f64);
        w.counter("unipc_mixed_cond_batches_total", "Batched runs spanning >= 2 conditionings.", self.mixed_cond_batches as f64);
        w.counter("unipc_workspace_reuses_total", "Runs started from pooled workspace capacity.", self.workspace_reuses as f64);
        w.counter("unipc_worker_restarts_total", "Workers respawned after a panic.", self.worker_restarts as f64);
        w.counter("unipc_quarantined_members_total", "Members failed for non-finite output inside a healthy cohort.", self.quarantined_members as f64);
        w.counter("unipc_batch_retries_total", "Members re-run solo after a mid-batch panic.", self.batch_retries as f64);
        w.counter("unipc_steals_total", "Jobs popped by a worker homed on another shard.", self.steals as f64);
        w.counter("unipc_health_runs_total", "Runs reporting solver numerical health.", self.health_runs as f64);
        let failures: Vec<(&str, f64)> = FailureKind::ALL
            .iter()
            .map(|k| (k.as_str(), self.failures_by_kind[k.index()] as f64))
            .collect();
        w.counter_vec("unipc_failures_total", "Failures by kind.", "kind", &failures);
        w.histogram(
            "unipc_batch_size",
            "Member requests per plan-executed run.",
            &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0],
            &self.batch_size_hist,
            None,
        );
        w.histogram(
            "unipc_cond_distinct",
            "Distinct model conditionings per batched run.",
            &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0],
            &self.cond_distinct_hist,
            None,
        );
        w.histogram(
            "unipc_shard_depth",
            "Queue depth observed after each enqueue.",
            &[1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0],
            &self.shard_depth_hist,
            None,
        );
        w.histogram(
            "unipc_corrector_delta",
            "Per-run mean predictor-corrector relative delta norm.",
            &[1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0],
            &self.corrector_delta_hist,
            None,
        );
        w.histogram(
            "unipc_nonfinite_first_step",
            "First step index whose state went non-finite.",
            &[0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0],
            &self.nonfinite_first_step_hist,
            None,
        );
        for (name, help, d) in [
            ("unipc_queue_us", "Queue wait per completion (microseconds).", &mut self.queue),
            ("unipc_compute_us", "Compute time per completion (microseconds).", &mut self.compute),
            ("unipc_e2e_us", "End-to-end latency per completion (microseconds).", &mut self.e2e),
            ("unipc_model_eval_us", "Model-evaluation share of compute (microseconds).", &mut self.model_eval),
            ("unipc_solver_us", "Solver share of compute (microseconds).", &mut self.solver),
        ] {
            let count = d.count() as u64;
            let sum: u64 = d.samples_sorted().iter().sum();
            let quantiles = [
                (0.5, d.percentile_us(50.0) as f64),
                (0.95, d.percentile_us(95.0) as f64),
                (0.99, d.percentile_us(99.0) as f64),
            ];
            w.summary(name, help, &quantiles, sum as f64, count);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn completion_updates_everything() {
        let mut m = Metrics::default();
        m.record_completion(
            3,
            4,
            10,
            Duration::from_micros(50),
            Duration::from_micros(950),
            Duration::from_micros(600),
            7,
        );
        assert_eq!(m.completed, 1);
        assert_eq!(m.samples_out, 4);
        assert_eq!(m.nfe_total, 10);
        // The windowed ring saw the same completion at second 3.
        let t = m.windows.totals(3, 1);
        assert_eq!(t.completed, 1);
        assert_eq!(t.e2e_sum_us, 1000);
        let snap = m.snapshot_json();
        assert_eq!(snap.get("completed").unwrap().as_f64(), Some(1.0));
        assert_eq!(snap.get("e2e_p50_us").unwrap().as_f64(), Some(1000.0));
        // The split digests tile compute exactly: model 600 + solver 350.
        assert_eq!(snap.get("model_eval_p50_us").unwrap().as_f64(), Some(600.0));
        assert_eq!(snap.get("solver_p50_us").unwrap().as_f64(), Some(350.0));
        let slowest = snap.get("slowest").unwrap().as_arr().unwrap();
        assert_eq!(slowest.len(), 1);
        assert_eq!(slowest[0].get("trace_id").unwrap().as_f64(), Some(7.0));
        assert_eq!(slowest[0].get("e2e_us").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn model_eval_is_clamped_to_compute() {
        let mut m = Metrics::default();
        // A model-eval reading slightly above compute (clock skew between
        // the two measurements) must clamp, keeping solver non-negative.
        m.record_completion(
            0,
            1,
            5,
            Duration::ZERO,
            Duration::from_micros(100),
            Duration::from_micros(130),
            1,
        );
        let snap = m.snapshot_json();
        assert_eq!(snap.get("model_eval_p50_us").unwrap().as_f64(), Some(100.0));
        assert_eq!(snap.get("solver_p50_us").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn record_batch_updates_hist_and_counters() {
        let mut m = Metrics::default();
        m.record_batch(0, 1, 1, 1);
        m.record_batch(0, 4, 3, 1);
        m.record_batch(0, 12, 12, 0);
        assert_eq!(m.batched_runs, 2, "singletons are not batched runs");
        assert_eq!(m.batch_size_hist[0], 1);
        assert_eq!(m.batch_size_hist[3], 1);
        assert_eq!(m.batch_size_hist[7], 1, "oversize runs land in the last bucket");
        assert_eq!(m.workspace_reuses, 2);
        assert_eq!(m.mixed_cond_batches, 2, "uniform runs are not mixed");
        assert_eq!(m.cond_distinct_hist[0], 1);
        assert_eq!(m.cond_distinct_hist[2], 1);
        assert_eq!(m.cond_distinct_hist[7], 1, "≥8 distinct views hit the last bucket");
        let snap = m.snapshot_json();
        assert_eq!(snap.get("batched_runs").unwrap().as_f64(), Some(2.0));
        assert_eq!(snap.get("mixed_cond_batches").unwrap().as_f64(), Some(2.0));
        let hist = snap.get("batch_size_hist").unwrap().as_arr().unwrap();
        assert_eq!(hist.len(), 8);
        assert_eq!(hist[3].as_f64(), Some(1.0));
        let chist = snap.get("cond_distinct_hist").unwrap().as_arr().unwrap();
        assert_eq!(chist.len(), 8);
        assert_eq!(chist[2].as_f64(), Some(1.0));
    }

    #[test]
    fn health_buckets_and_counters() {
        let mut m = Metrics::default();
        m.record_health(Some(5e-4), None);
        m.record_health(None, Some(0));
        m.record_health(Some(2.0), Some(40));
        assert_eq!(m.health_runs, 3);
        assert_eq!(m.corrector_delta_hist[3], 1, "5e-4 lands in le=1e-3");
        assert_eq!(m.corrector_delta_hist[7], 1, ">1 lands in the overflow bucket");
        assert_eq!(m.nonfinite_first_step_hist[0], 1, "step 0 provenance");
        assert_eq!(m.nonfinite_first_step_hist[7], 1, "step 40 overflow");
        let snap = m.snapshot_json();
        assert_eq!(snap.get("health_runs").unwrap().as_f64(), Some(3.0));
        let hist = snap.get("corrector_delta_hist").unwrap().as_arr().unwrap();
        assert_eq!(hist.len(), 8);
    }

    #[test]
    fn prometheus_exposition_round_trips_for_a_populated_store() {
        let mut m = Metrics::default();
        m.submitted = 9;
        m.record_completion(
            2,
            4,
            10,
            Duration::from_micros(50),
            Duration::from_micros(950),
            Duration::from_micros(600),
            7,
        );
        m.record_failure(2, FailureKind::QueueFull);
        m.record_batch(2, 4, 2, 1);
        m.record_depth(2, 3);
        m.record_steal(2);
        m.record_health(Some(1e-3), Some(5));
        let mut w = PromWriter::new();
        m.prometheus_into(&mut w);
        let text = w.finish();
        let parsed =
            crate::telemetry::parse_exposition(&text).expect("exposition must parse");
        assert_eq!(parsed.value("unipc_submitted_total", &[]), Some(9.0));
        assert_eq!(parsed.value("unipc_completed_total", &[]), Some(1.0));
        assert_eq!(
            parsed.value("unipc_failures_total", &[("kind", "queue_full")]),
            Some(1.0)
        );
        assert_eq!(parsed.value("unipc_batch_size_count", &[]), Some(1.0));
        assert_eq!(parsed.value("unipc_e2e_us_count", &[]), Some(1.0));
        assert_eq!(parsed.value("unipc_e2e_us_sum", &[]), Some(1000.0));
        assert_eq!(parsed.value("unipc_e2e_us", &[("quantile", "0.5")]), Some(1000.0));
        assert_eq!(parsed.value("unipc_health_runs_total", &[]), Some(1.0));
    }

    #[test]
    fn snapshot_is_valid_json() {
        let mut m = Metrics::default();
        let s = m.snapshot_json().to_string();
        assert!(crate::json::parse(&s).is_ok());
    }

    /// The sharded aggregator must be lossless: merging two stores and
    /// snapshotting must equal recording everything into one store —
    /// counters and histograms bucket-for-bucket, percentiles from the
    /// union of raw samples (NOT the sum of per-store percentile fields,
    /// which is what a snapshot-level aggregator would lossily produce).
    #[test]
    fn merge_is_exact_no_lossy_rebinning() {
        let mut a = Metrics::default();
        let mut b = Metrics::default();
        let mut whole = Metrics::default();
        // Skewed latencies: percentiles of the union differ wildly from
        // any per-store percentile, so a lossy aggregator can't pass.
        for us in [10u64, 20, 30] {
            let (q, c, me) =
                (Duration::from_micros(us), Duration::from_micros(us), Duration::from_micros(us / 2));
            a.record_completion(us, 2, 8, q, c, me, us);
            whole.record_completion(us, 2, 8, q, c, me, us);
        }
        for us in [10_000u64, 20_000] {
            let (q, c, me) =
                (Duration::from_micros(us), Duration::from_micros(us), Duration::from_micros(us / 4));
            b.record_completion(7, 1, 5, q, c, me, us);
            whole.record_completion(7, 1, 5, q, c, me, us);
        }
        a.record_batch(1, 3, 2, 1);
        whole.record_batch(1, 3, 2, 1);
        b.record_batch(2, 3, 1, 0);
        b.record_batch(2, 12, 9, 1);
        whole.record_batch(2, 3, 1, 0);
        whole.record_batch(2, 12, 9, 1);
        a.record_depth(1, 1);
        whole.record_depth(1, 1);
        b.record_depth(3, 40);
        whole.record_depth(3, 40);
        a.record_failure(5, FailureKind::WorkerPanic);
        whole.record_failure(5, FailureKind::WorkerPanic);
        a.record_steal(6);
        a.record_steal(6);
        whole.record_steal(6);
        whole.record_steal(6);
        a.record_health(Some(1e-3), None);
        whole.record_health(Some(1e-3), None);
        b.record_health(Some(0.4), Some(11));
        whole.record_health(Some(0.4), Some(11));

        let mut merged = Metrics::default();
        merged.merge(&a);
        merged.merge(&b);
        assert_eq!(merged.completed, whole.completed);
        assert_eq!(merged.samples_out, whole.samples_out);
        assert_eq!(merged.nfe_total, whole.nfe_total);
        assert_eq!(merged.failed, whole.failed);
        assert_eq!(merged.steals, whole.steals);
        assert_eq!(merged.batch_size_hist, whole.batch_size_hist);
        assert_eq!(merged.cond_distinct_hist, whole.cond_distinct_hist);
        assert_eq!(merged.mixed_cond_batches, whole.mixed_cond_batches);
        assert_eq!(merged.shard_depth_hist, whole.shard_depth_hist);
        assert_eq!(merged.failures_by_kind, whole.failures_by_kind);
        assert_eq!(merged.windows, whole.windows, "windowed slots merge exactly");
        assert_eq!(merged.health_runs, whole.health_runs);
        assert_eq!(merged.corrector_delta_hist, whole.corrector_delta_hist);
        assert_eq!(merged.nonfinite_first_step_hist, whole.nonfinite_first_step_hist);
        assert_eq!(merged.fingerprint(), whole.fingerprint());
        let (ms, mw) = (merged.snapshot_json(), whole.snapshot_json());
        // Exact percentiles prove the digests merged raw samples: the p50
        // of the union (30us) is not derivable from the two stores' own
        // p50s (20us and 10000+us).
        for key in [
            "e2e_p50_us",
            "e2e_p99_us",
            "queue_p50_us",
            "e2e_mean_us",
            "model_eval_p50_us",
            "model_eval_p99_us",
            "solver_p50_us",
            "solver_p99_us",
        ] {
            assert_eq!(ms.get(key), mw.get(key), "{key}");
        }
        assert_eq!(ms, mw, "merged snapshot must equal the single-store snapshot");
    }

    /// The merged exemplar store is the **global** K slowest — identical to
    /// a single store that saw every completion — not the concatenation of
    /// per-shard stores (which would over-represent whichever shard
    /// happened to merge first).
    #[test]
    fn slowest_k_merge_keeps_the_global_tail() {
        let mut a = Metrics::default();
        let mut b = Metrics::default();
        let mut whole = Metrics::default();
        // 12 completions split across two stores; e2e = queue + compute is
        // distinct per completion so the global top-8 is unambiguous.
        for i in 0..12u64 {
            let q = Duration::from_micros(100 * (i + 1));
            let c = Duration::from_micros(50);
            let me = Duration::from_micros(20);
            let store = if i % 2 == 0 { &mut a } else { &mut b };
            store.record_completion(0, 1, 5, q, c, me, i);
            whole.record_completion(0, 1, 5, q, c, me, i);
        }
        let mut merged = Metrics::default();
        merged.merge(&a);
        merged.merge(&b);
        let got: Vec<u64> = merged.slowest.items().iter().map(|e| e.trace_id).collect();
        let want: Vec<u64> = whole.slowest.items().iter().map(|e| e.trace_id).collect();
        assert_eq!(got, want, "merge must keep the global K slowest");
        assert_eq!(got.len(), SLOWEST_K);
        // Slowest first, and the global slowest (trace 11, e2e 1250us) leads.
        assert_eq!(got[0], 11);
        let items = merged.slowest.items();
        assert!(items.windows(2).all(|w| w[0].e2e_us >= w[1].e2e_us));
        // Every retained exemplar's split tiles its compute exactly.
        for ex in items {
            assert_eq!(ex.model_eval_us + ex.solver_us, ex.compute_us);
            assert_eq!(ex.queue_us + ex.compute_us, ex.e2e_us);
        }
        assert_eq!(merged.snapshot_json(), whole.snapshot_json());
    }

    #[test]
    fn depth_buckets_are_power_of_two() {
        for (depth, bucket) in
            [(0, 0), (1, 0), (2, 1), (3, 2), (4, 2), (5, 3), (8, 3), (9, 4), (16, 4), (17, 5), (33, 6), (64, 6), (65, 7), (10_000, 7)]
        {
            assert_eq!(Metrics::depth_bucket(depth), bucket, "depth {depth}");
        }
        let mut m = Metrics::default();
        m.record_depth(0, 7);
        assert_eq!(m.shard_depth_hist[3], 1);
        let snap = m.snapshot_json();
        let hist = snap.get("shard_depth_hist").unwrap().as_arr().unwrap();
        assert_eq!(hist.len(), 8);
        assert_eq!(hist[3].as_f64(), Some(1.0));
        assert_eq!(snap.get("steals").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn record_failure_counts_per_kind() {
        let mut m = Metrics::default();
        m.record_failure(0, FailureKind::DeadlineExceeded);
        m.record_failure(0, FailureKind::DeadlineExceeded);
        m.record_failure(1, FailureKind::WorkerPanic);
        m.worker_restarts = 1;
        m.quarantined_members = 2;
        m.batch_retries = 3;
        assert_eq!(m.failed, 3);
        let snap = m.snapshot_json();
        assert_eq!(snap.get("failed").unwrap().as_f64(), Some(3.0));
        assert_eq!(snap.get("deadline_exceeded").unwrap().as_f64(), Some(2.0));
        assert_eq!(snap.get("worker_panic").unwrap().as_f64(), Some(1.0));
        assert_eq!(snap.get("non_finite_output").unwrap().as_f64(), Some(0.0));
        assert_eq!(snap.get("worker_restarts").unwrap().as_f64(), Some(1.0));
        assert_eq!(snap.get("quarantined_members").unwrap().as_f64(), Some(2.0));
        assert_eq!(snap.get("batch_retries").unwrap().as_f64(), Some(3.0));
    }
}
