//! Serving metrics: counters + latency digests, snapshotted as JSON for the
//! `stats` op and the bench harness.

use super::request::FailureKind;
use crate::json::Value;
use crate::stats::LatencyDigest;
use std::time::Duration;

/// Mutable metrics store (guarded by the service's mutex).
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub samples_out: u64,
    pub nfe_total: u64,
    /// Sampling plans built (one per distinct solver config).
    pub plan_builds: u64,
    /// Requests served from a cached `Arc<SamplePlan>`.
    pub plan_hits: u64,
    /// Plan-executed runs that grouped ≥ 2 requests into one lockstep batch.
    pub batched_runs: u64,
    /// Histogram over batched-path run sizes: bucket `i` counts runs with
    /// `i + 1` member requests; the last bucket collects runs with ≥ 8.
    pub batch_size_hist: [u64; 8],
    /// Runs served entirely from a worker's pooled `BatchWorkspace`
    /// (no solver-side allocation to start the run).
    pub workspace_reuses: u64,
    /// Per-kind failure counters, indexed by [`FailureKind::index`] and
    /// surfaced flat in the snapshot under each kind's wire name.
    pub failures_by_kind: [u64; 6],
    /// Workers respawned by the supervisor after a panic retired them
    /// (pool size is an invariant; this counts how often it was restored).
    pub worker_restarts: u64,
    /// Batch members failed individually for non-finite output while their
    /// cohort completed normally.
    pub quarantined_members: u64,
    /// Batch members re-run solo after a mid-batch panic poisoned their
    /// lockstep run.
    pub batch_retries: u64,
    pub queue: LatencyDigest,
    pub compute: LatencyDigest,
    pub e2e: LatencyDigest,
}

impl Metrics {
    pub fn record_completion(
        &mut self,
        n_samples: usize,
        nfe: usize,
        queue: Duration,
        compute: Duration,
    ) {
        self.completed += 1;
        self.samples_out += n_samples as u64;
        self.nfe_total += nfe as u64;
        self.queue.record(queue);
        self.compute.record(compute);
        self.e2e.record(queue + compute);
    }

    /// Count one typed failure: the `failed` total plus the per-kind
    /// counter.
    pub fn record_failure(&mut self, kind: FailureKind) {
        self.failed += 1;
        self.failures_by_kind[kind.index()] += 1;
    }

    /// Record one plan-executed run that served `members` requests,
    /// `reuses` of whose workspace acquisitions came from pooled capacity
    /// (0 or 1 for a single run; passed as a delta so callers can batch).
    pub fn record_batch(&mut self, members: usize, reuses: u64) {
        debug_assert!(members >= 1);
        self.batch_size_hist[members.min(8) - 1] += 1;
        if members >= 2 {
            self.batched_runs += 1;
        }
        self.workspace_reuses += reuses;
    }

    pub fn snapshot_json(&mut self) -> Value {
        let mut pairs = vec![
            ("submitted", Value::from(self.submitted as f64)),
            ("rejected", Value::from(self.rejected as f64)),
            ("completed", Value::from(self.completed as f64)),
            ("failed", Value::from(self.failed as f64)),
            ("samples_out", Value::from(self.samples_out as f64)),
            ("nfe_total", Value::from(self.nfe_total as f64)),
            ("plan_builds", Value::from(self.plan_builds as f64)),
            ("plan_hits", Value::from(self.plan_hits as f64)),
            ("batched_runs", Value::from(self.batched_runs as f64)),
            (
                "batch_size_hist",
                Value::Arr(
                    self.batch_size_hist.iter().map(|&c| Value::Num(c as f64)).collect(),
                ),
            ),
            ("workspace_reuses", Value::from(self.workspace_reuses as f64)),
        ];
        for k in FailureKind::ALL {
            pairs.push((k.as_str(), Value::from(self.failures_by_kind[k.index()] as f64)));
        }
        pairs.extend([
            ("worker_restarts", Value::from(self.worker_restarts as f64)),
            ("quarantined_members", Value::from(self.quarantined_members as f64)),
            ("batch_retries", Value::from(self.batch_retries as f64)),
            ("queue_p50_us", Value::from(self.queue.percentile_us(50.0) as f64)),
            ("queue_p99_us", Value::from(self.queue.percentile_us(99.0) as f64)),
            ("compute_p50_us", Value::from(self.compute.percentile_us(50.0) as f64)),
            ("compute_p99_us", Value::from(self.compute.percentile_us(99.0) as f64)),
            ("e2e_p50_us", Value::from(self.e2e.percentile_us(50.0) as f64)),
            ("e2e_p95_us", Value::from(self.e2e.percentile_us(95.0) as f64)),
            ("e2e_p99_us", Value::from(self.e2e.percentile_us(99.0) as f64)),
            ("e2e_mean_us", Value::from(self.e2e.mean_us())),
        ]);
        Value::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn completion_updates_everything() {
        let mut m = Metrics::default();
        m.record_completion(4, 10, Duration::from_micros(50), Duration::from_micros(950));
        assert_eq!(m.completed, 1);
        assert_eq!(m.samples_out, 4);
        assert_eq!(m.nfe_total, 10);
        let snap = m.snapshot_json();
        assert_eq!(snap.get("completed").unwrap().as_f64(), Some(1.0));
        assert_eq!(snap.get("e2e_p50_us").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn record_batch_updates_hist_and_counters() {
        let mut m = Metrics::default();
        m.record_batch(1, 1);
        m.record_batch(4, 1);
        m.record_batch(12, 0);
        assert_eq!(m.batched_runs, 2, "singletons are not batched runs");
        assert_eq!(m.batch_size_hist[0], 1);
        assert_eq!(m.batch_size_hist[3], 1);
        assert_eq!(m.batch_size_hist[7], 1, "oversize runs land in the last bucket");
        assert_eq!(m.workspace_reuses, 2);
        let snap = m.snapshot_json();
        assert_eq!(snap.get("batched_runs").unwrap().as_f64(), Some(2.0));
        let hist = snap.get("batch_size_hist").unwrap().as_arr().unwrap();
        assert_eq!(hist.len(), 8);
        assert_eq!(hist[3].as_f64(), Some(1.0));
    }

    #[test]
    fn snapshot_is_valid_json() {
        let mut m = Metrics::default();
        let s = m.snapshot_json().to_string();
        assert!(crate::json::parse(&s).is_ok());
    }

    #[test]
    fn record_failure_counts_per_kind() {
        let mut m = Metrics::default();
        m.record_failure(FailureKind::DeadlineExceeded);
        m.record_failure(FailureKind::DeadlineExceeded);
        m.record_failure(FailureKind::WorkerPanic);
        m.worker_restarts = 1;
        m.quarantined_members = 2;
        m.batch_retries = 3;
        assert_eq!(m.failed, 3);
        let snap = m.snapshot_json();
        assert_eq!(snap.get("failed").unwrap().as_f64(), Some(3.0));
        assert_eq!(snap.get("deadline_exceeded").unwrap().as_f64(), Some(2.0));
        assert_eq!(snap.get("worker_panic").unwrap().as_f64(), Some(1.0));
        assert_eq!(snap.get("non_finite_output").unwrap().as_f64(), Some(0.0));
        assert_eq!(snap.get("worker_restarts").unwrap().as_f64(), Some(1.0));
        assert_eq!(snap.get("quarantined_members").unwrap().as_f64(), Some(2.0));
        assert_eq!(snap.get("batch_retries").unwrap().as_f64(), Some(3.0));
    }
}
