//! The serving coordinator: request admission, queueing/backpressure, a
//! sampling worker pool, and per-request solver state. Together with the
//! [`crate::runtime`] executor (which owns dynamic batching at the PJRT
//! boundary) this is the L3 system the paper's technique plugs into: UniPC
//! is just a `method` string on the request.
//!
//! * [`request`] — wire-level request/response types + JSON codecs,
//!   including the structured [`FailureKind`] failure taxonomy and
//!   per-request deadlines.
//! * [`service`] — the **sharded** supervised worker pool: N partitions
//!   (queue + condvar + worker sub-pool each) with batch-key-hash routing
//!   ([`service::shard_for_key`]; the key is the plan key alone, so
//!   conditioning never splits or re-routes a cohort) and cross-shard work
//!   stealing; typed admission rejection (invalid/queue-full/shut-down);
//!   deterministic per-request seeds; the batch assembler that coalesces
//!   same-plan requests — mixed class/guidance included — into lockstep
//!   batched runs over a shared `Arc<SamplePlan>`, evaluated through the
//!   row-conditioned [`service::CohortModel`] (one [`service::CondSlab`]
//!   per distinct conditioning) and per-worker pooled workspaces; panic
//!   isolation + worker respawn, deadline shedding, per-member output
//!   quarantine, and the seeded chaos-injection backend
//!   ([`service::ChaosConfig`]).
//! * [`metrics`] — per-shard counters (including per-failure-kind) +
//!   latency digests, snapshotted as JSON and merged exactly
//!   ([`Metrics::merge`]) into the service-wide aggregate. Completion
//!   digests split `compute` into exact model-eval vs. solver time, and a
//!   slowest-K exemplar store ([`metrics::ExemplarStore`]) keeps the
//!   worst end-to-end requests with their trace ids for drill-down.
//!
//! Request lifecycles are additionally traced as span events (admit →
//! route/queue → assemble → per-step model_eval/solver_step → respond)
//! into per-shard bounded rings — see [`crate::trace`] and the tracing
//! section of [`service`].

pub mod metrics;
pub mod request;
pub mod service;

pub use metrics::{Exemplar, ExemplarStore, Metrics, SLOWEST_K};
pub use request::{Conditioning, FailureKind, SampleRequest, SampleResponse};
pub use service::{
    shard_for_key, silence_injected_panics, ChaosConfig, CohortModel, CondSlab,
    ModelBackend, Service,
};
