//! The serving coordinator: request admission, queueing/backpressure, a
//! sampling worker pool, and per-request solver state. Together with the
//! [`crate::runtime`] executor (which owns dynamic batching at the PJRT
//! boundary) this is the L3 system the paper's technique plugs into: UniPC
//! is just a `method` string on the request.
//!
//! * [`request`] — wire-level request/response types + JSON codecs,
//!   including the structured [`FailureKind`] failure taxonomy and
//!   per-request deadlines.
//! * [`service`] — the **sharded** supervised worker pool: N partitions
//!   (queue + condvar + worker sub-pool each) with batch-key-hash routing
//!   ([`service::shard_for_key`]; the key is the plan key alone, so
//!   conditioning never splits or re-routes a cohort) and cross-shard work
//!   stealing; typed admission rejection (invalid/queue-full/shut-down);
//!   deterministic per-request seeds; the batch assembler that coalesces
//!   same-plan requests — mixed class/guidance included — into lockstep
//!   batched runs over a shared `Arc<SamplePlan>`, evaluated through the
//!   row-conditioned [`service::CohortModel`] (one [`service::CondSlab`]
//!   per distinct conditioning) and per-worker pooled workspaces; panic
//!   isolation + worker respawn, deadline shedding, per-member output
//!   quarantine, and the seeded chaos-injection backend
//!   ([`service::ChaosConfig`]).
//! * [`metrics`] — per-shard counters (including per-failure-kind) +
//!   latency digests, snapshotted as JSON and merged exactly
//!   ([`Metrics::merge`]) into the service-wide aggregate.

pub mod metrics;
pub mod request;
pub mod service;

pub use metrics::Metrics;
pub use request::{Conditioning, FailureKind, SampleRequest, SampleResponse};
pub use service::{
    shard_for_key, silence_injected_panics, ChaosConfig, CohortModel, CondSlab,
    ModelBackend, Service,
};
