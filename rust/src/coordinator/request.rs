//! Wire-level request/response types (newline-delimited JSON protocol).

use crate::json::Value;
use crate::solver::Method;
use anyhow::{anyhow, bail, Result};
use std::fmt;

/// A sampling request.
#[derive(Clone, Debug, PartialEq)]
pub struct SampleRequest {
    /// Number of samples (batch rows) to generate.
    pub n: usize,
    /// Solver steps (multistep) / NFE budget (singlestep).
    pub steps: usize,
    /// Method id, e.g. `unipc-3`, `dpmpp-3m`, `ddim` (see [`Method::parse`]).
    pub method: String,
    /// Apply the UniC corrector after every step (UniPC when the base is
    /// UniP; "+UniC" for any other solver).
    pub unic: bool,
    /// Class label for conditional sampling (None = unconditional).
    pub class: Option<usize>,
    /// Classifier-free guidance scale (requires `class`).
    pub guidance: Option<f64>,
    /// RNG seed for x_T (deterministic replay).
    pub seed: u64,
    /// Include the generated samples in the response (off for pure
    /// load-testing).
    pub return_samples: bool,
    /// Per-request deadline in milliseconds, measured from admission.
    /// `None` uses the server default (`ServerConfig::default_deadline_ms`);
    /// `Some(0)` disables the deadline for this request. Jobs still queued
    /// past their deadline are shed with [`FailureKind::DeadlineExceeded`]
    /// instead of executing.
    pub deadline_ms: Option<u64>,
    /// Client-chosen trace id (wire key `"trace_id"`, nonzero). `None` (or
    /// 0) lets the service mint one at admission; either way the id is
    /// echoed on [`SampleResponse::trace_id`] and stamps every span event
    /// the request records, so a client can correlate its own logs with
    /// the server's `{"op":"trace"}` span trees.
    pub trace_id: Option<u64>,
}

impl Default for SampleRequest {
    fn default() -> Self {
        SampleRequest {
            n: 1,
            steps: 10,
            method: "unipc-3".into(),
            unic: true,
            class: None,
            guidance: None,
            seed: 0,
            return_samples: true,
            deadline_ms: None,
            trace_id: None,
        }
    }
}

/// A batch member's model conditioning: the (class, guidance) pair that
/// selects the model view its rows evaluate under. This is NOT part of
/// the batch key — requests sharing a sampling plan batch together
/// regardless of conditioning, and the worker evaluates each contiguous
/// same-conditioning row range (slab) of the stacked batch under its own
/// view (`coordinator::CohortModel`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Conditioning {
    /// Class label (None = unconditional).
    pub class: Option<usize>,
    /// Classifier-free guidance scale (requires `class`).
    pub guidance: Option<f64>,
}

impl Conditioning {
    /// Exact equality as the batch assembler sees it: class by value,
    /// guidance by f64 bits (matching `SampleRequest::conditioning_key`).
    pub fn same(&self, other: &Conditioning) -> bool {
        self.class == other.class
            && self.guidance.map(f64::to_bits) == other.guidance.map(f64::to_bits)
    }

    /// Total-order key grouping equal conditionings adjacently when the
    /// worker sorts a mixed cohort before stacking (slab contiguity).
    pub fn order_key(&self) -> (Option<usize>, Option<u64>) {
        (self.class, self.guidance.map(f64::to_bits))
    }
}

impl SampleRequest {
    /// Parse + validate the configured method.
    pub fn parsed_method(&self) -> Result<Method> {
        Method::parse(&self.method).ok_or_else(|| anyhow!("unknown method '{}'", self.method))
    }

    /// This request's model conditioning (class + guidance).
    pub fn conditioning(&self) -> Conditioning {
        Conditioning { class: self.class, guidance: self.guidance }
    }

    /// Model-conditioning identity string: class and guidance compared
    /// exactly (guidance by bits). Since the backend became
    /// row-conditioned this is no longer part of the batch key — mixed
    /// class/guidance cohorts stack into one lockstep run — but it is kept
    /// as the legacy key suffix behind `ServerConfig::split_cond_batches`
    /// (the conditioning-split ablation baseline).
    pub fn conditioning_key(&self) -> String {
        format!("|class={:?}|g={:?}", self.class, self.guidance.map(f64::to_bits))
    }

    pub fn validate(&self, max_n: usize) -> Result<()> {
        if self.n == 0 || self.n > max_n {
            bail!("n must be in 1..={max_n}");
        }
        if self.steps == 0 || self.steps > 1000 {
            bail!("steps must be in 1..=1000");
        }
        if self.guidance.is_some() && self.class.is_none() {
            bail!("guidance requires a class");
        }
        self.parsed_method()?;
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        let mut pairs = vec![
            ("op", Value::from("sample")),
            ("n", Value::from(self.n)),
            ("steps", Value::from(self.steps)),
            ("method", Value::from(self.method.as_str())),
            ("unic", Value::from(self.unic)),
            ("seed", Value::from(self.seed as f64)),
            ("return_samples", Value::from(self.return_samples)),
        ];
        if let Some(c) = self.class {
            pairs.push(("class", Value::from(c)));
        }
        if let Some(g) = self.guidance {
            pairs.push(("guidance", Value::from(g)));
        }
        if let Some(d) = self.deadline_ms {
            pairs.push(("deadline_ms", Value::from(d as f64)));
        }
        if let Some(t) = self.trace_id {
            pairs.push(("trace_id", Value::from(t as f64)));
        }
        Value::obj(pairs)
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let mut r = SampleRequest::default();
        if let Some(n) = v.get("n") {
            r.n = n.as_usize().ok_or_else(|| anyhow!("bad 'n'"))?;
        }
        if let Some(s) = v.get("steps") {
            r.steps = s.as_usize().ok_or_else(|| anyhow!("bad 'steps'"))?;
        }
        if let Some(m) = v.get("method") {
            r.method = m.as_str().ok_or_else(|| anyhow!("bad 'method'"))?.to_string();
        }
        if let Some(u) = v.get("unic") {
            r.unic = u.as_bool().ok_or_else(|| anyhow!("bad 'unic'"))?;
        }
        if let Some(c) = v.get("class") {
            r.class = Some(c.as_usize().ok_or_else(|| anyhow!("bad 'class'"))?);
        }
        if let Some(g) = v.get("guidance") {
            r.guidance = Some(g.as_f64().ok_or_else(|| anyhow!("bad 'guidance'"))?);
        }
        if let Some(s) = v.get("seed") {
            r.seed = s.as_f64().ok_or_else(|| anyhow!("bad 'seed'"))? as u64;
        }
        if let Some(rs) = v.get("return_samples") {
            r.return_samples = rs.as_bool().ok_or_else(|| anyhow!("bad 'return_samples'"))?;
        }
        if let Some(d) = v.get("deadline_ms") {
            r.deadline_ms = Some(d.as_usize().ok_or_else(|| anyhow!("bad 'deadline_ms'"))? as u64);
        }
        if let Some(t) = v.get("trace_id") {
            r.trace_id = Some(t.as_f64().ok_or_else(|| anyhow!("bad 'trace_id'"))? as u64);
        }
        Ok(r)
    }
}

/// Why a request failed: the structured failure taxonomy. Every non-ok
/// [`SampleResponse`] carries exactly one kind, and the service surfaces
/// per-kind counters in `metrics_json` (snake_case of these names).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailureKind {
    /// Rejected at admission: malformed parameters or unknown method.
    InvalidRequest,
    /// Rejected at admission: queue at capacity (backpressure).
    QueueFull,
    /// Shed before execution: still queued past the request deadline.
    DeadlineExceeded,
    /// Executed, but the solver produced NaN/Inf rows for this request.
    NonFiniteOutput,
    /// The worker thread panicked while executing this request.
    WorkerPanic,
    /// Everything else: backend/runtime errors, shutdown shedding.
    BackendError,
}

impl FailureKind {
    /// Every kind, in counter order (`index` is the position here).
    pub const ALL: [FailureKind; 6] = [
        FailureKind::InvalidRequest,
        FailureKind::QueueFull,
        FailureKind::DeadlineExceeded,
        FailureKind::NonFiniteOutput,
        FailureKind::WorkerPanic,
        FailureKind::BackendError,
    ];

    /// Stable wire/metric name (snake_case).
    pub fn as_str(self) -> &'static str {
        match self {
            FailureKind::InvalidRequest => "invalid_request",
            FailureKind::QueueFull => "queue_full",
            FailureKind::DeadlineExceeded => "deadline_exceeded",
            FailureKind::NonFiniteOutput => "non_finite_output",
            FailureKind::WorkerPanic => "worker_panic",
            FailureKind::BackendError => "backend_error",
        }
    }

    /// Parse the wire name back.
    pub fn parse(s: &str) -> Option<FailureKind> {
        FailureKind::ALL.iter().copied().find(|k| k.as_str() == s)
    }

    /// Position in [`FailureKind::ALL`] (per-kind counter index).
    pub fn index(self) -> usize {
        self as usize
    }
}

impl fmt::Display for FailureKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// A completed (or failed) sampling response.
#[derive(Clone, Debug)]
pub struct SampleResponse {
    pub ok: bool,
    /// The failure taxonomy entry; `None` exactly when `ok`.
    pub kind: Option<FailureKind>,
    /// Human-readable failure detail.
    pub error: Option<String>,
    pub nfe: usize,
    /// Time spent waiting in the queue.
    pub queue_us: u64,
    /// Time spent inside the solver (includes batched PJRT waits).
    pub compute_us: u64,
    /// Portion of `compute_us` spent inside model (network) evaluations.
    pub model_eval_us: u64,
    /// Portion of `compute_us` spent in solver kernels and batch plumbing
    /// (`compute_us − model_eval_us`).
    pub solver_us: u64,
    /// The trace id this request ran under (0 = tracing not stamped, e.g.
    /// a response from a peer predating the trace subsystem).
    pub trace_id: u64,
    /// Flattened samples `[n * dim]` when requested.
    pub samples: Option<Vec<f64>>,
    pub dim: usize,
    /// Mean per-step predictor→corrector delta ‖x̃ᶜ−x̃ᵖ‖/‖x̃ᶜ‖ across the
    /// cohort this request ran in — the zero-extra-NFE local error estimate
    /// the UniC corrector yields for free. Stamped only under `trace=steps`
    /// and only on steps that actually applied a corrector.
    pub corrector_delta_mean: Option<f64>,
    /// Max per-step corrector delta over the run (same gating).
    pub corrector_delta_max: Option<f64>,
    /// First solver step index whose state contained a non-finite value
    /// (numerical-health provenance; same gating). `None` = all finite or
    /// tracing below `steps`.
    pub first_nonfinite_step: Option<u32>,
}

impl SampleResponse {
    /// A successful response; queue/compute stamps are filled by the caller.
    pub fn success(nfe: usize, samples: Option<Vec<f64>>, dim: usize) -> Self {
        SampleResponse {
            ok: true,
            kind: None,
            error: None,
            nfe,
            queue_us: 0,
            compute_us: 0,
            model_eval_us: 0,
            solver_us: 0,
            trace_id: 0,
            samples,
            dim,
            corrector_delta_mean: None,
            corrector_delta_max: None,
            first_nonfinite_step: None,
        }
    }

    /// A typed failure response.
    pub fn failure(kind: FailureKind, msg: String) -> Self {
        SampleResponse {
            ok: false,
            kind: Some(kind),
            error: Some(msg),
            nfe: 0,
            queue_us: 0,
            compute_us: 0,
            model_eval_us: 0,
            solver_us: 0,
            trace_id: 0,
            samples: None,
            dim: 0,
            corrector_delta_mean: None,
            corrector_delta_max: None,
            first_nonfinite_step: None,
        }
    }

    pub fn to_json(&self) -> Value {
        let mut pairs = vec![
            ("ok", Value::from(self.ok)),
            ("nfe", Value::from(self.nfe)),
            ("queue_us", Value::from(self.queue_us as f64)),
            ("compute_us", Value::from(self.compute_us as f64)),
            ("model_eval_us", Value::from(self.model_eval_us as f64)),
            ("solver_us", Value::from(self.solver_us as f64)),
            ("trace_id", Value::from(self.trace_id as f64)),
            ("dim", Value::from(self.dim)),
        ];
        if let Some(k) = self.kind {
            pairs.push(("kind", Value::from(k.as_str())));
        }
        if let Some(e) = &self.error {
            pairs.push(("error", Value::from(e.as_str())));
        }
        if let Some(s) = &self.samples {
            pairs.push((
                "samples",
                Value::Arr(s.iter().map(|&v| Value::Num(v)).collect()),
            ));
        }
        if let Some(d) = self.corrector_delta_mean {
            pairs.push(("corrector_delta_mean", Value::from(d)));
        }
        if let Some(d) = self.corrector_delta_max {
            pairs.push(("corrector_delta_max", Value::from(d)));
        }
        if let Some(k) = self.first_nonfinite_step {
            pairs.push(("first_nonfinite_step", Value::from(k as usize)));
        }
        Value::obj(pairs)
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let ok = v.get("ok").and_then(Value::as_bool).unwrap_or(false);
        let kind = match (ok, v.get("kind").and_then(Value::as_str)) {
            (true, _) => None,
            (false, Some(s)) => Some(
                FailureKind::parse(s).ok_or_else(|| anyhow!("unknown failure kind '{s}'"))?,
            ),
            // Failure from a peer predating the taxonomy: least-specific kind.
            (false, None) => Some(FailureKind::BackendError),
        };
        Ok(SampleResponse {
            ok,
            kind,
            error: v.get("error").and_then(Value::as_str).map(str::to_string),
            nfe: v.get("nfe").and_then(Value::as_usize).unwrap_or(0),
            queue_us: v.get("queue_us").and_then(Value::as_f64).unwrap_or(0.0) as u64,
            compute_us: v.get("compute_us").and_then(Value::as_f64).unwrap_or(0.0) as u64,
            model_eval_us: v.get("model_eval_us").and_then(Value::as_f64).unwrap_or(0.0) as u64,
            solver_us: v.get("solver_us").and_then(Value::as_f64).unwrap_or(0.0) as u64,
            trace_id: v.get("trace_id").and_then(Value::as_f64).unwrap_or(0.0) as u64,
            samples: v.get("samples").and_then(Value::as_arr).map(|a| {
                a.iter().filter_map(Value::as_f64).collect()
            }),
            dim: v.get("dim").and_then(Value::as_usize).unwrap_or(0),
            corrector_delta_mean: v.get("corrector_delta_mean").and_then(Value::as_f64),
            corrector_delta_max: v.get("corrector_delta_max").and_then(Value::as_f64),
            first_nonfinite_step: v
                .get("first_nonfinite_step")
                .and_then(Value::as_usize)
                .map(|k| k as u32),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    #[test]
    fn request_roundtrip() {
        let r = SampleRequest {
            n: 4,
            steps: 7,
            method: "dpmpp-2m".into(),
            unic: true,
            class: Some(3),
            guidance: Some(2.0),
            seed: 99,
            return_samples: false,
            deadline_ms: Some(1500),
            trace_id: Some(77),
        };
        let v = json::parse(&r.to_json().to_string()).unwrap();
        let r2 = SampleRequest::from_json(&v).unwrap();
        assert_eq!(r, r2);
    }

    #[test]
    fn trace_id_roundtrips_and_is_omitted_when_unset() {
        let r = SampleRequest::default();
        let v = json::parse(&r.to_json().to_string()).unwrap();
        assert!(v.get("trace_id").is_none(), "None is not serialized");
        assert_eq!(SampleRequest::from_json(&v).unwrap().trace_id, None);

        let mut resp = SampleResponse::success(10, None, 2);
        resp.trace_id = 42;
        resp.model_eval_us = 900;
        resp.solver_us = 100;
        let v = json::parse(&resp.to_json().to_string()).unwrap();
        let r2 = SampleResponse::from_json(&v).unwrap();
        assert_eq!(r2.trace_id, 42);
        assert_eq!(r2.model_eval_us, 900);
        assert_eq!(r2.solver_us, 100);
    }

    #[test]
    fn deadline_omitted_means_server_default() {
        let r = SampleRequest::default();
        assert_eq!(r.deadline_ms, None);
        let v = json::parse(&r.to_json().to_string()).unwrap();
        assert!(v.get("deadline_ms").is_none(), "None is not serialized");
        assert_eq!(SampleRequest::from_json(&v).unwrap().deadline_ms, None);
    }

    #[test]
    fn validation() {
        let mut r = SampleRequest::default();
        r.validate(64).unwrap();
        r.n = 0;
        assert!(r.validate(64).is_err());
        r.n = 128;
        assert!(r.validate(64).is_err());
        r = SampleRequest { guidance: Some(1.0), ..Default::default() };
        assert!(r.validate(64).is_err(), "guidance without class");
        r = SampleRequest { method: "bogus".into(), ..Default::default() };
        assert!(r.validate(64).is_err());
    }

    #[test]
    fn conditioning_key_separates_model_views() {
        let base = SampleRequest::default();
        let classed = SampleRequest { class: Some(1), ..Default::default() };
        let guided =
            SampleRequest { class: Some(1), guidance: Some(2.0), ..Default::default() };
        assert_eq!(base.conditioning_key(), base.conditioning_key());
        assert_ne!(base.conditioning_key(), classed.conditioning_key());
        assert_ne!(classed.conditioning_key(), guided.conditioning_key());
        // Seed/steps don't condition the model and must not split batches.
        let reseeded = SampleRequest { seed: 99, steps: 50, ..Default::default() };
        assert_eq!(base.conditioning_key(), reseeded.conditioning_key());
    }

    #[test]
    fn conditioning_equality_matches_the_key_and_orders_stably() {
        let a = SampleRequest { class: Some(1), guidance: Some(2.0), ..Default::default() };
        let b = SampleRequest { class: Some(1), guidance: Some(2.0), ..Default::default() };
        let c = SampleRequest { class: Some(1), guidance: Some(-0.0), ..Default::default() };
        let d = SampleRequest { class: Some(1), guidance: Some(0.0), ..Default::default() };
        assert!(a.conditioning().same(&b.conditioning()));
        // Bit comparison, exactly like the key: -0.0 and 0.0 are distinct
        // conditionings (distinct f64 bits), matching conditioning_key.
        assert!(!c.conditioning().same(&d.conditioning()));
        assert_ne!(c.conditioning_key(), d.conditioning_key());
        // `same` ⟺ equal order keys, so sorting by order_key makes equal
        // conditionings adjacent (the slab-contiguity invariant).
        assert_eq!(a.conditioning().order_key(), b.conditioning().order_key());
        assert_ne!(c.conditioning().order_key(), d.conditioning().order_key());
        // Unconditional sorts first and compares equal to itself.
        let un = SampleRequest::default().conditioning();
        assert!(un.same(&un));
        assert!(un.order_key() < a.conditioning().order_key());
    }

    #[test]
    fn response_roundtrip_with_samples() {
        let mut resp = SampleResponse::success(10, Some(vec![0.5, -1.0]), 2);
        resp.queue_us = 12;
        resp.compute_us = 345;
        let v = json::parse(&resp.to_json().to_string()).unwrap();
        let r2 = SampleResponse::from_json(&v).unwrap();
        assert!(r2.ok);
        assert_eq!(r2.kind, None);
        assert_eq!(r2.samples.unwrap(), vec![0.5, -1.0]);
        assert_eq!(r2.compute_us, 345);
    }

    #[test]
    fn health_fields_roundtrip_and_are_omitted_when_unset() {
        let r = SampleResponse::success(10, None, 2);
        let v = json::parse(&r.to_json().to_string()).unwrap();
        assert!(v.get("corrector_delta_mean").is_none());
        assert!(v.get("first_nonfinite_step").is_none());
        let r2 = SampleResponse::from_json(&v).unwrap();
        assert_eq!(r2.corrector_delta_mean, None);
        assert_eq!(r2.first_nonfinite_step, None);

        let mut r = SampleResponse::success(10, None, 2);
        r.corrector_delta_mean = Some(1.5e-3);
        r.corrector_delta_max = Some(4.0e-3);
        r.first_nonfinite_step = Some(7);
        let v = json::parse(&r.to_json().to_string()).unwrap();
        let r2 = SampleResponse::from_json(&v).unwrap();
        assert!((r2.corrector_delta_mean.unwrap() - 1.5e-3).abs() < 1e-12);
        assert!((r2.corrector_delta_max.unwrap() - 4.0e-3).abs() < 1e-12);
        assert_eq!(r2.first_nonfinite_step, Some(7));
    }

    #[test]
    fn failure_response_carries_its_kind() {
        let r = SampleResponse::failure(FailureKind::QueueFull, "queue full".into());
        let v = json::parse(&r.to_json().to_string()).unwrap();
        let r2 = SampleResponse::from_json(&v).unwrap();
        assert!(!r2.ok);
        assert_eq!(r2.kind, Some(FailureKind::QueueFull));
        assert_eq!(r2.error.as_deref(), Some("queue full"));
    }

    #[test]
    fn failure_kind_names_roundtrip() {
        for k in FailureKind::ALL {
            assert_eq!(FailureKind::parse(k.as_str()), Some(k));
            assert_eq!(k.to_string(), k.as_str());
        }
        assert_eq!(FailureKind::parse("wat"), None);
        // Counter indices are dense and stable.
        for (i, k) in FailureKind::ALL.iter().enumerate() {
            assert_eq!(k.index(), i);
        }
        // Untyped legacy failures map to the least-specific kind.
        let v = json::parse(r#"{"ok": false, "error": "boom"}"#).unwrap();
        let r = SampleResponse::from_json(&v).unwrap();
        assert_eq!(r.kind, Some(FailureKind::BackendError));
    }
}
